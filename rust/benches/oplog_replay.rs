//! Durable-oplog bench: journaling overhead on a decode-dominant fleet
//! workload, crash-recovery latency, and deterministic replay throughput.
//!
//! Three measurements over the same seeded workload:
//!
//!  1. **overhead** — the identical request set served twice on a fresh
//!     2-worker sim fleet, journal OFF vs journal ON (every admit, dispatch,
//!     token, and terminal framed + CRC'd + appended).  The gate is the
//!     headline robustness cost: decode throughput with journaling must stay
//!     within 5% of the journal-less baseline.
//!  2. **recovery** — a fleet is crashed mid-decode (`simulate_crash`: the
//!     core thread exits with nothing settled) and `Router::recover` boots a
//!     replacement from the journal alone; reported as time-to-recover (log
//!     scan + truncate + resubmission) and time-to-drain every resumed
//!     stream to completion.
//!  3. **replay** — the clean captured trace re-executed bit-identically on
//!     a fresh fleet via `replay()`; ASSERTS every deterministic stream
//!     matches exactly.
//!
//!   cargo bench --bench oplog_replay            # full run
//!   cargo bench --bench oplog_replay -- --smoke # CI crash-recovery leg
//!
//! Emits `BENCH_oplog_replay.json` and ASSERTS overhead ≤5% and exact
//! replay.  No artifacts required.

use std::time::{Duration, Instant};

use prefixquant::bench_support::{emit_bench_json, smoke_mode};
use prefixquant::coordinator::{
    read_log, replay, BackendDesc, GenRequest, Oplog, Router, RouterConfig, Server, ServerConfig,
    SimBackend, StreamEvent, TraceView,
};
use prefixquant::model::QuantMode;
use prefixquant::util::args::Args;
use prefixquant::util::rng::SplitMix64;
use prefixquant::util::table::{f as ff, Table};

const N_WORKERS: usize = 2;
const B_EXEC: usize = 4;
const S_EXEC: usize = 48;
const N_PREFIX: usize = 2;
const CACHE_MAX: usize = 96;
const PROMPT_LEN: usize = 12;
const MAX_NEW: usize = 12;
/// per-round decode cost: large enough that decode dominates, small enough
/// that the bench stays fast — the realistic regime the 5% gate targets
const DECODE_COST: Duration = Duration::from_micros(200);

fn sim_desc() -> BackendDesc {
    BackendDesc::Sim {
        b_exec: B_EXEC as u32,
        s_exec: S_EXEC as u32,
        n_prefix: N_PREFIX as u32,
        cache_max: CACHE_MAX as u32,
    }
}

fn sim_worker(decode: Duration) -> Server {
    let cfg = ServerConfig::builder(QuantMode::Static)
        .batch_window(Duration::from_millis(1))
        .build();
    Server::start_sim(
        move || {
            Ok(SimBackend::new(B_EXEC, S_EXEC, N_PREFIX, CACHE_MAX)
                .with_costs(Duration::from_micros(100), decode))
        },
        cfg,
    )
    .expect("sim worker boots")
}

/// Seeded, mixed-length requests — the seeds are journaled, so the captured
/// trace is self-contained for replay.
fn workload(n: usize, seed: u64) -> Vec<GenRequest> {
    let mut rng = SplitMix64::new(seed);
    (0..n)
        .map(|i| {
            let prompt: Vec<i32> = (0..PROMPT_LEN).map(|_| 10 + rng.below(200) as i32).collect();
            GenRequest::builder(i as u64)
                .prompt(prompt)
                .max_new(MAX_NEW / 2 + rng.below(MAX_NEW as u64 / 2 + 1) as usize)
                .seed(rng.below(u64::MAX))
                .build()
        })
        .collect()
}

fn tmp(name: &str) -> std::path::PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("pq-oplog-bench-{name}-{}", std::process::id()));
    p
}

/// Serve `reqs` on a fresh fleet; returns (wall seconds, generated tokens).
fn run_fleet(reqs: &[GenRequest], log: Option<Oplog>) -> (f64, usize) {
    let workers: Vec<Server> = (0..N_WORKERS).map(|_| sim_worker(DECODE_COST)).collect();
    let mut cfg = RouterConfig::default();
    if let Some(log) = log {
        cfg = cfg.oplog(log);
    }
    let router = Router::new(workers, cfg).expect("router boots");
    let t0 = Instant::now();
    let handles: Vec<_> =
        reqs.iter().map(|r| router.submit(r.clone()).expect("submit")).collect();
    let mut tokens = 0usize;
    for h in handles {
        tokens += h.collect().expect("bench stream completes").tokens.len();
    }
    let wall = t0.elapsed().as_secs_f64();
    assert_eq!(router.report().expect("report").fleet.unresolved(), 0, "ledger must balance");
    router.shutdown();
    (wall, tokens)
}

fn main() {
    let args = Args::from_env();
    let smoke = smoke_mode();
    let n_requests = args.usize_or("requests", if smoke { 32 } else { 128 }).expect("--requests");
    let repeats = args.usize_or("repeats", if smoke { 2 } else { 4 }).expect("--repeats");
    let reqs = workload(n_requests, 0x0910_0CAB);
    let log_path = tmp("trace");

    println!(
        "oplog bench{}: {n_requests} requests, {N_WORKERS} workers x {B_EXEC} slots, \
         {repeats} repeats, decode {DECODE_COST:?}/round",
        if smoke { " [smoke]" } else { "" }
    );

    // -- 1. journaling overhead: best-of-N for both configurations ----------
    let mut base_wall = f64::INFINITY;
    let mut journal_wall = f64::INFINITY;
    let mut total_tokens = 0usize;
    for _ in 0..repeats {
        let (w, t) = run_fleet(&reqs, None);
        base_wall = base_wall.min(w);
        total_tokens = t;
        let log = Oplog::create(&log_path, &sim_desc()).expect("create oplog");
        let (w, t2) = run_fleet(&reqs, Some(log));
        journal_wall = journal_wall.min(w);
        assert_eq!(t, t2, "journaling must not change the streams");
    }
    let base_tps = total_tokens as f64 / base_wall;
    let journal_tps = total_tokens as f64 / journal_wall;
    let overhead_pct = (journal_wall / base_wall - 1.0) * 100.0;
    let log_bytes = std::fs::metadata(&log_path).expect("journal exists").len();

    // -- 2. crash recovery: kill the fleet mid-decode, rebuild from the log -
    let crash_path = tmp("crash");
    let crash_log = Oplog::create(&crash_path, &sim_desc()).expect("create oplog");
    let crash_router = Router::new(
        vec![sim_worker(Duration::from_millis(2))],
        RouterConfig::default().oplog(crash_log),
    )
    .expect("router boots");
    let crash_handles: Vec<_> =
        reqs.iter().take(8).map(|r| crash_router.submit(r.clone()).expect("submit")).collect();
    // let the fleet make journaled progress, then crash it mid-flight
    for _ in 0..3 {
        match crash_handles[0].recv().expect("token before crash") {
            StreamEvent::Token(_) => {}
            ev => panic!("expected a token, got {ev:?}"),
        }
    }
    crash_router.simulate_crash();
    drop(crash_handles);

    let t0 = Instant::now();
    let (rec_router, resumed) = Router::recover(
        (0..N_WORKERS).map(|_| sim_worker(DECODE_COST)).collect(),
        RouterConfig::default(),
        &crash_path,
    )
    .expect("recover from journal");
    let recover_s = t0.elapsed().as_secs_f64();
    let n_resumed = resumed.len();
    for h in resumed {
        let resp = h.collect().expect("resumed stream completes");
        assert!(!resp.tokens.is_empty(), "resumed stream produced its full token list");
    }
    let resume_complete_s = t0.elapsed().as_secs_f64();
    assert_eq!(rec_router.report().expect("report").fleet.worker_lost, 0);
    rec_router.shutdown();

    // -- 3. deterministic replay of the clean captured trace ----------------
    let rec = read_log(&log_path).expect("read journal");
    assert_eq!(rec.dropped_bytes, 0, "clean shutdown leaves no torn tail");
    let view = TraceView::from_entries(&rec.entries);
    let replay_router = Router::new(
        (0..N_WORKERS).map(|_| sim_worker(DECODE_COST)).collect(),
        RouterConfig::default(),
    )
    .expect("router boots");
    let report = replay(&view, &replay_router).expect("replay runs");
    replay_router.shutdown();
    let replay_tps = report.replayed_tokens as f64 / report.wall_s.max(1e-9);

    let mut t = Table::new(
        "durable oplog: journaling overhead, crash recovery, replay",
        &["phase", "wall s", "tok/s", "detail"],
    );
    t.rowv(vec![
        "serve (no journal)".into(),
        ff(base_wall),
        ff(base_tps),
        format!("{total_tokens} tokens"),
    ]);
    t.rowv(vec![
        "serve (journal on)".into(),
        ff(journal_wall),
        ff(journal_tps),
        format!("{overhead_pct:+.2}% wall, {log_bytes} B journal"),
    ]);
    t.rowv(vec![
        "recover".into(),
        ff(recover_s),
        String::new(),
        format!("{n_resumed} streams resumed"),
    ]);
    t.rowv(vec![
        "drain resumed".into(),
        ff(resume_complete_s),
        String::new(),
        "crash-to-all-streams-complete".into(),
    ]);
    t.rowv(vec![
        "replay".into(),
        ff(report.wall_s),
        ff(replay_tps),
        format!("{}/{} exact", report.exact, report.total),
    ]);
    t.print();

    emit_bench_json(
        "oplog_replay",
        &[
            ("requests", n_requests as f64),
            ("workers", N_WORKERS as f64),
            ("total_tokens", total_tokens as f64),
            ("base_wall_s", base_wall),
            ("journal_wall_s", journal_wall),
            ("base_tok_per_s", base_tps),
            ("journal_tok_per_s", journal_tps),
            ("overhead_pct", overhead_pct),
            ("journal_bytes", log_bytes as f64),
            ("bytes_per_token", log_bytes as f64 / total_tokens as f64),
            ("recover_s", recover_s),
            ("resume_complete_s", resume_complete_s),
            ("resumed_streams", n_resumed as f64),
            ("replay_total", report.total as f64),
            ("replay_exact", report.exact as f64),
            ("replay_wall_s", report.wall_s),
            ("replay_tok_per_s", replay_tps),
            ("smoke", if smoke { 1.0 } else { 0.0 }),
        ],
    );

    std::fs::remove_file(&log_path).ok();
    std::fs::remove_file(&crash_path).ok();

    // headline gates: journaling is ≤5% of decode throughput, and the
    // captured trace replays bit-identically
    assert!(
        overhead_pct <= 5.0,
        "journaling overhead {overhead_pct:.2}% exceeds the 5% gate \
         (base {base_wall:.3}s vs journaled {journal_wall:.3}s)"
    );
    assert!(
        report.ok() && report.exact == report.total,
        "replay diverged: {}/{} exact, mismatched seq(s) {:?}",
        report.exact,
        report.total,
        report.mismatched
    );
    println!(
        "headline: journaling {overhead_pct:+.2}% wall overhead ({:.0} B/token), \
         recovery in {:.1} ms ({n_resumed} streams), replay {}/{} exact",
        log_bytes as f64 / total_tokens as f64,
        recover_s * 1e3,
        report.exact,
        report.total
    );
}
