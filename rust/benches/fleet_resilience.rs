//! Fleet-resilience bench: SLO goodput under failpoint-driven chaos, with
//! and without the self-healing layer.
//!
//! Two identically-provisioned fleets face the SAME seeded open-loop trace
//! while every worker is armed to crash (silent thread exit) at a staggered
//! serve-pass offset:
//!
//! - **baseline**: stream resume on, but no supervisor, no admission
//!   control, no retry budget — each crash permanently removes a worker;
//! - **resilient**: the same fleet plus supervised restarts (seeded
//!   exponential backoff, windowed budget), overload-protected admission,
//!   and a global redispatch retry budget.
//!
//!   cargo bench --bench fleet_resilience            # full run
//!   cargo bench --bench fleet_resilience -- --smoke # CI trail
//!
//! Emits `BENCH_fleet_resilience.json` and ASSERTS the headline wins:
//! - the trace is deterministic (same seed → identical fingerprint) and both
//!   fleets face byte-identical traffic;
//! - the resilient fleet sustains ≥2x the baseline's goodput under chaos;
//! - both fleets settle every request exactly once
//!   (completed + cancelled + shed + quarantined + errors == offered, and
//!   the router ledger drains to zero unresolved);
//! - every crashed worker is rebooted, and no restart runs ahead of its
//!   backoff schedule (zero violations);
//! - a poison request is quarantined after exactly two worker deaths, and
//!   the fleet survives with ≥ workers−2 slots alive.
//!
//! No artifacts required.

use std::time::Duration;

use prefixquant::bench_support::{emit_bench_json, smoke_mode};
use prefixquant::coordinator::failpoint::names;
use prefixquant::coordinator::{
    AdmissionConfig, FailAction, Failpoints, FinishReason, FleetMetrics, GenRequest, KvLayout,
    PriorityPreempt, Router, RouterConfig, Server, ServerConfig, SimBackend, StreamEvent,
    SupervisorConfig, WorkerState,
};
use prefixquant::model::QuantMode;
use prefixquant::workload::{run_trace, RunScore, Target, Trace, Workload};

const B_EXEC: usize = 4;
const S_EXEC: usize = 96;
const N_PREFIX: usize = 1;
const CACHE_MAX: usize = 192;
const N_WORKERS: usize = 4;
const SEED: u64 = 0x5AFE;

/// One sim worker; `failpoints` lets the chaos schedule crash its serve loop.
fn sim_worker(decode: Duration, failpoints: Failpoints) -> anyhow::Result<Server> {
    Server::start_sim(
        move || {
            Ok(SimBackend::new(B_EXEC, S_EXEC, N_PREFIX, CACHE_MAX)
                .with_costs(Duration::from_micros(500), decode))
        },
        ServerConfig::builder(QuantMode::Static)
            .max_batch(B_EXEC)
            .batch_window(Duration::from_millis(1))
            .policy(Box::new(PriorityPreempt::default()))
            .kv(KvLayout::Paged { page_size: 8, n_pages: 0 })
            .failpoints(failpoints)
            .build(),
    )
}

/// Boot the chaos fleet: every worker armed to crash at a staggered
/// serve-pass offset.  `resilient` adds the self-healing layer; replacement
/// workers boot healthy (unarmed failpoints).
fn chaos_fleet(resilient: bool) -> anyhow::Result<Target> {
    let decode = Duration::from_millis(1);
    let workers = (0..N_WORKERS)
        .map(|w| {
            let fp = Failpoints::default();
            // staggered chaos: the fleet decays worker by worker, early
            // enough that the baseline spends most of the run short-handed
            fp.arm(names::WORKER_CRASH, 60 + 60 * w, FailAction::Crash);
            sim_worker(decode, fp)
        })
        .collect::<anyhow::Result<Vec<_>>>()?;
    let mut cfg = RouterConfig::default()
        .resume_streams(true)
        .health_interval(Duration::from_millis(5))
        .probe_timeout(Duration::from_millis(250));
    if resilient {
        cfg = cfg
            .supervise(
                SupervisorConfig::default()
                    .backoff_base(Duration::from_millis(20))
                    .backoff_max(Duration::from_millis(200))
                    .max_restarts(4)
                    .seed(SEED),
                Box::new(move |_w| sim_worker(decode, Failpoints::default())),
            )
            .admission(AdmissionConfig::default().est_token_cost_s(0.0002))
            .retry_budget(256, 64.0);
    }
    Ok(Target::Router(Router::new(workers, cfg)?))
}

/// Driver-level exactly-once ledger (the router-side one is checked via
/// `unresolved()`): with resume on, no stream may settle outside these five
/// buckets.
fn assert_ledger(tag: &str, s: &RunScore) {
    let settled = s.completed + s.cancelled + s.shed + s.quarantined + s.errors;
    assert_eq!(
        settled, s.submitted,
        "{tag}: every offered request must settle exactly once \
         (completed {} + cancelled {} + shed {} + quarantined {} + errors {} != offered {})",
        s.completed, s.cancelled, s.shed, s.quarantined, s.errors, s.submitted
    );
}

/// Run the chaos trace against one fleet flavor; returns the driver score
/// plus the router's own fleet counters.
fn run_chaos(trace: &Trace, resilient: bool) -> (RunScore, FleetMetrics) {
    let target = chaos_fleet(resilient).expect("chaos fleet boots");
    let report = run_trace(trace, &target).expect("open-loop run completes");
    let fleet = match &target {
        Target::Router(r) => r.report().expect("fleet report").fleet,
        Target::Server(_) => unreachable!("chaos fleet is routed"),
    };
    target.shutdown();
    (report.score, fleet)
}

/// Poison-request scenario: one stream implicated in two worker deaths must
/// quarantine, with ≥ N_WORKERS−2 slots still alive and serving.
fn poison_scenario() -> (usize, usize) {
    let workers = (0..N_WORKERS)
        .map(|_| sim_worker(Duration::from_millis(20), Failpoints::default()))
        .collect::<anyhow::Result<Vec<_>>>()
        .expect("poison fleet boots");
    let router = Router::new(workers, RouterConfig::default().resume_streams(true))
        .expect("poison fleet routes");
    let poison = GenRequest::new(0, vec![13, 31, 77, 99], 40);
    let h = router.submit(poison).expect("poison submits");
    match h.recv().expect("poison produces a token") {
        StreamEvent::Token(_) => {}
        ev => panic!("expected a token first, got {ev:?}"),
    }
    let mut deaths = 0usize;
    for round in 0..2 {
        let w = router
            .locate(h.id())
            .expect("locate works")
            .unwrap_or_else(|| panic!("poison stream in flight before death {round}"));
        router.kill_worker(w).expect("kill reaches the worker");
        deaths += 1;
        let quarantined_now = router.report().expect("report").fleet.quarantined;
        if round == 0 {
            assert_eq!(quarantined_now, 0, "one death must NOT quarantine");
        }
    }
    let resp = loop {
        match h.recv().expect("poison stream settles") {
            StreamEvent::Token(_) => {}
            StreamEvent::Done(resp) => break resp,
            StreamEvent::Error(e) => panic!("poison stream errored instead of quarantining: {e}"),
        }
    };
    assert_eq!(resp.finish, FinishReason::Quarantined, "2 deaths → quarantine");
    assert!(!resp.tokens.is_empty(), "delivered tokens come back with the quarantine");

    let report = router.report().expect("report");
    assert_eq!(report.fleet.quarantined, 1);
    assert_eq!(report.fleet.unresolved(), 0, "poison ledger balances");
    let alive = report
        .workers
        .iter()
        .filter(|w| matches!(w.state, WorkerState::Alive | WorkerState::Draining))
        .count();
    assert!(
        alive >= N_WORKERS - 2,
        "fleet must survive the poison request with >= {} alive (got {alive})",
        N_WORKERS - 2
    );
    // the survivors still serve fresh traffic
    let fresh = GenRequest::new(0, vec![1, 2, 3, 4], 4);
    let resp = router.submit(fresh).expect("fresh submit").collect().expect("fresh completes");
    assert_eq!(resp.finish, FinishReason::Length);
    router.shutdown();
    (deaths, alive)
}

fn main() {
    let smoke = smoke_mode();
    let (rate, duration_s, min_req) = if smoke { (350.0, 0.5, 60) } else { (350.0, 1.2, 150) };
    let n = ((rate * duration_s).ceil() as usize).max(min_req);
    let workload = Workload::mixed(SEED).with_rate(rate).with_requests(n);

    // determinism gate: the chaos trace is a pure function of the spec
    let trace = workload.clone().generate();
    let again = workload.generate();
    assert_eq!(trace, again, "trace generation must be pure at {rate} rps");
    assert_eq!(trace.fingerprint(), again.fingerprint());

    // warm both flavors with a throwaway run (thread spin-up, first faults)
    for resilient in [false, true] {
        let warm = Workload::mixed(1).with_rate(100.0).with_requests(10).generate();
        let target = chaos_fleet(resilient).expect("warm fleet");
        let _ = run_trace(&warm, &target);
        target.shutdown();
    }

    eprintln!(
        "chaos run: {N_WORKERS} workers, every worker armed to crash, {rate:.0} rps x \
         {duration_s}s{}",
        if smoke { " [smoke]" } else { "" }
    );
    let (base, base_fleet) = run_chaos(&trace, false);
    let (res, res_fleet) = run_chaos(&trace, true);

    println!(
        "baseline : goodput {:>7.1} rps  attain {:.3}  completed {:>4}  errors {:>4}  \
         crashes {}",
        base.goodput_rps,
        base.attainment,
        base.completed,
        base.errors,
        base_fleet.workers_dead + base_fleet.workers_killed
    );
    println!(
        "resilient: goodput {:>7.1} rps  attain {:.3}  completed {:>4}  shed {:>3}  \
         quarantined {:>2}  restarts {} (violations {})",
        res.goodput_rps,
        res.attainment,
        res.completed,
        res.shed,
        res.quarantined,
        res_fleet.workers_restarted,
        res_fleet.restart_schedule_violations
    );

    // exactly-once: driver-side AND router-side
    assert_ledger("baseline", &base);
    assert_ledger("resilient", &res);
    assert_eq!(base_fleet.unresolved(), 0, "baseline router ledger drains to zero");
    assert_eq!(res_fleet.unresolved(), 0, "resilient router ledger drains to zero");

    // chaos actually happened, and only the resilient fleet healed from it
    assert!(
        base_fleet.workers_dead >= N_WORKERS - 1,
        "chaos must kill most of the baseline fleet (got {} dead)",
        base_fleet.workers_dead
    );
    assert_eq!(base_fleet.workers_restarted, 0, "the baseline fleet must not self-heal");
    assert!(
        res_fleet.workers_restarted >= N_WORKERS - 1,
        "the supervisor must reboot the crashed workers (got {} restarts)",
        res_fleet.workers_restarted
    );
    assert_eq!(
        res_fleet.restart_schedule_violations, 0,
        "no restart may run ahead of its backoff schedule"
    );

    let ratio = res.goodput_rps / base.goodput_rps.max(1e-9);
    assert!(
        ratio >= 2.0,
        "supervised+admission fleet must sustain >=2x baseline goodput under chaos \
         (got {ratio:.2}x: {:.1} vs {:.1} rps)",
        res.goodput_rps,
        base.goodput_rps
    );

    let (poison_deaths, poison_alive) = poison_scenario();
    println!(
        "\nchaos goodput ratio {ratio:.2}x; poison quarantined after {poison_deaths} deaths, \
         {poison_alive}/{N_WORKERS} workers alive"
    );

    emit_bench_json(
        "fleet_resilience",
        &[
            ("offered_rps", rate),
            ("baseline_goodput_rps", base.goodput_rps),
            ("baseline_attainment", base.attainment),
            ("resilient_goodput_rps", res.goodput_rps),
            ("resilient_attainment", res.attainment),
            ("goodput_ratio", ratio),
            ("resilient_shed", res.shed as f64),
            ("resilient_quarantined", res.quarantined as f64),
            ("workers_restarted", res_fleet.workers_restarted as f64),
            ("restart_schedule_violations", res_fleet.restart_schedule_violations as f64),
            ("retries_denied", res_fleet.retries_denied as f64),
            ("poison_deaths_to_quarantine", poison_deaths as f64),
            ("poison_alive_workers", poison_alive as f64),
            ("smoke", if smoke { 1.0 } else { 0.0 }),
        ],
    );
}
