//! Headline serving bench: SLO **goodput** vs offered load, swept past the
//! saturation knee on the mixed open-loop workload.
//!
//! Two fleets face identical seeded traces at each offered load:
//!
//! - **baseline**: Fcfs scheduling, round-robin dispatch, radix cache off —
//!   the no-policy stack;
//! - **full stack**: PriorityPreempt scheduling, least-loaded dispatch,
//!   radix prefix cache on.
//!
//! The driver is open-loop (arrivals never wait for completions), so
//! overload shows up as collapsing attainment instead of a silently
//! stretched clock.  Per-call busy-wait costs on the sim backend make fleet
//! capacity a property of the cost model, so the knee lands mid-sweep on
//! any host.
//!
//!   cargo bench --bench goodput            # full sweep
//!   cargo bench --bench goodput -- --smoke # CI trail (3 loads)
//!
//! Emits `BENCH_goodput.json` and ASSERTS the headline wins:
//! - traces are deterministic (same seed → identical fingerprint, and both
//!   sweeps replay byte-identical traffic);
//! - the full-stack sweep bends (goodput at the deepest overload is below
//!   the knee);
//! - at an offered load where the baseline falls under 90% SLO attainment,
//!   the full stack sustains ≥1.5x the baseline's goodput.
//!
//! No artifacts required.

use std::time::Duration;

use prefixquant::bench_support::{emit_bench_json, smoke_mode};
use prefixquant::coordinator::{
    DispatchPolicy, Fcfs, KvLayout, LeastLoaded, PriorityPreempt, RoundRobin, Router,
    RouterConfig, SchedulePolicy, Server, ServerConfig, SimBackend,
};
use prefixquant::util::table::Table;
use prefixquant::workload::{run_trace, sweep_rates, Target, Workload};

const B_EXEC: usize = 4;
const S_EXEC: usize = 96;
const N_PREFIX: usize = 1;
const CACHE_MAX: usize = 192;
const N_WORKERS: usize = 2;
const SEED: u64 = 0x600D;

/// Boot a two-worker sim fleet: the full serving stack, or the baseline.
fn fleet(full_stack: bool) -> anyhow::Result<Target> {
    let workers = (0..N_WORKERS)
        .map(|_| {
            let sched: Box<dyn SchedulePolicy> = if full_stack {
                Box::new(PriorityPreempt::default())
            } else {
                Box::new(Fcfs)
            };
            Server::start_sim(
                move || {
                    Ok(SimBackend::new(B_EXEC, S_EXEC, N_PREFIX, CACHE_MAX)
                        .with_costs(Duration::from_micros(500), Duration::from_millis(1)))
                },
                ServerConfig::builder(prefixquant::model::QuantMode::Static)
                    .max_batch(B_EXEC)
                    .batch_window(Duration::from_millis(1))
                    .policy(sched)
                    .kv(KvLayout::Paged { page_size: 8, n_pages: 0 })
                    .radix_cache(full_stack)
                    .build(),
            )
        })
        .collect::<anyhow::Result<Vec<_>>>()?;
    let dispatch: Box<dyn DispatchPolicy> = if full_stack {
        Box::new(LeastLoaded::new())
    } else {
        Box::new(RoundRobin::new())
    };
    Ok(Target::Router(Router::new(workers, RouterConfig::default().policy(dispatch))?))
}

fn main() {
    let smoke = smoke_mode();
    let (rates, duration_s, min_req): (Vec<f64>, f64, usize) = if smoke {
        (vec![150.0, 600.0, 2400.0], 0.3, 40)
    } else {
        (vec![75.0, 150.0, 300.0, 600.0, 1200.0, 2400.0], 1.0, 60)
    };
    let workload = Workload::mixed(SEED);

    // determinism gate: the trace at every swept rate is a pure function of
    // the spec — regeneration must be byte-identical
    for &r in &rates {
        let n = ((r * duration_s).ceil() as usize).max(min_req);
        let a = workload.clone().with_rate(r).with_requests(n).generate();
        let b = workload.clone().with_rate(r).with_requests(n).generate();
        assert_eq!(a, b, "trace generation must be pure at {r} rps");
        assert_eq!(a.fingerprint(), b.fingerprint());
    }

    // warm both stacks with a throwaway run (thread spin-up, first faults)
    for full in [false, true] {
        let warm = workload.clone().with_rate(100.0).with_requests(10).generate();
        let target = fleet(full).expect("warm fleet");
        let _ = run_trace(&warm, &target);
        target.shutdown();
    }

    eprintln!(
        "sweeping {} offered loads x 2 stacks ({N_WORKERS} workers, mixed workload){}",
        rates.len(),
        if smoke { " [smoke]" } else { "" }
    );
    let baseline = sweep_rates(&workload, &rates, duration_s, min_req, || fleet(false))
        .expect("baseline sweep");
    let full = sweep_rates(&workload, &rates, duration_s, min_req, || fleet(true))
        .expect("full-stack sweep");

    // both sweeps must have faced byte-identical offered traffic
    for (b, f) in baseline.points.iter().zip(&full.points) {
        assert_eq!(
            b.trace_fingerprint, f.trace_fingerprint,
            "stacks must be swept with identical traces"
        );
    }

    let mut t = Table::new(
        "SLO goodput vs offered load (baseline: fcfs/round-robin/no-radix; \
         full: priority-preempt/least-loaded/radix)",
        &[
            "offered rps",
            "base goodput",
            "base attain",
            "full goodput",
            "full attain",
            "goodput ratio",
        ],
    );
    let mut best_ratio = 0.0f64;
    let mut best_rate = 0.0f64;
    let mut qualifying = 0usize;
    for (b, f) in baseline.points.iter().zip(&full.points) {
        let ratio = f.score.goodput_rps / b.score.goodput_rps.max(1e-9);
        t.rowv(vec![
            format!("{:.0}", b.offered_rps),
            format!("{:.1}", b.score.goodput_rps),
            format!("{:.3}", b.score.attainment),
            format!("{:.1}", f.score.goodput_rps),
            format!("{:.3}", f.score.attainment),
            format!("{ratio:.2}x"),
        ]);
        if b.score.attainment < 0.90 {
            qualifying += 1;
            if ratio > best_ratio {
                best_ratio = ratio;
                best_rate = b.offered_rps;
            }
        }
    }
    t.print();
    let knee = full.knee_point();
    println!(
        "\nfull-stack knee: {:.0} rps offered -> {:.1} rps goodput; \
         best overload win: {best_ratio:.2}x at {best_rate:.0} rps offered",
        knee.offered_rps, knee.score.goodput_rps
    );

    assert!(
        qualifying > 0,
        "sweep must reach an offered load where the baseline misses 90% SLO attainment"
    );
    assert!(
        full.saturated(),
        "sweep must run past the full stack's saturation knee (knee at {:.0} rps, \
         last point {:.0} rps)",
        knee.offered_rps,
        full.points.last().map(|p| p.offered_rps).unwrap_or(0.0)
    );
    assert!(
        best_ratio >= 1.5,
        "full stack must sustain >=1.5x baseline goodput under overload (got {best_ratio:.2}x)"
    );

    let mut fields: Vec<(String, f64)> = Vec::new();
    for (b, f) in baseline.points.iter().zip(&full.points) {
        let r = b.offered_rps as u64;
        fields.push((format!("offered_rps_{r}"), b.offered_rps));
        fields.push((format!("baseline_goodput_rps_{r}"), b.score.goodput_rps));
        fields.push((format!("baseline_attainment_{r}"), b.score.attainment));
        fields.push((format!("full_goodput_rps_{r}"), f.score.goodput_rps));
        fields.push((format!("full_attainment_{r}"), f.score.attainment));
    }
    fields.push(("knee_offered_rps".to_string(), knee.offered_rps));
    fields.push(("knee_goodput_rps".to_string(), knee.score.goodput_rps));
    fields.push(("overload_goodput_ratio".to_string(), best_ratio));
    fields.push(("overload_ratio_at_rps".to_string(), best_rate));
    fields.push(("smoke".to_string(), if smoke { 1.0 } else { 0.0 }));
    let field_refs: Vec<(&str, f64)> = fields.iter().map(|(k, v)| (k.as_str(), *v)).collect();
    emit_bench_json("goodput", &field_refs);
}
