//! Coordinator micro-benchmarks: batcher throughput, KV-cache operations
//! (dense vs paged slot churn, retirement isolation), tokenizer, corpus
//! generation.  No artifacts required.
//!
//!   cargo bench --bench coordinator_micro            # full run
//!   cargo bench --bench coordinator_micro -- --smoke # CI perf trail
//!
//! Emits `BENCH_coordinator_micro.json` (and a `BENCH_JSON` stdout line) so
//! CI can track the retirement cost trajectory.

use std::time::Instant;

use prefixquant::bench_support::{bench_fn, emit_bench_json, smoke_mode};
use prefixquant::config::{CorpusSpec, ModelConfig, TokenizerSpec};
use prefixquant::coordinator::{Batcher, GenRequest, KvCache, KvLayout};
use prefixquant::data::Language;
use prefixquant::model::PrefixState;
use prefixquant::tensor::Tensor;
use prefixquant::tokenizer::Tokenizer;
use prefixquant::util::table::Table;

/// Median nanoseconds of `reset_slot` after filling `plen` prompt positions:
/// the retirement cost in isolation (the admit write is outside the timer).
fn retire_ns(kv: &mut KvCache, plen: usize, samples: usize) -> f64 {
    let shape = [kv.n_layers, 1, kv.n_heads, plen, kv.d_head];
    let fill = Tensor::full(&shape, 1.0);
    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples {
        kv.write_prefill_row(3, &fill, &fill, 0, plen).unwrap();
        let t = Instant::now();
        kv.reset_slot(3).unwrap();
        times.push(t.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    times[times.len() / 2] * 1e9
}

fn main() {
    let smoke = smoke_mode();
    let samples = if smoke { 10 } else { 50 };
    let mut t = Table::new("coordinator micro-benchmarks", &["op", "median", "per-unit"]);

    // batcher: push+drain 1024 mixed-length requests
    let st = bench_fn("batcher", 3, 50, || {
        let mut b = Batcher::new(8);
        for i in 0..1024u64 {
            b.push(GenRequest::new(i, vec![5; 8 * (1 + (i % 4) as usize)], 4));
        }
        while !b.is_empty() {
            std::hint::black_box(b.next_batch());
        }
    });
    t.rowv(vec![
        "batcher push+drain 1024 reqs".into(),
        format!("{:.3}ms", st.per_call_ms()),
        format!("{:.2}us/req", st.median_s * 1e6 / 1024.0),
    ]);

    // kv-cache: install prefix + write prefill at serving geometry
    let cfg = ModelConfig {
        name: "bench".into(),
        vocab_size: 272,
        d_model: 128,
        n_layers: 4,
        n_heads: 4,
        d_head: 32,
        d_ff: 256,
        o_model: 3,
        inject_amp: 1.0,
        inject_delta: 0.05,
        max_prefix: 4,
        train_seq: 128,
        eval_seq: 256,
        cache_max: 320,
        sites: vec!["down_in".into()],
    };
    let pshape = [cfg.n_layers, cfg.n_heads, cfg.max_prefix, cfg.d_head];
    let prefix = PrefixState {
        tokens: vec![1, 49, 13],
        n_prefix: 3,
        n_ctx_sinks: 3,
        k: Tensor::full(&pshape, 0.5),
        v: Tensor::full(&pshape, 0.5),
    };
    let kshape = [cfg.n_layers, 8, cfg.n_heads, 256, cfg.d_head];
    let kfill = Tensor::full(&kshape, 1.0);
    let st = bench_fn("kvcache", 3, 30, || {
        let mut kv = KvCache::new(&cfg, 8);
        kv.install_prefix(&prefix).unwrap();
        kv.write_prefill(&kfill, &kfill, 256).unwrap();
        std::hint::black_box(kv.max_len());
    });
    t.rowv(vec![
        "kvcache prefix+prefill (B=8,S=256)".into(),
        format!("{:.3}ms", st.per_call_ms()),
        format!(
            "{:.1}MB/s",
            2.0 * kshape.iter().product::<usize>() as f64 * 4.0 / st.median_s / 1e6
        ),
    ]);

    // slot churn: admit into one slot, append, retire (continuous engine's
    // per-request cache work, everything but the model execution) — dense
    // baseline vs paged cache
    let row_shape = [cfg.n_layers, 1, cfg.n_heads, 256, cfg.d_head];
    let row_fill = Tensor::full(&row_shape, 1.0);
    let tok_shape = [cfg.n_layers, cfg.n_heads, cfg.d_head];
    let tok_fill = Tensor::full(&tok_shape, 2.0);
    let mut churn_ms = Vec::new();
    for (name, layout) in [
        ("dense", KvLayout::Dense),
        ("paged", KvLayout::Paged { page_size: 16, n_pages: 0 }),
    ] {
        let mut kv = KvCache::with_layout(&cfg, 8, layout);
        kv.install_prefix(&prefix).unwrap();
        let st = bench_fn("slot churn", 3, samples, || {
            kv.write_prefill_row(3, &row_fill, &row_fill, 0, 256).unwrap();
            for _ in 0..16 {
                kv.append_token_row(3, &tok_fill, &tok_fill).unwrap();
            }
            kv.reset_slot(3).unwrap();
            std::hint::black_box(kv.row_len(3));
        });
        t.rowv(vec![
            format!("{name} slot admit+16 appends+retire (S=256)"),
            format!("{:.3}ms", st.per_call_ms()),
            format!("{:.2}us/token", st.median_s * 1e6 / 16.0),
        ]);
        churn_ms.push(st.per_call_ms());
    }

    // retirement in isolation: the dense memset scales with what the
    // sequence used; paged retirement only drops page refs — O(1) per page,
    // no KV byte touched — so its cost stays flat as sequences grow
    let mut kv_dense = KvCache::new(&cfg, 8);
    kv_dense.install_prefix(&prefix).unwrap();
    let mut kv_paged = KvCache::with_layout(&cfg, 8, KvLayout::Paged { page_size: 16, n_pages: 0 });
    kv_paged.install_prefix(&prefix).unwrap();
    let dense_64 = retire_ns(&mut kv_dense, 64, samples);
    let dense_256 = retire_ns(&mut kv_dense, 256, samples);
    let paged_64 = retire_ns(&mut kv_paged, 64, samples);
    let paged_256 = retire_ns(&mut kv_paged, 256, samples);
    for (name, s64, s256) in [("dense", dense_64, dense_256), ("paged", paged_64, paged_256)] {
        t.rowv(vec![
            format!("{name} slot retirement"),
            format!("{:.0}ns @S=64", s64),
            format!("{:.0}ns @S=256", s256),
        ]);
    }
    println!(
        "retirement at S=256: paged {paged_256:.0}ns vs dense {dense_256:.0}ns \
         ({:.0}x cheaper; no per-token memset)",
        dense_256 / paged_256.max(1.0)
    );
    assert!(
        paged_256 < dense_256,
        "paged retirement (no memset) must beat the dense memset at S=256"
    );

    // tokenizer round-trip
    let tok = Tokenizer::new(TokenizerSpec {
        pad: 0,
        bos: 1,
        eos: 2,
        byte_offset: 3,
        vocab_size: 272,
        delimiter_ids: vec![13, 49],
    });
    let text = "lorem ipsum dolor sit amet. consectetur adipiscing elit.\n".repeat(100);
    let st = bench_fn("tokenize", 3, 200, || {
        std::hint::black_box(tok.encode(&text, true));
    });
    t.rowv(vec![
        format!("tokenize {} chars", text.len()),
        format!("{:.3}ms", st.per_call_ms()),
        format!("{:.0}MB/s", text.len() as f64 / st.median_s / 1e6),
    ]);

    // corpus generation
    let lang = Language::new(CorpusSpec {
        n_words: 256,
        n_followers: 8,
        follow_prob10: 7,
        word_seed: 1,
        train_seed: 2,
        eval_seed: 3,
        train_chars: 100_000,
        eval_chars: 1000,
    });
    let st = bench_fn("corpus", 2, 20, || {
        std::hint::black_box(lang.generate(7, 100_000));
    });
    t.rowv(vec![
        "generate 100k-char corpus".into(),
        format!("{:.2}ms", st.per_call_ms()),
        format!("{:.1}MB/s", 0.1 / st.median_s),
    ]);

    t.print();

    emit_bench_json(
        "coordinator_micro",
        &[
            ("churn_ms_dense", churn_ms[0]),
            ("churn_ms_paged", churn_ms[1]),
            ("retire_ns_dense_s64", dense_64),
            ("retire_ns_dense_s256", dense_256),
            ("retire_ns_paged_s64", paged_64),
            ("retire_ns_paged_s256", paged_256),
            ("retire_speedup_s256", dense_256 / paged_256.max(1.0)),
            ("smoke", if smoke { 1.0 } else { 0.0 }),
        ],
    );
}
