//! Coordinator micro-benchmarks: batcher throughput, KV-cache operations,
//! tokenizer, corpus generation.  No artifacts required.
//!
//!   cargo bench --bench coordinator_micro

use prefixquant::bench_support::bench_fn;
use prefixquant::config::{CorpusSpec, ModelConfig, TokenizerSpec};
use prefixquant::coordinator::{Batcher, GenRequest, KvCache};
use prefixquant::data::Language;
use prefixquant::model::PrefixState;
use prefixquant::tensor::Tensor;
use prefixquant::tokenizer::Tokenizer;
use prefixquant::util::table::Table;

fn main() {
    let mut t = Table::new("coordinator micro-benchmarks", &["op", "median", "per-unit"]);

    // batcher: push+drain 1024 mixed-length requests
    let st = bench_fn("batcher", 3, 50, || {
        let mut b = Batcher::new(8);
        for i in 0..1024u64 {
            b.push(GenRequest { id: i, prompt: vec![5; 8 * (1 + (i % 4) as usize)], max_new: 4 });
        }
        while !b.is_empty() {
            std::hint::black_box(b.next_batch());
        }
    });
    t.rowv(vec![
        "batcher push+drain 1024 reqs".into(),
        format!("{:.3}ms", st.per_call_ms()),
        format!("{:.2}us/req", st.median_s * 1e6 / 1024.0),
    ]);

    // kv-cache: install prefix + write prefill at serving geometry
    let cfg = ModelConfig {
        name: "bench".into(),
        vocab_size: 272,
        d_model: 128,
        n_layers: 4,
        n_heads: 4,
        d_head: 32,
        d_ff: 256,
        o_model: 3,
        inject_amp: 1.0,
        inject_delta: 0.05,
        max_prefix: 4,
        train_seq: 128,
        eval_seq: 256,
        cache_max: 320,
        sites: vec!["down_in".into()],
    };
    let pshape = [cfg.n_layers, cfg.n_heads, cfg.max_prefix, cfg.d_head];
    let prefix = PrefixState {
        tokens: vec![1, 49, 13],
        n_prefix: 3,
        n_ctx_sinks: 3,
        k: Tensor::full(&pshape, 0.5),
        v: Tensor::full(&pshape, 0.5),
    };
    let kshape = [cfg.n_layers, 8, cfg.n_heads, 256, cfg.d_head];
    let kfill = Tensor::full(&kshape, 1.0);
    let st = bench_fn("kvcache", 3, 30, || {
        let mut kv = KvCache::new(&cfg, 8);
        kv.install_prefix(&prefix).unwrap();
        kv.write_prefill(&kfill, &kfill, 256).unwrap();
        std::hint::black_box(kv.max_len());
    });
    t.rowv(vec![
        "kvcache prefix+prefill (B=8,S=256)".into(),
        format!("{:.3}ms", st.per_call_ms()),
        format!(
            "{:.1}MB/s",
            2.0 * kshape.iter().product::<usize>() as f64 * 4.0 / st.median_s / 1e6
        ),
    ]);

    // slot churn: admit into one slot, append, retire (continuous engine's
    // per-request cache work, everything but the model execution)
    let row_shape = [cfg.n_layers, 1, cfg.n_heads, 256, cfg.d_head];
    let row_fill = Tensor::full(&row_shape, 1.0);
    let tok_shape = [cfg.n_layers, cfg.n_heads, cfg.d_head];
    let tok_fill = Tensor::full(&tok_shape, 2.0);
    let mut kv = KvCache::new(&cfg, 8);
    kv.install_prefix(&prefix).unwrap();
    let st = bench_fn("slot churn", 3, 50, || {
        kv.write_prefill_row(3, &row_fill, &row_fill, 0, 256).unwrap();
        for _ in 0..16 {
            kv.append_token_row(3, &tok_fill, &tok_fill).unwrap();
        }
        kv.reset_slot(3).unwrap();
        std::hint::black_box(kv.row_len(3));
    });
    t.rowv(vec![
        "slot admit+16 appends+retire (S=256)".into(),
        format!("{:.3}ms", st.per_call_ms()),
        format!("{:.2}us/token", st.median_s * 1e6 / 16.0),
    ]);

    // tokenizer round-trip
    let tok = Tokenizer::new(TokenizerSpec {
        pad: 0,
        bos: 1,
        eos: 2,
        byte_offset: 3,
        vocab_size: 272,
        delimiter_ids: vec![13, 49],
    });
    let text = "lorem ipsum dolor sit amet. consectetur adipiscing elit.\n".repeat(100);
    let st = bench_fn("tokenize", 3, 200, || {
        std::hint::black_box(tok.encode(&text, true));
    });
    t.rowv(vec![
        format!("tokenize {} chars", text.len()),
        format!("{:.3}ms", st.per_call_ms()),
        format!("{:.0}MB/s", text.len() as f64 / st.median_s / 1e6),
    ]);

    // corpus generation
    let lang = Language::new(CorpusSpec {
        n_words: 256,
        n_followers: 8,
        follow_prob10: 7,
        word_seed: 1,
        train_seed: 2,
        eval_seed: 3,
        train_chars: 100_000,
        eval_chars: 1000,
    });
    let st = bench_fn("corpus", 2, 20, || {
        std::hint::black_box(lang.generate(7, 100_000));
    });
    t.rowv(vec![
        "generate 100k-char corpus".into(),
        format!("{:.2}ms", st.per_call_ms()),
        format!("{:.1}MB/s", 0.1 / st.median_s),
    ]);

    t.print();
}
