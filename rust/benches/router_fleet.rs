//! Cluster dispatch-policy bench: RoundRobin vs LeastLoaded vs
//! PrefixAffinity over a 4-worker sim fleet under a shared-prefix workload
//! (75% of requests drawn from 8 conversation groups that share a 24-token
//! prompt prefix, 25% fully unique).
//!
//! The page-hit accounting comes from the REAL radix prefix cache: every
//! worker runs with `ServerConfig::radix_cache(true)` over a page-starved
//! paged pool (32 pages of 8 tokens — small enough that one worker cannot
//! keep all 8 groups resident), and each policy is scored by the fleet's
//! merged `radix_hit_tokens` counter: cache positions admission actually
//! served from mapped pages instead of prefill.  Prefix-affinity keeps each
//! group's pages hot on one worker; round-robin smears every group across
//! all four trees and thrashes the LRU.  The router's own affinity counters
//! are reported separately.
//!
//!   cargo bench --bench router_fleet            # full run
//!   cargo bench --bench router_fleet -- --smoke # CI perf trail
//!
//! Emits `BENCH_router_fleet.json` and ASSERTS the headline win:
//! PrefixAffinity ≥1.3x the shared-prefix page-hit rate of RoundRobin, with
//! strictly fewer net (cold) prefill tokens.  No artifacts required.

use std::time::{Duration, Instant};

use prefixquant::bench_support::{emit_bench_json, smoke_mode};
use prefixquant::coordinator::{
    DispatchPolicy, GenRequest, KvLayout, LeastLoaded, PrefixAffinity, RoundRobin, Router,
    RouterConfig, Server, ServerConfig, SimBackend,
};
use prefixquant::model::QuantMode;
use prefixquant::util::args::Args;
use prefixquant::util::rng::SplitMix64;
use prefixquant::util::table::{f as ff, Table};

const N_WORKERS: usize = 4;
const B_EXEC: usize = 4;
const S_EXEC: usize = 48;
const N_PREFIX: usize = 2;
const CACHE_MAX: usize = 96;
const N_GROUPS: usize = 8;
const GROUP_PREFIX: usize = 24;
const TAIL: usize = 4;
const MAX_NEW: usize = 8;
/// KV page size — one radix-tree node per completed 8-token chunk
const PAGE: usize = 8;
/// per-worker pool: 4 slots × 5 worst-case pages + 1 prefix page leaves
/// ~11 pages of tree budget — 8 groups need 24 shared pages, so no single
/// worker can keep every group hot
const POOL_PAGES: usize = 32;

fn sim_worker() -> Server {
    let cfg = ServerConfig::builder(QuantMode::Static)
        .batch_window(Duration::from_millis(1))
        .radix_cache(true)
        .build();
    Server::start_sim(
        move || {
            Ok(SimBackend::new(B_EXEC, S_EXEC, N_PREFIX, CACHE_MAX)
                .with_costs(Duration::from_micros(300), Duration::from_micros(200))
                .with_kv_layout(KvLayout::Paged { page_size: PAGE, n_pages: POOL_PAGES }))
        },
        cfg,
    )
    .expect("sim worker boots")
}

/// 75% shared-prefix requests (8 groups × 24-token prefix + unique 4-token
/// tail), 25% fully unique — the "≥50% share a prompt prefix" workload from
/// the acceptance criteria, with headroom.
fn workload(n: usize, seed: u64) -> Vec<GenRequest> {
    let mut rng = SplitMix64::new(seed);
    let groups: Vec<Vec<i32>> = (0..N_GROUPS)
        .map(|_| (0..GROUP_PREFIX).map(|_| 10 + rng.below(200) as i32).collect())
        .collect();
    (0..n)
        .map(|i| {
            let shared = rng.below(4) < 3;
            let prompt: Vec<i32> = if shared {
                let g = rng.below(N_GROUPS as u64) as usize;
                let mut p = groups[g].clone();
                for _ in 0..TAIL {
                    p.push(10 + rng.below(200) as i32);
                }
                p
            } else {
                (0..GROUP_PREFIX + TAIL).map(|_| 10 + rng.below(200) as i32).collect()
            };
            GenRequest::new(i as u64, prompt, MAX_NEW)
        })
        .collect()
}

struct PolicyRun {
    name: &'static str,
    /// real page-hit rate: radix-matched positions / dispatched prompt tokens
    hit_rate: f64,
    hit_tokens: usize,
    total_tokens: usize,
    /// prompt tokens the engines actually prefilled cold (after radix skip)
    net_prefill_tokens: usize,
    cow_splits: usize,
    evicted_pages: usize,
    wall_s: f64,
    mean_ttft_ms: f64,
    /// the router's own affinity accounting (0 for policies without a tracker)
    router_hit_rate: f64,
}

fn run(name: &'static str, policy: Box<dyn DispatchPolicy>, reqs: &[GenRequest]) -> PolicyRun {
    let workers: Vec<Server> = (0..N_WORKERS).map(|_| sim_worker()).collect();
    let router = Router::new(workers, RouterConfig::default().policy(policy)).expect("router");
    let t0 = Instant::now();
    let handles: Vec<_> =
        reqs.iter().map(|r| router.submit(r.clone()).expect("submit")).collect();
    for h in handles {
        h.collect().expect("bench stream completes");
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let report = router.report().expect("fleet report");
    assert_eq!(report.fleet.unresolved(), 0, "{name}: ledger must balance");
    router.shutdown();

    // score from the real caches: merged engine counters across the fleet
    let hit_tokens = report.merged.radix_hit_tokens;
    let total_tokens = report.fleet.dispatched_prefill_tokens;
    PolicyRun {
        name,
        hit_rate: hit_tokens as f64 / total_tokens.max(1) as f64,
        hit_tokens,
        total_tokens,
        net_prefill_tokens: report.merged.prefill_tokens,
        cow_splits: report.merged.radix_cow_splits,
        evicted_pages: report.merged.radix_evicted_pages,
        wall_s,
        mean_ttft_ms: report.merged.mean_ttft() * 1e3,
        router_hit_rate: report.fleet.prefix_hit_rate(),
    }
}

fn main() {
    let args = Args::from_env();
    let smoke = smoke_mode();
    let n_requests = args.usize_or("requests", if smoke { 48 } else { 160 }).expect("--requests");
    let reqs = workload(n_requests, 0xF1EE7);

    println!(
        "router fleet bench{}: {n_requests} requests, {N_WORKERS} workers x {B_EXEC} slots, \
         {N_GROUPS} groups sharing {GROUP_PREFIX}-token prefixes, {POOL_PAGES}-page pools",
        if smoke { " [smoke]" } else { "" }
    );

    let rr = run("round-robin", Box::new(RoundRobin::new()), &reqs);
    let ll = run("least-loaded", Box::new(LeastLoaded::new()), &reqs);
    let pa = run(
        "prefix-affinity",
        Box::new(PrefixAffinity::new().with_block(PAGE).with_capacity(12)),
        &reqs,
    );

    let mut t = Table::new(
        "dispatch policy vs shared-prefix page hits (real radix cache)",
        &[
            "policy",
            "hit rate",
            "hit tok",
            "net prefill tok",
            "cow",
            "evicted",
            "wall s",
            "mean ttft ms",
        ],
    );
    for r in [&rr, &ll, &pa] {
        t.rowv(vec![
            r.name.to_string(),
            format!("{:.1}%", r.hit_rate * 100.0),
            r.hit_tokens.to_string(),
            r.net_prefill_tokens.to_string(),
            r.cow_splits.to_string(),
            r.evicted_pages.to_string(),
            ff(r.wall_s),
            ff(r.mean_ttft_ms),
        ]);
    }
    t.print();
    println!(
        "router-native affinity hit rates: rr={:.1}% ll={:.1}% pa={:.1}% (total prefill \
         dispatched: {} tokens)",
        rr.router_hit_rate * 100.0,
        ll.router_hit_rate * 100.0,
        pa.router_hit_rate * 100.0,
        pa.total_tokens
    );

    let ratio = pa.hit_rate / rr.hit_rate.max(1e-9);
    emit_bench_json(
        "router_fleet",
        &[
            ("requests", n_requests as f64),
            ("workers", N_WORKERS as f64),
            ("rr_hit_rate", rr.hit_rate),
            ("ll_hit_rate", ll.hit_rate),
            ("pa_hit_rate", pa.hit_rate),
            ("pa_over_rr_hit_ratio", ratio),
            ("rr_net_prefill_tokens", rr.net_prefill_tokens as f64),
            ("ll_net_prefill_tokens", ll.net_prefill_tokens as f64),
            ("pa_net_prefill_tokens", pa.net_prefill_tokens as f64),
            ("rr_cow_splits", rr.cow_splits as f64),
            ("pa_cow_splits", pa.cow_splits as f64),
            ("rr_evicted_pages", rr.evicted_pages as f64),
            ("pa_evicted_pages", pa.evicted_pages as f64),
            ("rr_wall_s", rr.wall_s),
            ("ll_wall_s", ll.wall_s),
            ("pa_wall_s", pa.wall_s),
            ("rr_mean_ttft_ms", rr.mean_ttft_ms),
            ("pa_mean_ttft_ms", pa.mean_ttft_ms),
            ("pa_router_hit_rate", pa.router_hit_rate),
            ("smoke", if smoke { 1.0 } else { 0.0 }),
        ],
    );

    // headline win: affinity routing keeps shared prefixes hot in the REAL
    // radix caches — more matched pages, fewer cold prefill tokens
    assert!(
        pa.hit_rate >= 1.3 * rr.hit_rate,
        "PrefixAffinity page-hit rate {:.3} must be ≥1.3x RoundRobin {:.3}",
        pa.hit_rate,
        rr.hit_rate
    );
    assert!(
        pa.net_prefill_tokens < rr.net_prefill_tokens,
        "PrefixAffinity must prefill fewer cold tokens ({} vs {})",
        pa.net_prefill_tokens,
        rr.net_prefill_tokens
    );
    println!(
        "headline: prefix-affinity {:.1}% vs round-robin {:.1}% page-hit rate ({:.2}x), \
         {} fewer cold prefill tokens",
        pa.hit_rate * 100.0,
        rr.hit_rate * 100.0,
        ratio,
        rr.net_prefill_tokens - pa.net_prefill_tokens
    );
}
