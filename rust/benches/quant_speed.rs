//! Host-kernel speed trail: new blocked/FWHT/fused kernels vs the FROZEN
//! naive references (`kernels::naive`) — the first BENCH baseline for host
//! compute.
//!
//!   cargo bench --bench quant_speed            # full run
//!   cargo bench --bench quant_speed -- --smoke # CI perf trail
//!
//! Three microkernels and one end-to-end leg, all artifact-free:
//!
//!   * matmul: blocked multithreaded `Tensor::matmul` backend vs the naive
//!     triple loop, at a size whose B matrix busts the cache (the naive
//!     kernel re-streams all of B for every output row).
//!   * FWHT: O(n log n) in-place rotation fold vs the explicit
//!     Hadamard-matrix product it replaces.
//!   * weight quantizer: fused single-pass pruned-grid kernel vs the frozen
//!     two-pass column-strided scan.
//!   * end-to-end "quantize floor": norm-absorb + full rotation fold + 40-
//!     point per-channel grid quant of every projection — the host compute
//!     `pq quantize --save` pays — new kernels vs naive everywhere.
//!
//! ASSERTS (the issue's acceptance bars): ≥4x end-to-end in every mode;
//! ≥8x on the FWHT microkernel in every mode; ≥8x on the matmul microkernel
//! in full mode (the smoke shape is too small to exercise the cache
//! hierarchy on arbitrary CI hosts, so smoke asserts ≥3x there); fused
//! quantizer ≥2x.  Emits `BENCH_quant_speed.json`.

use prefixquant::bench_support::{bench_fn, emit_bench_json, smoke_mode};
use prefixquant::config::ModelConfig;
use prefixquant::kernels::{self, fwht, naive};
use prefixquant::quant::pipeline::QUANT_WEIGHTS;
use prefixquant::quant::{quantizer, rotation};
use prefixquant::runtime::WeightStore;
use prefixquant::tensor::Tensor;
use prefixquant::util::rng::SplitMix64;
use prefixquant::util::table::Table;

fn synth_cfg(smoke: bool) -> ModelConfig {
    let (d, h, ff, l, vocab) =
        if smoke { (128, 4, 512, 2, 192) } else { (256, 8, 1024, 4, 512) };
    ModelConfig {
        name: "pq-kernel-synth".into(),
        vocab_size: vocab,
        d_model: d,
        n_layers: l,
        n_heads: h,
        d_head: d / h,
        d_ff: ff,
        o_model: 3,
        inject_amp: 0.0,
        inject_delta: 0.0,
        max_prefix: 4,
        train_seq: 64,
        eval_seq: 64,
        cache_max: 96,
        sites: vec!["attn_in".into(), "o_in".into(), "mlp_in".into(), "down_in".into()],
    }
}

fn rt(rng: &mut SplitMix64, shape: &[usize]) -> Tensor {
    let n: usize = shape.iter().product();
    Tensor::new(shape.to_vec(), (0..n).map(|_| rng.range_f32(-0.5, 0.5)).collect()).unwrap()
}

/// Everything rotation folding touches, pq-tiny-shaped at bench scale.
fn synth_weights(cfg: &ModelConfig, rng: &mut SplitMix64) -> WeightStore {
    let d = cfg.d_model;
    let ff = cfg.d_ff;
    let mut pairs: Vec<(String, Tensor)> = vec![
        ("emb".into(), rt(rng, &[cfg.vocab_size, d])),
        ("head".into(), rt(rng, &[d, cfg.vocab_size])),
        ("lnf".into(), Tensor::full(&[d], 1.0)),
    ];
    for l in 0..cfg.n_layers {
        for t in ["wq", "wk", "wv", "wo"] {
            pairs.push((format!("layers.{l}.{t}"), rt(rng, &[d, d])));
        }
        for t in ["wg", "wu"] {
            pairs.push((format!("layers.{l}.{t}"), rt(rng, &[d, ff])));
        }
        pairs.push((format!("layers.{l}.wd"), rt(rng, &[ff, d])));
        pairs.push((format!("layers.{l}.ln1"), Tensor::full(&[d], 1.0)));
        pairs.push((format!("layers.{l}.ln2"), Tensor::full(&[d], 1.0)));
    }
    WeightStore::from_pairs(pairs)
}

/// End-to-end host quantize floor with the frozen naive kernels.
fn e2e_naive(cfg: &ModelConfig, base: &WeightStore) -> WeightStore {
    let mut ws = base.clone();
    rotation::absorb_norm_gains(cfg, &mut ws).unwrap();
    naive::fold_rotations(cfg, &mut ws).unwrap();
    let qm = quantizer::qmax(4);
    for l in 0..cfg.n_layers {
        for t in QUANT_WEIGHTS {
            let w = ws.get_mut(&format!("layers.{l}.{t}")).unwrap();
            naive::quant_weight_per_channel(w, qm, 40);
        }
    }
    ws
}

/// The same floor through the host-kernel layer.
fn e2e_kernels(cfg: &ModelConfig, base: &WeightStore) -> WeightStore {
    let mut ws = base.clone();
    rotation::absorb_norm_gains(cfg, &mut ws).unwrap();
    rotation::fold_rotations(cfg, &mut ws).unwrap();
    for l in 0..cfg.n_layers {
        for t in QUANT_WEIGHTS {
            let w = ws.get_mut(&format!("layers.{l}.{t}")).unwrap();
            quantizer::quant_weight_per_channel(w, 4, 40);
        }
    }
    ws
}

fn main() {
    let smoke = smoke_mode();
    let threads = kernels::threads();
    let mut rng = SplitMix64::new(0x5EED);

    let mut table = Table::new(
        "host kernels vs frozen naive baselines (quantize-path compute)",
        &["kernel", "naive ms", "new ms", "speedup"],
    );
    let mut row = |name: &str, naive_s: f64, new_s: f64| -> f64 {
        let speedup = naive_s / new_s.max(1e-9);
        table.rowv(vec![
            name.into(),
            format!("{:.2}", naive_s * 1e3),
            format!("{:.2}", new_s * 1e3),
            format!("{speedup:.1}x"),
        ]);
        speedup
    };

    // --- matmul microkernel (cache-hostile B) ---------------------------
    let (m, k, n) = if smoke { (128, 768, 768) } else { (256, 1536, 1536) };
    let a = rt(&mut rng, &[m, k]);
    let b = rt(&mut rng, &[k, n]);
    let (warm, samples) = if smoke { (1, 3) } else { (1, 5) };
    let mm_naive = bench_fn("matmul naive", warm, samples, || {
        std::hint::black_box(naive::matmul(&a, &b));
    });
    let mm_new = bench_fn("matmul blocked", warm, samples, || {
        std::hint::black_box(a.matmul(&b));
    });
    let matmul_speedup =
        row(&format!("matmul {m}x{k}x{n}"), mm_naive.median_s, mm_new.median_s);

    // --- FWHT vs explicit Hadamard product ------------------------------
    let hn = if smoke { 512 } else { 1024 };
    let x = rt(&mut rng, &[hn, hn]);
    let h = rotation::hadamard(hn);
    let fw_naive = bench_fn("rotate via H-matmul", warm, samples, || {
        std::hint::black_box(naive::matmul(&x, &h));
    });
    let fw_new = bench_fn("rotate via FWHT", warm, samples, || {
        let mut y = x.clone();
        fwht::fwht_rows_nt(&mut y.data, hn, hn, threads);
        std::hint::black_box(y);
    });
    let fwht_speedup =
        row(&format!("rotation fold {hn}x{hn}"), fw_naive.median_s, fw_new.median_s);

    // --- fused weight quantizer vs frozen two-pass ----------------------
    let (qr, qc) = if smoke { (512, 128) } else { (1024, 256) };
    let wq = rt(&mut rng, &[qr, qc]);
    let qm = quantizer::qmax(4);
    let q_naive = bench_fn("quant two-pass", warm, samples, || {
        let mut w = wq.clone();
        std::hint::black_box(naive::quant_weight_per_channel(&mut w, qm, 40));
    });
    let q_new = bench_fn("quant fused", warm, samples, || {
        let mut w = wq.clone();
        std::hint::black_box(quantizer::quant_weight_per_channel(&mut w, 4, 40));
    });
    let quant_speedup =
        row(&format!("weight quant {qr}x{qc} grid40"), q_naive.median_s, q_new.median_s);

    // --- end-to-end quantize floor --------------------------------------
    let cfg = synth_cfg(smoke);
    let base = synth_weights(&cfg, &mut rng);
    let e2e_warm = if smoke { 0 } else { 1 };
    let e2e_n = bench_fn("e2e naive", e2e_warm, 3, || {
        std::hint::black_box(e2e_naive(&cfg, &base));
    });
    let e2e_k = bench_fn("e2e kernels", e2e_warm, 3, || {
        std::hint::black_box(e2e_kernels(&cfg, &base));
    });
    let e2e_speedup = row("e2e quantize floor", e2e_n.median_s, e2e_k.median_s);

    table.print();
    println!(
        "\n{threads} worker thread(s) (PQ_THREADS knob); naive baselines are \
         the frozen pre-kernel-layer implementations (kernels::naive)"
    );

    assert!(
        e2e_speedup >= 4.0,
        "end-to-end quantize must be ≥4x the frozen naive baseline (got {e2e_speedup:.2}x)"
    );
    assert!(
        fwht_speedup >= 8.0,
        "FWHT fold must be ≥8x the explicit-H matmul (got {fwht_speedup:.2}x)"
    );
    let matmul_floor = if smoke { 3.0 } else { 8.0 };
    assert!(
        matmul_speedup >= matmul_floor,
        "blocked matmul must be ≥{matmul_floor}x naive at this size \
         (got {matmul_speedup:.2}x)"
    );
    assert!(
        quant_speedup >= 2.0,
        "fused quantizer must be ≥2x the two-pass scan (got {quant_speedup:.2}x)"
    );

    emit_bench_json(
        "quant_speed",
        &[
            ("matmul_naive_ms", mm_naive.median_s * 1e3),
            ("matmul_new_ms", mm_new.median_s * 1e3),
            ("matmul_speedup", matmul_speedup),
            ("fwht_naive_ms", fw_naive.median_s * 1e3),
            ("fwht_new_ms", fw_new.median_s * 1e3),
            ("fwht_speedup", fwht_speedup),
            ("weight_quant_naive_ms", q_naive.median_s * 1e3),
            ("weight_quant_new_ms", q_new.median_s * 1e3),
            ("weight_quant_speedup", quant_speedup),
            ("e2e_naive_ms", e2e_n.median_s * 1e3),
            ("e2e_new_ms", e2e_k.median_s * 1e3),
            ("e2e_quantize_speedup", e2e_speedup),
            ("threads", threads as f64),
            ("smoke", if smoke { 1.0 } else { 0.0 }),
        ],
    );
}
