//! Table 5: time-to-first-token (prefill) — FP16 vs dynamic vs static W4A4.
//!
//! End-to-end prefill through the full-model executables at the eval context
//! length, batch sizes 1-equivalent and full (the executables have a fixed
//! batch of 8; batch "1" duplicates one row — same compute shape the paper's
//! batch sweep probes).  PrefixQuant = static path + prefixed KV installed.
//!
//!   cargo bench --bench table5_ttft

use std::rc::Rc;

use anyhow::Result;
use prefixquant::bench_support::{auto_samples, bench_fn};
use prefixquant::data::{self, Language};
use prefixquant::model::{Model, QuantMode};
use prefixquant::quant::{pipeline, SchemeConfig};
use prefixquant::runtime::Engine;
use prefixquant::tensor::IntTensor;
use prefixquant::tokenizer::Tokenizer;
use prefixquant::util::table::Table;

fn main() -> Result<()> {
    let dir = prefixquant::artifacts_dir();
    let engine = Rc::new(Engine::new(&dir)?);
    let tok = Tokenizer::new(engine.manifest.tokenizer.clone());
    let lang = Language::new(engine.manifest.corpus.clone());

    // quantize once with PrefixQuant (static) — model then serves all modes
    let mut model = Model::load(engine.clone(), "pq-tiny")?;
    let (b, s) = model.fwd_geom()?;
    let cw = data::calibration_windows(&lang, |t| tok.encode(t, false), s, b, tok.spec.bos);
    let calib = IntTensor::new(vec![b, s], cw.into_iter().flatten().collect())?;
    let scheme = SchemeConfig::prefixquant_wo_ft(4, 4, 4);
    pipeline::quantize(&mut model, &scheme, &calib, &tok)?;

    let tokens = calib; // representative full-context batch
    let mut table = Table::new(
        &format!("Table 5: prefill TTFT, seq={s}, exec batch={b} (median ms)"),
        &["Method", "prefill ms", "speedup vs FP-path"],
    );
    // warm all three executables
    for mode in [QuantMode::Fp, QuantMode::Dynamic, QuantMode::Static] {
        model.forward(mode, &tokens)?;
    }
    let probe = std::time::Instant::now();
    model.forward(QuantMode::Fp, &tokens)?;
    let samples = auto_samples(probe.elapsed().as_secs_f64(), 3.0, 8, 60);

    let mut base = 0.0f64;
    for (name, mode) in [
        ("FP16", QuantMode::Fp),
        ("QuaRot-path (dynamic W4A4)", QuantMode::Dynamic),
        ("PrefixQuant (static W4A4)", QuantMode::Static),
    ] {
        let st = bench_fn(name, 2, samples, || {
            model.forward(mode, &tokens).unwrap();
        });
        if base == 0.0 {
            base = st.median_s;
        }
        table.rowv(vec![
            name.into(),
            format!("{:.2}", st.per_call_ms()),
            format!("{:.2}x", base / st.median_s),
        ]);
    }
    // §Perf L3-1: resident quant-state buffers (frozen) vs per-call uploads
    let frozen = bench_fn("static frozen", 2, samples, || {
        model.forward(QuantMode::Static, &tokens).unwrap();
    });
    model.unfreeze();
    let unfrozen = bench_fn("static unfrozen", 2, samples, || {
        model.forward(QuantMode::Static, &tokens).unwrap();
    });
    model.freeze()?;
    table.rowv(vec![
        "static, per-call state upload (pre-opt)".into(),
        format!("{:.2}", unfrozen.per_call_ms()),
        format!("{:.2}x", base / unfrozen.median_s),
    ]);
    table.rowv(vec![
        "static, frozen resident state (L3-1)".into(),
        format!("{:.2}", frozen.per_call_ms()),
        format!("{:.2}x", base / frozen.median_s),
    ]);
    table.print();
    println!("(paper: PrefixQuant 2.81x vs FP16 and 1.2-1.3x vs QuaRot on GPU INT4;");
    println!(" CPU fake-quant cannot beat FP — compare the static row against the");
    println!(" dynamic row: static must not be slower, since it skips the per-token");
    println!(" reduction. See table8/table9 for the isolated mechanism.)");
    Ok(())
}
