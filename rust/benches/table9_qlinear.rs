//! Table 9: quantized linear layer vs FP linear.
//!
//! Compositions per shape: FP matmul; dynamic-quant linear (per-token scale
//! reduction + matmul + dequant); fused static-quant linear (the paper's
//! "+ static quant" row — quantization fused into the GEMM consumption).
//!
//!   cargo bench --bench table9_qlinear

use std::path::Path;

use anyhow::Result;
use prefixquant::bench_support::{auto_samples, bench_fn};
use prefixquant::runtime::{Engine, Value};
use prefixquant::tensor::Tensor;
use prefixquant::util::rng::SplitMix64;
use prefixquant::util::table::Table;

fn main() -> Result<()> {
    let engine = Engine::new(Path::new(
        &std::env::var("PQ_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    ))?;
    let mut rng = SplitMix64::new(9);
    let shapes = [(1usize, 1024usize, 1024usize), (64, 1024, 1024), (512, 1024, 1024)];
    let mut table = Table::new(
        "Table 9: linear-layer compositions (median ms)",
        &["(M, K, N)", "FP16", "dynamic W4A4", "static W4A4", "static vs dyn"],
    );
    for (m, k, n) in shapes {
        let x = Tensor::new(vec![m, k], (0..m * k).map(|_| rng.normal_f32()).collect())?;
        let w = Tensor::new(vec![k, n], (0..k * n).map(|_| rng.normal_f32() * 0.05).collect())?;
        let wq = Tensor::new(
            vec![k, n],
            w.data.iter().map(|&v| (v / 0.01).round().clamp(-8.0, 7.0)).collect(),
        )?;
        let sw = Tensor::full(&[n], 0.01);
        let sx = Tensor::scalar(0.05);
        let qm = Tensor::scalar(7.0);

        let fp_sig = engine.manifest.kernel(&format!("mm_fp_jnp_{m}x{k}x{n}"))?.clone();
        let dyn_sig = engine.manifest.kernel(&format!("qmm_dynamic_jnp_{m}x{k}x{n}"))?.clone();
        let st_sig = engine.manifest.kernel(&format!("qmm_static_jnp_{m}x{k}x{n}"))?.clone();
        engine.run(&fp_sig, &[Value::F32(&x), Value::F32(&w)])?;
        engine.run(&dyn_sig, &[Value::F32(&x), Value::F32(&wq), Value::F32(&sw), Value::F32(&qm)])?;
        engine.run(
            &st_sig,
            &[Value::F32(&x), Value::F32(&wq), Value::F32(&sx), Value::F32(&sw), Value::F32(&qm)],
        )?;

        let probe = std::time::Instant::now();
        engine.run(&fp_sig, &[Value::F32(&x), Value::F32(&w)])?;
        let samples = auto_samples(probe.elapsed().as_secs_f64(), 2.0, 8, 100);
        let fp = bench_fn("fp", 2, samples, || {
            engine.run(&fp_sig, &[Value::F32(&x), Value::F32(&w)]).unwrap();
        });
        let dy = bench_fn("dyn", 2, samples, || {
            engine
                .run(&dyn_sig, &[Value::F32(&x), Value::F32(&wq), Value::F32(&sw), Value::F32(&qm)])
                .unwrap();
        });
        let st = bench_fn("static", 2, samples, || {
            engine
                .run(
                    &st_sig,
                    &[
                        Value::F32(&x),
                        Value::F32(&wq),
                        Value::F32(&sx),
                        Value::F32(&sw),
                        Value::F32(&qm),
                    ],
                )
                .unwrap();
        });
        table.rowv(vec![
            format!("({m}, {k}, {n})"),
            format!("{:.3}", fp.per_call_ms()),
            format!("{:.3}", dy.per_call_ms()),
            format!("{:.3}", st.per_call_ms()),
            format!("{:.2}x", dy.median_s / st.median_s),
        ]);
    }
    table.print();
    println!("(CPU substrate: no real INT4 GEMM — the static-vs-dynamic gap is the");
    println!(" paper's mechanism; absolute FP-vs-INT speedups are GPU-specific.)");
    Ok(())
}
