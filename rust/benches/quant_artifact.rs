//! Cold-start bench: serve-boot from a saved QuantArtifact vs booting with
//! an inline quantization run (the v1 server-factory behavior).
//!
//!   cargo bench --bench quant_artifact            # full run
//!   cargo bench --bench quant_artifact -- --smoke # CI perf trail
//!
//! Artifact-free leg (always runs, asserted in CI): a pq-tiny-shaped
//! synthetic checkpoint is quantized host-side — rotation folding (R1/R2/R4)
//! + per-channel grid weight quantization, the FLOOR of what an inline
//! `quantize()` must pay before any observation/grid/fine-tuning — and
//! compared against a full `QuantArtifact::load` (metadata + content-hash
//! verification + both tensor stores) plus installing the prefix K/V into a
//! paged KV cache's shared-prefix pages.  ASSERTS boot-from-artifact is
//! ≥5x faster than even that floor (the real pipeline adds observation,
//! calibration, and fine-tuning on top, so end-to-end the gap is larger —
//! see serve_batch's cold-start table for live numbers).
//!
//! With real artifacts AND a real PJRT runtime, an end-to-end comparison
//! (full recipe run vs artifact load through the engine) also runs; it
//! skips gracefully under the vendored execute-less xla stub.
//!
//! Emits `BENCH_quant_artifact.json`.

use prefixquant::bench_support::{bench_fn, emit_bench_json, smoke_mode};
use prefixquant::config::ModelConfig;
use prefixquant::coordinator::{KvCache, KvLayout};
use prefixquant::model::QuantMode;
use prefixquant::quant::pipeline::QUANT_WEIGHTS;
use prefixquant::quant::{
    quantizer, rotation, ArtifactMeta, Precision, QuantArtifact, FORMAT_VERSION,
};
use prefixquant::runtime::WeightStore;
use prefixquant::tensor::Tensor;
use prefixquant::util::rng::SplitMix64;
use prefixquant::util::table::Table;

fn synth_cfg() -> ModelConfig {
    ModelConfig {
        name: "pq-bench-synth".into(),
        vocab_size: 272,
        d_model: 128,
        n_layers: 4,
        n_heads: 4,
        d_head: 32,
        d_ff: 256,
        o_model: 3,
        inject_amp: 0.0,
        inject_delta: 0.0,
        max_prefix: 4,
        train_seq: 64,
        eval_seq: 64,
        cache_max: 96,
        sites: vec!["attn_in".into(), "o_in".into(), "mlp_in".into(), "down_in".into()],
    }
}

fn rt(rng: &mut SplitMix64, shape: &[usize]) -> Tensor {
    let n: usize = shape.iter().product();
    Tensor::new(shape.to_vec(), (0..n).map(|_| rng.range_f32(-0.5, 0.5)).collect()).unwrap()
}

/// A pq-tiny-shaped synthetic checkpoint (everything rotation folding touches).
fn synth_weights(cfg: &ModelConfig, rng: &mut SplitMix64) -> WeightStore {
    let d = cfg.d_model;
    let ff = cfg.d_ff;
    let mut pairs: Vec<(String, Tensor)> = vec![
        ("emb".into(), rt(rng, &[cfg.vocab_size, d])),
        ("head".into(), rt(rng, &[d, cfg.vocab_size])),
        ("lnf".into(), Tensor::full(&[d], 1.0)),
    ];
    for l in 0..cfg.n_layers {
        for t in ["wq", "wk", "wv", "wo"] {
            pairs.push((format!("layers.{l}.{t}"), rt(rng, &[d, d])));
        }
        for t in ["wg", "wu"] {
            pairs.push((format!("layers.{l}.{t}"), rt(rng, &[d, ff])));
        }
        pairs.push((format!("layers.{l}.wd"), rt(rng, &[ff, d])));
        pairs.push((format!("layers.{l}.ln1"), Tensor::full(&[d], 1.0)));
        pairs.push((format!("layers.{l}.ln2"), Tensor::full(&[d], 1.0)));
    }
    WeightStore::from_pairs(pairs)
}

/// The host-side floor of an inline quantize: rotation folding + per-channel
/// grid weight quantization (observation / grid-init / FT come on top).
fn inline_quantize_floor(cfg: &ModelConfig, base: &WeightStore) -> WeightStore {
    let mut ws = base.clone();
    rotation::absorb_norm_gains(cfg, &mut ws).unwrap();
    rotation::fold_rotations(cfg, &mut ws).unwrap();
    for l in 0..cfg.n_layers {
        for t in QUANT_WEIGHTS {
            let w = ws.get_mut(&format!("layers.{l}.{t}")).unwrap();
            quantizer::quant_weight_per_channel(w, 4, 40);
        }
    }
    ws
}

fn synth_artifact(cfg: &ModelConfig, weights: WeightStore, rng: &mut SplitMix64) -> QuantArtifact {
    let (l, h, dh, p) = (cfg.n_layers, cfg.n_heads, cfg.d_head, cfg.max_prefix);
    let state = WeightStore::from_pairs(vec![
        ("act_scales".into(), rt(rng, &[l, 4])),
        ("kv_scales".into(), rt(rng, &[l, 2, h])),
        ("qmax_act".into(), Tensor::scalar(7.0)),
        ("qmax_kv".into(), Tensor::scalar(7.0)),
        ("r3".into(), rotation::hadamard(dh)),
        ("r4".into(), rotation::hadamard(cfg.d_ff)),
        ("prefix_k".into(), rt(rng, &[l, h, p, dh])),
        ("prefix_v".into(), rt(rng, &[l, h, p, dh])),
    ]);
    QuantArtifact {
        meta: ArtifactMeta {
            format_version: FORMAT_VERSION,
            model: cfg.name.clone(),
            mode: QuantMode::Static,
            recipe: "PrefixQuant w/o FT W4A4KV4".into(),
            passes: vec!["rotate".into(), "find-prefix".into(), "grid-init".into()],
            stage_seconds: vec![0.0, 0.0, 0.0],
            precision: Some(Precision::new(4, 4, 4)),
            rotated: true,
            prefix_tokens: vec![1, 49, 49],
            n_prefix: 3,
            n_ctx_sinks: 3,
            weight_quant: vec![],
            content_hash: 0,
        },
        weights,
        state,
    }
}

/// End-to-end comparison on the real artifacts (needs a PJRT runtime that
/// can execute the AOT graphs; the vendored stub cannot, so this skips).
fn real_model_comparison(smoke: bool) -> anyhow::Result<(f64, f64)> {
    use prefixquant::data::{self, Language};
    use prefixquant::model::Model;
    use prefixquant::quant::{model_state, Recipe};
    use prefixquant::runtime::Engine;
    use prefixquant::tensor::IntTensor;
    use prefixquant::tokenizer::Tokenizer;
    use std::rc::Rc;
    use std::time::Instant;

    let dir = prefixquant::artifacts_dir();
    let engine = Rc::new(Engine::new(&dir)?);
    let tok = Tokenizer::new(engine.manifest.tokenizer.clone());
    let lang = Language::new(engine.manifest.corpus.clone());
    let recipe = Recipe::prefixquant_wo_ft(Precision::new(4, 4, 4));
    let t_q = Instant::now();
    let mut model = Model::load(engine.clone(), "pq-tiny")?;
    let (b, s) = model.fwd_geom()?;
    let w = data::calibration_windows(&lang, |t| tok.encode(t, false), s, b, tok.spec.bos);
    let calib = IntTensor::new(vec![b, s], w.into_iter().flatten().collect())?;
    recipe.run(&mut model, &calib, &tok)?;
    let quantize_s = t_q.elapsed().as_secs_f64();

    let adir = std::env::temp_dir().join(format!("pq_bench_artifact_{}", std::process::id()));
    QuantArtifact::save_model(&model, recipe.mode, None, &adir)?;
    drop(model);
    let samples = if smoke { 3 } else { 10 };
    let mut best = f64::INFINITY;
    for _ in 0..samples {
        let t = Instant::now();
        let (m, _mode) = model_state::load(engine.clone(), &adir)?;
        best = best.min(t.elapsed().as_secs_f64());
        drop(m);
    }
    Ok((quantize_s, best))
}

fn main() {
    let smoke = smoke_mode();
    let cfg = synth_cfg();
    let mut rng = SplitMix64::new(0xA27);
    let base = synth_weights(&cfg, &mut rng);

    // --- inline-quantize floor -----------------------------------------
    let (warm, samples) = if smoke { (1, 5) } else { (2, 15) };
    let inline = bench_fn("inline quantize (host floor)", warm, samples, || {
        std::hint::black_box(inline_quantize_floor(&cfg, &base));
    });

    // --- boot from artifact ---------------------------------------------
    let quantized = inline_quantize_floor(&cfg, &base);
    let mut art = synth_artifact(&cfg, quantized, &mut rng);
    let adir = std::env::temp_dir().join(format!("pq_art_bench_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&adir);
    art.save(&adir).expect("bench artifact save");
    let (warm_a, samples_a) = if smoke { (2, 10) } else { (5, 50) };
    let boot = bench_fn("boot from artifact (load+verify+prefix pages)", warm_a, samples_a, || {
        let loaded = QuantArtifact::load(&adir).expect("artifact load");
        let ps = loaded.prefix_state(&cfg).expect("prefix state");
        let mut kv = KvCache::with_layout(&cfg, 4, KvLayout::Paged { page_size: 16, n_pages: 0 });
        kv.install_prefix(&ps).expect("install prefix");
        std::hint::black_box(kv.row_len(0));
    });

    let speedup = inline.median_s / boot.median_s.max(1e-9);
    let mut t = Table::new(
        "serve cold start: inline quantize vs QuantArtifact boot (synthetic pq-tiny shape)",
        &["path", "median ms", "p10 ms", "p90 ms"],
    );
    for s in [&inline, &boot] {
        t.rowv(vec![
            s.name.clone(),
            format!("{:.2}", s.median_s * 1e3),
            format!("{:.2}", s.p10_s * 1e3),
            format!("{:.2}", s.p90_s * 1e3),
        ]);
    }
    t.print();
    println!(
        "\nboot-from-artifact is {speedup:.1}x faster than the inline-quantize FLOOR \
         (rotation fold + weight grid only; the full pipeline adds observation, \
         calibration, and fine-tuning)"
    );
    assert!(
        speedup >= 5.0,
        "artifact boot must be ≥5x faster than inline quantization (got {speedup:.2}x)"
    );

    // --- optional end-to-end on real artifacts ---------------------------
    let mut real_quant_s = 0.0;
    let mut real_boot_s = 0.0;
    if prefixquant::artifacts_dir().join("manifest.json").exists() {
        match real_model_comparison(smoke) {
            Ok((q, l)) => {
                real_quant_s = q;
                real_boot_s = l;
                println!(
                    "real model: inline quantize {q:.2}s vs artifact boot {l:.3}s \
                     ({:.1}x)",
                    q / l.max(1e-9)
                );
                assert!(
                    q / l.max(1e-9) >= 5.0,
                    "real-model artifact boot must be ≥5x faster (got {:.2}x)",
                    q / l.max(1e-9)
                );
            }
            Err(e) => println!("skipping real-model comparison: {e:#}"),
        }
    } else {
        println!("(real artifacts absent — synthetic floor only; run `make artifacts` for more)");
    }

    emit_bench_json(
        "quant_artifact",
        &[
            ("inline_quantize_floor_ms", inline.median_s * 1e3),
            ("artifact_boot_ms", boot.median_s * 1e3),
            ("cold_start_speedup", speedup),
            ("real_inline_quantize_s", real_quant_s),
            ("real_artifact_boot_s", real_boot_s),
            ("smoke", if smoke { 1.0 } else { 0.0 }),
        ],
    );
}
