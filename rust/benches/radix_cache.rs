//! Headline radix-prefix-cache bench: paged engine WITH vs WITHOUT the
//! radix cache on a page-starved pool under a 75%-shared-prefix workload.
//!
//! Geometry: 8 slots over a 27-page pool (8 tokens/page; 1 page goes to the
//! shared n_prefix entries).  Every request is a 63-token prompt + 8 new
//! tokens → 9 worst-case pages, so the paged baseline admits ⌊26/9⌋ = 2
//! rows at a time.  75% of requests share a 62-token prefix: with the radix
//! cache, admission maps the 7 matched pages (BOS + 55 more positions) and
//! reserves only 2 fresh pages, so 6 shared rows fit concurrently — the
//! cache multiplies admitted concurrency, which at saturation divides mean
//! TTFT.
//!
//!   cargo bench --bench radix_cache            # full run
//!   cargo bench --bench radix_cache -- --smoke # CI perf trail
//!
//! Emits `BENCH_radix_cache.json` and ASSERTS the headline win: ≥2x peak
//! admitted concurrency OR ≥2x lower mean TTFT at saturation, with every
//! stream token-identical to the dense-reference run.  No artifacts needed.

use std::time::{Duration, Instant};

use prefixquant::bench_support::{emit_bench_json, smoke_mode};
use prefixquant::coordinator::continuous::run_to_completion;
use prefixquant::coordinator::{
    ContinuousEngine, FinishReason, GenRequest, GenResponse, KvLayout, SimBackend, StreamEvent,
};
use prefixquant::util::args::Args;
use prefixquant::util::rng::SplitMix64;
use prefixquant::util::table::{f as ff, Table};

const B_EXEC: usize = 8;
const S_EXEC: usize = 96;
const N_PREFIX: usize = 2;
const CACHE_MAX: usize = 96;
const PAGE: usize = 8;
/// pool: 1 prefix page + 26 row pages — starves the 9-page worst-case rows
/// down to 2 concurrent without the radix cache
const POOL_PAGES: usize = 27;
const SHARED_PREFIX: usize = 62;
const TAIL: usize = 1;
const MAX_NEW: usize = 8;

fn backend() -> SimBackend {
    SimBackend::new(B_EXEC, S_EXEC, N_PREFIX, CACHE_MAX)
        .with_costs(Duration::from_micros(500), Duration::from_micros(200))
        .with_kv_layout(KvLayout::Paged { page_size: PAGE, n_pages: POOL_PAGES })
}

/// 75% of requests share one 62-token prefix (+1 unique tail token); every
/// 4th request is a fully unique 63-token prompt.
fn workload(n: usize, seed: u64) -> Vec<GenRequest> {
    let mut rng = SplitMix64::new(seed);
    let shared: Vec<i32> = (0..SHARED_PREFIX).map(|_| 10 + rng.below(200) as i32).collect();
    (0..n)
        .map(|i| {
            let prompt: Vec<i32> = if i % 4 != 3 {
                let mut p = shared.clone();
                for _ in 0..TAIL {
                    p.push(10 + rng.below(200) as i32);
                }
                p
            } else {
                (0..SHARED_PREFIX + TAIL).map(|_| 10 + rng.below(200) as i32).collect()
            };
            GenRequest::new(i as u64, prompt, MAX_NEW)
        })
        .collect()
}

struct RunStats {
    name: &'static str,
    peak_slots: usize,
    mean_ttft_ms: f64,
    wall_s: f64,
    prefill_tokens: usize,
    hit_tokens: usize,
    cow_splits: usize,
    evicted_pages: usize,
    deferred: usize,
    responses: Vec<GenResponse>,
}

fn drain(rx: &std::sync::mpsc::Receiver<StreamEvent>) -> GenResponse {
    loop {
        match rx.recv().expect("stream alive") {
            StreamEvent::Token(_) => {}
            StreamEvent::Done(resp) => return resp,
            StreamEvent::Error(e) => panic!("bench stream errored: {e}"),
        }
    }
}

fn run(name: &'static str, radix: bool, reqs: &[GenRequest]) -> RunStats {
    let mut engine = ContinuousEngine::new(backend()).expect("engine boots");
    if radix {
        engine = engine.with_radix_cache().expect("radix enables on the paged layout");
    }
    let t0 = Instant::now();
    let rxs: Vec<_> = reqs.iter().map(|r| engine.submit_stream(r.clone())).collect();
    engine.run_to_idle().expect("engine drains");
    let wall_s = t0.elapsed().as_secs_f64();
    let responses: Vec<GenResponse> = rxs.iter().map(drain).collect();
    let m = engine.metrics();
    RunStats {
        name,
        peak_slots: engine.stats.peak_active_slots,
        mean_ttft_ms: m.mean_ttft() * 1e3,
        wall_s,
        prefill_tokens: m.prefill_tokens,
        hit_tokens: m.radix_hit_tokens,
        cow_splits: m.radix_cow_splits,
        evicted_pages: m.radix_evicted_pages,
        deferred: m.deferred_admissions,
        responses,
    }
}

fn main() {
    let args = Args::from_env();
    let smoke = smoke_mode();
    let n_requests = args.usize_or("requests", if smoke { 32 } else { 96 }).expect("--requests");
    let reqs = workload(n_requests, 0x5EED_CAFE);

    println!(
        "radix cache bench{}: {n_requests} requests, {B_EXEC} slots over a {POOL_PAGES}-page \
         pool, 75% sharing a {SHARED_PREFIX}-token prefix",
        if smoke { " [smoke]" } else { "" }
    );

    // token-identity oracle: the same workload on a fresh dense-capacity
    // backend via the run-to-completion baseline scheduler
    let reference =
        run_to_completion(&SimBackend::new(B_EXEC, S_EXEC, N_PREFIX, CACHE_MAX), &reqs)
            .expect("reference run");

    let base = run("paged baseline", false, &reqs);
    let rdx = run("radix cache", true, &reqs);

    for r in [&base, &rdx] {
        assert_eq!(r.responses.len(), reference.len(), "{}: every stream finished", r.name);
        for (resp, oracle) in r.responses.iter().zip(&reference) {
            assert_eq!(resp.id, oracle.id, "{}: response order", r.name);
            assert_eq!(resp.finish, FinishReason::Length, "{}: seq {}", r.name, resp.id);
            assert_eq!(
                resp.tokens, oracle.tokens,
                "{}: seq {} must be token-identical to the dense reference",
                r.name, resp.id
            );
        }
    }
    assert!(rdx.hit_tokens > 0, "the shared prefix must actually hit the radix cache");

    let mut t = Table::new(
        "paged baseline vs radix prefix cache (shared-prefix saturation)",
        &[
            "engine",
            "peak slots",
            "mean ttft ms",
            "wall s",
            "prefill tok",
            "hit tok",
            "cow",
            "evicted",
            "deferred",
        ],
    );
    for r in [&base, &rdx] {
        t.rowv(vec![
            r.name.to_string(),
            r.peak_slots.to_string(),
            ff(r.mean_ttft_ms),
            ff(r.wall_s),
            r.prefill_tokens.to_string(),
            r.hit_tokens.to_string(),
            r.cow_splits.to_string(),
            r.evicted_pages.to_string(),
            r.deferred.to_string(),
        ]);
    }
    t.print();

    let conc_ratio = rdx.peak_slots as f64 / base.peak_slots.max(1) as f64;
    let ttft_ratio = base.mean_ttft_ms / rdx.mean_ttft_ms.max(1e-9);
    emit_bench_json(
        "radix_cache",
        &[
            ("requests", n_requests as f64),
            ("pool_pages", POOL_PAGES as f64),
            ("base_peak_slots", base.peak_slots as f64),
            ("radix_peak_slots", rdx.peak_slots as f64),
            ("concurrency_ratio", conc_ratio),
            ("base_mean_ttft_ms", base.mean_ttft_ms),
            ("radix_mean_ttft_ms", rdx.mean_ttft_ms),
            ("ttft_ratio", ttft_ratio),
            ("base_prefill_tokens", base.prefill_tokens as f64),
            ("radix_prefill_tokens", rdx.prefill_tokens as f64),
            ("radix_hit_tokens", rdx.hit_tokens as f64),
            ("radix_cow_splits", rdx.cow_splits as f64),
            ("radix_evicted_pages", rdx.evicted_pages as f64),
            ("base_wall_s", base.wall_s),
            ("radix_wall_s", rdx.wall_s),
            ("smoke", if smoke { 1.0 } else { 0.0 }),
        ],
    );

    // headline win: the radix cache turns shared prefixes into admitted
    // concurrency (or, equivalently at saturation, into TTFT)
    assert!(
        conc_ratio >= 2.0 || ttft_ratio >= 2.0,
        "radix cache must double admitted concurrency ({} vs {} peak slots, {conc_ratio:.2}x) \
         or halve mean TTFT ({:.2} vs {:.2} ms, {ttft_ratio:.2}x)",
        rdx.peak_slots,
        base.peak_slots,
        rdx.mean_ttft_ms,
        base.mean_ttft_ms
    );
    println!(
        "headline: {:.2}x peak concurrency ({} vs {} slots), {:.2}x mean TTFT ({:.2} vs {:.2} \
         ms), {} prefill tokens skipped",
        conc_ratio,
        rdx.peak_slots,
        base.peak_slots,
        ttft_ratio,
        rdx.mean_ttft_ms,
        base.mean_ttft_ms,
        base.prefill_tokens.saturating_sub(rdx.prefill_tokens)
    );
}
