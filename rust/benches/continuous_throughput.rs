//! Continuous batching vs run-to-completion on a mixed workload, plus the
//! paged-KV capacity study.
//!
//! Part 1 — scheduling: both engines run over the deterministic SimBackend
//! with per-CALL busy-wait costs that model the fixed-geometry executable
//! economics: a prefill or decode execution costs the same wall time however
//! many rows are real, so a scheduling policy wins by wasting fewer calls
//! and freeing slots sooner.  The workload is a burst of requests with mixed
//! prompt lengths AND mixed generation budgets — the regime where
//! run-to-completion loses slots to uniform-length bucketing and holds short
//! requests hostage to the longest `max_new` in their batch.
//!
//! Part 2 — paging: a long-tail burst (mostly short sequences, a few long)
//! served at FIXED KV memory.  The dense cache pins worst-case rows, so its
//! slot count is memory-bound; the paged cache admits by actual page demand,
//! so the same bytes serve far more concurrent sequences — and at EQUAL
//! concurrency, a working-set-sized pool serves the same streams in half the
//! resident bytes.
//!
//!   cargo bench --bench continuous_throughput            # full run
//!   cargo bench --bench continuous_throughput -- --smoke # CI perf trail
//!
//! Emits `BENCH_continuous_throughput.json`.  No artifacts required.

use std::time::{Duration, Instant};

use prefixquant::bench_support::{emit_bench_json, smoke_mode};
use prefixquant::coordinator::continuous::{run_to_completion, ContinuousEngine, SimBackend};
use prefixquant::coordinator::{Batcher, GenRequest, KvLayout, StreamEvent};
use prefixquant::util::rng::SplitMix64;
use prefixquant::util::table::Table;

const B_EXEC: usize = 4;
const S_EXEC: usize = 48;
const N_PREFIX: usize = 3;
const CACHE_MAX: usize = 96;
/// simulated cost of one prefill execution (B×S forward)
const PREFILL_COST: Duration = Duration::from_micros(4000);
/// simulated cost of one decode execution (B×1 step)
const DECODE_COST: Duration = Duration::from_micros(1500);

fn backend(n_requests: usize) -> SimBackend {
    // smoke runs shrink the workload; keep call costs only for full runs so
    // CI measures scheduling structure, not spin loops
    let (p, d) = if n_requests < 32 {
        (Duration::ZERO, Duration::ZERO)
    } else {
        (PREFILL_COST, DECODE_COST)
    };
    SimBackend::new(B_EXEC, S_EXEC, N_PREFIX, CACHE_MAX).with_costs(p, d)
}

/// Burst workload: prompt lengths alternate between two buckets, budgets
/// cycle through [24, 2, 6, 2] (mean 8.5 — mostly short requests sharing
/// batches with occasional long ones).
fn workload(n: usize) -> Vec<GenRequest> {
    let mut rng = SplitMix64::new(0xBEBC4);
    let budgets = [24usize, 2, 6, 2];
    (0..n)
        .map(|i| {
            let plen = if i % 2 == 0 { 8 } else { 12 };
            GenRequest::new(
                i as u64,
                (0..plen).map(|_| 3 + rng.below(260) as i32).collect(),
                budgets[i % budgets.len()],
            )
        })
        .collect()
}

struct RunStats {
    wall_s: f64,
    generated: usize,
    ttfts_s: Vec<f64>,
    dispatches: String,
}

fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * p).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Baseline: dynamic batcher (uniform-length buckets) + run-to-completion,
/// batches dispatched strictly one after another.
fn run_baseline(reqs: &[GenRequest]) -> RunStats {
    let be = backend(reqs.len());
    let mut batcher = Batcher::new(B_EXEC);
    let t0 = Instant::now();
    for r in reqs {
        batcher.push(r.clone());
    }
    let mut ttfts = Vec::new();
    let mut generated = 0usize;
    let mut batches = 0usize;
    while !batcher.is_empty() {
        let batch = batcher.next_batch();
        let wave: Vec<GenRequest> = batch.iter().map(|p| p.req.clone()).collect();
        let dispatched = t0.elapsed().as_secs_f64();
        for r in run_to_completion(&be, &wave).expect("baseline run") {
            ttfts.push(dispatched + r.ttft_s); // all requests arrived at t0
            generated += r.tokens.len();
        }
        batches += 1;
    }
    RunStats {
        wall_s: t0.elapsed().as_secs_f64(),
        generated,
        ttfts_s: ttfts,
        dispatches: format!("{batches} batches"),
    }
}

/// Continuous engine: everything submitted at t0, slots admit as they free.
fn run_continuous(reqs: &[GenRequest]) -> RunStats {
    let mut engine = ContinuousEngine::new(backend(reqs.len())).expect("engine");
    let t0 = Instant::now();
    let streams: Vec<_> = reqs.iter().map(|r| engine.submit_stream(r.clone())).collect();
    engine.run_to_idle().expect("continuous run");
    let wall_s = t0.elapsed().as_secs_f64();
    let mut ttfts = Vec::new();
    let mut generated = 0usize;
    for rx in streams {
        while let Ok(ev) = rx.try_recv() {
            if let StreamEvent::Done(r) = ev {
                ttfts.push(r.ttft_s);
                generated += r.tokens.len();
                break;
            }
        }
    }
    let s = &engine.stats;
    RunStats {
        wall_s,
        generated,
        ttfts_s: ttfts,
        dispatches: format!(
            "{} prefill waves, {} decode calls over {} rounds, {} mid-decode admissions",
            s.prefill_calls, s.decode_calls, s.decode_rounds, s.mid_decode_admissions
        ),
    }
}

// ---------------------------------------------------------------------------
// Part 2: paged-KV capacity study on a long-tail burst
// ---------------------------------------------------------------------------

/// geometry of the capacity study (page_size divides CACHE_MAX)
const LT_PAGE: usize = 8;
/// slots a dense cache of the reference memory budget can hold
const LT_B_DENSE: usize = 4;
/// slots offered to the paged engine over the SAME memory budget
const LT_B_PAGED: usize = 16;
/// pages equal in bytes to the dense reference (LT_B_DENSE full rows)
const LT_POOL_EQUAL_MEM: usize = LT_B_DENSE * CACHE_MAX / LT_PAGE;
/// working-set-sized pool for the equal-concurrency comparison
const LT_POOL_SMALL: usize = LT_B_DENSE * CACHE_MAX / LT_PAGE / 2;

/// Long-tail burst: ~87% short requests (4-8 prompt, 2-6 new), ~13% long
/// (24-32 prompt, 24-32 new).  Mean sequence ≪ CACHE_MAX, which is exactly
/// when dense worst-case rows waste memory.
fn longtail_workload(n: usize) -> Vec<GenRequest> {
    let mut rng = SplitMix64::new(0x17A11);
    (0..n)
        .map(|i| {
            let long = i % 8 == 5;
            let plen = if long { 24 + (i % 3) * 4 } else { 4 + i % 5 };
            let max_new = if long { 24 + (i % 2) * 8 } else { 2 + i % 5 };
            GenRequest::new(
                i as u64,
                (0..plen).map(|_| 3 + rng.below(260) as i32).collect(),
                max_new,
            )
        })
        .collect()
}

struct LongtailStats {
    wall_s: f64,
    peak_slots: usize,
    resident_bytes: usize,
    deferred: usize,
    tokens: Vec<(u64, Vec<i32>)>,
}

fn run_longtail(b_exec: usize, layout: KvLayout, reqs: &[GenRequest]) -> LongtailStats {
    let be = SimBackend::new(b_exec, S_EXEC, N_PREFIX, CACHE_MAX).with_kv_layout(layout);
    let mut engine = ContinuousEngine::new(be).expect("engine");
    let t0 = Instant::now();
    let streams: Vec<_> = reqs.iter().map(|r| (r.id, engine.submit_stream(r.clone()))).collect();
    engine.run_to_idle().expect("longtail run");
    let wall_s = t0.elapsed().as_secs_f64();
    let mut tokens = Vec::new();
    for (id, rx) in streams {
        let mut toks = Vec::new();
        while let Ok(ev) = rx.try_recv() {
            match ev {
                StreamEvent::Token(t) => toks.push(t),
                StreamEvent::Done(_) => break,
                StreamEvent::Error(e) => panic!("longtail request {id} failed: {e}"),
            }
        }
        tokens.push((id, toks));
    }
    LongtailStats {
        wall_s,
        peak_slots: engine.stats.peak_active_slots,
        resident_bytes: engine.kv().resident_kv_bytes(),
        deferred: engine.stats.deferred_admissions,
        tokens,
    }
}

fn main() {
    let smoke = smoke_mode();
    let n_requests = if smoke { 16 } else { 32 };
    let reqs = workload(n_requests);
    let total_budget: usize = reqs.iter().map(|r| r.max_new).sum();
    println!(
        "workload: {} requests, prompt lens 8/12, budgets 24/2/6/2 ({} tokens total); \
         {} slots{}",
        reqs.len(),
        total_budget,
        B_EXEC,
        if smoke { " [smoke]" } else { "" }
    );

    // warm both paths once (page in code, stabilize the spin calibration)
    let _ = run_baseline(&reqs);
    let _ = run_continuous(&reqs);

    let base = run_baseline(&reqs);
    let cont = run_continuous(&reqs);

    let mut t = Table::new(
        "continuous batching vs run-to-completion (mixed lengths + budgets)",
        &["engine", "wall s", "tokens", "agg tok/s", "mean TTFT ms", "p90 TTFT ms"],
    );
    let mut ttft_means = Vec::new();
    for (name, st) in [("run-to-completion", &base), ("continuous", &cont)] {
        let mut sorted = st.ttfts_s.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = sorted.iter().sum::<f64>() / sorted.len().max(1) as f64;
        ttft_means.push(mean);
        t.rowv(vec![
            name.into(),
            format!("{:.3}", st.wall_s),
            st.generated.to_string(),
            format!("{:.0}", st.generated as f64 / st.wall_s),
            format!("{:.1}", mean * 1e3),
            format!("{:.1}", percentile(&sorted, 0.9) * 1e3),
        ]);
    }
    t.print();
    println!("baseline:   {}", base.dispatches);
    println!("continuous: {}", cont.dispatches);

    let tok_gain = (cont.generated as f64 / cont.wall_s) / (base.generated as f64 / base.wall_s);
    println!(
        "\ncontinuous vs baseline: {:.2}x aggregate decode throughput, {:.2}x mean TTFT",
        tok_gain,
        ttft_means[0] / ttft_means[1].max(1e-9)
    );
    assert_eq!(base.generated, cont.generated, "both engines must serve the full workload");

    // ---- part 2: long-tail capacity at fixed KV memory ---------------------
    let lt = longtail_workload(if smoke { 24 } else { 64 });
    let dense = run_longtail(LT_B_DENSE, KvLayout::Dense, &lt);
    let paged = run_longtail(
        LT_B_PAGED,
        KvLayout::Paged { page_size: LT_PAGE, n_pages: LT_POOL_EQUAL_MEM },
        &lt,
    );
    // equal concurrency (dense slot count), working-set-sized pool
    let lean = run_longtail(
        LT_B_DENSE,
        KvLayout::Paged { page_size: LT_PAGE, n_pages: LT_POOL_SMALL },
        &lt,
    );

    // streams are layout- and admission-order-independent: all three runs
    // must serve identical tokens per request
    for other in [&paged, &lean] {
        for ((ida, a), (idb, b)) in dense.tokens.iter().zip(&other.tokens) {
            assert_eq!(ida, idb);
            assert_eq!(a, b, "request {ida} diverged across cache layouts");
        }
    }

    let mut t2 = Table::new(
        "paged vs dense on a long-tail burst",
        &["cache", "slots", "peak active", "resident KV MB", "wall s", "page waits"],
    );
    for (name, slots, st) in [
        ("dense (worst-case rows)", LT_B_DENSE, &dense),
        ("paged (= memory)", LT_B_PAGED, &paged),
        ("paged (= concurrency)", LT_B_DENSE, &lean),
    ] {
        t2.rowv(vec![
            name.into(),
            slots.to_string(),
            st.peak_slots.to_string(),
            format!("{:.2}", st.resident_bytes as f64 / 1e6),
            format!("{:.3}", st.wall_s),
            st.deferred.to_string(),
        ]);
    }
    t2.print();

    let capacity_ratio = paged.peak_slots as f64 / dense.peak_slots.max(1) as f64;
    // the lean pool run may lazily materialize the gather view; SimBackend
    // never does, so resident bytes are the pool itself
    let resident_ratio = lean.resident_bytes as f64 / dense.resident_bytes.max(1) as f64;
    println!(
        "\npaged vs dense at equal KV memory: {capacity_ratio:.2}x admission capacity; \
         at equal concurrency: {:.0}% of the resident bytes",
        resident_ratio * 100.0
    );
    assert!(
        capacity_ratio >= 1.5,
        "paged cache must admit ≥1.5x concurrent sequences at fixed KV memory \
         (got {capacity_ratio:.2}x)"
    );
    assert!(
        resident_ratio <= 0.6,
        "working-set pool must cut resident KV bytes at equal concurrency \
         (got {resident_ratio:.2})"
    );

    emit_bench_json(
        "continuous_throughput",
        &[
            ("wall_s_baseline", base.wall_s),
            ("wall_s_continuous", cont.wall_s),
            ("tok_s_baseline", base.generated as f64 / base.wall_s),
            ("tok_s_continuous", cont.generated as f64 / cont.wall_s),
            ("mean_ttft_ms_baseline", ttft_means[0] * 1e3),
            ("mean_ttft_ms_continuous", ttft_means[1] * 1e3),
            ("longtail_peak_slots_dense", dense.peak_slots as f64),
            ("longtail_peak_slots_paged", paged.peak_slots as f64),
            ("longtail_capacity_ratio", capacity_ratio),
            ("longtail_resident_mb_dense", dense.resident_bytes as f64 / 1e6),
            ("longtail_resident_mb_paged_lean", lean.resident_bytes as f64 / 1e6),
            ("longtail_resident_ratio", resident_ratio),
            ("longtail_page_waits", (paged.deferred + lean.deferred) as f64),
            ("smoke", if smoke { 1.0 } else { 0.0 }),
        ],
    );
}
