//! Continuous batching vs run-to-completion on a mixed workload.
//!
//! Both engines run over the deterministic SimBackend with per-CALL busy-wait
//! costs that model the fixed-geometry executable economics: a prefill or
//! decode execution costs the same wall time however many rows are real, so
//! a scheduling policy wins by wasting fewer calls and freeing slots sooner.
//! The workload is a burst of requests with mixed prompt lengths AND mixed
//! generation budgets — the regime where run-to-completion loses slots to
//! uniform-length bucketing and holds short requests hostage to the longest
//! `max_new` in their batch.
//!
//!   cargo bench --bench continuous_throughput
//!
//! No artifacts required.

use std::time::{Duration, Instant};

use prefixquant::coordinator::continuous::{run_to_completion, ContinuousEngine, SimBackend};
use prefixquant::coordinator::{Batcher, GenRequest, StreamEvent};
use prefixquant::util::rng::SplitMix64;
use prefixquant::util::table::Table;

const B_EXEC: usize = 4;
const S_EXEC: usize = 48;
const N_PREFIX: usize = 3;
const CACHE_MAX: usize = 96;
const N_REQUESTS: usize = 32;
/// simulated cost of one prefill execution (B×S forward)
const PREFILL_COST: Duration = Duration::from_micros(4000);
/// simulated cost of one decode execution (B×1 step)
const DECODE_COST: Duration = Duration::from_micros(1500);

fn backend() -> SimBackend {
    SimBackend::new(B_EXEC, S_EXEC, N_PREFIX, CACHE_MAX).with_costs(PREFILL_COST, DECODE_COST)
}

/// Burst workload: prompt lengths alternate between two buckets, budgets
/// cycle through [24, 2, 6, 2] (mean 8.5 — mostly short requests sharing
/// batches with occasional long ones).
fn workload() -> Vec<GenRequest> {
    let mut rng = SplitMix64::new(0xBEBC4);
    let budgets = [24usize, 2, 6, 2];
    (0..N_REQUESTS)
        .map(|i| {
            let plen = if i % 2 == 0 { 8 } else { 12 };
            GenRequest {
                id: i as u64,
                prompt: (0..plen).map(|_| 3 + rng.below(260) as i32).collect(),
                max_new: budgets[i % budgets.len()],
            }
        })
        .collect()
}

struct RunStats {
    wall_s: f64,
    generated: usize,
    ttfts_s: Vec<f64>,
    dispatches: String,
}

fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * p).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Baseline: dynamic batcher (uniform-length buckets) + run-to-completion,
/// batches dispatched strictly one after another.
fn run_baseline(reqs: &[GenRequest]) -> RunStats {
    let be = backend();
    let mut batcher = Batcher::new(B_EXEC);
    let t0 = Instant::now();
    for r in reqs {
        batcher.push(r.clone());
    }
    let mut ttfts = Vec::new();
    let mut generated = 0usize;
    let mut batches = 0usize;
    while !batcher.is_empty() {
        let batch = batcher.next_batch();
        let wave: Vec<GenRequest> = batch.iter().map(|p| p.req.clone()).collect();
        let dispatched = t0.elapsed().as_secs_f64();
        for r in run_to_completion(&be, &wave).expect("baseline run") {
            ttfts.push(dispatched + r.ttft_s); // all requests arrived at t0
            generated += r.tokens.len();
        }
        batches += 1;
    }
    RunStats {
        wall_s: t0.elapsed().as_secs_f64(),
        generated,
        ttfts_s: ttfts,
        dispatches: format!("{batches} batches"),
    }
}

/// Continuous engine: everything submitted at t0, slots admit as they free.
fn run_continuous(reqs: &[GenRequest]) -> RunStats {
    let mut engine = ContinuousEngine::new(backend()).expect("engine");
    let t0 = Instant::now();
    let streams: Vec<_> = reqs.iter().map(|r| engine.submit_stream(r.clone())).collect();
    engine.run_to_idle().expect("continuous run");
    let wall_s = t0.elapsed().as_secs_f64();
    let mut ttfts = Vec::new();
    let mut generated = 0usize;
    for rx in streams {
        while let Ok(ev) = rx.try_recv() {
            if let StreamEvent::Done(r) = ev {
                ttfts.push(r.ttft_s);
                generated += r.tokens.len();
                break;
            }
        }
    }
    let s = &engine.stats;
    RunStats {
        wall_s,
        generated,
        ttfts_s: ttfts,
        dispatches: format!(
            "{} prefill waves, {} decode calls over {} rounds, {} mid-decode admissions",
            s.prefill_calls, s.decode_calls, s.decode_rounds, s.mid_decode_admissions
        ),
    }
}

fn main() {
    let reqs = workload();
    let total_budget: usize = reqs.iter().map(|r| r.max_new).sum();
    println!(
        "workload: {} requests, prompt lens 8/12, budgets 24/2/6/2 ({} tokens total); \
         prefill {:?}/call, decode {:?}/call, {} slots",
        reqs.len(),
        total_budget,
        PREFILL_COST,
        DECODE_COST,
        B_EXEC
    );

    // warm both paths once (page in code, stabilize the spin calibration)
    let _ = run_baseline(&reqs);
    let _ = run_continuous(&reqs);

    let base = run_baseline(&reqs);
    let cont = run_continuous(&reqs);

    let mut t = Table::new(
        "continuous batching vs run-to-completion (mixed lengths + budgets)",
        &["engine", "wall s", "tokens", "agg tok/s", "mean TTFT ms", "p90 TTFT ms"],
    );
    for (name, st) in [("run-to-completion", &base), ("continuous", &cont)] {
        let mut sorted = st.ttfts_s.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = sorted.iter().sum::<f64>() / sorted.len().max(1) as f64;
        t.rowv(vec![
            name.into(),
            format!("{:.3}", st.wall_s),
            st.generated.to_string(),
            format!("{:.0}", st.generated as f64 / st.wall_s),
            format!("{:.1}", mean * 1e3),
            format!("{:.1}", percentile(&sorted, 0.9) * 1e3),
        ]);
    }
    t.print();
    println!("baseline:   {}", base.dispatches);
    println!("continuous: {}", cont.dispatches);

    let tok_gain = (cont.generated as f64 / cont.wall_s) / (base.generated as f64 / base.wall_s);
    let base_mean = base.ttfts_s.iter().sum::<f64>() / base.ttfts_s.len().max(1) as f64;
    let cont_mean = cont.ttfts_s.iter().sum::<f64>() / cont.ttfts_s.len().max(1) as f64;
    println!(
        "\ncontinuous vs baseline: {:.2}x aggregate decode throughput, {:.2}x mean TTFT",
        tok_gain,
        base_mean / cont_mean.max(1e-9)
    );
    assert_eq!(base.generated, cont.generated, "both engines must serve the full workload");
}
