//! Scheduling-policy bench: Fcfs vs PriorityPreempt on a saturated
//! mixed-priority burst over the deterministic SimBackend.
//!
//! A wave of long Batch requests saturates every slot, then short
//! Interactive requests arrive.  Under Fcfs they wait for whole batch
//! decode runs to drain; under PriorityPreempt they jump the queue and
//! preempt Decoding slots (whose requests resume later with their streams
//! intact — asserted by cross-policy stream equality, since greedy streams
//! depend only on each request's own prompt).  Per-call busy-wait costs
//! model the fixed-geometry executable economics, so TTFT differences are
//! real wall time.
//!
//!   cargo bench --bench scheduler_policy            # full run
//!   cargo bench --bench scheduler_policy -- --smoke # CI perf trail
//!
//! Emits `BENCH_scheduler_policy.json` and ASSERTS the headline win:
//! PriorityPreempt cuts saturated-load Interactive p50 TTFT ≥2x vs Fcfs.
//! No artifacts required.

use std::collections::HashMap;
use std::time::{Duration, Instant};

use prefixquant::bench_support::{emit_bench_json, smoke_mode};
use prefixquant::coordinator::continuous::{ContinuousEngine, SimBackend};
use prefixquant::coordinator::{
    Fcfs, GenRequest, Priority, PriorityPreempt, SchedulePolicy, StreamEvent,
};
use prefixquant::util::table::Table;

const B_EXEC: usize = 4;
const S_EXEC: usize = 48;
const N_PREFIX: usize = 3;
const CACHE_MAX: usize = 96;

fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * p).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

fn p50(mut xs: Vec<f64>) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile(&xs, 0.5)
}

struct RunStats {
    inter_ttfts_s: Vec<f64>,
    batch_ttfts_s: Vec<f64>,
    wall_s: f64,
    preemptions: usize,
    streams: HashMap<u64, Vec<i32>>,
}

fn batch_req(i: usize) -> GenRequest {
    GenRequest::builder(i as u64)
        .prompt(vec![5 + (i % 7) as i32; 10])
        .max_new(24)
        .priority(Priority::Batch)
        .build()
}

fn inter_req(i: usize) -> GenRequest {
    GenRequest::builder(1000 + i as u64)
        .prompt(vec![4 + (i % 5) as i32; 4])
        .max_new(2)
        .priority(Priority::Interactive)
        .build()
}

/// Saturate the slots with Batch work, then submit the Interactive burst.
fn run(
    policy: Box<dyn SchedulePolicy>,
    n_batch: usize,
    n_inter: usize,
    costs: (Duration, Duration),
) -> RunStats {
    let be = SimBackend::new(B_EXEC, S_EXEC, N_PREFIX, CACHE_MAX).with_costs(costs.0, costs.1);
    let mut engine = ContinuousEngine::new(be).expect("engine").with_policy(policy);
    let t0 = Instant::now();
    let mut rxs = Vec::new();
    for i in 0..n_batch {
        rxs.push((Priority::Batch, engine.submit_stream(batch_req(i))));
    }
    // let the batch load occupy every slot and start decoding
    engine.step().expect("warm step");
    engine.step().expect("warm step");
    for i in 0..n_inter {
        rxs.push((Priority::Interactive, engine.submit_stream(inter_req(i))));
    }
    engine.run_to_idle().expect("drain");
    let wall_s = t0.elapsed().as_secs_f64();

    let mut st = RunStats {
        inter_ttfts_s: Vec::new(),
        batch_ttfts_s: Vec::new(),
        wall_s,
        preemptions: engine.stats.preemptions,
        streams: HashMap::new(),
    };
    for (class, rx) in rxs {
        let mut tokens = Vec::new();
        while let Ok(ev) = rx.try_recv() {
            match ev {
                StreamEvent::Token(t) => tokens.push(t),
                StreamEvent::Done(r) => {
                    match class {
                        Priority::Interactive => st.inter_ttfts_s.push(r.ttft_s),
                        _ => st.batch_ttfts_s.push(r.ttft_s),
                    }
                    st.streams.insert(r.id, tokens);
                    break;
                }
                StreamEvent::Error(e) => panic!("bench request failed: {e}"),
            }
        }
    }
    st
}

fn main() {
    let smoke = smoke_mode();
    let (n_batch, n_inter) = if smoke { (6, 4) } else { (12, 8) };
    let costs = if smoke {
        (Duration::from_micros(400), Duration::from_micros(150))
    } else {
        (Duration::from_micros(2000), Duration::from_micros(600))
    };
    println!(
        "workload: {n_batch} batch (24 new) saturating {B_EXEC} slots, then {n_inter} \
         interactive (2 new){}",
        if smoke { " [smoke]" } else { "" }
    );

    // warm both paths (page in code, stabilize spin calibration)
    let _ = run(Box::new(Fcfs), n_batch.min(4), 2, costs);
    let _ = run(Box::new(PriorityPreempt::default()), n_batch.min(4), 2, costs);

    let fcfs = run(Box::new(Fcfs), n_batch, n_inter, costs);
    let pp = run(Box::new(PriorityPreempt::default()), n_batch, n_inter, costs);

    // greedy streams depend only on each request's own prompt: scheduling —
    // including preemption + resume — must be invisible in the tokens
    for (id, toks) in &fcfs.streams {
        assert_eq!(
            pp.streams.get(id),
            Some(toks),
            "request {id} diverged between policies (preemption corrupted a stream)"
        );
    }
    assert!(
        pp.preemptions > 0,
        "the interactive burst must preempt Decoding slots under PriorityPreempt"
    );

    let f_i50 = p50(fcfs.inter_ttfts_s.clone());
    let p_i50 = p50(pp.inter_ttfts_s.clone());
    let f_b50 = p50(fcfs.batch_ttfts_s.clone());
    let p_b50 = p50(pp.batch_ttfts_s.clone());
    let speedup = f_i50 / p_i50.max(1e-9);

    let mut t = Table::new(
        "scheduling policy under a saturated mixed-priority burst",
        &["policy", "inter p50 TTFT ms", "batch p50 TTFT ms", "wall s", "preemptions"],
    );
    for (name, i50, b50, st) in
        [("fcfs", f_i50, f_b50, &fcfs), ("priority-preempt", p_i50, p_b50, &pp)]
    {
        t.rowv(vec![
            name.into(),
            format!("{:.1}", i50 * 1e3),
            format!("{:.1}", b50 * 1e3),
            format!("{:.3}", st.wall_s),
            st.preemptions.to_string(),
        ]);
    }
    t.print();
    println!(
        "\npriority-preempt vs fcfs: {speedup:.2}x lower interactive p50 TTFT \
         ({:.1}ms → {:.1}ms), batch p50 {:.1}ms → {:.1}ms",
        f_i50 * 1e3,
        p_i50 * 1e3,
        f_b50 * 1e3,
        p_b50 * 1e3
    );
    assert!(
        speedup >= 2.0,
        "PriorityPreempt must cut saturated-load Interactive p50 TTFT ≥2x vs Fcfs \
         (got {speedup:.2}x)"
    );

    emit_bench_json(
        "scheduler_policy",
        &[
            ("inter_p50_ttft_ms_fcfs", f_i50 * 1e3),
            ("inter_p50_ttft_ms_priority", p_i50 * 1e3),
            ("batch_p50_ttft_ms_fcfs", f_b50 * 1e3),
            ("batch_p50_ttft_ms_priority", p_b50 * 1e3),
            ("inter_p50_speedup", speedup),
            ("preemptions", pp.preemptions as f64),
            ("wall_s_fcfs", fcfs.wall_s),
            ("wall_s_priority", pp.wall_s),
            ("smoke", if smoke { 1.0 } else { 0.0 }),
        ],
    );
}
