//! Scheduling-policy bench: Fcfs vs PriorityPreempt on a saturated
//! mixed-priority burst over the deterministic SimBackend.
//!
//! A wave of long Batch requests saturates every slot, then short
//! Interactive requests arrive.  Under Fcfs they wait for whole batch
//! decode runs to drain; under PriorityPreempt they jump the queue and
//! preempt Decoding slots (whose requests resume later with their streams
//! intact — asserted by cross-policy stream equality, since greedy streams
//! depend only on each request's own prompt).  Per-call busy-wait costs
//! model the fixed-geometry executable economics, so TTFT differences are
//! real wall time.
//!
//! Both waves come from the seeded workload generator
//! ([`Workload::single`] over [`Scenario::batch_fill`] /
//! [`Scenario::interactive_burst`]), so the request population is shared
//! byte-for-byte across the two policy runs by construction.
//!
//!   cargo bench --bench scheduler_policy            # full run
//!   cargo bench --bench scheduler_policy -- --smoke # CI perf trail
//!
//! Emits `BENCH_scheduler_policy.json` and ASSERTS the headline win:
//! PriorityPreempt cuts saturated-load Interactive p50 TTFT ≥2x vs Fcfs.
//! No artifacts required.

use std::collections::HashMap;
use std::time::{Duration, Instant};

use prefixquant::bench_support::{emit_bench_json, smoke_mode};
use prefixquant::coordinator::continuous::{ContinuousEngine, SimBackend};
use prefixquant::coordinator::{
    Fcfs, GenRequest, Priority, PriorityPreempt, SchedulePolicy, StreamEvent,
};
use prefixquant::util::table::Table;
use prefixquant::workload::{Scenario, Workload};

const B_EXEC: usize = 4;
const S_EXEC: usize = 48;
const N_PREFIX: usize = 3;
const CACHE_MAX: usize = 96;

fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * p).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

fn p50(mut xs: Vec<f64>) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile(&xs, 0.5)
}

struct RunStats {
    inter_ttfts_s: Vec<f64>,
    batch_ttfts_s: Vec<f64>,
    wall_s: f64,
    preemptions: usize,
    streams: HashMap<u64, Vec<i32>>,
}

/// Seeded request waves from the workload generator: a saturating Batch
/// fill and a short Interactive burst.  Interactive ids are offset so the
/// two waves never collide in the stream map.
fn waves(n_batch: usize, n_inter: usize) -> (Vec<GenRequest>, Vec<GenRequest>) {
    let batch: Vec<GenRequest> = Workload::single("batch-fill", Scenario::batch_fill(), 0xBEEF)
        .with_requests(n_batch)
        .generate()
        .events
        .into_iter()
        .map(|e| e.req)
        .collect();
    let inter: Vec<GenRequest> =
        Workload::single("interactive-burst", Scenario::interactive_burst(), 0xCAFE)
            .with_requests(n_inter)
            .generate()
            .events
            .into_iter()
            .map(|e| {
                let mut r = e.req;
                r.id += 1000;
                r
            })
            .collect();
    (batch, inter)
}

/// Saturate the slots with Batch work, then submit the Interactive burst.
fn run(
    policy: Box<dyn SchedulePolicy>,
    n_batch: usize,
    n_inter: usize,
    costs: (Duration, Duration),
) -> RunStats {
    let (batch, inter) = waves(n_batch, n_inter);
    let be = SimBackend::new(B_EXEC, S_EXEC, N_PREFIX, CACHE_MAX).with_costs(costs.0, costs.1);
    let mut engine = ContinuousEngine::new(be).expect("engine").with_policy(policy);
    let t0 = Instant::now();
    let mut rxs = Vec::new();
    for req in batch {
        rxs.push((Priority::Batch, engine.submit_stream(req)));
    }
    // let the batch load occupy every slot and start decoding
    engine.step().expect("warm step");
    engine.step().expect("warm step");
    for req in inter {
        rxs.push((Priority::Interactive, engine.submit_stream(req)));
    }
    engine.run_to_idle().expect("drain");
    let wall_s = t0.elapsed().as_secs_f64();

    let mut st = RunStats {
        inter_ttfts_s: Vec::new(),
        batch_ttfts_s: Vec::new(),
        wall_s,
        preemptions: engine.stats.preemptions,
        streams: HashMap::new(),
    };
    for (class, rx) in rxs {
        let mut tokens = Vec::new();
        while let Ok(ev) = rx.try_recv() {
            match ev {
                StreamEvent::Token(t) => tokens.push(t),
                StreamEvent::Done(r) => {
                    match class {
                        Priority::Interactive => st.inter_ttfts_s.push(r.ttft_s),
                        _ => st.batch_ttfts_s.push(r.ttft_s),
                    }
                    st.streams.insert(r.id, tokens);
                    break;
                }
                StreamEvent::Error(e) => panic!("bench request failed: {e}"),
            }
        }
    }
    st
}

fn main() {
    let smoke = smoke_mode();
    let (n_batch, n_inter) = if smoke { (6, 4) } else { (12, 8) };
    let costs = if smoke {
        (Duration::from_micros(400), Duration::from_micros(150))
    } else {
        (Duration::from_micros(2000), Duration::from_micros(600))
    };
    println!(
        "workload: {n_batch} generated batch-fill (20-24 new) saturating {B_EXEC} slots, \
         then {n_inter} generated interactive (2 new){}",
        if smoke { " [smoke]" } else { "" }
    );

    // warm both paths (page in code, stabilize spin calibration)
    let _ = run(Box::new(Fcfs), n_batch.min(4), 2, costs);
    let _ = run(Box::new(PriorityPreempt::default()), n_batch.min(4), 2, costs);

    let fcfs = run(Box::new(Fcfs), n_batch, n_inter, costs);
    let pp = run(Box::new(PriorityPreempt::default()), n_batch, n_inter, costs);

    // greedy streams depend only on each request's own prompt: scheduling —
    // including preemption + resume — must be invisible in the tokens
    for (id, toks) in &fcfs.streams {
        assert_eq!(
            pp.streams.get(id),
            Some(toks),
            "request {id} diverged between policies (preemption corrupted a stream)"
        );
    }
    assert!(
        pp.preemptions > 0,
        "the interactive burst must preempt Decoding slots under PriorityPreempt"
    );

    let f_i50 = p50(fcfs.inter_ttfts_s.clone());
    let p_i50 = p50(pp.inter_ttfts_s.clone());
    let f_b50 = p50(fcfs.batch_ttfts_s.clone());
    let p_b50 = p50(pp.batch_ttfts_s.clone());
    let speedup = f_i50 / p_i50.max(1e-9);

    let mut t = Table::new(
        "scheduling policy under a saturated mixed-priority burst",
        &["policy", "inter p50 TTFT ms", "batch p50 TTFT ms", "wall s", "preemptions"],
    );
    for (name, i50, b50, st) in
        [("fcfs", f_i50, f_b50, &fcfs), ("priority-preempt", p_i50, p_b50, &pp)]
    {
        t.rowv(vec![
            name.into(),
            format!("{:.1}", i50 * 1e3),
            format!("{:.1}", b50 * 1e3),
            format!("{:.3}", st.wall_s),
            st.preemptions.to_string(),
        ]);
    }
    t.print();
    println!(
        "\npriority-preempt vs fcfs: {speedup:.2}x lower interactive p50 TTFT \
         ({:.1}ms → {:.1}ms), batch p50 {:.1}ms → {:.1}ms",
        f_i50 * 1e3,
        p_i50 * 1e3,
        f_b50 * 1e3,
        p_b50 * 1e3
    );
    assert!(
        speedup >= 2.0,
        "PriorityPreempt must cut saturated-load Interactive p50 TTFT ≥2x vs Fcfs \
         (got {speedup:.2}x)"
    );

    emit_bench_json(
        "scheduler_policy",
        &[
            ("inter_p50_ttft_ms_fcfs", f_i50 * 1e3),
            ("inter_p50_ttft_ms_priority", p_i50 * 1e3),
            ("batch_p50_ttft_ms_fcfs", f_b50 * 1e3),
            ("batch_p50_ttft_ms_priority", p_b50 * 1e3),
            ("inter_p50_speedup", speedup),
            ("preemptions", pp.preemptions as f64),
            ("wall_s_fcfs", fcfs.wall_s),
            ("wall_s_priority", pp.wall_s),
            ("smoke", if smoke { 1.0 } else { 0.0 }),
        ],
    );
}
