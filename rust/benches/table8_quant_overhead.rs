//! Table 8: quantization-op overhead, per-tensor STATIC vs per-token DYNAMIC.
//!
//! The paper measures the standalone quantize kernels on GPU and reports a
//! ~3x static advantage; here the same two operators (exported at the
//! paper's shapes, C=4096) run on the CPU PJRT backend.  The *mechanism* is
//! identical: dynamic needs a per-row abs-max reduction before scaling.
//!
//!   cargo bench --bench table8_quant_overhead

use std::path::Path;

use anyhow::Result;
use prefixquant::bench_support::{auto_samples, bench_fn};
use prefixquant::runtime::{Engine, Value};
use prefixquant::tensor::Tensor;
use prefixquant::util::rng::SplitMix64;
use prefixquant::util::table::Table;

fn main() -> Result<()> {
    let engine = Engine::new(Path::new(
        &std::env::var("PQ_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    ))?;
    let mut rng = SplitMix64::new(7);
    let shapes = [(1usize, 4096usize), (16, 4096), (256, 4096), (2048, 4096)];
    let mut table = Table::new(
        "Table 8: quantization overhead — static vs dynamic (median ms)",
        &["(T, C)", "per-token dynamic", "per-tensor static", "speedup"],
    );
    for (t, c) in shapes {
        let x = Tensor::new(
            vec![t, c],
            (0..t * c).map(|_| rng.normal_f32()).collect(),
        )?;
        let s = Tensor::scalar(0.05);
        let qm = Tensor::scalar(7.0);
        let stat_sig = engine.manifest.kernel(&format!("quant_static_jnp_{t}x{c}"))?.clone();
        let dyn_sig = engine.manifest.kernel(&format!("quant_dynamic_jnp_{t}x{c}"))?.clone();
        // warm the compile cache
        engine.run(&stat_sig, &[Value::F32(&x), Value::F32(&s), Value::F32(&qm)])?;
        engine.run(&dyn_sig, &[Value::F32(&x), Value::F32(&qm)])?;
        let probe = std::time::Instant::now();
        engine.run(&stat_sig, &[Value::F32(&x), Value::F32(&s), Value::F32(&qm)])?;
        let n = auto_samples(probe.elapsed().as_secs_f64(), 1.5, 10, 200);
        let st = bench_fn("static", 3, n, || {
            engine
                .run(&stat_sig, &[Value::F32(&x), Value::F32(&s), Value::F32(&qm)])
                .unwrap();
        });
        let dy = bench_fn("dynamic", 3, n, || {
            engine.run(&dyn_sig, &[Value::F32(&x), Value::F32(&qm)]).unwrap();
        });
        table.rowv(vec![
            format!("({t}, {c})"),
            format!("{:.4}", dy.per_call_ms()),
            format!("{:.4}", st.per_call_ms()),
            format!("{:.2}x", dy.median_s / st.median_s),
        ]);
    }
    table.print();
    println!("(paper: 3.31x on RTX3090, 2.82x on A100 — same direction expected)");
    Ok(())
}
