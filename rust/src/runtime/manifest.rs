//! Typed view over artifacts/manifest.json.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};

use crate::config::{CorpusSpec, ModelConfig, TokenizerSpec};
use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "float32" => Ok(DType::F32),
            "int32" => Ok(DType::I32),
            other => Err(anyhow!("unsupported dtype {other}")),
        }
    }
}

#[derive(Debug, Clone)]
pub struct TensorSig {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
}

#[derive(Debug, Clone)]
pub struct ExecSig {
    pub file: String,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<String>,
    pub batch: usize,
    pub seq: usize,
}

impl ExecSig {
    pub fn input_index(&self, name: &str) -> Result<usize> {
        self.inputs
            .iter()
            .position(|t| t.name == name)
            .ok_or_else(|| anyhow!("executable {} has no input {name:?}", self.file))
    }

    pub fn output_index(&self, name: &str) -> Result<usize> {
        self.outputs
            .iter()
            .position(|t| t == name)
            .ok_or_else(|| anyhow!("executable {} has no output {name:?}", self.file))
    }
}

#[derive(Debug, Clone)]
pub struct ModelManifest {
    pub config: ModelConfig,
    pub weights_file: String,
    pub weight_names: Vec<String>,
    pub pretrain_final_loss: Option<f64>,
    pub executables: BTreeMap<String, ExecSig>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub tokenizer: TokenizerSpec,
    pub corpus: CorpusSpec,
    pub models: BTreeMap<String, ModelManifest>,
    pub kernels: BTreeMap<String, ExecSig>,
}

fn parse_sig(j: &Json) -> Result<ExecSig> {
    let inputs = j
        .get("inputs")?
        .as_arr()?
        .iter()
        .map(|t| {
            Ok(TensorSig {
                name: t.get("name")?.as_str()?.to_string(),
                dtype: DType::parse(t.get("dtype")?.as_str()?)?,
                shape: t
                    .get("shape")?
                    .as_arr()?
                    .iter()
                    .map(|d| d.as_usize())
                    .collect::<Result<_>>()?,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    let outputs = j
        .get("outputs")?
        .as_arr()?
        .iter()
        .map(|o| Ok(o.as_str()?.to_string()))
        .collect::<Result<Vec<_>>>()?;
    let (batch, seq) = match j.opt("geom") {
        Some(g) => (g.get("batch")?.as_usize()?, g.get("seq")?.as_usize()?),
        None => (0, 0),
    };
    Ok(ExecSig { file: j.get("file")?.as_str()?.to_string(), inputs, outputs, batch, seq })
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .map_err(|e| anyhow!("reading manifest in {dir:?}: {e} (run `make artifacts`)"))?;
        let j = Json::parse(&text)?;
        let mut models = BTreeMap::new();
        for (name, mj) in j.get("models")?.as_obj()? {
            let execs = mj
                .get("executables")?
                .as_obj()?
                .iter()
                .map(|(k, v)| Ok((k.clone(), parse_sig(v)?)))
                .collect::<Result<BTreeMap<_, _>>>()?;
            models.insert(
                name.clone(),
                ModelManifest {
                    config: ModelConfig::from_json(mj.get("config")?)?,
                    weights_file: mj.get("weights_file")?.as_str()?.to_string(),
                    weight_names: mj
                        .get("weight_names")?
                        .as_arr()?
                        .iter()
                        .map(|s| Ok(s.as_str()?.to_string()))
                        .collect::<Result<_>>()?,
                    pretrain_final_loss: mj
                        .opt("pretrain")
                        .and_then(|p| p.opt("final_loss"))
                        .and_then(|v| v.as_f64().ok()),
                    executables: execs,
                },
            );
        }
        let kernels = j
            .get("kernels")?
            .as_obj()?
            .iter()
            .map(|(k, v)| Ok((k.clone(), parse_sig(v)?)))
            .collect::<Result<BTreeMap<_, _>>>()?;
        Ok(Manifest {
            dir: dir.to_path_buf(),
            tokenizer: TokenizerSpec::from_json(j.get("tokenizer")?)?,
            corpus: CorpusSpec::from_json(j.get("corpus")?)?,
            models,
            kernels,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelManifest> {
        self.models.get(name).ok_or_else(|| anyhow!("model {name:?} not in manifest"))
    }

    pub fn kernel(&self, name: &str) -> Result<&ExecSig> {
        self.kernels.get(name).ok_or_else(|| anyhow!("kernel {name:?} not in manifest"))
    }
}
