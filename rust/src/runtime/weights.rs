//! weights.bin reader/writer — bit-exact twin of python/compile/artifact_io.py.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Result};

use crate::tensor::Tensor;

const MAGIC: &[u8; 4] = b"PQTW";
const VERSION: u32 = 1;

/// Named f32 tensors in file order plus a name index.
#[derive(Debug, Clone)]
pub struct WeightStore {
    pub names: Vec<String>,
    pub tensors: Vec<Tensor>,
    index: BTreeMap<String, usize>,
}

impl WeightStore {
    pub fn from_pairs(pairs: Vec<(String, Tensor)>) -> Self {
        let mut names = Vec::new();
        let mut tensors = Vec::new();
        let mut index = BTreeMap::new();
        for (n, t) in pairs {
            index.insert(n.clone(), names.len());
            names.push(n);
            tensors.push(t);
        }
        Self { names, tensors, index }
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.index.get(name).map(|&i| &self.tensors[i])
    }

    pub fn get_mut(&mut self, name: &str) -> Option<&mut Tensor> {
        self.index.get(name).map(|&i| &mut self.tensors[i])
    }

    pub fn set(&mut self, name: &str, t: Tensor) {
        match self.index.get(name) {
            Some(&i) => self.tensors[i] = t,
            None => {
                self.index.insert(name.to_string(), self.names.len());
                self.names.push(name.to_string());
                self.tensors.push(t);
            }
        }
    }

    /// Tensors in the canonical order recorded by the manifest.
    pub fn ordered<'a>(&'a self, order: &[String]) -> Result<Vec<&'a Tensor>> {
        order
            .iter()
            .map(|n| {
                self.get(n).ok_or_else(|| anyhow::anyhow!("weight {n:?} missing from store"))
            })
            .collect()
    }

    pub fn load(path: &Path) -> Result<WeightStore> {
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        Self::read_from(&mut f, path)
    }

    /// Parse the weights.bin format from an in-memory buffer (one disk read
    /// shared between integrity hashing and parsing — see
    /// `quant::model_state`).  `origin` labels errors.
    pub fn from_bytes(bytes: &[u8], origin: &Path) -> Result<WeightStore> {
        let mut cursor = bytes;
        Self::read_from(&mut cursor, origin)
    }

    fn read_from(f: &mut impl Read, path: &Path) -> Result<WeightStore> {
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{path:?}: bad magic");
        }
        let version = read_u32(&mut *f)?;
        if version != VERSION {
            bail!("{path:?}: unsupported version {version}");
        }
        let count = read_u32(&mut *f)? as usize;
        let mut pairs = Vec::with_capacity(count);
        for _ in 0..count {
            let nlen = read_u16(&mut *f)? as usize;
            let mut nb = vec![0u8; nlen];
            f.read_exact(&mut nb)?;
            let name = String::from_utf8(nb)?;
            let mut hdr = [0u8; 2];
            f.read_exact(&mut hdr)?;
            let (dtype, ndim) = (hdr[0], hdr[1] as usize);
            if dtype != 0 {
                bail!("{path:?}: tensor {name}: only f32 weights supported, got dtype {dtype}");
            }
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                dims.push(read_u32(&mut *f)? as usize);
            }
            let n: usize = dims.iter().product::<usize>().max(1);
            let mut raw = vec![0u8; 4 * n];
            f.read_exact(&mut raw)?;
            let data: Vec<f32> = raw
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            pairs.push((name, Tensor::new(dims, data)?));
        }
        Ok(WeightStore::from_pairs(pairs))
    }

    /// Serialize to the weights.bin format in memory (lets callers hash and
    /// write the same buffer without a read-back).
    pub fn to_bytes(&self) -> Vec<u8> {
        let payload: usize =
            self.tensors.iter().map(|t| 2 + 4 * t.shape.len() + 4 * t.data.len()).sum();
        let names: usize = self.names.iter().map(|n| 2 + n.len()).sum();
        let mut out = Vec::with_capacity(12 + names + payload);
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&(self.names.len() as u32).to_le_bytes());
        for (name, t) in self.names.iter().zip(&self.tensors) {
            let nb = name.as_bytes();
            out.extend_from_slice(&(nb.len() as u16).to_le_bytes());
            out.extend_from_slice(nb);
            out.extend_from_slice(&[0u8, t.shape.len() as u8]);
            for d in &t.shape {
                out.extend_from_slice(&(*d as u32).to_le_bytes());
            }
            for v in &t.data {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        out
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(&self.to_bytes())?;
        Ok(())
    }
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u16(r: &mut impl Read) -> Result<u16> {
    let mut b = [0u8; 2];
    r.read_exact(&mut b)?;
    Ok(u16::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("pqtw_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("w.bin");
        let ws = WeightStore::from_pairs(vec![
            ("a".into(), Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap()),
            ("b.c".into(), Tensor::new(vec![3], vec![-1.0, 0.5, 2.5]).unwrap()),
        ]);
        ws.save(&p).unwrap();
        let re = WeightStore::load(&p).unwrap();
        assert_eq!(re.names, ws.names);
        assert_eq!(re.get("a").unwrap().data, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(re.get("b.c").unwrap().shape, vec![3]);
        // the in-memory serialization is the on-disk format
        assert_eq!(ws.to_bytes(), std::fs::read(&p).unwrap());
        let mem = WeightStore::from_bytes(&ws.to_bytes(), &p).unwrap();
        assert_eq!(mem.names, ws.names);
        assert_eq!(mem.get("a").unwrap().data, re.get("a").unwrap().data);
    }

    #[test]
    fn ordered_lookup() {
        let ws = WeightStore::from_pairs(vec![
            ("x".into(), Tensor::scalar(1.0)),
            ("y".into(), Tensor::scalar(2.0)),
        ]);
        let o = ws.ordered(&["y".into(), "x".into()]).unwrap();
        assert_eq!(o[0].data[0], 2.0);
        assert!(ws.ordered(&["z".into()]).is_err());
    }
}
