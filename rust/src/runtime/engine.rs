//! PJRT execution engine: load HLO text artifacts, compile once, execute.
//!
//! Pattern from /opt/xla-example/load_hlo: `HloModuleProto::from_text_file`
//! -> `XlaComputation::from_proto` -> `client.compile` -> `execute_b`.
//! Weights are uploaded once into resident `PjRtBuffer`s (`ResidentSet`);
//! per-call inputs (tokens, scales, caches) are uploaded per execute.
//!
//! PJRT handles are not `Send`; the coordinator owns the Engine on a single
//! model thread and talks to it over channels (see coordinator/server.rs).

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;

use anyhow::{anyhow, bail, Context, Result};

use super::manifest::{DType, ExecSig, Manifest};
use crate::tensor::{IntTensor, Tensor};

pub struct Engine {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

/// A per-call input value.
pub enum Value<'a> {
    F32(&'a Tensor),
    I32(&'a IntTensor),
    /// Pre-uploaded resident buffer (weights).
    Buf(&'a xla::PjRtBuffer),
}

/// One output tensor, converted back to host.
#[derive(Debug, Clone)]
pub enum Out {
    F32(Tensor),
    I32(IntTensor),
}

impl Out {
    pub fn f32(self) -> Result<Tensor> {
        match self {
            Out::F32(t) => Ok(t),
            Out::I32(_) => bail!("output is i32, expected f32"),
        }
    }

    pub fn i32(self) -> Result<IntTensor> {
        match self {
            Out::I32(t) => Ok(t),
            Out::F32(_) => bail!("output is f32, expected i32"),
        }
    }
}

/// Weights resident on device in manifest order.
pub struct ResidentSet {
    pub buffers: Vec<xla::PjRtBuffer>,
}

impl Engine {
    pub fn new(artifacts_dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Engine { client, manifest, cache: RefCell::new(HashMap::new()) })
    }

    /// Compile (or fetch from cache) the executable for a manifest entry.
    pub fn load(&self, sig: &ExecSig) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.borrow().get(&sig.file) {
            return Ok(e.clone());
        }
        let path = self.manifest.dir.join(&sig.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {path:?}: {e:?}"))?;
        let rc = Rc::new(exe);
        self.cache.borrow_mut().insert(sig.file.clone(), rc.clone());
        Ok(rc)
    }

    /// Upload a host tensor to a resident device buffer.
    pub fn upload(&self, t: &Tensor) -> Result<xla::PjRtBuffer> {
        let dims = if t.shape.is_empty() { vec![] } else { t.shape.clone() };
        self.client
            .buffer_from_host_buffer(&t.data, &dims, None)
            .map_err(|e| anyhow!("upload f32 {:?}: {e:?}", t.shape))
    }

    pub fn upload_i32(&self, t: &IntTensor) -> Result<xla::PjRtBuffer> {
        let dims = if t.shape.is_empty() { vec![] } else { t.shape.clone() };
        self.client
            .buffer_from_host_buffer(&t.data, &dims, None)
            .map_err(|e| anyhow!("upload i32 {:?}: {e:?}", t.shape))
    }

    /// Upload a weight list (manifest order) into resident buffers.
    pub fn upload_weights(&self, tensors: &[&Tensor]) -> Result<ResidentSet> {
        let buffers =
            tensors.iter().map(|t| self.upload(t)).collect::<Result<Vec<_>>>()?;
        Ok(ResidentSet { buffers })
    }

    /// Execute `sig` with inputs given in signature order; validates shapes
    /// and dtypes against the manifest before launching.
    pub fn run(&self, sig: &ExecSig, inputs: &[Value]) -> Result<Vec<Out>> {
        if inputs.len() != sig.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                sig.file,
                sig.inputs.len(),
                inputs.len()
            );
        }
        // shape/dtype validation (buffers are trusted — they're weights)
        for (v, is) in inputs.iter().zip(&sig.inputs) {
            match v {
                Value::F32(t) => {
                    if is.dtype != DType::F32 || t.shape != is.shape {
                        bail!(
                            "{}: input {:?} wants {:?} {:?}, got f32 {:?}",
                            sig.file, is.name, is.dtype, is.shape, t.shape
                        );
                    }
                }
                Value::I32(t) => {
                    if is.dtype != DType::I32 || t.shape != is.shape {
                        bail!(
                            "{}: input {:?} wants {:?} {:?}, got i32 {:?}",
                            sig.file, is.name, is.dtype, is.shape, t.shape
                        );
                    }
                }
                Value::Buf(_) => {}
            }
        }
        let exe = self.load(sig)?;
        // materialize per-call buffers; weights pass through
        let mut owned: Vec<xla::PjRtBuffer> = Vec::new();
        let mut order: Vec<usize> = Vec::with_capacity(inputs.len()); // index into owned or resident marker
        enum Slot<'a> {
            Owned(usize),
            Resident(&'a xla::PjRtBuffer),
        }
        let mut slots: Vec<Slot> = Vec::with_capacity(inputs.len());
        for v in inputs {
            match v {
                Value::F32(t) => {
                    owned.push(self.upload(t)?);
                    slots.push(Slot::Owned(owned.len() - 1));
                }
                Value::I32(t) => {
                    owned.push(self.upload_i32(t)?);
                    slots.push(Slot::Owned(owned.len() - 1));
                }
                Value::Buf(b) => slots.push(Slot::Resident(b)),
            }
        }
        let _ = &mut order;
        let arg_refs: Vec<&xla::PjRtBuffer> = slots
            .iter()
            .map(|s| match s {
                Slot::Owned(i) => &owned[*i],
                Slot::Resident(b) => *b,
            })
            .collect();
        let results = exe
            .execute_b(&arg_refs)
            .map_err(|e| anyhow!("executing {}: {e:?}", sig.file))?;
        let lit = results[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {}: {e:?}", sig.file))?;
        // exported with return_tuple=True: always a tuple literal
        let parts = lit.to_tuple().map_err(|e| anyhow!("untuple {}: {e:?}", sig.file))?;
        if parts.len() != sig.outputs.len() {
            bail!(
                "{}: manifest lists {} outputs, executable returned {}",
                sig.file,
                sig.outputs.len(),
                parts.len()
            );
        }
        parts.into_iter().map(|p| literal_to_out(&p, &sig.file)).collect()
    }

    /// Convenience: run and pick one named output.
    pub fn run_get(&self, sig: &ExecSig, inputs: &[Value], output: &str) -> Result<Out> {
        let idx = sig.output_index(output)?;
        let mut outs = self.run(sig, inputs)?;
        Ok(outs.swap_remove(idx))
    }
}

fn literal_to_out(lit: &xla::Literal, what: &str) -> Result<Out> {
    let shape = lit
        .array_shape()
        .map_err(|e| anyhow!("shape of {what} output: {e:?}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    match shape.ty() {
        xla::ElementType::F32 => {
            let data = lit.to_vec::<f32>().map_err(|e| anyhow!("{what}: {e:?}"))?;
            Ok(Out::F32(Tensor::new(dims, data).context(what.to_string())?))
        }
        xla::ElementType::S32 => {
            let data = lit.to_vec::<i32>().map_err(|e| anyhow!("{what}: {e:?}"))?;
            Ok(Out::I32(IntTensor::new(dims, data).context(what.to_string())?))
        }
        xla::ElementType::Pred => {
            // bool outputs come back as u8; widen to i32
            let data = lit.to_vec::<u8>().map_err(|e| anyhow!("{what}: {e:?}"))?;
            Ok(Out::I32(IntTensor::new(dims, data.into_iter().map(|b| b as i32).collect())?))
        }
        other => bail!("{what}: unsupported output element type {other:?}"),
    }
}
