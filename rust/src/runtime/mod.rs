//! PJRT runtime: manifest parsing, weight container, execution engine.
//!
//! Pattern (see /opt/xla-example/load_hlo): python lowers jax to HLO *text*
//! at build time; this module loads the text, compiles it on the PJRT CPU
//! client and executes it from the rust request path. Python never runs at
//! serving time.

pub mod engine;
pub mod manifest;
pub mod weights;

pub use engine::{Engine, Out, ResidentSet, Value};
pub use manifest::{DType, ExecSig, Manifest, ModelManifest, TensorSig};
pub use weights::WeightStore;
