//! Hand-rolled bench harness (criterion is not cached offline).
//!
//! `bench_fn` warms up, then runs timed samples and reports median /
//! mean / p10-p90 wall time.  Benches are `harness = false` binaries that
//! print paper-style tables (see rust/benches/).

use std::time::Instant;

#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub samples: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub p10_s: f64,
    pub p90_s: f64,
}

impl BenchStats {
    pub fn per_call_ms(&self) -> f64 {
        self.median_s * 1e3
    }
}

/// Time `f` with `warmup` throwaway calls and `samples` measured calls.
pub fn bench_fn(name: &str, warmup: usize, samples: usize, mut f: impl FnMut()) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t = Instant::now();
        f();
        times.push(t.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| times[((times.len() - 1) as f64 * p) as usize];
    BenchStats {
        name: name.to_string(),
        samples,
        mean_s: times.iter().sum::<f64>() / times.len() as f64,
        median_s: pct(0.5),
        p10_s: pct(0.1),
        p90_s: pct(0.9),
    }
}

/// Adaptive sample count: aim for ~`budget_s` seconds of measurement.
pub fn auto_samples(probe_s: f64, budget_s: f64, min: usize, max: usize) -> usize {
    ((budget_s / probe_s.max(1e-9)) as usize).clamp(min, max)
}

/// True when the bench binary was invoked with `--smoke` (CI runs a reduced
/// workload so the perf trail is recorded on every push without burning
/// minutes).
pub fn smoke_mode() -> bool {
    std::env::args().any(|a| a == "--smoke")
}

/// Emit a machine-readable bench result: writes `BENCH_<name>.json` in the
/// working directory and prints a greppable `BENCH_JSON <name> {...}` line.
/// Values are (key, value) pairs; non-finite values are serialized as 0 so
/// the output stays valid JSON.
pub fn emit_bench_json(name: &str, fields: &[(&str, f64)]) {
    use crate::util::json::{num, obj};
    let j = obj(
        fields
            .iter()
            .map(|&(k, v)| (k, num(if v.is_finite() { v } else { 0.0 })))
            .collect(),
    );
    let text = j.to_string();
    let path = format!("BENCH_{name}.json");
    if let Err(e) = std::fs::write(&path, &text) {
        eprintln!("warning: could not write {path}: {e}");
    }
    println!("BENCH_JSON {name} {text}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ordered() {
        let s = bench_fn("noop", 2, 32, || {
            std::hint::black_box(1 + 1);
        });
        assert!(s.p10_s <= s.median_s && s.median_s <= s.p90_s);
        assert_eq!(s.samples, 32);
    }

    #[test]
    fn auto_samples_clamps() {
        assert_eq!(auto_samples(1.0, 0.5, 5, 100), 5);
        assert_eq!(auto_samples(0.001, 10.0, 5, 100), 100);
    }
}
