//! Experiment report collection: accumulates paper-style tables/figures and
//! writes them under artifacts/reports/ for EXPERIMENTS.md.

use std::io::Write;
use std::path::PathBuf;

use anyhow::Result;

pub struct ReportSink {
    dir: PathBuf,
    buffer: String,
    name: String,
}

impl ReportSink {
    pub fn new(artifacts: &std::path::Path, name: &str) -> Result<Self> {
        let dir = artifacts.join("reports");
        std::fs::create_dir_all(&dir)?;
        Ok(Self { dir, buffer: String::new(), name: name.to_string() })
    }

    /// Print to stdout AND record for the report file.
    pub fn emit(&mut self, text: &str) {
        print!("{text}");
        let _ = std::io::stdout().flush();
        self.buffer.push_str(text);
    }

    pub fn emit_line(&mut self, text: &str) {
        self.emit(&format!("{text}\n"));
    }

    pub fn table(&mut self, t: &crate::util::table::Table) {
        self.emit(&t.render());
    }

    pub fn save(&self) -> Result<PathBuf> {
        let path = self.dir.join(format!("{}.txt", self.name));
        std::fs::write(&path, &self.buffer)?;
        Ok(path)
    }
}
