//! SmoothQuant-analog baseline: channel-wise activation→weight scaling.
//!
//! s_c = max|X_c|^α / max|W_c|^{1-α}; activations divided by s (folded into
//! the preceding RMSNorm gain), weights multiplied by s.  Only the norm→linear
//! pairs (attn_in, mlp_in) can absorb the scaling — like the real method —
//! while o_in / down_in stay untouched.

use anyhow::Result;

use crate::model::Model;
use crate::tensor::Tensor;

use super::outlier::Observation;

/// Per-channel abs-max of the post-norm activations, computed host-side from
/// the captured block inputs (rmsnorm with the current gains).
fn channel_absmax_postnorm(x: &Tensor, gamma: &Tensor) -> Vec<f32> {
    let d = *x.shape.last().unwrap();
    let rows = x.numel() / d;
    let mut maxes = vec![0.0f32; d];
    for r in 0..rows {
        let row = &x.data[r * d..(r + 1) * d];
        let ms = row.iter().map(|v| (v * v) as f64).sum::<f64>() / d as f64;
        let inv = 1.0 / ((ms + 1e-5).sqrt() as f32);
        for c in 0..d {
            maxes[c] = maxes[c].max((row[c] * inv * gamma.data[c]).abs());
        }
    }
    maxes
}

fn weight_absmax_rows(w: &Tensor) -> Vec<f32> {
    let (rows, cols) = (w.shape[0], w.shape[1]);
    let mut m = vec![0.0f32; rows];
    for i in 0..rows {
        for j in 0..cols {
            m[i] = m[i].max(w.data[i * cols + j].abs());
        }
    }
    m
}

/// Apply SmoothQuant scaling in place (α = 0.5, the canonical setting).
pub fn apply(model: &mut Model, obs: &Observation, alpha: f32) -> Result<()> {
    let cfg = model.cfg.clone();
    for li in 0..cfg.n_layers {
        let x = obs.captures.index0(li);
        for (ln, targets) in
            [("ln1", vec!["wq", "wk", "wv"]), ("ln2", vec!["wg", "wu"])]
        {
            let gamma = model.weights.get(&format!("layers.{li}.{ln}")).unwrap().clone();
            let act_max = channel_absmax_postnorm(&x, &gamma);
            // w-side max across all consumers of this activation
            let mut w_max = vec![0.0f32; cfg.d_model];
            for t in &targets {
                let w = model.layer_weight(li, t)?;
                for (c, m) in weight_absmax_rows(w).into_iter().enumerate() {
                    w_max[c] = w_max[c].max(m);
                }
            }
            let s: Vec<f32> = act_max
                .iter()
                .zip(&w_max)
                .map(|(&a, &w)| {
                    (a.max(1e-5).powf(alpha) / w.max(1e-5).powf(1.0 - alpha)).clamp(1e-3, 1e3)
                })
                .collect();
            // gamma' = gamma / s ; W' = diag(s) W
            let mut g2 = gamma.clone();
            for c in 0..cfg.d_model {
                g2.data[c] /= s[c];
            }
            model.weights.set(&format!("layers.{li}.{ln}"), g2);
            for t in &targets {
                let w = model.weights.get_mut(&format!("layers.{li}.{t}")).unwrap();
                let cols = w.shape[1];
                for c in 0..cfg.d_model {
                    for j in 0..cols {
                        w.data[c * cols + j] *= s[c];
                    }
                }
            }
        }
    }
    model.refresh_weights()?;
    Ok(())
}
