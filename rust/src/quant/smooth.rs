//! SmoothQuant-analog baseline: channel-wise activation→weight scaling.
//!
//! s_c = max|X_c|^α / max|W_c|^{1-α}; activations divided by s (folded into
//! the preceding RMSNorm gain), weights multiplied by s.  Only the norm→linear
//! pairs (attn_in, mlp_in) can absorb the scaling — like the real method —
//! while o_in / down_in stay untouched.
//!
//! Statistics and the weight scaling run on the host-kernel layer
//! (`crate::kernels`): the post-norm abs-max scan is banded over capture
//! rows (max is exactly associative, so the band merge is bit-identical for
//! any `PQ_THREADS`), and the diag(s)·W application is the threaded
//! row-scaling kernel.

use anyhow::Result;

use crate::kernels::{self, ops};
use crate::model::Model;
use crate::tensor::Tensor;

use super::outlier::Observation;

/// Per-channel abs-max of the post-norm activations, computed host-side from
/// the captured block inputs (rmsnorm with the current gains).  The fused
/// rmsnorm+gamma column-max runs per row band under the kernel layer's
/// banded max-reduce (per-row math identical to the serial scan; max merge
/// exactly associative).
fn channel_absmax_postnorm(x: &Tensor, gamma: &Tensor, nthreads: usize) -> Vec<f32> {
    let d = *x.shape.last().unwrap();
    let rows = x.numel() / d;
    ops::rowband_max_nt(&x.data, rows, d, nthreads, |chunk: &[f32]| {
        let mut maxes = vec![0.0f32; d];
        for row in chunk.chunks(d) {
            let ms = row.iter().map(|v| (v * v) as f64).sum::<f64>() / d as f64;
            let inv = 1.0 / ((ms + 1e-5).sqrt() as f32);
            for (mx, (&v, g)) in maxes.iter_mut().zip(row.iter().zip(&gamma.data)) {
                *mx = mx.max((v * inv * g).abs());
            }
        }
        maxes
    })
}

/// Apply SmoothQuant scaling in place (α = 0.5, the canonical setting).
pub fn apply(model: &mut Model, obs: &Observation, alpha: f32) -> Result<()> {
    let cfg = model.cfg.clone();
    let nt = kernels::threads();
    for li in 0..cfg.n_layers {
        let x = obs.captures.index0(li);
        for (ln, targets) in
            [("ln1", vec!["wq", "wk", "wv"]), ("ln2", vec!["wg", "wu"])]
        {
            let gamma = model.weights.get(&format!("layers.{li}.{ln}")).unwrap().clone();
            let act_max = channel_absmax_postnorm(&x, &gamma, nt);
            // w-side max across all consumers of this activation
            let mut w_max = vec![0.0f32; cfg.d_model];
            for t in &targets {
                let w = model.layer_weight(li, t)?;
                let rows = ops::absmax_rows_nt(&w.data, w.shape[0], w.shape[1], nt);
                for (c, m) in rows.into_iter().enumerate() {
                    w_max[c] = w_max[c].max(m);
                }
            }
            let s: Vec<f32> = act_max
                .iter()
                .zip(&w_max)
                .map(|(&a, &w)| {
                    (a.max(1e-5).powf(alpha) / w.max(1e-5).powf(1.0 - alpha)).clamp(1e-3, 1e3)
                })
                .collect();
            // gamma' = gamma / s ; W' = diag(s) W
            let mut g2 = gamma.clone();
            for c in 0..cfg.d_model {
                g2.data[c] /= s[c];
            }
            model.weights.set(&format!("layers.{li}.{ln}"), g2);
            for t in &targets {
                let w = model.weights.get_mut(&format!("layers.{li}.{t}")).unwrap();
                let cols = w.shape[1];
                ops::scale_rows_nt(&mut w.data, cfg.d_model, cols, &s, nt);
            }
        }
    }
    model.refresh_weights()?;
    Ok(())
}
