//! Block-wise fine-tuning (§5.2) — EfficientQAT-style.
//!
//! Sequentially per transformer block: minimize the MSE between the quantized
//! block's output and the fp block's output (captured during observation),
//! training BOTH the quantization step sizes (LSQ gradients, exported in
//! `block_grads_*`) and the full-precision weights, with separate learning
//! rates — the paper's recipe.  The running input propagates through the
//! *quantized* blocks, so later blocks learn to compensate earlier error.

use anyhow::Result;

use crate::kernels::{self, ops};
use crate::model::{Model, QuantMode};
use crate::tensor::Tensor;

use super::blockrun::{self, BlockCtx, LAYER_TENSORS};
use super::outlier::Observation;

#[derive(Debug, Clone)]
pub struct FtCfg {
    pub epochs: usize,
    pub lr_scales: f32,
    pub lr_weights: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    /// train weights too (EfficientQAT Block-AP); false = scales only
    pub train_weights: bool,
}

impl Default for FtCfg {
    fn default() -> Self {
        Self {
            epochs: 10,
            lr_scales: 5e-4,
            lr_weights: 5e-5,
            beta1: 0.9,
            beta2: 0.95,
            eps: 1e-8,
            train_weights: true,
        }
    }
}

struct Adam {
    m: Vec<f32>,
    v: Vec<f32>,
    t: usize,
}

impl Adam {
    fn new(n: usize) -> Self {
        Self { m: vec![0.0; n], v: vec![0.0; n], t: 0 }
    }

    /// One fused, banded-parallel Adam update (`kernels::ops::adam_step_nt`;
    /// element-independent, so bit-identical for every `PQ_THREADS`).  The
    /// weight tensors of every block step through here each epoch — the
    /// host-side hot loop of fine-tuning.
    fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32, cfg: &FtCfg) {
        self.t += 1;
        let k = ops::AdamStep {
            lr,
            beta1: cfg.beta1,
            beta2: cfg.beta2,
            eps: cfg.eps,
            b1c: 1.0 - cfg.beta1.powi(self.t as i32),
            b2c: 1.0 - cfg.beta2.powi(self.t as i32),
        };
        ops::adam_step_nt(params, &mut self.m, &mut self.v, grads, k, kernels::threads());
    }
}

/// Result of fine-tuning one model: per-layer loss trajectory.
#[derive(Debug, Clone, Default)]
pub struct FtReport {
    /// (layer, first-epoch loss, last-epoch loss)
    pub layers: Vec<(usize, f32, f32)>,
}

/// Fine-tune the model in place (static or dynamic activation quant mode).
/// The observation provides the fp targets; the mode picks the grads
/// executable (`block_grads_static` / `block_grads_dynamic`).
pub fn finetune(
    model: &mut Model,
    obs: &Observation,
    mode: QuantMode,
    cfg: &FtCfg,
) -> Result<FtReport> {
    let exec_name = match mode {
        QuantMode::Static => "block_grads_static",
        QuantMode::Dynamic => "block_grads_dynamic",
        QuantMode::Fp => anyhow::bail!("cannot fine-tune the fp path"),
    };
    model.unfreeze(); // scales/weights are about to change
    let sig = model.exec(exec_name)?;
    let n_layers = model.cfg.n_layers;
    let mut report = FtReport::default();
    let mut x = obs.captures.index0(0);

    for li in 0..n_layers {
        let target = obs.captures.index0(li + 1);
        // working copies of the trainables
        let mut act = model.quant.act_scales.index0(li);
        let mut kv = model.quant.kv_scales.index0(li);
        let mut weights: Vec<Tensor> = LAYER_TENSORS
            .iter()
            .map(|t| model.layer_weight(li, t).map(|w| w.clone()))
            .collect::<Result<_>>()?;
        let mut opt_act = Adam::new(act.data.len());
        let mut opt_kv = Adam::new(kv.data.len());
        let mut opt_w: Vec<Adam> = weights.iter().map(|w| Adam::new(w.data.len())).collect();

        let (mut first, mut last) = (f32::NAN, f32::NAN);
        for epoch in 0..cfg.epochs {
            let ctx = BlockCtx::from_model(model, li)?
                .with_act_scales(act.clone())
                .with_kv_scales(kv.clone());
            let wrefs: [&Tensor; 9] = {
                let v: Vec<&Tensor> = weights.iter().collect();
                v.try_into().unwrap()
            };
            let outs =
                blockrun::run_block(model, &sig, &ctx, &x, &obs.active, &wrefs, Some(&target))?;
            let loss = outs[sig.output_index("loss")?].clone().f32()?.data[0];
            if epoch == 0 {
                first = loss;
            }
            last = loss;
            let g_act = outs[sig.output_index("g_act_scales")?].clone().f32()?;
            let g_kv = outs[sig.output_index("g_kv_scales")?].clone().f32()?;
            opt_act.step(&mut act.data, &g_act.data, cfg.lr_scales, cfg);
            opt_kv.step(&mut kv.data, &g_kv.data, cfg.lr_scales, cfg);
            // step sizes must stay positive
            for s in act.data.iter_mut().chain(kv.data.iter_mut()) {
                *s = s.max(1e-8);
            }
            if cfg.train_weights {
                for (wi, t) in LAYER_TENSORS.iter().enumerate() {
                    let g = outs[sig.output_index(&format!("g_{t}"))?].clone().f32()?;
                    opt_w[wi].step(&mut weights[wi].data, &g.data, cfg.lr_weights, cfg);
                }
            }
        }
        // commit the trained parameters
        for site in 0..act.data.len() {
            model.quant.act_scales.data[li * act.data.len() + site] = act.data[site];
        }
        let kvn = kv.data.len();
        for i in 0..kvn {
            model.quant.kv_scales.data[li * kvn + i] = kv.data[i];
        }
        if cfg.train_weights {
            for (wi, t) in LAYER_TENSORS.iter().enumerate() {
                model.weights.set(&format!("layers.{li}.{t}"), weights[wi].clone());
            }
        }
        report.layers.push((li, first, last));
        // roll the quantized input forward with the trained block
        let ctx = BlockCtx::from_model(model, li)?;
        let fwd_mode = if mode == QuantMode::Dynamic { QuantMode::Dynamic } else { QuantMode::Static };
        x = blockrun::block_forward(model, fwd_mode, &ctx, &x, &obs.active)?;
    }
    // weights changed → refresh resident buffers for full-model executables
    model.refresh_weights()?;
    Ok(report)
}
