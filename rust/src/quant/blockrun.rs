//! Per-layer block executable driver (grid search + fine-tuning substrate).
//!
//! The block executables (`block_static` / `block_dynamic` / `block_fp` /
//! `block_grads_*`) operate on one transformer block with explicit inputs for
//! everything the block needs; this module slices the per-layer views out of
//! the model state and binds them by name.

use anyhow::Result;

use crate::model::{Model, QuantMode};
use crate::runtime::{ExecSig, Out, Value};
use crate::tensor::{IntTensor, Tensor};

pub const LAYER_TENSORS: [&str; 9] =
    ["wq", "wk", "wv", "wo", "wg", "wu", "wd", "ln1", "ln2"];

/// Per-layer views needed by a block executable call.
pub struct BlockCtx {
    pub layer: usize,
    pub act_scales: Tensor,  // [4]
    pub kv_scales: Tensor,   // [2,H]
    pub prefix_k: Tensor,    // [H,P,dh]
    pub prefix_v: Tensor,
    pub inject_v: Tensor,    // [F]
    pub n_prefix: IntTensor, // scalar
}

impl BlockCtx {
    pub fn from_model(model: &Model, layer: usize) -> Result<BlockCtx> {
        let iv = model
            .weights
            .get("inject_v")
            .ok_or_else(|| anyhow::anyhow!("missing inject_v"))?;
        Ok(BlockCtx {
            layer,
            act_scales: model.quant.act_scales.index0(layer),
            kv_scales: model.quant.kv_scales.index0(layer),
            prefix_k: model.prefix.k.index0(layer),
            prefix_v: model.prefix.v.index0(layer),
            inject_v: iv.index0(layer),
            n_prefix: IntTensor::scalar(model.prefix.n_prefix),
        })
    }

    /// Override the per-layer activation scales (grid-search candidates).
    pub fn with_act_scales(mut self, s: Tensor) -> Self {
        self.act_scales = s;
        self
    }

    pub fn with_kv_scales(mut self, s: Tensor) -> Self {
        self.kv_scales = s;
        self
    }
}

/// Run one block executable. `x` is the block input [B,S,D], `active` the
/// sink mask [B,S]; `weights` supplies the 9 layer tensors (usually the
/// model's, but fine-tuning passes its own working copies); `target` is
/// required by the grads executables.
#[allow(clippy::too_many_arguments)]
pub fn run_block(
    model: &Model,
    sig: &ExecSig,
    ctx: &BlockCtx,
    x: &Tensor,
    active: &Tensor,
    weights: &[&Tensor; 9],
    target: Option<&Tensor>,
) -> Result<Vec<Out>> {
    let mut extra: Vec<(&str, Value)> = vec![
        ("x", Value::F32(x)),
        ("active", Value::F32(active)),
        ("n_prefix", Value::I32(&ctx.n_prefix)),
        ("prefix_k", Value::F32(&ctx.prefix_k)),
        ("prefix_v", Value::F32(&ctx.prefix_v)),
        ("act_scales", Value::F32(&ctx.act_scales)),
        ("kv_scales", Value::F32(&ctx.kv_scales)),
        ("inject_v", Value::F32(&ctx.inject_v)),
    ];
    for (i, t) in LAYER_TENSORS.iter().enumerate() {
        extra.push((t, Value::F32(weights[i])));
    }
    if let Some(t) = target {
        extra.push(("target", Value::F32(t)));
    }
    let inputs = model.bind(sig, &extra)?;
    model.engine.run(sig, &inputs)
}

/// The model's own weights for one layer, in LAYER_TENSORS order.
pub fn layer_weights<'a>(model: &'a Model, layer: usize) -> Result<[&'a Tensor; 9]> {
    let mut out: Vec<&Tensor> = Vec::with_capacity(9);
    for t in LAYER_TENSORS {
        out.push(model.layer_weight(layer, t)?);
    }
    Ok(out.try_into().map_err(|_| anyhow::anyhow!("layer weight arity")).unwrap())
}

/// Block forward returning only `y` [B,S,D].
pub fn block_forward(
    model: &Model,
    mode: QuantMode,
    ctx: &BlockCtx,
    x: &Tensor,
    active: &Tensor,
) -> Result<Tensor> {
    let sig = model.exec(mode.block_exec())?;
    let w = layer_weights(model, ctx.layer)?;
    let idx = sig.output_index("y")?;
    let mut outs = run_block(model, &sig, ctx, x, active, &w, None)?;
    outs.swap_remove(idx).f32()
}
