//! Host-side weight quantization (Eq. 1 of the paper).
//!
//! Weights are quantized on the host before upload: the executables receive
//! the already fake-quantized (dequantized) weights, exactly as a real INT4
//! deployment would hold integer codes + per-channel steps.  Per-channel
//! symmetric is the paper's setting; per-group is the Atom-analog baseline.
//!
//! Since the host-kernel layer (see `crate::kernels`), the heavy lifting —
//! panel transposes, fused scale-search + fake-quant, the lossless pruned
//! γ grid, channel-level threading — lives in `kernels::quantize`; this
//! module is the `Tensor`-level surface the pipeline calls.  Step sizes are
//! pre-clamped at construction (≥ `kernels::quantize::STEP_FLOOR`), so the
//! per-element `s.max(1e-8)` clamp of the old `fq` is gone and inner loops
//! multiply by precomputed reciprocals instead of dividing.

use crate::kernels::{self, quantize as kq};
use crate::tensor::Tensor;

/// qmax for N-bit symmetric quantization: 2^{N-1} - 1.
pub fn qmax(bits: usize) -> f32 {
    ((1i64 << (bits - 1)) - 1) as f32
}

/// Fake-quantize one value with step `s` (clamp to [-qmax-1, qmax]).
/// `s` must be positive and pre-clamped — every step produced by
/// [`search_scale`] / the weight quantizers is.
#[inline]
pub fn fq(x: f32, s: f32, qm: f32) -> f32 {
    kq::fq_scalar(x, s, 1.0 / s, qm)
}

/// Integer code for one value (same pre-clamped `s` contract as [`fq`]).
#[inline]
pub fn code(x: f32, s: f32, qm: f32) -> f32 {
    (x * (1.0 / s)).round().clamp(-qm - 1.0, qm)
}

/// Fake-quant a whole slice with one pre-clamped step and its precomputed
/// reciprocal; returns the sum of squared error.  This is the fused weight
/// quantizer's (and any fine-tune host path's) inner loop.
pub fn fq_slice(xs: &mut [f32], s: f32, rinv: f32, qm: f32) -> f64 {
    kq::fq_slice(xs, s, rinv, qm)
}

/// Grid-search the step size for one slice: s = γ·max|x|/qmax minimizing
/// MSE (lossless pruned search — identical winner to the full scan).
/// With `grid == 1` this degenerates to RTN (γ = 1).
pub fn search_scale(xs: &[f32], bits: usize, grid: usize) -> f32 {
    kq::search_step(xs, qmax(bits), grid)
}

/// Per-(output-)channel symmetric weight quantization of w[in, out].
/// Returns the per-channel steps. `grid==1` → RTN init, else grid search.
pub fn quant_weight_per_channel(w: &mut Tensor, bits: usize, grid: usize) -> Vec<f32> {
    assert_eq!(w.rank(), 2, "per-channel quant expects a matrix");
    if bits >= 16 {
        return vec![];
    }
    let (rows, cols) = (w.shape[0], w.shape[1]);
    kq::quant_per_channel_nt(&mut w.data, rows, cols, qmax(bits), grid, kernels::threads())
}

/// Per-group weight quantization (groups along the input dim, Atom-analog).
/// Returns the per-group steps, channel-major (all groups of output
/// channel 0, then channel 1, …; ⌈rows/group⌉ per channel).
pub fn quant_weight_per_group(w: &mut Tensor, bits: usize, group: usize, grid: usize) -> Vec<f32> {
    assert_eq!(w.rank(), 2);
    if bits >= 16 {
        return vec![];
    }
    let (rows, cols) = (w.shape[0], w.shape[1]);
    kq::quant_per_group_nt(&mut w.data, rows, cols, qmax(bits), group, grid, kernels::threads())
}

/// Grid-search a *single* static step for a value population against its own
/// quantization MSE (used for per-head KV scales — "layer output" objective).
pub fn search_scale_pop(values: &[f32], bits: usize, grid: usize) -> f32 {
    search_scale(values, bits, grid)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sq_err(xs: &[f32], s: f32, qm: f32) -> f64 {
        kq::sse(xs, s, 1.0 / s, qm)
    }

    #[test]
    fn qmax_values() {
        assert_eq!(qmax(4), 7.0);
        assert_eq!(qmax(8), 127.0);
        assert_eq!(qmax(16), 32767.0);
    }

    #[test]
    fn fq_roundtrip_idempotent() {
        let s = 0.1;
        for &x in &[0.0f32, 0.04, -0.06, 0.65, -0.7, 100.0] {
            let q = fq(x, s, 7.0);
            assert_eq!(fq(q, s, 7.0), q, "fq idempotent at {x}");
            assert!(q <= 7.0 * s + 1e-6 && q >= -8.0 * s - 1e-6);
        }
    }

    #[test]
    fn grid_beats_rtn_with_outlier() {
        // a mild outlier over a dense bulk: RTN wastes range, grid clips it
        let mut xs = vec![0.2f32; 511];
        xs.push(2.0);
        let s_rtn = search_scale(&xs, 4, 1);
        let s_grid = search_scale(&xs, 4, 40);
        assert!(sq_err(&xs, s_grid, 7.0) <= sq_err(&xs, s_rtn, 7.0));
        assert!(s_grid < s_rtn);
    }

    #[test]
    fn per_channel_reduces_error_vs_shared() {
        // two columns with very different ranges
        let w0 = Tensor::new(vec![2, 2], vec![1.0, 0.01, -1.0, -0.01]).unwrap();
        let mut w = w0.clone();
        let steps = quant_weight_per_channel(&mut w, 4, 20);
        assert_eq!(steps.len(), 2);
        assert!(steps[0] > steps[1]);
        // small column survives (error << its magnitude)
        assert!((w.data[1] - 0.01).abs() < 0.005);
    }

    #[test]
    fn bits16_is_noop() {
        let mut w = Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let orig = w.clone();
        let steps = quant_weight_per_channel(&mut w, 16, 20);
        assert!(steps.is_empty());
        assert_eq!(w, orig);
    }

    #[test]
    fn per_group_groups_and_returns_steps() {
        let mut w = Tensor::new(vec![4, 1], vec![0.1, 0.1, 10.0, 10.0]).unwrap();
        let steps = quant_weight_per_group(&mut w, 4, 2, 10);
        // group 0 keeps fidelity on small values despite group 1's outliers
        assert!((w.data[0] - 0.1).abs() < 0.02);
        // one step per (channel × group), small group's step much smaller
        assert_eq!(steps.len(), 2);
        assert!(steps[0] < steps[1]);
    }

    #[test]
    fn steps_are_pre_clamped() {
        // an all-zero channel must yield the floored step, not a denormal
        let mut w = Tensor::new(vec![3, 1], vec![0.0, 0.0, 0.0]).unwrap();
        let steps = quant_weight_per_channel(&mut w, 4, 40);
        assert!(steps[0] >= kq::STEP_FLOOR);
        assert_eq!(w.data, vec![0.0, 0.0, 0.0]);
    }
}
