//! Pipeline entry points (Quantization API v2).
//!
//! [`quantize`] is the one-call surface: it bridges a legacy
//! [`SchemeConfig`] through [`Recipe::from_scheme`] and runs the composable
//! pass pipeline (see [`super::recipe`]).  New code should construct a
//! [`Recipe`] directly (presets or builder) and call `Recipe::run`.
//!
//! [`quantize_legacy`] is the frozen v1 implementation — the golden
//! reference the parity suite (`tests/recipe_parity.rs`) compares every
//! preset recipe against.  Do not modify it; change recipes instead.

use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::model::{qmax_for_bits, Model, QuantMode};
use crate::tensor::{IntTensor, Tensor};
use crate::tokenizer::Tokenizer;

use super::calibrate::{self, GridCfg};
use super::finetune::{self, FtCfg, FtReport};
use super::outlier::{self, OutlierReport, ETA};
use super::prefix;
use super::quantizer;
use super::recipe::{Recipe, RecipeReport};
use super::rotation;
use super::smooth;
use super::SchemeConfig;

/// Run the quantization pass pipeline for a legacy `SchemeConfig` on a
/// freshly-loaded model.  `calib` is the [B,S] calibration batch (geometry
/// of `fwd_obs`).  Equivalent to `Recipe::from_scheme(scheme).run(...)`.
pub fn quantize(
    model: &mut Model,
    scheme: &SchemeConfig,
    calib: &IntTensor,
    tok: &Tokenizer,
) -> Result<RecipeReport> {
    Recipe::from_scheme(scheme).run(model, calib, tok)
}

/// Weight tensors that get quantized (all linear projections).
pub const QUANT_WEIGHTS: [&str; 7] = ["wq", "wk", "wv", "wo", "wg", "wu", "wd"];

/// The step sizes one quantized tensor ended up with: per output channel,
/// or per (channel × input-group) in channel-major order when `group` is
/// set.
#[derive(Debug, Clone)]
pub struct TensorSteps {
    /// weight-store name, e.g. "layers.0.wq"
    pub name: String,
    /// group size along the input dim (None = per-channel)
    pub group: Option<usize>,
    pub steps: Vec<f32>,
}

/// What weight quantization did: the configuration plus every tensor's
/// chosen steps.  Returned by [`quantize_weights_raw`], carried through
/// [`super::RecipeReport`], and recorded into [`super::QuantArtifact`]
/// provenance (summaries in `artifact.json`, full step vectors as
/// `wsteps.*` tensors in the state store).
#[derive(Debug, Clone, Default)]
pub struct WeightQuantReport {
    pub w_bits: usize,
    pub grid: usize,
    pub tensors: Vec<TensorSteps>,
}

/// Quantize the projection weights host-side (legacy config surface).
pub fn quantize_weights(model: &mut Model, scheme: &SchemeConfig) -> Result<WeightQuantReport> {
    quantize_weights_raw(
        model,
        scheme.w_bits,
        scheme.w_group,
        if scheme.grid_search { 40 } else { 1 },
    )
}

/// Quantize the projection weights host-side: `w_bits` per-channel symmetric
/// (or per-`group` along the input dim), `grid` scale candidates (1 = RTN).
/// Returns the per-tensor step sizes (per-group steps included — they used
/// to be silently discarded).
pub fn quantize_weights_raw(
    model: &mut Model,
    w_bits: usize,
    w_group: Option<usize>,
    grid: usize,
) -> Result<WeightQuantReport> {
    let mut report = WeightQuantReport { w_bits, grid, tensors: Vec::new() };
    if w_bits >= 16 {
        return Ok(report);
    }
    for li in 0..model.cfg.n_layers {
        for t in QUANT_WEIGHTS {
            let name = format!("layers.{li}.{t}");
            let w = model.weights.get_mut(&name).ok_or_else(|| {
                anyhow!("quantize_weights: tensor {name:?} missing from the model's weight store")
            })?;
            let steps = match w_group {
                Some(g) => quantizer::quant_weight_per_group(w, w_bits, g, grid),
                None => quantizer::quant_weight_per_channel(w, w_bits, grid),
            };
            report.tensors.push(TensorSteps { name, group: w_group, steps });
        }
    }
    model.refresh_weights()?;
    Ok(report)
}

// ---------------------------------------------------------------------------
// Frozen v1 pipeline (golden reference for the recipe parity suite)
// ---------------------------------------------------------------------------

/// Everything the v1 harness reported about one pipeline run.
pub struct PipelineReport {
    pub scheme: SchemeConfig,
    pub pre_report: OutlierReport,
    pub post_report: Option<OutlierReport>,
    pub prefix_tokens: Vec<i32>,
    pub prefix_rendered: String,
    pub ft: Option<FtReport>,
    /// Table 10 breakdown (seconds)
    pub t_find_prefix: f64,
    pub t_grid: f64,
    pub t_ft: f64,
    pub t_total: f64,
}

/// The frozen v1 monolithic pipeline.  Kept verbatim as the golden reference
/// that `tests/recipe_parity.rs` compares every preset [`Recipe`] against
/// (identical PPL, prefix tokens, scales).  Order of operations:
///
///   1. (baseline) SmoothQuant channel scaling, if configured;
///   2. rotation folding (R1/R2/R4 weight-side; R3/R4 online matrices);
///   3. observation #1 → outlier report → prefix selection → install
///      prefixed KV ("Find Prefixed Outliers", seconds);
///   4. observation #2 with the prefix in place → fp captures/targets;
///   5. host weight quantization (per-channel RTN or grid);
///   6. static-scale initialization: max-init, then per-head KV grid and
///      block-output coordinate-descent grid search;
///   7. optional block-wise fine-tuning.
pub fn quantize_legacy(
    model: &mut Model,
    scheme: &SchemeConfig,
    calib: &IntTensor,
    tok: &Tokenizer,
) -> Result<PipelineReport> {
    let t0 = Instant::now();

    // qmax scalars for the executables
    model.quant.qmax_act = Tensor::scalar(qmax_for_bits(scheme.a_bits.max(2)));
    model.quant.qmax_kv = Tensor::scalar(qmax_for_bits(scheme.kv_bits.max(2)));

    // 1. SmoothQuant baseline scaling (needs pre-rotation captures)
    if scheme.smooth {
        let obs0 = outlier::observe(model, calib)?;
        smooth::apply(model, &obs0, 0.5)?;
    }

    // 2. rotation folding
    if scheme.rotate {
        rotation::absorb_norm_gains(&model.cfg.clone(), &mut model.weights)?;
        rotation::fold_rotations(&model.cfg.clone(), &mut model.weights)?;
        let (r3, r4) = rotation::online_matrices(&model.cfg, true);
        model.quant.r3 = r3;
        model.quant.r4 = r4;
        model.quant.rotated = true;
        model.refresh_weights()?;
    }

    // 3. find prefixed outliers (observation + selection + install)
    let t_find = Instant::now();
    let (mut obs, pre_report) = outlier::observe_and_analyze(model, calib, ETA)?;
    let mut prefix_tokens = Vec::new();
    if scheme.use_prefix {
        prefix_tokens = match &scheme.prefix_override {
            Some(p) => prefix::select_with_policy(&pre_report, tok, p),
            None => prefix::select_tokens(&pre_report, tok),
        };
        prefix::install(model, &prefix_tokens, tok.spec.pad)?;
    }
    let t_find_prefix = t_find.elapsed().as_secs_f64();

    // 4. re-observe with the prefix installed (fp targets for calibration/FT)
    let mut post_report = None;
    if scheme.use_prefix && !prefix_tokens.is_empty() {
        let (obs2, rep2) = outlier::observe_and_analyze(model, calib, ETA)?;
        obs = obs2;
        post_report = Some(rep2);
    }

    // 5. host weight quantization
    quantize_weights(model, scheme)?;

    // 6. static scale initialization
    let t_grid_start = Instant::now();
    if scheme.mode == QuantMode::Static {
        let qa = model.quant.qmax_act.data[0];
        model.quant.act_scales = calibrate::max_init_act_scales(model, &obs, qa);
        if scheme.kv_bits < 16 {
            model.quant.kv_scales = calibrate::kv_scales_grid(
                model,
                &obs,
                scheme.kv_bits,
                if scheme.grid_search { GridCfg::default().kv_points } else { 1 },
            );
        } else {
            // near-lossless 16-bit static: max-based per-head init
            model.quant.kv_scales = calibrate::kv_scales_grid(model, &obs, 16, 1);
        }
        if scheme.grid_search && scheme.a_bits < 16 {
            calibrate::act_scales_grid(model, &obs, &GridCfg::default())?;
        }
    }
    let t_grid = t_grid_start.elapsed().as_secs_f64();

    // 7. block-wise fine-tuning
    let t_ft_start = Instant::now();
    let mut ft = None;
    if scheme.ft_epochs > 0 {
        let ft_cfg = FtCfg { epochs: scheme.ft_epochs, ..FtCfg::default() };
        let mode = if scheme.mode == QuantMode::Dynamic {
            QuantMode::Dynamic
        } else {
            QuantMode::Static
        };
        ft = Some(finetune::finetune(model, &obs, mode, &ft_cfg)?);
    }
    let t_ft = t_ft_start.elapsed().as_secs_f64();

    // hot-path: park the now-final quant/prefix state on device (§Perf L3-1)
    model.freeze()?;

    Ok(PipelineReport {
        scheme: scheme.clone(),
        pre_report,
        post_report,
        prefix_rendered: prefix::render(&prefix_tokens, tok),
        prefix_tokens,
        ft,
        t_find_prefix,
        t_grid,
        t_ft,
        t_total: t0.elapsed().as_secs_f64(),
    })
}
