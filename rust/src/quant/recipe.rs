//! Composable quantization pass pipeline (Quantization API v2).
//!
//! The monolithic `pipeline::quantize()` is re-expressed as an ordered list
//! of small passes over a shared [`QuantCtx`]:
//!
//! ```text
//!   Recipe (typed config: Precision + Granularity + flags)
//!     │  RecipeBuilder::build() compiles the config into passes
//!     ▼
//!   [smooth] → [rotate] → [find-prefix] → [re-observe]
//!            → [weight-quant] → [grid-init] → [finetune]
//!     │  each pass: run(&mut QuantCtx) -> StageReport (timed by the runner)
//!     ▼
//!   RecipeReport (per-pass timing — Table 10 generalized to any recipe —
//!                 + outlier reports + prefix tokens + FT trajectory)
//! ```
//!
//! [`QuantCtx`] owns a cached calibration observation (`fwd_obs` capture +
//! outlier analysis).  Passes read it through [`QuantCtx::with_observation`];
//! a pass that changes the model function (weights, rotations, prefix)
//! declares [`QuantPass::invalidates_observation`] and the runner drops the
//! cache after it.  This is what removes the redundant `observe_and_analyze`
//! runs of the v1 pipeline: a pure-dynamic recipe (RTN/QuaRot/Atom without
//! fine-tuning) now runs ZERO observations, and every other recipe runs
//! exactly as many as its passes consume.
//!
//! All paper presets are recipe constructors ([`Recipe::fp16`],
//! [`Recipe::rtn`], [`Recipe::quarot`], [`Recipe::smoothquant`],
//! [`Recipe::atom`], [`Recipe::prefixquant_wo_ft`], [`Recipe::prefixquant`]);
//! [`Recipe::from_scheme`] bridges the legacy [`SchemeConfig`] so the golden
//! parity suite can compare against `pipeline::quantize_legacy`.

use std::time::Instant;

use anyhow::Result;

use crate::model::{qmax_for_bits, Model, QuantMode};
use crate::tensor::{IntTensor, Tensor};
use crate::tokenizer::Tokenizer;

use super::calibrate::{self, GridCfg};
use super::finetune::{self, FtCfg, FtReport};
use super::outlier::{self, Observation, OutlierReport, ETA};
use super::pipeline::{self, WeightQuantReport};
use super::prefix;
use super::rotation;
use super::smooth;
use super::{PrefixPolicy, SchemeConfig};

/// Bit-widths of one scheme (weights / activations / KV cache; 16 = keep fp).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Precision {
    pub w: usize,
    pub a: usize,
    pub kv: usize,
}

impl Precision {
    /// Full precision (no quantization anywhere).
    pub const FP16: Precision = Precision { w: 16, a: 16, kv: 16 };

    pub fn new(w: usize, a: usize, kv: usize) -> Precision {
        Precision { w, a, kv }
    }

    /// The paper's "W{w}A{a}KV{kv}" rendering.
    pub fn label(&self) -> String {
        format!("W{}A{}KV{}", self.w, self.a, self.kv)
    }
}

/// Weight-quantization granularity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Granularity {
    /// per-output-channel symmetric (the paper's setting)
    PerChannel,
    /// per-group along the input dim (Atom-analog baseline)
    PerGroup(usize),
}

/// What one pass did and how long it took (the runner stamps `seconds`, so a
/// pass only fills `pass` and `detail`).  A [`RecipeReport`] holds one per
/// executed pass — Table 10's breakdown generalized to any recipe.
#[derive(Debug, Clone)]
pub struct StageReport {
    pub pass: String,
    pub seconds: f64,
    /// one-line summary of what the pass did
    pub detail: String,
}

impl StageReport {
    fn new(pass: &str, detail: String) -> StageReport {
        StageReport { pass: pass.to_string(), seconds: 0.0, detail }
    }
}

/// Shared state the passes operate on: the model being quantized, the
/// calibration batch, and a cached observation (calibration forward capture +
/// outlier analysis) so consecutive passes never re-run `fwd_obs` unless a
/// pass invalidated it.
pub struct QuantCtx<'a> {
    pub model: &'a mut Model,
    pub calib: &'a IntTensor,
    pub tok: &'a Tokenizer,
    pub precision: Precision,
    pub mode: QuantMode,
    /// outlier-detection threshold (η)
    pub eta: f32,
    /// analysis of the FIRST observation (legacy `pre_report`)
    pub pre_report: Option<OutlierReport>,
    /// analysis of the re-observation after a non-empty prefix was installed
    pub post_report: Option<OutlierReport>,
    /// prefixed tokens selected/installed by the find-prefix pass
    pub prefix_tokens: Vec<i32>,
    /// fine-tuning trajectory, when a finetune pass ran
    pub ft: Option<FtReport>,
    /// per-tensor step sizes chosen by the weight-quant pass (artifact
    /// provenance)
    pub weight_quant: Option<WeightQuantReport>,
    /// `fwd_obs` executions so far (the cache-efficiency observable)
    observation_runs: usize,
    cache: Option<(Observation, OutlierReport)>,
}

impl QuantCtx<'_> {
    fn ensure_observed(&mut self) -> Result<()> {
        if self.cache.is_none() {
            let pair = outlier::observe_and_analyze(self.model, self.calib, self.eta)?;
            self.observation_runs += 1;
            self.cache = Some(pair);
        }
        Ok(())
    }

    /// Run `f` with the model and the current (cached) observation.  The
    /// observation is captured on first use and reused until a pass
    /// invalidates it.
    pub fn with_observation<T>(
        &mut self,
        f: impl FnOnce(&mut Model, &Observation, &OutlierReport) -> Result<T>,
    ) -> Result<T> {
        self.ensure_observed()?;
        let (obs, rep) = self.cache.take().expect("ensured above");
        let out = f(&mut *self.model, &obs, &rep);
        self.cache = Some((obs, rep));
        out
    }

    /// The current observation's outlier analysis (cached like
    /// [`QuantCtx::with_observation`]).
    pub fn report(&mut self) -> Result<OutlierReport> {
        self.ensure_observed()?;
        Ok(self.cache.as_ref().expect("ensured above").1.clone())
    }

    /// Drop the cached observation (the model function changed).  The runner
    /// calls this after every pass whose
    /// [`QuantPass::invalidates_observation`] is true; a pass may also call
    /// it directly for finer-grained control.
    pub fn invalidate_observation(&mut self) {
        self.cache = None;
    }

    pub fn observation_runs(&self) -> usize {
        self.observation_runs
    }
}

/// One composable quantization pass.
pub trait QuantPass {
    /// Stable pass name (keys [`RecipeReport::stage_seconds`]).
    fn name(&self) -> &str;

    /// Whether this pass changes the model function (weights, rotations,
    /// prefix), so cached observations must be re-captured afterwards.
    /// Passes that only set quantization scales return false: observations
    /// run the fp `fwd_obs` path, which ignores them.
    fn invalidates_observation(&self) -> bool {
        false
    }

    /// Execute the pass.  `seconds` of the returned report is stamped by the
    /// runner (wall time of this call).
    fn run(&self, ctx: &mut QuantCtx) -> Result<StageReport>;
}

// ---------------------------------------------------------------------------
// The seven passes
// ---------------------------------------------------------------------------

/// SmoothQuant-analog channel scaling (baseline; uses pre-rotation captures).
struct SmoothPass {
    alpha: f32,
}

impl QuantPass for SmoothPass {
    fn name(&self) -> &str {
        "smooth"
    }

    fn invalidates_observation(&self) -> bool {
        true // norm gains and weights change
    }

    fn run(&self, ctx: &mut QuantCtx) -> Result<StageReport> {
        let alpha = self.alpha;
        ctx.with_observation(|model, obs, _| smooth::apply(model, obs, alpha))?;
        Ok(StageReport::new(self.name(), format!("α={alpha} channel scaling (norm→linear)")))
    }
}

/// Hadamard rotation folding (R1/R2/R4 weight-side, R3/R4 online).
struct RotatePass;

impl QuantPass for RotatePass {
    fn name(&self) -> &str {
        "rotate"
    }

    fn invalidates_observation(&self) -> bool {
        true // weights move into the rotated basis
    }

    fn run(&self, ctx: &mut QuantCtx) -> Result<StageReport> {
        let cfg = ctx.model.cfg.clone();
        rotation::absorb_norm_gains(&cfg, &mut ctx.model.weights)?;
        rotation::fold_rotations(&cfg, &mut ctx.model.weights)?;
        let (r3, r4) = rotation::online_matrices(&ctx.model.cfg, true);
        ctx.model.quant.r3 = r3;
        ctx.model.quant.r4 = r4;
        ctx.model.quant.rotated = true;
        ctx.model.refresh_weights()?;
        Ok(StageReport::new(self.name(), "R1/R2/R4 folded, R3/R4 online".into()))
    }
}

/// Observe → select prefixed outlier tokens → materialize + install their KV
/// (§5.1 "Find Prefixed Outliers"; the paper's ~1-minute offline step).
struct FindPrefixPass {
    policy: Option<PrefixPolicy>,
}

impl QuantPass for FindPrefixPass {
    fn name(&self) -> &str {
        "find-prefix"
    }

    // Invalidation is conditional (declared inside run): an EMPTY selection
    // (the FirstN(0) ablation) leaves the model function unchanged, so the
    // cached observation stays valid — exactly the v1 behavior.

    fn run(&self, ctx: &mut QuantCtx) -> Result<StageReport> {
        let report = ctx.report()?;
        let toks = match &self.policy {
            Some(p) => prefix::select_with_policy(&report, ctx.tok, p),
            None => prefix::select_tokens(&report, ctx.tok),
        };
        prefix::install(ctx.model, &toks, ctx.tok.spec.pad)?;
        let detail = if toks.is_empty() {
            "(empty prefix — policy selected no tokens)".to_string()
        } else {
            // a non-empty prefix changes every downstream capture
            ctx.invalidate_observation();
            format!("prefix={:?} (o={})", prefix::render(&toks, ctx.tok), report.o)
        };
        ctx.pre_report = Some(report);
        ctx.prefix_tokens = toks;
        Ok(StageReport::new(self.name(), detail))
    }
}

/// Materialize the observation later passes consume as fp targets (block
/// captures + fp KV).  After a find-prefix pass this is the re-observation
/// with the prefix in place; for prefix-less recipes it is the first (and
/// only) observation.
struct ReObservePass;

impl QuantPass for ReObservePass {
    fn name(&self) -> &str {
        "re-observe"
    }

    fn run(&self, ctx: &mut QuantCtx) -> Result<StageReport> {
        let report = ctx.report()?;
        let detail = format!(
            "fp targets captured ({} in-sequence outliers)",
            report.total_outliers
        );
        if ctx.pre_report.is_none() {
            ctx.pre_report = Some(report);
        } else if !ctx.prefix_tokens.is_empty() {
            ctx.post_report = Some(report);
        }
        Ok(StageReport::new(self.name(), detail))
    }
}

/// Host-side weight quantization (per-channel RTN/grid, or per-group).
struct WeightQuantPass {
    granularity: Granularity,
    grid_search: bool,
}

impl QuantPass for WeightQuantPass {
    fn name(&self) -> &str {
        "weight-quant"
    }

    // Deliberately does NOT invalidate: the fp targets for grid-init and
    // fine-tuning are captured BEFORE weight quantization (v1 semantics).

    fn run(&self, ctx: &mut QuantCtx) -> Result<StageReport> {
        let grid = if self.grid_search { 40 } else { 1 };
        let group = match self.granularity {
            Granularity::PerChannel => None,
            Granularity::PerGroup(g) => Some(g),
        };
        let rep = pipeline::quantize_weights_raw(ctx.model, ctx.precision.w, group, grid)?;
        let n_tensors = rep.tensors.len();
        ctx.weight_quant = Some(rep);
        let w = ctx.precision.w;
        let detail = format!("w{w} {:?} grid={grid} ({n_tensors} tensors)", self.granularity);
        Ok(StageReport::new(self.name(), detail))
    }
}

/// Static activation/KV scale initialization (max-init + per-head KV grid +
/// block-output coordinate-descent act grid, §6.1).
struct GridInitPass {
    grid_search: bool,
}

impl QuantPass for GridInitPass {
    fn name(&self) -> &str {
        "grid-init"
    }

    fn run(&self, ctx: &mut QuantCtx) -> Result<StageReport> {
        let precision = ctx.precision;
        let grid_search = self.grid_search;
        ctx.with_observation(|model, obs, _| {
            let qa = model.quant.qmax_act.data[0];
            model.quant.act_scales = calibrate::max_init_act_scales(model, obs, qa);
            if precision.kv < 16 {
                model.quant.kv_scales = calibrate::kv_scales_grid(
                    model,
                    obs,
                    precision.kv,
                    if grid_search { GridCfg::default().kv_points } else { 1 },
                );
            } else {
                // near-lossless 16-bit static: max-based per-head init
                model.quant.kv_scales = calibrate::kv_scales_grid(model, obs, 16, 1);
            }
            if grid_search && precision.a < 16 {
                calibrate::act_scales_grid(model, obs, &GridCfg::default())?;
            }
            Ok(())
        })?;
        Ok(StageReport::new(
            self.name(),
            format!(
                "static scales (kv grid={}, act grid={})",
                precision.kv < 16 && grid_search,
                precision.a < 16 && grid_search
            ),
        ))
    }
}

/// Block-wise fine-tuning of step sizes + weights (§5.2).
struct FinetunePass {
    epochs: usize,
}

impl QuantPass for FinetunePass {
    fn name(&self) -> &str {
        "finetune"
    }

    fn invalidates_observation(&self) -> bool {
        true // weights change (irrelevant for the last pass, but honest)
    }

    fn run(&self, ctx: &mut QuantCtx) -> Result<StageReport> {
        let ft_cfg = FtCfg { epochs: self.epochs, ..FtCfg::default() };
        let ft_mode = if ctx.mode == QuantMode::Dynamic {
            QuantMode::Dynamic
        } else {
            QuantMode::Static
        };
        let rep = ctx.with_observation(|m, obs, _| finetune::finetune(m, obs, ft_mode, &ft_cfg))?;
        let detail = format!("{} epochs over {} blocks", self.epochs, rep.layers.len());
        ctx.ft = Some(rep);
        Ok(StageReport::new(self.name(), detail))
    }
}

// ---------------------------------------------------------------------------
// Recipe: typed config compiled to an ordered pass list
// ---------------------------------------------------------------------------

/// An ordered, named quantization pass list.  Construct via the presets or
/// [`Recipe::builder`]; execute with [`Recipe::run`].
pub struct Recipe {
    pub name: String,
    pub precision: Precision,
    /// activation/KV quantization mode of the serving executables
    pub mode: QuantMode,
    passes: Vec<Box<dyn QuantPass>>,
}

/// Builder for [`Recipe`] (mirrors `ServerConfig::builder`): typed knobs in,
/// ordered pass list out.  `build()` compiles the configuration into the
/// canonical order smooth → rotate → find-prefix → re-observe → weight-quant
/// → grid-init → finetune, including only the passes the config needs.
pub struct RecipeBuilder {
    name: Option<String>,
    precision: Precision,
    mode: QuantMode,
    rotate: bool,
    smooth: bool,
    use_prefix: bool,
    prefix_policy: Option<PrefixPolicy>,
    grid_search: bool,
    ft_epochs: usize,
    granularity: Granularity,
}

impl Recipe {
    /// Builder with RTN-like defaults: dynamic mode, per-channel weights,
    /// no rotation/smooth/prefix/grid/fine-tuning.
    pub fn builder(precision: Precision) -> RecipeBuilder {
        RecipeBuilder {
            name: None,
            precision,
            mode: QuantMode::Dynamic,
            rotate: false,
            smooth: false,
            use_prefix: false,
            prefix_policy: None,
            grid_search: false,
            ft_epochs: 0,
            granularity: Granularity::PerChannel,
        }
    }

    // --- paper presets (Tables 3-6) -------------------------------------

    pub fn fp16() -> Recipe {
        Recipe::builder(Precision::FP16).mode(QuantMode::Fp).name("FP16").build()
    }

    /// Round-to-nearest, per-token dynamic (the ablation baseline, Table 6).
    pub fn rtn(p: Precision) -> Recipe {
        Recipe::builder(p).name(&format!("RTN {}", p.label())).build()
    }

    /// QuaRot-analog: Hadamard rotation + per-token dynamic quantization.
    pub fn quarot(p: Precision) -> Recipe {
        Recipe::builder(p).rotate(true).name(&format!("QuaRot {}", p.label())).build()
    }

    /// SmoothQuant-analog: channel scaling + static per-tensor activations.
    pub fn smoothquant(p: Precision) -> Recipe {
        Recipe::builder(p)
            .mode(QuantMode::Static)
            .smooth(true)
            .grid_search(true)
            .name(&format!("SmoothQuant {}", p.label()))
            .build()
    }

    /// Atom-analog: per-group weights, dynamic activations.
    pub fn atom(p: Precision) -> Recipe {
        Recipe::builder(p)
            .granularity(Granularity::PerGroup(64))
            .name(&format!("Atom {}", p.label()))
            .build()
    }

    /// PrefixQuant without fine-tuning (grid search only).
    pub fn prefixquant_wo_ft(p: Precision) -> Recipe {
        Recipe::builder(p)
            .mode(QuantMode::Static)
            .rotate(true)
            .prefix(true)
            .grid_search(true)
            .name(&format!("PrefixQuant w/o FT {}", p.label()))
            .build()
    }

    /// Full PrefixQuant with block-wise fine-tuning.
    pub fn prefixquant(p: Precision, epochs: usize) -> Recipe {
        Recipe::builder(p)
            .mode(QuantMode::Static)
            .rotate(true)
            .prefix(true)
            .grid_search(true)
            .finetune(epochs)
            .name(&format!("PrefixQuant {}", p.label()))
            .build()
    }

    /// Bridge from the legacy v1 [`SchemeConfig`] (exact semantics, any
    /// combination of the ten fields) — used by `pipeline::quantize` and the
    /// golden parity suite.
    pub fn from_scheme(s: &SchemeConfig) -> Recipe {
        let mut b = Recipe::builder(Precision::new(s.w_bits, s.a_bits, s.kv_bits))
            .name(&s.name)
            .mode(s.mode)
            .rotate(s.rotate)
            .smooth(s.smooth)
            .prefix(s.use_prefix)
            .grid_search(s.grid_search)
            .finetune(s.ft_epochs);
        if let Some(g) = s.w_group {
            b = b.granularity(Granularity::PerGroup(g));
        }
        if let Some(p) = &s.prefix_override {
            b = b.prefix_policy(p.clone());
        }
        b.build()
    }

    /// Ordered pass names (the compiled plan).
    pub fn pass_names(&self) -> Vec<&str> {
        self.passes.iter().map(|p| p.name()).collect()
    }

    /// Execute the recipe on a freshly-loaded model.  `calib` is the [B,S]
    /// calibration batch (geometry of `fwd_obs`).  Sets the qmax scalars,
    /// runs every pass (timing each), and freezes the final state on device.
    pub fn run(
        &self,
        model: &mut Model,
        calib: &IntTensor,
        tok: &Tokenizer,
    ) -> Result<RecipeReport> {
        let t0 = Instant::now();
        model.quant.qmax_act = Tensor::scalar(qmax_for_bits(self.precision.a.max(2)));
        model.quant.qmax_kv = Tensor::scalar(qmax_for_bits(self.precision.kv.max(2)));
        let mut ctx = QuantCtx {
            model,
            calib,
            tok,
            precision: self.precision,
            mode: self.mode,
            eta: ETA,
            pre_report: None,
            post_report: None,
            prefix_tokens: Vec::new(),
            ft: None,
            weight_quant: None,
            observation_runs: 0,
            cache: None,
        };
        let mut stages = Vec::with_capacity(self.passes.len());
        for pass in &self.passes {
            let t = Instant::now();
            let mut sr = pass.run(&mut ctx)?;
            sr.seconds = t.elapsed().as_secs_f64();
            if pass.invalidates_observation() {
                ctx.invalidate_observation();
            }
            stages.push(sr);
        }
        let QuantCtx {
            model,
            pre_report,
            post_report,
            prefix_tokens,
            ft,
            weight_quant,
            observation_runs,
            ..
        } = ctx;
        // hot-path: park the now-final quant/prefix state on device
        model.freeze()?;
        Ok(RecipeReport {
            recipe: self.name.clone(),
            precision: self.precision,
            mode: self.mode,
            prefix_rendered: prefix::render(&prefix_tokens, tok),
            stages,
            pre_report,
            post_report,
            prefix_tokens,
            ft,
            weight_quant,
            observation_runs,
            t_total: t0.elapsed().as_secs_f64(),
        })
    }
}

/// Everything a harness wants to know about one recipe run.
pub struct RecipeReport {
    pub recipe: String,
    pub precision: Precision,
    pub mode: QuantMode,
    /// one entry per executed pass, in order, with wall time
    pub stages: Vec<StageReport>,
    /// analysis of the first observation (None for recipes that observe
    /// nothing, e.g. pure-dynamic RTN without fine-tuning)
    pub pre_report: Option<OutlierReport>,
    /// re-observation after a non-empty prefix was installed
    pub post_report: Option<OutlierReport>,
    pub prefix_tokens: Vec<i32>,
    pub prefix_rendered: String,
    pub ft: Option<FtReport>,
    /// per-tensor weight step sizes (None when no weight-quant pass ran);
    /// recorded into [`super::QuantArtifact`] provenance on save
    pub weight_quant: Option<WeightQuantReport>,
    /// `fwd_obs` executions across the run (cache-efficiency observable)
    pub observation_runs: usize,
    pub t_total: f64,
}

impl RecipeReport {
    /// Wall seconds of the named pass (0.0 when the recipe did not run it).
    pub fn stage_seconds(&self, pass: &str) -> f64 {
        self.stages.iter().filter(|s| s.pass == pass).map(|s| s.seconds).sum()
    }

    /// Table 10's "Find Prefixed Outliers" column.
    pub fn t_find_prefix(&self) -> f64 {
        self.stage_seconds("find-prefix")
    }

    /// Table 10's "Grid-search init" column.
    pub fn t_grid(&self) -> f64 {
        self.stage_seconds("grid-init")
    }

    /// Table 10's "Fine-tuning" column.
    pub fn t_ft(&self) -> f64 {
        self.stage_seconds("finetune")
    }

    /// One-line per-pass timing breakdown (Table 10 for any recipe).
    pub fn timing_summary(&self) -> String {
        let mut parts: Vec<String> =
            self.stages.iter().map(|s| format!("{} {:.2}s", s.pass, s.seconds)).collect();
        parts.push(format!("total {:.2}s", self.t_total));
        parts.join(" | ")
    }
}

impl RecipeBuilder {
    pub fn name(mut self, name: &str) -> Self {
        self.name = Some(name.to_string());
        self
    }

    pub fn mode(mut self, mode: QuantMode) -> Self {
        self.mode = mode;
        self
    }

    pub fn rotate(mut self, on: bool) -> Self {
        self.rotate = on;
        self
    }

    pub fn smooth(mut self, on: bool) -> Self {
        self.smooth = on;
        self
    }

    /// Include the find-prefix pass (select + install prefixed outliers).
    pub fn prefix(mut self, on: bool) -> Self {
        self.use_prefix = on;
        self
    }

    /// Override the prefix content (Table 14/15/17 ablations).  Only
    /// meaningful with `prefix(true)`.
    pub fn prefix_policy(mut self, policy: PrefixPolicy) -> Self {
        self.prefix_policy = Some(policy);
        self
    }

    pub fn grid_search(mut self, on: bool) -> Self {
        self.grid_search = on;
        self
    }

    /// Block-wise fine-tuning epochs (0 = no finetune pass).
    pub fn finetune(mut self, epochs: usize) -> Self {
        self.ft_epochs = epochs;
        self
    }

    pub fn granularity(mut self, granularity: Granularity) -> Self {
        self.granularity = granularity;
        self
    }

    /// Compile the typed config into the ordered pass list.
    pub fn build(self) -> Recipe {
        let mut passes: Vec<Box<dyn QuantPass>> = Vec::new();
        if self.smooth {
            passes.push(Box::new(SmoothPass { alpha: 0.5 }));
        }
        if self.rotate {
            passes.push(Box::new(RotatePass));
        }
        if self.use_prefix {
            passes.push(Box::new(FindPrefixPass { policy: self.prefix_policy }));
        }
        // fp targets are consumed by grid-init and finetune, and the
        // re-observation after a prefix install is part of the paper's flow
        let needs_obs = self.mode == QuantMode::Static || self.ft_epochs > 0 || self.use_prefix;
        if needs_obs {
            passes.push(Box::new(ReObservePass));
        }
        if self.precision.w < 16 {
            passes.push(Box::new(WeightQuantPass {
                granularity: self.granularity,
                grid_search: self.grid_search,
            }));
        }
        if self.mode == QuantMode::Static {
            passes.push(Box::new(GridInitPass { grid_search: self.grid_search }));
        }
        if self.ft_epochs > 0 {
            passes.push(Box::new(FinetunePass { epochs: self.ft_epochs }));
        }
        let name = self.name.unwrap_or_else(|| format!("custom {}", self.precision.label()));
        Recipe { name, precision: self.precision, mode: self.mode, passes }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_compile_to_expected_passes() {
        assert!(Recipe::fp16().pass_names().is_empty());
        assert_eq!(Recipe::rtn(Precision::new(4, 4, 4)).pass_names(), ["weight-quant"]);
        assert_eq!(
            Recipe::quarot(Precision::new(4, 4, 4)).pass_names(),
            ["rotate", "weight-quant"]
        );
        assert_eq!(
            Recipe::smoothquant(Precision::new(4, 4, 4)).pass_names(),
            ["smooth", "re-observe", "weight-quant", "grid-init"]
        );
        assert_eq!(Recipe::atom(Precision::new(4, 4, 4)).pass_names(), ["weight-quant"]);
        assert_eq!(
            Recipe::prefixquant_wo_ft(Precision::new(4, 4, 4)).pass_names(),
            ["rotate", "find-prefix", "re-observe", "weight-quant", "grid-init"]
        );
        assert_eq!(
            Recipe::prefixquant(Precision::new(4, 4, 4), 10).pass_names(),
            ["rotate", "find-prefix", "re-observe", "weight-quant", "grid-init", "finetune"]
        );
    }

    #[test]
    fn presets_match_legacy_names_and_modes() {
        let p = Precision::new(4, 4, 4);
        let pairs: Vec<(SchemeConfig, Recipe)> = vec![
            (SchemeConfig::fp16(), Recipe::fp16()),
            (SchemeConfig::rtn(4, 4, 4), Recipe::rtn(p)),
            (SchemeConfig::quarot(4, 4, 4), Recipe::quarot(p)),
            (SchemeConfig::smoothquant(4, 4, 4), Recipe::smoothquant(p)),
            (SchemeConfig::atom(4, 4, 4), Recipe::atom(p)),
            (SchemeConfig::prefixquant_wo_ft(4, 4, 4), Recipe::prefixquant_wo_ft(p)),
            (SchemeConfig::prefixquant(4, 4, 4, 10), Recipe::prefixquant(p, 10)),
        ];
        for (scheme, recipe) in pairs {
            assert_eq!(scheme.name, recipe.name);
            assert_eq!(scheme.mode, recipe.mode);
            assert_eq!(
                Precision::new(scheme.w_bits, scheme.a_bits, scheme.kv_bits),
                recipe.precision
            );
            // from_scheme must compile to the same plan as the preset
            let bridged = Recipe::from_scheme(&scheme);
            assert_eq!(bridged.name, recipe.name);
            assert_eq!(bridged.mode, recipe.mode);
            assert_eq!(bridged.precision, recipe.precision);
            assert_eq!(bridged.pass_names(), recipe.pass_names());
        }
    }

    #[test]
    fn fp16_and_w16_skip_weight_quant() {
        // a W16 static scheme (Table 2 shape) has no weight-quant pass
        let r = Recipe::builder(Precision::new(16, 4, 16))
            .mode(QuantMode::Static)
            .grid_search(true)
            .build();
        assert_eq!(r.pass_names(), ["re-observe", "grid-init"]);
        assert_eq!(r.name, "custom W16A4KV16");
    }

    #[test]
    fn builder_knobs_map_to_passes() {
        let r = Recipe::builder(Precision::new(3, 16, 16))
            .mode(QuantMode::Static)
            .granularity(Granularity::PerGroup(64))
            .grid_search(true)
            .prefix(true)
            .finetune(2)
            .build();
        assert_eq!(
            r.pass_names(),
            ["find-prefix", "re-observe", "weight-quant", "grid-init", "finetune"]
        );
        // dynamic without fine-tuning needs no observation at all
        let dynamic = Recipe::builder(Precision::new(4, 4, 4)).rotate(true).build();
        assert_eq!(dynamic.pass_names(), ["rotate", "weight-quant"]);
    }

    #[test]
    fn precision_label() {
        assert_eq!(Precision::new(4, 8, 4).label(), "W4A8KV4");
        assert_eq!(Precision::FP16, Precision::new(16, 16, 16));
    }
}
