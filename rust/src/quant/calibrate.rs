//! Grid-search initialization of static quantization scales (§6.1).
//!
//! Paper protocol: initialize all quantization parameters by grid search on a
//! small calibration set; minimize *block outputs* for per-tensor activation
//! scales (coordinate descent over the 4 sites per block) and *layer outputs*
//! for fine-grained per-head KV scales (host-side population MSE — no
//! executable round-trip needed, the fp K/V populations are in the
//! observation).

use anyhow::Result;

use crate::model::{Model, QuantMode};
use crate::tensor::Tensor;

use super::blockrun::{self, BlockCtx};
use super::outlier::Observation;
use super::quantizer;

/// Grid-search configuration.
#[derive(Debug, Clone)]
pub struct GridCfg {
    /// γ grid for activation scales (γ·max|x| / qmax), block-output MSE.
    pub act_points: usize,
    pub act_lo: f32,
    pub act_hi: f32,
    /// γ grid for per-head KV scales (population MSE).
    pub kv_points: usize,
    /// coordinate-descent sweeps over the 4 sites
    pub sweeps: usize,
}

impl Default for GridCfg {
    fn default() -> Self {
        Self { act_points: 12, act_lo: 0.35, act_hi: 1.0, kv_points: 24, sweeps: 1 }
    }
}

/// Max-based initial activation scales from the observed site stats:
/// scale[l][site] = top1[l][site] / qmax  (RTN-style init).
pub fn max_init_act_scales(model: &Model, obs: &Observation, qmax_act: f32) -> Tensor {
    let cfg = &model.cfg;
    let (l, n_sites) = (cfg.n_layers, cfg.n_sites());
    let (b, s) = (obs.active.shape[0], obs.active.shape[1]);
    let mut scales = Tensor::zeros(&[l, 4]);
    for li in 0..l {
        for site in 0..4 {
            let mut top = 0.0f32;
            for bi in 0..b {
                for si in 0..s {
                    top = top.max(obs.stats.data[((li * n_sites + site) * b + bi) * s + si]);
                }
            }
            scales.data[li * 4 + site] = (top / qmax_act).max(1e-8);
        }
    }
    scales
}

/// Per-head static KV scales by population grid search over the observed fp
/// K/V values ("layer output" objective — fine-grained per the paper).
/// Parallelized over the (layer × cache × head) scale slots via the host
/// kernel layer; each slot's gather + pruned search is independent, so the
/// result is identical for every `PQ_THREADS`.
pub fn kv_scales_grid(model: &Model, obs: &Observation, kv_bits: usize, points: usize) -> Tensor {
    let cfg = &model.cfg;
    let (l, h, dh) = (cfg.n_layers, cfg.n_heads, cfg.d_head);
    let b = obs.k_cache.shape[1];
    let s = obs.k_cache.shape[3];
    let mut scales = Tensor::zeros(&[l, 2, h]);
    let caches = [&obs.k_cache, &obs.v_cache];
    let units = l * 2 * h;
    // few slots, heavy gathers: size the worker count by total elements
    let nt = crate::kernels::useful_threads(crate::kernels::threads(), units, units * b * s * dh);
    crate::kernels::par_bands(&mut scales.data, units, 1, nt, |u0, band| {
        for (off, slot) in band.iter_mut().enumerate() {
            // slot u = (li·2 + ci)·h + hi — same layout as the serial scan
            let u = u0 + off;
            let (li, ci, hi) = (u / (2 * h), (u / h) % 2, u % h);
            let cache = caches[ci];
            // gather this head's population across batch and positions
            let mut vals = Vec::with_capacity(b * s * dh);
            for bi in 0..b {
                for si in 0..s {
                    let base = (((li * b + bi) * h + hi) * s + si) * dh;
                    vals.extend_from_slice(&cache.data[base..base + dh]);
                }
            }
            *slot = quantizer::search_scale(&vals, kv_bits, points);
        }
    });
    scales
}

/// Coordinate-descent grid search of the 4 per-tensor activation scales of
/// every block, minimizing block-output MSE against the fp captures.
/// Uses the *quantized-path running input* (x rolls through block_static), as
/// the paper propagates quantized activations block by block.
/// Returns the calibrated scales and the per-layer final MSE.
pub fn act_scales_grid(
    model: &mut Model,
    obs: &Observation,
    grid: &GridCfg,
) -> Result<Vec<f32>> {
    let cfg = model.cfg.clone();
    let l = cfg.n_layers;
    let mut layer_mse = Vec::with_capacity(l);
    let mut x = obs.captures.index0(0); // embedding output (identical in quant path)
    for li in 0..l {
        let target = obs.captures.index0(li + 1);
        let mut best_scales = model.quant.act_scales.index0(li);
        let mut best_mse = eval_block_mse(model, li, &best_scales, &x, &obs.active, &target)?;
        for _sweep in 0..grid.sweeps {
            for site in 0..4 {
                let base = best_scales.data[site];
                for p in 0..grid.act_points {
                    let gamma = grid.act_lo
                        + (grid.act_hi - grid.act_lo) * p as f32
                            / (grid.act_points - 1).max(1) as f32;
                    let mut cand = best_scales.clone();
                    cand.data[site] = base * gamma;
                    let mse = eval_block_mse(model, li, &cand, &x, &obs.active, &target)?;
                    if mse < best_mse {
                        best_mse = mse;
                        best_scales = cand;
                    }
                }
            }
        }
        // write back the winning scales for this layer
        for site in 0..4 {
            model.quant.act_scales.data[li * 4 + site] = best_scales.data[site];
        }
        layer_mse.push(best_mse);
        // roll the quantized path forward with the calibrated scales
        let ctx = BlockCtx::from_model(model, li)?;
        x = blockrun::block_forward(model, QuantMode::Static, &ctx, &x, &obs.active)?;
    }
    Ok(layer_mse)
}

fn eval_block_mse(
    model: &Model,
    layer: usize,
    act_scales: &Tensor,
    x: &Tensor,
    active: &Tensor,
    target: &Tensor,
) -> Result<f32> {
    let ctx = BlockCtx::from_model(model, layer)?.with_act_scales(act_scales.clone());
    let y = blockrun::block_forward(model, QuantMode::Static, &ctx, x, active)?;
    Ok(y.mse(target))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_grid_sane() {
        let g = GridCfg::default();
        assert!(g.act_lo < g.act_hi);
        assert!(g.act_points >= 2 && g.kv_points >= 2);
    }
}
