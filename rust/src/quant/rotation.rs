//! Hadamard rotations (Sec. C of the paper / QuaRot / SpinQuant).
//!
//! Fold plan (computational invariance, checked by integration tests):
//!   0. absorb RMSNorm gains into the adjacent projections
//!      (ln1 → wq/wk/wv, ln2 → wg/wu, lnf → head), set gains to 1;
//!   1. R1 (hidden basis, d_model): emb ← emb·R1; in-projections
//!      (wq,wk,wv,wg,wu) ← R1ᵀ·w; out-projections (wo, wd) ← w·R1;
//!      head ← R1ᵀ·head;
//!   2. R2 (per-head value basis, d_head): wv column-blocks ← block·R2,
//!      wo row-blocks ← R2ᵀ·block;
//!   3. R4 (down_proj input, d_ff): wd ← R4ᵀ·wd — the executables apply
//!      x·R4 *online*, so folding wd keeps the function identical;
//!   4. R3 (post-RoPE Q/K, d_head) is online-only and self-cancelling in the
//!      attention inner product — nothing to fold.
//!
//! RMSNorm (with unit gain) is equivariant under orthogonal basis change, so
//! the folded model computes exactly the same function (fp path), while every
//! quantizer input lives in the outlier-spread Hadamard basis.
//!
//! Since the host-kernel layer, the folds are O(n log n) in-place fast
//! Walsh–Hadamard transforms (`kernels::fwht`) instead of explicit
//! Hadamard-matrix products — H is the Kronecker power of H₂ and symmetric,
//! so `·R1` is a row-wise butterfly and `R1ᵀ·` a column-wise one.  The
//! explicit [`hadamard`] matrix stays as the online-rotation upload and the
//! parity reference (`tests/kernel_parity.rs`,
//! `kernels::naive::fold_rotations`).

use anyhow::{bail, Result};

use crate::config::ModelConfig;
use crate::kernels::{self, fwht, ops};
use crate::runtime::WeightStore;
use crate::tensor::Tensor;

/// Normalized Sylvester-Hadamard matrix (n a power of two): H·Hᵀ = I.
pub fn hadamard(n: usize) -> Tensor {
    assert!(n.is_power_of_two(), "hadamard size {n} not a power of 2");
    let mut h = vec![1.0f32];
    let mut size = 1;
    while size < n {
        let ns = size * 2;
        let mut nh = vec![0.0f32; ns * ns];
        for i in 0..size {
            for j in 0..size {
                let v = h[i * size + j];
                nh[i * ns + j] = v;
                nh[i * ns + j + size] = v;
                nh[(i + size) * ns + j] = v;
                nh[(i + size) * ns + j + size] = -v;
            }
        }
        h = nh;
        size = ns;
    }
    let norm = 1.0 / (n as f32).sqrt();
    Tensor { shape: vec![n, n], data: h.into_iter().map(|v| v * norm).collect() }
}

/// Scale row i of a matrix by g[i] (diag(g) · W), threaded.
fn scale_rows(w: &mut Tensor, g: &[f32]) {
    let (rows, cols) = (w.shape[0], w.shape[1]);
    assert_eq!(rows, g.len());
    ops::scale_rows_nt(&mut w.data, rows, cols, g, kernels::threads());
}

/// Absorb RMSNorm gains into adjacent projections; gains become 1.
pub fn absorb_norm_gains(cfg: &ModelConfig, ws: &mut WeightStore) -> Result<()> {
    for l in 0..cfg.n_layers {
        let ln1 = ws.get(&format!("layers.{l}.ln1")).unwrap().data.clone();
        for t in ["wq", "wk", "wv"] {
            scale_rows(ws.get_mut(&format!("layers.{l}.{t}")).unwrap(), &ln1);
        }
        let ln2 = ws.get(&format!("layers.{l}.ln2")).unwrap().data.clone();
        for t in ["wg", "wu"] {
            scale_rows(ws.get_mut(&format!("layers.{l}.{t}")).unwrap(), &ln2);
        }
        ws.set(&format!("layers.{l}.ln1"), Tensor::full(&[cfg.d_model], 1.0));
        ws.set(&format!("layers.{l}.ln2"), Tensor::full(&[cfg.d_model], 1.0));
    }
    let lnf = ws.get("lnf").unwrap().data.clone();
    scale_rows(ws.get_mut("head").unwrap(), &lnf);
    ws.set("lnf", Tensor::full(&[cfg.d_model], 1.0));
    Ok(())
}

/// Fold the absorbable rotations R1/R2 and the R4 weight-side factor, all
/// as in-place FWHTs (no Hadamard matrix is ever materialized here).
/// Call `absorb_norm_gains` first (checked).
pub fn fold_rotations(cfg: &ModelConfig, ws: &mut WeightStore) -> Result<()> {
    for l in 0..cfg.n_layers {
        let ln1 = ws.get(&format!("layers.{l}.ln1")).unwrap();
        if ln1.data.iter().any(|&g| (g - 1.0).abs() > 1e-6) {
            bail!("fold_rotations requires absorbed norm gains (layer {l})");
        }
    }
    let nt = kernels::threads();
    let (d, dh, h, ff) = (cfg.d_model, cfg.d_head, cfg.n_heads, cfg.d_ff);

    // embedding rows into the rotated basis (emb ← emb·R1)
    let emb = ws.get_mut("emb").unwrap();
    let vocab = emb.shape[0];
    fwht::fwht_rows_nt(&mut emb.data, vocab, d, nt);
    // head maps rotated hidden back to logits (head ← R1ᵀ·head)
    let head = ws.get_mut("head").unwrap();
    let head_cols = head.shape[1];
    fwht::fwht_cols_nt(&mut head.data, d, head_cols, nt);

    for l in 0..cfg.n_layers {
        let name = |t: &str| format!("layers.{l}.{t}");
        for t in ["wq", "wk", "wv", "wg", "wu"] {
            let w = ws.get_mut(&name(t)).unwrap();
            let cols = w.shape[1];
            fwht::fwht_cols_nt(&mut w.data, d, cols, nt); // w ← R1ᵀ·w
        }
        for t in ["wo", "wd"] {
            let w = ws.get_mut(&name(t)).unwrap();
            let rows = w.shape[0];
            fwht::fwht_rows_nt(&mut w.data, rows, d, nt); // w ← w·R1
        }
        // R2: per-head value-basis rotation — each wv column block ·R2 is a
        // row-wise FWHT on that head's column slice; each wo row block R2ᵀ·
        // is a column-wise FWHT on that head's row slab.
        let wv = ws.get_mut(&name("wv")).unwrap();
        for head_i in 0..h {
            fwht::fwht_rows_sub_nt(&mut wv.data, d, d, head_i * dh, dh, nt);
        }
        let wo = ws.get_mut(&name("wo")).unwrap();
        for head_i in 0..h {
            let blk = &mut wo.data[head_i * dh * d..(head_i + 1) * dh * d];
            fwht::fwht_cols_nt(blk, dh, d, nt);
        }
        // R4 weight-side factor (executables apply x·R4 online)
        let wd = ws.get_mut(&name("wd")).unwrap();
        let wd_cols = wd.shape[1];
        fwht::fwht_cols_nt(&mut wd.data, ff, wd_cols, nt);
    }
    Ok(())
}

/// Online rotation matrices for the executables (identity when off).
pub fn online_matrices(cfg: &ModelConfig, rotate: bool) -> (Tensor, Tensor) {
    if rotate {
        (hadamard(cfg.d_head), hadamard(cfg.d_ff))
    } else {
        (crate::model::eye(cfg.d_head), crate::model::eye(cfg.d_ff))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hadamard_orthogonal() {
        for n in [2usize, 4, 32, 128] {
            let h = hadamard(n);
            let prod = h.matmul(&h.transpose2());
            for i in 0..n {
                for j in 0..n {
                    let want = if i == j { 1.0 } else { 0.0 };
                    assert!(
                        (prod.data[i * n + j] - want).abs() < 1e-4,
                        "H Hᵀ != I at ({i},{j}) for n={n}"
                    );
                }
            }
        }
    }

    #[test]
    fn hadamard_entries_uniform_magnitude() {
        let h = hadamard(8);
        let m = 1.0 / (8.0f32).sqrt();
        assert!(h.data.iter().all(|v| (v.abs() - m).abs() < 1e-6));
    }

    #[test]
    #[should_panic]
    fn hadamard_rejects_non_pow2() {
        hadamard(12);
    }

    #[test]
    fn scale_rows_works() {
        let mut w = Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        scale_rows(&mut w, &[2.0, 0.5]);
        assert_eq!(w.data, vec![2.0, 4.0, 1.5, 2.0]);
    }

    #[test]
    fn fwht_fold_matches_explicit_hadamard_product() {
        // y = x·H via FWHT must match the explicit matrix product
        let n = 64;
        let h = hadamard(n);
        let x = Tensor::new(
            vec![3, n],
            (0..3 * n).map(|i| ((i * 37 % 101) as f32) / 50.0 - 1.0).collect(),
        )
        .unwrap();
        let want = x.matmul(&h);
        let mut got = x.clone();
        fwht::fwht_rows_nt(&mut got.data, 3, n, 2);
        let scale = want.max_abs().max(1.0);
        for (a, b) in got.data.iter().zip(&want.data) {
            assert!((a - b).abs() <= 1e-5 * scale, "{a} vs {b}");
        }
    }
}
