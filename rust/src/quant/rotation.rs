//! Hadamard rotations (Sec. C of the paper / QuaRot / SpinQuant).
//!
//! Fold plan (computational invariance, checked by integration tests):
//!   0. absorb RMSNorm gains into the adjacent projections
//!      (ln1 → wq/wk/wv, ln2 → wg/wu, lnf → head), set gains to 1;
//!   1. R1 (hidden basis, d_model): emb ← emb·R1; in-projections
//!      (wq,wk,wv,wg,wu) ← R1ᵀ·w; out-projections (wo, wd) ← w·R1;
//!      head ← R1ᵀ·head;
//!   2. R2 (per-head value basis, d_head): wv column-blocks ← block·R2,
//!      wo row-blocks ← R2ᵀ·block;
//!   3. R4 (down_proj input, d_ff): wd ← R4ᵀ·wd — the executables apply
//!      x·R4 *online*, so folding wd keeps the function identical;
//!   4. R3 (post-RoPE Q/K, d_head) is online-only and self-cancelling in the
//!      attention inner product — nothing to fold.
//!
//! RMSNorm (with unit gain) is equivariant under orthogonal basis change, so
//! the folded model computes exactly the same function (fp path), while every
//! quantizer input lives in the outlier-spread Hadamard basis.

use anyhow::{bail, Result};

use crate::config::ModelConfig;
use crate::runtime::WeightStore;
use crate::tensor::Tensor;

/// Normalized Sylvester-Hadamard matrix (n a power of two): H·Hᵀ = I.
pub fn hadamard(n: usize) -> Tensor {
    assert!(n.is_power_of_two(), "hadamard size {n} not a power of 2");
    let mut h = vec![1.0f32];
    let mut size = 1;
    while size < n {
        let ns = size * 2;
        let mut nh = vec![0.0f32; ns * ns];
        for i in 0..size {
            for j in 0..size {
                let v = h[i * size + j];
                nh[i * ns + j] = v;
                nh[i * ns + j + size] = v;
                nh[(i + size) * ns + j] = v;
                nh[(i + size) * ns + j + size] = -v;
            }
        }
        h = nh;
        size = ns;
    }
    let norm = 1.0 / (n as f32).sqrt();
    Tensor { shape: vec![n, n], data: h.into_iter().map(|v| v * norm).collect() }
}

/// Scale row i of a matrix by g[i] (diag(g) · W).
fn scale_rows(w: &mut Tensor, g: &[f32]) {
    let (rows, cols) = (w.shape[0], w.shape[1]);
    assert_eq!(rows, g.len());
    for i in 0..rows {
        for j in 0..cols {
            w.data[i * cols + j] *= g[i];
        }
    }
}

/// Absorb RMSNorm gains into adjacent projections; gains become 1.
pub fn absorb_norm_gains(cfg: &ModelConfig, ws: &mut WeightStore) -> Result<()> {
    for l in 0..cfg.n_layers {
        let ln1 = ws.get(&format!("layers.{l}.ln1")).unwrap().data.clone();
        for t in ["wq", "wk", "wv"] {
            scale_rows(ws.get_mut(&format!("layers.{l}.{t}")).unwrap(), &ln1);
        }
        let ln2 = ws.get(&format!("layers.{l}.ln2")).unwrap().data.clone();
        for t in ["wg", "wu"] {
            scale_rows(ws.get_mut(&format!("layers.{l}.{t}")).unwrap(), &ln2);
        }
        ws.set(&format!("layers.{l}.ln1"), Tensor::full(&[cfg.d_model], 1.0));
        ws.set(&format!("layers.{l}.ln2"), Tensor::full(&[cfg.d_model], 1.0));
    }
    let lnf = ws.get("lnf").unwrap().data.clone();
    scale_rows(ws.get_mut("head").unwrap(), &lnf);
    ws.set("lnf", Tensor::full(&[cfg.d_model], 1.0));
    Ok(())
}

/// Fold the absorbable rotations R1/R2 and the R4 weight-side factor.
/// Call `absorb_norm_gains` first (checked).
pub fn fold_rotations(cfg: &ModelConfig, ws: &mut WeightStore) -> Result<()> {
    for l in 0..cfg.n_layers {
        let ln1 = ws.get(&format!("layers.{l}.ln1")).unwrap();
        if ln1.data.iter().any(|&g| (g - 1.0).abs() > 1e-6) {
            bail!("fold_rotations requires absorbed norm gains (layer {l})");
        }
    }
    let r1 = hadamard(cfg.d_model);
    let r1t = r1.transpose2();
    let r2 = hadamard(cfg.d_head);
    let r2t = r2.transpose2();
    let r4 = hadamard(cfg.d_ff);
    let r4t = r4.transpose2();

    // embedding rows into the rotated basis
    let emb = ws.get("emb").unwrap().clone();
    ws.set("emb", emb.matmul(&r1));
    // head maps rotated hidden back to logits
    let head = ws.get("head").unwrap().clone();
    ws.set("head", r1t.matmul(&head));

    for l in 0..cfg.n_layers {
        let name = |t: &str| format!("layers.{l}.{t}");
        for t in ["wq", "wk", "wv", "wg", "wu"] {
            let w = ws.get(&name(t)).unwrap().clone();
            ws.set(&name(t), r1t.matmul(&w));
        }
        for t in ["wo", "wd"] {
            let w = ws.get(&name(t)).unwrap().clone();
            ws.set(&name(t), w.matmul(&r1));
        }
        // R2: per-head value-basis rotation (wv column blocks, wo row blocks)
        let (d, dh, h) = (cfg.d_model, cfg.d_head, cfg.n_heads);
        let mut wv = ws.get(&name("wv")).unwrap().clone();
        for head_i in 0..h {
            // block = wv[:, hi*dh..(hi+1)*dh] · R2
            let mut block = Tensor::zeros(&[d, dh]);
            for i in 0..d {
                for j in 0..dh {
                    block.data[i * dh + j] = wv.data[i * d + head_i * dh + j];
                }
            }
            let rotated = block.matmul(&r2);
            for i in 0..d {
                for j in 0..dh {
                    wv.data[i * d + head_i * dh + j] = rotated.data[i * dh + j];
                }
            }
        }
        ws.set(&name("wv"), wv);
        let mut wo = ws.get(&name("wo")).unwrap().clone();
        for head_i in 0..h {
            let mut block = Tensor::zeros(&[dh, d]);
            for i in 0..dh {
                for j in 0..d {
                    block.data[i * d + j] = wo.data[(head_i * dh + i) * d + j];
                }
            }
            let rotated = r2t.matmul(&block);
            for i in 0..dh {
                for j in 0..d {
                    wo.data[(head_i * dh + i) * d + j] = rotated.data[i * d + j];
                }
            }
        }
        ws.set(&name("wo"), wo);
        // R4 weight-side factor (executables apply x·R4 online)
        let wd = ws.get(&name("wd")).unwrap().clone();
        ws.set(&name("wd"), r4t.matmul(&wd));
    }
    Ok(())
}

/// Online rotation matrices for the executables (identity when off).
pub fn online_matrices(cfg: &ModelConfig, rotate: bool) -> (Tensor, Tensor) {
    if rotate {
        (hadamard(cfg.d_head), hadamard(cfg.d_ff))
    } else {
        (crate::model::eye(cfg.d_head), crate::model::eye(cfg.d_ff))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hadamard_orthogonal() {
        for n in [2usize, 4, 32, 128] {
            let h = hadamard(n);
            let prod = h.matmul(&h.transpose2());
            for i in 0..n {
                for j in 0..n {
                    let want = if i == j { 1.0 } else { 0.0 };
                    assert!(
                        (prod.data[i * n + j] - want).abs() < 1e-4,
                        "H Hᵀ != I at ({i},{j}) for n={n}"
                    );
                }
            }
        }
    }

    #[test]
    fn hadamard_entries_uniform_magnitude() {
        let h = hadamard(8);
        let m = 1.0 / (8.0f32).sqrt();
        assert!(h.data.iter().all(|v| (v.abs() - m).abs() < 1e-6));
    }

    #[test]
    #[should_panic]
    fn hadamard_rejects_non_pow2() {
        hadamard(12);
    }

    #[test]
    fn scale_rows_works() {
        let mut w = Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        scale_rows(&mut w, &[2.0, 0.5]);
        assert_eq!(w.data, vec![2.0, 4.0, 1.5, 2.0]);
    }
}
