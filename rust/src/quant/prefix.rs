//! Prefixed-token selection and prefix-KV materialization (§5.1).
//!
//! Selection: the top-o high-frequency outlier tokens (frequency measured by
//! the η-detector, initial positions excluded) followed by [BOS] — Table 1's
//! recipe.  If no non-initial outliers exist (Llama-3/Qwen-2 pattern), the
//! prefix is just [BOS].
//!
//! Materialization: run the tiny `fwd_prefix` executable over the prefix
//! tokens alone (no pre-existing prefix, n_ctx_sinks = 0) and keep its fp
//! K/V as the shared prefixed entries; `n_ctx_sinks` is read back from the
//! graph's own sink mask so rust and the executables can never disagree on
//! how many sink slots the prefix fills.

use anyhow::Result;

use crate::model::{Model, PrefixState};
use crate::runtime::Value;
use crate::tensor::{IntTensor, Tensor};
use crate::tokenizer::Tokenizer;
use crate::util::rng::SplitMix64;

use super::outlier::OutlierReport;
use super::PrefixPolicy;

/// Choose the prefix token ids from an outlier report (default policy).
///
/// [BOS] occupies position 0 — the initial-token outlier slot — followed by
/// the top-(o-1) high-frequency outlier tokens, so the prefix fills exactly
/// the model's o sink slots.  (The paper renders the same set as
/// ".\n[BOS]"; sequence order puts the initial-position token first.)
/// If fewer distinct outlier tokens exist than slots, the top one repeats.
pub fn select_tokens(report: &OutlierReport, tok: &Tokenizer) -> Vec<i32> {
    let mut toks = vec![tok.spec.bos];
    if report.o > 1 {
        let need = report.o - 1;
        for i in 0..need {
            match report.freq.get(i).or_else(|| report.freq.first()) {
                Some(&(id, _)) => toks.push(id),
                None => break,
            }
        }
    }
    toks
}

/// Apply an ablation policy to the default selection.
pub fn select_with_policy(
    report: &OutlierReport,
    tok: &Tokenizer,
    policy: &PrefixPolicy,
) -> Vec<i32> {
    let default = select_tokens(report, tok);
    match policy {
        PrefixPolicy::FirstN(n) => default.into_iter().take(*n).collect(),
        PrefixPolicy::OnlyHighestFreq => {
            let top = report.freq.first().map(|&(id, _)| id).unwrap_or(tok.spec.bos);
            vec![top; default.len()]
        }
        PrefixPolicy::Random(seed) => {
            let mut rng = SplitMix64::new(*seed);
            (0..default.len())
                .map(|_| {
                    // random printable non-delimiter byte tokens
                    loop {
                        let id = tok.spec.byte_offset + 33 + rng.below(90) as i32;
                        if !tok.is_delimiter(id) {
                            return id;
                        }
                    }
                })
                .collect()
        }
        PrefixPolicy::Fixed3 => {
            // QFeP-analog: always exactly 3 prefixed tokens
            let mut t = vec![tok.spec.bos];
            for i in 0..2 {
                t.push(report.freq.get(i).or_else(|| report.freq.first()).map(|&(id, _)| id).unwrap_or(tok.spec.bos));
            }
            t
        }
    }
}

/// Human-readable prefix content (Table 1 rendering).
pub fn render(tokens: &[i32], tok: &Tokenizer) -> String {
    tokens.iter().map(|&t| tok.token_repr(t)).collect::<Vec<_>>().join("")
}

/// Compute the prefix KV with the model's *current* weights/rotations and
/// install it on the model.  Pass an empty token list to clear the prefix.
pub fn install(model: &mut Model, tokens: &[i32], pad_id: i32) -> Result<()> {
    model.unfreeze(); // prefix state is about to change
    let cfg = model.cfg.clone();
    let p = cfg.max_prefix;
    if tokens.is_empty() {
        model.prefix = PrefixState::empty(&cfg);
        return Ok(());
    }
    if tokens.len() > p {
        anyhow::bail!("prefix length {} exceeds padded capacity {p}", tokens.len());
    }
    let sig = model.exec("fwd_prefix")?;
    let mut padded = tokens.to_vec();
    padded.resize(p, pad_id);
    let toks = IntTensor::new(vec![1, p], padded)?;
    // the prefix is computed as a fresh sequence: no prefix, no context sinks
    let zero = IntTensor::scalar(0);
    let empty = PrefixState::empty(&cfg);
    let inputs = model.bind(
        &sig,
        &[
            ("tokens", Value::I32(&toks)),
            ("n_prefix", Value::I32(&zero)),
            ("n_ctx_sinks", Value::I32(&zero)),
            ("prefix_k", Value::F32(&empty.k)),
            ("prefix_v", Value::F32(&empty.v)),
        ],
    )?;
    let outs = model.engine.run(&sig, &inputs)?;
    let k_idx = sig.output_index("k_cache")?;
    let v_idx = sig.output_index("v_cache")?;
    let a_idx = sig.output_index("active")?;
    let k = outs[k_idx].clone().f32()?; // [L,1,H,P,dh]
    let v = outs[v_idx].clone().f32()?;
    let active = outs[a_idx].clone().f32()?; // [1,P]

    let (l, h, dh) = (cfg.n_layers, cfg.n_heads, cfg.d_head);
    let n = tokens.len();
    // squeeze batch dim and zero the padded slots beyond n
    let reshaped = |t: &Tensor| -> Result<Tensor> {
        let mut out = Tensor::zeros(&[l, h, p, dh]);
        for li in 0..l {
            for hi in 0..h {
                for pi in 0..n {
                    for di in 0..dh {
                        let src = (((li * 1 + 0) * h + hi) * p + pi) * dh + di;
                        let dst = ((li * h + hi) * p + pi) * dh + di;
                        out.data[dst] = t.data[src];
                    }
                }
            }
        }
        Ok(out)
    };
    let n_ctx_sinks = active.data[..n].iter().filter(|&&a| a > 0.5).count() as i32;
    model.prefix = PrefixState {
        tokens: tokens.to_vec(),
        n_prefix: n as i32,
        n_ctx_sinks,
        k: reshaped(&k)?,
        v: reshaped(&v)?,
    };
    Ok(())
}

/// Quick sanity: with the prefix installed, the first `n_prefix` RoPE
/// positions are taken, so downstream sequences start at position n_prefix.
pub fn describe(model: &Model, tok: &Tokenizer) -> Result<String> {
    let p = &model.prefix;
    if p.n_prefix == 0 {
        return Ok("(no prefix)".into());
    }
    Ok(format!(
        "prefix={} (n={}, sinks={})",
        render(&p.tokens, tok),
        p.n_prefix,
        p.n_ctx_sinks
    ))
}

#[allow(dead_code)]
fn _assert_model_send(_m: &Model) {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TokenizerSpec;
    use crate::quant::outlier::{OutlierReport, SiteStat};

    fn tok() -> Tokenizer {
        Tokenizer::new(TokenizerSpec {
            pad: 0,
            bos: 1,
            eos: 2,
            byte_offset: 3,
            vocab_size: 272,
            delimiter_ids: vec![13, 49],
        })
    }

    fn report(o: usize, freq: Vec<(i32, usize)>) -> OutlierReport {
        OutlierReport {
            site_stats: vec![vec![SiteStat { top1: 1.0, median: 1.0, min1: 1.0 }]],
            o_per_block: vec![o as f32],
            o,
            freq,
            positions: vec![],
            total_outliers: 0,
            eta: 64.0,
        }
    }

    #[test]
    fn default_selection_bos_then_topfreq() {
        let r = report(3, vec![(49, 10), (13, 4), (100, 1)]);
        let t = tok();
        assert_eq!(select_tokens(&r, &t), vec![1, 49, 13]);
        assert_eq!(render(&[1, 49, 13], &t), "[BOS].\\n");
    }

    #[test]
    fn initial_only_models_get_bos() {
        let r = report(0, vec![]);
        assert_eq!(select_tokens(&r, &tok()), vec![1]);
        let r1 = report(1, vec![]);
        assert_eq!(select_tokens(&r1, &tok()), vec![1]);
    }

    #[test]
    fn repeats_top_token_when_few_distinct() {
        let r = report(3, vec![(49, 10)]);
        assert_eq!(select_tokens(&r, &tok()), vec![1, 49, 49]);
    }

    #[test]
    fn policies() {
        let r = report(2, vec![(49, 10), (13, 4)]);
        let t = tok();
        assert_eq!(select_with_policy(&r, &t, &PrefixPolicy::FirstN(1)), vec![1]);
        assert_eq!(
            select_with_policy(&r, &t, &PrefixPolicy::OnlyHighestFreq),
            vec![49, 49]
        );
        let rand = select_with_policy(&r, &t, &PrefixPolicy::Random(7));
        assert_eq!(rand.len(), 2);
        assert!(rand.iter().all(|&id| !t.is_delimiter(id)));
        assert_eq!(select_with_policy(&r, &t, &PrefixPolicy::Fixed3).len(), 3);
    }
}
