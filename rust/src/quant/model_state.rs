//! Persist / restore a quantized model (the deployable artifact).
//!
//! `save` writes the post-pipeline state — folded+quantized weights, static
//! scales, online rotation matrices, prefixed tokens and their KV — into a
//! directory; `load` restores a ready-to-serve [`Model`] without re-running
//! the pipeline (the paper's "quantize once, deploy" story).

use std::path::Path;
use std::rc::Rc;

use anyhow::{anyhow, Result};

use crate::model::{Model, PrefixState, QuantMode, QuantState};
use crate::runtime::{Engine, WeightStore};
use crate::tensor::Tensor;
use crate::util::json::{self, Json};

const STATE_FILE: &str = "quant_state.bin";
const WEIGHTS_FILE: &str = "weights.bin";
const META_FILE: &str = "quantized.json";

pub fn save(model: &Model, mode: QuantMode, dir: &Path) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    model.weights.save(&dir.join(WEIGHTS_FILE))?;
    let q = &model.quant;
    let p = &model.prefix;
    let state = WeightStore::from_pairs(vec![
        ("act_scales".into(), q.act_scales.clone()),
        ("kv_scales".into(), q.kv_scales.clone()),
        ("qmax_act".into(), q.qmax_act.clone()),
        ("qmax_kv".into(), q.qmax_kv.clone()),
        ("r3".into(), q.r3.clone()),
        ("r4".into(), q.r4.clone()),
        ("prefix_k".into(), p.k.clone()),
        ("prefix_v".into(), p.v.clone()),
    ]);
    state.save(&dir.join(STATE_FILE))?;
    let meta = json::obj(vec![
        ("model", json::s(&model.name)),
        ("mode", json::s(match mode {
            QuantMode::Fp => "fp",
            QuantMode::Static => "static",
            QuantMode::Dynamic => "dynamic",
        })),
        ("rotated", Json::Bool(q.rotated)),
        (
            "prefix_tokens",
            Json::Arr(p.tokens.iter().map(|&t| json::num(t as f64)).collect()),
        ),
        ("n_prefix", json::num(p.n_prefix as f64)),
        ("n_ctx_sinks", json::num(p.n_ctx_sinks as f64)),
    ]);
    std::fs::write(dir.join(META_FILE), meta.to_string())?;
    Ok(())
}

pub fn load(engine: Rc<Engine>, dir: &Path) -> Result<(Model, QuantMode)> {
    let meta = Json::parse(&std::fs::read_to_string(dir.join(META_FILE))?)?;
    let name = meta.get("model")?.as_str()?.to_string();
    let mode = match meta.get("mode")?.as_str()? {
        "static" => QuantMode::Static,
        "dynamic" => QuantMode::Dynamic,
        _ => QuantMode::Fp,
    };
    let mut model = Model::load(engine, &name)?;
    model.weights = WeightStore::load(&dir.join(WEIGHTS_FILE))?;
    let state = WeightStore::load(&dir.join(STATE_FILE))?;
    let get = |n: &str| -> Result<Tensor> {
        state.get(n).cloned().ok_or_else(|| anyhow!("{STATE_FILE} missing {n}"))
    };
    model.quant = QuantState {
        act_scales: get("act_scales")?,
        kv_scales: get("kv_scales")?,
        qmax_act: get("qmax_act")?,
        qmax_kv: get("qmax_kv")?,
        r3: get("r3")?,
        r4: get("r4")?,
        rotated: meta.get("rotated")?.as_bool()?,
    };
    model.prefix = PrefixState {
        tokens: meta
            .get("prefix_tokens")?
            .as_arr()?
            .iter()
            .map(|v| Ok(v.as_i64()? as i32))
            .collect::<Result<_>>()?,
        n_prefix: meta.get("n_prefix")?.as_i64()? as i32,
        n_ctx_sinks: meta.get("n_ctx_sinks")?.as_i64()? as i32,
        k: get("prefix_k")?,
        v: get("prefix_v")?,
    };
    model.refresh_weights()?;
    model.freeze()?;
    Ok((model, mode))
}
