//! The versioned, deployable quantization artifact ([`QuantArtifact`]).
//!
//! Quantization API v2 makes the quantized model a first-class asset — the
//! offline/online boundary of the system: a recipe run produces weights +
//! static act/KV scales + rotation state + prefixed tokens and their
//! materialized K/V + recipe provenance, all captured into a directory that
//! serving loads in O(read) instead of re-running the pipeline (the paper's
//! "quantize once, deploy" story; IntactKV and CushionCache treat the tuned
//! prefix the same way).
//!
//! On disk:
//!
//! ```text
//!   <dir>/artifact.json     — ArtifactMeta: format version, model name,
//!                             mode, recipe provenance (pass names + per-pass
//!                             seconds), precision, prefix tokens, content
//!                             hash of the tensor files
//!   <dir>/weights.bin       — folded + fake-quantized weights (WeightStore)
//!   <dir>/quant_state.bin   — act/KV scales, qmax, R3/R4, prefix K/V
//! ```
//!
//! Versioning rules: [`FORMAT_VERSION`] is checked on load and a mismatch is
//! a hard, descriptive error (no silent best-effort reads).  The content
//! hash (FNV-1a over both tensor files) is verified on load, so a truncated
//! or bit-flipped artifact is rejected before any tensor reaches a model.
//! [`ArtifactMeta::peek`] reads metadata only (mode lookup for server
//! configs) without paying for tensors or hashing.
//!
//! The artifact's prefix K/V is exactly what
//! `KvCache::install_prefix` writes into the paged cache's refcounted
//! shared-prefix pages — [`QuantArtifact::prefix_state`] hands it over
//! without a `Model` in the loop.

use std::path::Path;
use std::rc::Rc;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::ModelConfig;
use crate::model::{Model, PrefixState, QuantMode, QuantState};
use crate::runtime::{Engine, WeightStore};
use crate::tensor::Tensor;
use crate::util::json::{self, Json};

use super::pipeline::WeightQuantReport;
use super::recipe::{Precision, RecipeReport};

/// Artifact format version written by this build (and the only one it reads).
pub const FORMAT_VERSION: u32 = 2;

const STATE_FILE: &str = "quant_state.bin";
const WEIGHTS_FILE: &str = "weights.bin";
const META_FILE: &str = "artifact.json";
/// Metadata file of the pre-v2 (PR 0-3) save format, detected for a clear
/// migration error.
const LEGACY_META_FILE: &str = "quantized.json";

fn mode_to_str(mode: QuantMode) -> &'static str {
    match mode {
        QuantMode::Fp => "fp",
        QuantMode::Static => "static",
        QuantMode::Dynamic => "dynamic",
    }
}

fn mode_from_str(s: &str) -> Result<QuantMode> {
    match s {
        "fp" => Ok(QuantMode::Fp),
        "static" => Ok(QuantMode::Static),
        "dynamic" => Ok(QuantMode::Dynamic),
        other => bail!("artifact metadata has unknown quant mode {other:?}"),
    }
}

/// FNV-1a 64-bit, chained across calls via `h`.
fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

/// Content hash over the artifact's serialized tensor stores (order:
/// weights, state).
fn content_hash(weights_bytes: &[u8], state_bytes: &[u8]) -> u64 {
    fnv1a(fnv1a(FNV_OFFSET, weights_bytes), state_bytes)
}

/// Weight-step provenance of one quantized tensor: the compact
/// `artifact.json` record (granularity + step count + range).  The full
/// step vector rides in `quant_state.bin` as a `wsteps.<tensor>` entry.
#[derive(Debug, Clone, PartialEq)]
pub struct WeightStepsMeta {
    pub tensor: String,
    /// input-dim group size (None = per-channel)
    pub group: Option<usize>,
    pub n_steps: usize,
    pub step_min: f64,
    pub step_max: f64,
}

/// Provenance + identity of a [`QuantArtifact`] (the `artifact.json` body).
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub format_version: u32,
    /// base checkpoint name in the artifacts manifest
    pub model: String,
    /// activation/KV mode the serving executables must run
    pub mode: QuantMode,
    /// recipe name that produced this artifact ("(unrecorded)" for captures
    /// without a report)
    pub recipe: String,
    /// ordered pass names of the producing recipe
    pub passes: Vec<String>,
    /// wall seconds per pass, aligned with `passes` (Table 10 provenance)
    pub stage_seconds: Vec<f64>,
    pub precision: Option<Precision>,
    pub rotated: bool,
    pub prefix_tokens: Vec<i32>,
    pub n_prefix: i32,
    pub n_ctx_sinks: i32,
    /// weight-quantization provenance: one summary per quantized tensor,
    /// recorded when the producing recipe reported step sizes (empty for
    /// fp-weight or unrecorded artifacts; absent in pre-PR5 v2 artifacts,
    /// which still load)
    pub weight_quant: Vec<WeightStepsMeta>,
    /// FNV-1a over weights.bin + quant_state.bin, verified on load
    pub content_hash: u64,
}

impl ArtifactMeta {
    /// Read ONLY the metadata of an artifact directory: format-version
    /// checked, content hash NOT verified (no tensor IO).  Use for cheap
    /// mode/provenance lookups; a full [`QuantArtifact::load`] still
    /// verifies integrity before any tensor is used.
    pub fn peek(dir: &Path) -> Result<ArtifactMeta> {
        let meta_path = dir.join(META_FILE);
        if !meta_path.exists() {
            if dir.join(LEGACY_META_FILE).exists() {
                bail!(
                    "{dir:?} holds a pre-v2 quantized model ({LEGACY_META_FILE}); \
                     re-run `pq quantize --save` to produce a versioned artifact"
                );
            }
            bail!("{dir:?} is not a quantization artifact (no {META_FILE})");
        }
        let text = std::fs::read_to_string(&meta_path)?;
        let j = Json::parse(&text)
            .with_context(|| format!("{META_FILE} in {dir:?} is not valid JSON"))?;
        // gate on the version BEFORE the full field parse, so a future
        // format with a different schema still gets the descriptive
        // version error rather than a missing-key parse failure
        let version = j.get("format_version")?.as_i64()? as u32;
        if version != FORMAT_VERSION {
            bail!(
                "artifact {dir:?} has format v{version}, this build reads v{FORMAT_VERSION}; \
                 re-create it with a matching `pq quantize --save`"
            );
        }
        ArtifactMeta::from_json(&j).with_context(|| format!("{META_FILE} in {dir:?} is malformed"))
    }

    fn from_json(j: &Json) -> Result<ArtifactMeta> {
        let precision = match j.opt("precision") {
            Some(Json::Null) | None => None,
            Some(p) => Some(Precision::new(
                p.get("w")?.as_usize()?,
                p.get("a")?.as_usize()?,
                p.get("kv")?.as_usize()?,
            )),
        };
        let hash_text = j.get("content_hash")?.as_str()?;
        let content_hash = u64::from_str_radix(hash_text, 16)
            .map_err(|e| anyhow!("bad content_hash {hash_text:?}: {e}"))?;
        // optional: absent in artifacts written before weight-step
        // provenance existed (same format version — purely additive), but
        // a PRESENT malformed value is rejected like every other field
        let weight_quant = match j.opt("weight_quant") {
            None | Some(Json::Null) => Vec::new(),
            Some(Json::Arr(items)) => items
                .iter()
                .map(|it| {
                    Ok(WeightStepsMeta {
                        tensor: it.get("tensor")?.as_str()?.to_string(),
                        group: match it.opt("group") {
                            Some(Json::Null) | None => None,
                            Some(g) => {
                                let g = g.as_i64()?;
                                if g < 0 {
                                    bail!("weight_quant group must be non-negative, got {g}");
                                }
                                Some(g as usize)
                            }
                        },
                        n_steps: it.get("n_steps")?.as_usize()?,
                        step_min: it.get("step_min")?.as_f64()?,
                        step_max: it.get("step_max")?.as_f64()?,
                    })
                })
                .collect::<Result<_>>()?,
            Some(other) => bail!("weight_quant must be an array, got {other:?}"),
        };
        Ok(ArtifactMeta {
            format_version: j.get("format_version")?.as_i64()? as u32,
            model: j.get("model")?.as_str()?.to_string(),
            mode: mode_from_str(j.get("mode")?.as_str()?)?,
            recipe: j.get("recipe")?.as_str()?.to_string(),
            passes: j
                .get("passes")?
                .as_arr()?
                .iter()
                .map(|v| Ok(v.as_str()?.to_string()))
                .collect::<Result<_>>()?,
            stage_seconds: j
                .get("stage_seconds")?
                .as_arr()?
                .iter()
                .map(|v| v.as_f64())
                .collect::<Result<_>>()?,
            precision,
            rotated: j.get("rotated")?.as_bool()?,
            prefix_tokens: j
                .get("prefix_tokens")?
                .as_arr()?
                .iter()
                .map(|v| Ok(v.as_i64()? as i32))
                .collect::<Result<_>>()?,
            n_prefix: j.get("n_prefix")?.as_i64()? as i32,
            n_ctx_sinks: j.get("n_ctx_sinks")?.as_i64()? as i32,
            weight_quant,
            content_hash,
        })
    }

    fn to_json(&self) -> Json {
        json::obj(vec![
            ("format_version", json::num(self.format_version as f64)),
            ("model", json::s(&self.model)),
            ("mode", json::s(mode_to_str(self.mode))),
            ("recipe", json::s(&self.recipe)),
            (
                "passes",
                Json::Arr(self.passes.iter().map(|p| json::s(p)).collect()),
            ),
            (
                "stage_seconds",
                Json::Arr(self.stage_seconds.iter().map(|&s| json::num(s)).collect()),
            ),
            (
                "precision",
                match &self.precision {
                    None => Json::Null,
                    Some(p) => json::obj(vec![
                        ("w", json::num(p.w as f64)),
                        ("a", json::num(p.a as f64)),
                        ("kv", json::num(p.kv as f64)),
                    ]),
                },
            ),
            ("rotated", Json::Bool(self.rotated)),
            (
                "prefix_tokens",
                Json::Arr(self.prefix_tokens.iter().map(|&t| json::num(t as f64)).collect()),
            ),
            ("n_prefix", json::num(self.n_prefix as f64)),
            ("n_ctx_sinks", json::num(self.n_ctx_sinks as f64)),
            (
                "weight_quant",
                Json::Arr(
                    self.weight_quant
                        .iter()
                        .map(|w| {
                            json::obj(vec![
                                ("tensor", json::s(&w.tensor)),
                                (
                                    "group",
                                    match w.group {
                                        None => Json::Null,
                                        Some(g) => json::num(g as f64),
                                    },
                                ),
                                ("n_steps", json::num(w.n_steps as f64)),
                                ("step_min", json::num(w.step_min)),
                                ("step_max", json::num(w.step_max)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("content_hash", json::s(&format!("{:016x}", self.content_hash))),
        ])
    }
}

/// A complete quantized deployment: metadata + the two tensor stores.
#[derive(Debug)]
pub struct QuantArtifact {
    pub meta: ArtifactMeta,
    /// folded + fake-quantized model weights
    pub weights: WeightStore,
    /// act/KV scales, qmax scalars, online rotations, prefix K/V
    pub state: WeightStore,
}

/// The quant/prefix state tensors as a store (small: scales, qmax,
/// rotations, prefix K/V), plus — when the producing recipe reported them —
/// the full weight step vectors as `wsteps.<tensor>` entries.
fn state_store(model: &Model, report: Option<&RecipeReport>) -> WeightStore {
    let q = &model.quant;
    let p = &model.prefix;
    let mut pairs = vec![
        ("act_scales".into(), q.act_scales.clone()),
        ("kv_scales".into(), q.kv_scales.clone()),
        ("qmax_act".into(), q.qmax_act.clone()),
        ("qmax_kv".into(), q.qmax_kv.clone()),
        ("r3".into(), q.r3.clone()),
        ("r4".into(), q.r4.clone()),
        ("prefix_k".into(), p.k.clone()),
        ("prefix_v".into(), p.v.clone()),
    ];
    if let Some(wq) = report.and_then(|r| r.weight_quant.as_ref()) {
        for t in &wq.tensors {
            let steps = Tensor { shape: vec![t.steps.len()], data: t.steps.clone() };
            pairs.push((format!("wsteps.{}", t.name), steps));
        }
    }
    WeightStore::from_pairs(pairs)
}

/// Compact per-tensor summaries of a weight-quant report (the
/// `artifact.json` side of the step provenance).
fn steps_meta_of(wq: &WeightQuantReport) -> Vec<WeightStepsMeta> {
    wq.tensors
        .iter()
        .map(|t| {
            let mut lo = f64::MAX;
            let mut hi = 0.0f64;
            for &s in &t.steps {
                lo = lo.min(s as f64);
                hi = hi.max(s as f64);
            }
            if t.steps.is_empty() {
                lo = 0.0;
            }
            WeightStepsMeta {
                tensor: t.name.clone(),
                group: t.group,
                n_steps: t.steps.len(),
                step_min: lo,
                step_max: hi,
            }
        })
        .collect()
}

/// Provenance metadata for a model + optional recipe report (hash unset).
fn meta_of(model: &Model, mode: QuantMode, report: Option<&RecipeReport>) -> ArtifactMeta {
    let (recipe, passes, stage_seconds, precision) = match report {
        Some(r) => (
            r.recipe.clone(),
            r.stages.iter().map(|s| s.pass.clone()).collect(),
            r.stages.iter().map(|s| s.seconds).collect(),
            Some(r.precision),
        ),
        None => ("(unrecorded)".to_string(), Vec::new(), Vec::new(), None),
    };
    let weight_quant =
        report.and_then(|r| r.weight_quant.as_ref()).map(steps_meta_of).unwrap_or_default();
    ArtifactMeta {
        format_version: FORMAT_VERSION,
        model: model.name.clone(),
        mode,
        recipe,
        passes,
        stage_seconds,
        precision,
        rotated: model.quant.rotated,
        prefix_tokens: model.prefix.tokens.clone(),
        n_prefix: model.prefix.n_prefix,
        n_ctx_sinks: model.prefix.n_ctx_sinks,
        weight_quant,
        content_hash: 0, // recorded by save, verified by load
    }
}

/// Serialize + hash + write one artifact (single serialization, no
/// read-back); returns the meta with the hash recorded.
fn write_artifact(
    mut meta: ArtifactMeta,
    weights: &WeightStore,
    state: &WeightStore,
    dir: &Path,
) -> Result<u64> {
    std::fs::create_dir_all(dir)?;
    let wb = weights.to_bytes();
    let sb = state.to_bytes();
    let hash = content_hash(&wb, &sb);
    std::fs::write(dir.join(WEIGHTS_FILE), &wb)?;
    std::fs::write(dir.join(STATE_FILE), &sb)?;
    meta.content_hash = hash;
    std::fs::write(dir.join(META_FILE), meta.to_json().to_string())?;
    Ok(hash)
}

impl QuantArtifact {
    /// Snapshot a quantized model (post-recipe) into an OWNED artifact
    /// (clones the weight store — use [`QuantArtifact::save_model`] to write
    /// straight from a model without the clone).  Pass the recipe's report
    /// to record provenance (recipe name, pass list, per-pass seconds,
    /// precision); `None` records "(unrecorded)".
    pub fn capture(model: &Model, mode: QuantMode, report: Option<&RecipeReport>) -> QuantArtifact {
        QuantArtifact {
            meta: meta_of(model, mode, report),
            weights: model.weights.clone(),
            state: state_store(model, report),
        }
    }

    /// Serialize a quantized model directly to `dir` — the peak-memory-
    /// friendly save path (no weight-store clone): the model's tensors are
    /// serialized and hashed in place.  Returns the recorded content hash.
    pub fn save_model(
        model: &Model,
        mode: QuantMode,
        report: Option<&RecipeReport>,
        dir: &Path,
    ) -> Result<u64> {
        let state = state_store(model, report);
        write_artifact(meta_of(model, mode, report), &model.weights, &state, dir)
    }

    /// Write the artifact; records the content hash in both the metadata
    /// file and `self.meta`, and returns it.  The hash is computed over the
    /// exact serialized bytes that hit the disk (single serialization — no
    /// read-back).
    pub fn save(&mut self, dir: &Path) -> Result<u64> {
        let hash = write_artifact(self.meta.clone(), &self.weights, &self.state, dir)?;
        self.meta.content_hash = hash;
        Ok(hash)
    }

    /// Load and VALIDATE an artifact: metadata parse, format-version check,
    /// content-hash verification, then the tensor stores — each file read
    /// from disk exactly once (hashing and parsing share the buffer).
    /// Every failure mode is a descriptive error (wrong version,
    /// corruption, missing files, legacy format) — never a silently wrong
    /// model.
    pub fn load(dir: &Path) -> Result<QuantArtifact> {
        let meta = ArtifactMeta::peek(dir)?;
        let wpath = dir.join(WEIGHTS_FILE);
        let spath = dir.join(STATE_FILE);
        let wb = std::fs::read(&wpath)
            .with_context(|| format!("artifact {dir:?} is missing {WEIGHTS_FILE}"))?;
        let sb = std::fs::read(&spath)
            .with_context(|| format!("artifact {dir:?} is missing {STATE_FILE}"))?;
        let actual = content_hash(&wb, &sb);
        if actual != meta.content_hash {
            bail!(
                "artifact {dir:?} is corrupted: content hash {actual:016x} does not match \
                 recorded {:016x} (re-create the artifact)",
                meta.content_hash
            );
        }
        let weights = WeightStore::from_bytes(&wb, &wpath)?;
        let state = WeightStore::from_bytes(&sb, &spath)?;
        Ok(QuantArtifact { meta, weights, state })
    }

    /// The prefixed-tokens state carried by this artifact, ready for
    /// `KvCache::install_prefix` (which maps it into the paged cache's
    /// refcounted shared-prefix pages) — no `Model` required.
    pub fn prefix_state(&self, cfg: &ModelConfig) -> Result<PrefixState> {
        let get = |n: &str| -> Result<Tensor> {
            self.state.get(n).cloned().ok_or_else(|| anyhow!("{STATE_FILE} missing {n}"))
        };
        let k = get("prefix_k")?;
        let want = [cfg.n_layers, cfg.n_heads, cfg.max_prefix, cfg.d_head];
        if k.shape != want {
            bail!("artifact prefix K shape {:?} does not match model geometry {want:?}", k.shape);
        }
        Ok(PrefixState {
            tokens: self.meta.prefix_tokens.clone(),
            n_prefix: self.meta.n_prefix,
            n_ctx_sinks: self.meta.n_ctx_sinks,
            k,
            v: get("prefix_v")?,
        })
    }

    /// Bind the artifact to an engine: load the base checkpoint shell,
    /// overwrite weights + quant/prefix state, upload, freeze.  This is the
    /// serving boot path — O(read + upload), no pipeline.
    pub fn into_model(self, engine: Rc<Engine>) -> Result<(Model, QuantMode)> {
        let QuantArtifact { meta, weights, state } = self;
        let mut model = Model::load(engine, &meta.model)
            .with_context(|| format!("artifact's base model {:?} not in manifest", meta.model))?;
        model.weights = weights;
        let get = |n: &str| -> Result<Tensor> {
            state.get(n).cloned().ok_or_else(|| anyhow!("{STATE_FILE} missing {n}"))
        };
        model.quant = QuantState {
            act_scales: get("act_scales")?,
            kv_scales: get("kv_scales")?,
            qmax_act: get("qmax_act")?,
            qmax_kv: get("qmax_kv")?,
            r3: get("r3")?,
            r4: get("r4")?,
            rotated: meta.rotated,
        };
        model.prefix = PrefixState {
            tokens: meta.prefix_tokens.clone(),
            n_prefix: meta.n_prefix,
            n_ctx_sinks: meta.n_ctx_sinks,
            k: get("prefix_k")?,
            v: get("prefix_v")?,
        };
        model.refresh_weights()?;
        model.freeze()?;
        Ok((model, meta.mode))
    }
}

/// Save a quantized model without recipe provenance (v1-compatible shape).
/// Prefer [`QuantArtifact::save_model`] with a report.
pub fn save(model: &Model, mode: QuantMode, dir: &Path) -> Result<()> {
    QuantArtifact::save_model(model, mode, None, dir).map(|_| ())
}

/// Load a ready-to-serve model from an artifact directory (O(read), the
/// pipeline never runs): validate, bind to `engine`, freeze.
pub fn load(engine: Rc<Engine>, dir: &Path) -> Result<(Model, QuantMode)> {
    QuantArtifact::load(dir)?.into_model(engine)
}
