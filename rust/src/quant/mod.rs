//! The PrefixQuant quantization pipeline (the paper's contribution).
//!
//! Submodules:
//!   * [`quantizer`] — host-side weight quantization (per-channel / per-group,
//!     RTN and grid-search init), backed by the fused single-pass kernels in
//!     [`crate::kernels::quantize`].
//!   * [`rotation`]  — Hadamard generation + absorbable R1/R2 folding and the
//!     R4 weight-side fold (computational invariance, QuaRot/SpinQuant style),
//!     folded via in-place FWHTs ([`crate::kernels::fwht`]).
//!   * [`outlier`]   — token-wise outlier statistics (Figs 2-4), η-detection,
//!     outlier-token frequency ranking.
//!   * [`prefix`]    — prefixed-token selection and prefix-KV materialization
//!     (§5.1 of the paper).
//!   * [`blockrun`]  — by-name binding of the block-level executables.
//!   * [`calibrate`] — grid-search initialization of static activation / KV
//!     scales against block-output MSE (§6.1 "Grid Search Setting").
//!   * [`finetune`]  — block-wise fine-tuning with Adam on quantization
//!     parameters + weights (§5.2, EfficientQAT-style).
//!   * [`smooth`]    — SmoothQuant-analog channel scaling baseline.
//!   * [`recipe`]    — Quantization API v2: the composable pass pipeline
//!     ([`recipe::QuantPass`] over a shared [`recipe::QuantCtx`]), typed
//!     config ([`recipe::Precision`], [`recipe::Granularity`]) compiled by
//!     [`recipe::Recipe::builder`] into an ordered pass list, all paper
//!     presets as recipe constructors, per-pass timing in
//!     [`recipe::RecipeReport`].
//!   * [`model_state`] — the versioned [`model_state::QuantArtifact`]
//!     (weights + scales + rotation + prefixed KV + recipe provenance +
//!     content hash): the offline/online boundary serving boots from.
//!   * [`pipeline`]  — `quantize()` entry point (bridges [`SchemeConfig`]
//!     to a recipe) + the frozen v1 `quantize_legacy` golden reference.

pub mod blockrun;
pub mod model_state;
pub mod calibrate;
pub mod finetune;
pub mod outlier;
pub mod pipeline;
pub mod prefix;
pub mod quantizer;
pub mod recipe;
pub mod rotation;
pub mod smooth;

pub use model_state::{ArtifactMeta, QuantArtifact, WeightStepsMeta, FORMAT_VERSION};
pub use pipeline::{TensorSteps, WeightQuantReport};
pub use recipe::{
    Granularity, Precision, QuantCtx, QuantPass, Recipe, RecipeBuilder, RecipeReport, StageReport,
};

use crate::model::QuantMode;

/// A complete quantization scheme — every baseline and ablation in the paper
/// is a point in this configuration space (Tables 3-6, 13-15).
///
/// This is the LEGACY (v1) flat configuration, retained so
/// `pipeline::quantize_legacy` stays frozen for the golden parity suite and
/// old call sites keep working through `pipeline::quantize` (which bridges
/// via [`Recipe::from_scheme`]).  New code should use [`Recipe`] presets or
/// [`Recipe::builder`] with typed [`Precision`]/[`Granularity`] instead.
#[derive(Debug, Clone)]
pub struct SchemeConfig {
    pub name: String,
    /// Weight bits (per-channel symmetric; 16 = keep fp).
    pub w_bits: usize,
    /// Activation bits (inputs of linear layers; 16 = keep fp).
    pub a_bits: usize,
    /// KV-cache bits (16 = keep fp).
    pub kv_bits: usize,
    /// Static (per-tensor / per-head) vs dynamic (per-token) act+KV quant.
    pub mode: QuantMode,
    /// Hadamard rotations R1-R4 (QuaRot / PrefixQuant substrate).
    pub rotate: bool,
    /// Prefix outlier tokens in the KV cache (the paper's contribution).
    pub use_prefix: bool,
    /// Override the selected prefix content (None = adaptive top-o + BOS).
    pub prefix_override: Option<PrefixPolicy>,
    /// Grid-search initialization of scales (vs plain max/RTN init).
    pub grid_search: bool,
    /// Block-wise fine-tuning epochs (0 = off).
    pub ft_epochs: usize,
    /// SmoothQuant-style channel scaling baseline.
    pub smooth: bool,
    /// Per-group weight quantization group size (Atom-analog; None = per-channel).
    pub w_group: Option<usize>,
}

/// Prefix-content policies for the Table 14/15/17 ablations.
#[derive(Debug, Clone, PartialEq)]
pub enum PrefixPolicy {
    /// First n of the default selection (Table 14 sweep, incl. 0 = none).
    FirstN(usize),
    /// Repeat the single highest-frequency outlier token o times (Table 15).
    OnlyHighestFreq,
    /// Random non-delimiter tokens (Table 15).
    Random(u64),
    /// Fixed 3 tokens regardless of the measured o (QFeP-analog, Table 17).
    Fixed3,
}

impl SchemeConfig {
    pub fn fp16() -> Self {
        Self {
            name: "FP16".into(),
            w_bits: 16,
            a_bits: 16,
            kv_bits: 16,
            mode: QuantMode::Fp,
            rotate: false,
            use_prefix: false,
            prefix_override: None,
            grid_search: false,
            ft_epochs: 0,
            smooth: false,
            w_group: None,
        }
    }

    /// Round-to-nearest, per-token dynamic (the ablation baseline, Table 6).
    pub fn rtn(w: usize, a: usize, kv: usize) -> Self {
        Self {
            name: format!("RTN W{w}A{a}KV{kv}"),
            w_bits: w,
            a_bits: a,
            kv_bits: kv,
            mode: QuantMode::Dynamic,
            rotate: false,
            use_prefix: false,
            prefix_override: None,
            grid_search: false,
            ft_epochs: 0,
            smooth: false,
            w_group: None,
        }
    }

    /// QuaRot-analog: Hadamard rotation + per-token dynamic quantization.
    pub fn quarot(w: usize, a: usize, kv: usize) -> Self {
        Self { name: format!("QuaRot W{w}A{a}KV{kv}"), rotate: true, ..Self::rtn(w, a, kv) }
    }

    /// SmoothQuant-analog: channel scaling + static per-tensor activations.
    pub fn smoothquant(w: usize, a: usize, kv: usize) -> Self {
        Self {
            name: format!("SmoothQuant W{w}A{a}KV{kv}"),
            mode: QuantMode::Static,
            smooth: true,
            grid_search: true,
            ..Self::rtn(w, a, kv)
        }
    }

    /// Atom-analog: per-group weights, dynamic activations.
    pub fn atom(w: usize, a: usize, kv: usize) -> Self {
        Self { name: format!("Atom W{w}A{a}KV{kv}"), w_group: Some(64), ..Self::rtn(w, a, kv) }
    }

    /// PrefixQuant without fine-tuning (grid search only).
    pub fn prefixquant_wo_ft(w: usize, a: usize, kv: usize) -> Self {
        Self {
            name: format!("PrefixQuant w/o FT W{w}A{a}KV{kv}"),
            mode: QuantMode::Static,
            rotate: true,
            use_prefix: true,
            grid_search: true,
            ..Self::rtn(w, a, kv)
        }
    }

    /// Full PrefixQuant with block-wise fine-tuning.
    pub fn prefixquant(w: usize, a: usize, kv: usize, epochs: usize) -> Self {
        Self { ft_epochs: epochs, ..Self::prefixquant_wo_ft(w, a, kv) }
            .renamed(&format!("PrefixQuant W{w}A{a}KV{kv}"))
    }

    pub fn renamed(mut self, name: &str) -> Self {
        self.name = name.to_string();
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_are_consistent() {
        let p = SchemeConfig::prefixquant(4, 4, 4, 10);
        assert!(p.rotate && p.use_prefix && p.grid_search);
        assert_eq!(p.mode, QuantMode::Static);
        assert_eq!(p.ft_epochs, 10);
        let q = SchemeConfig::quarot(4, 4, 4);
        assert!(q.rotate && !q.use_prefix);
        assert_eq!(q.mode, QuantMode::Dynamic);
        assert_eq!(SchemeConfig::fp16().mode, QuantMode::Fp);
    }
}
