//! Token-wise outlier statistics and detection (§4, §5.1, Figs 2-4).
//!
//! Observation runs the `fwd_obs` executable over a calibration batch; the
//! graph emits per-(layer, site) token-wise max-|x| stats M[L,S_sites,B,S],
//! block-input captures, and the fp KV tensors.  Host-side we compute the
//! paper's top-1/median/min-1 ratios, apply the η-threshold (Eq. 3), count
//! outlier tokens per block, and rank outlier-token contents by frequency.

use anyhow::{anyhow, Result};

use crate::model::{Model, QuantMode};
use crate::tensor::{median, IntTensor, Tensor};

/// Default detection threshold η (paper §5.1).
pub const ETA: f32 = 64.0;

/// Per-(layer, site) distribution summary of token-wise maxima.
#[derive(Debug, Clone)]
pub struct SiteStat {
    pub top1: f32,
    pub median: f32,
    pub min1: f32,
}

impl SiteStat {
    /// top-1 / median — "upper outliers" (large ⇒ massive activations).
    pub fn upper_ratio(&self) -> f32 {
        self.top1 / self.median.max(1e-12)
    }

    /// median / min-1 — "lower outliers" (large ⇒ vanishing sink tokens).
    pub fn lower_ratio(&self) -> f32 {
        self.median / self.min1.max(1e-12)
    }
}

/// Raw observation outputs kept for calibration / fine-tuning.
pub struct Observation {
    pub tokens: IntTensor,
    /// M[L, n_sites, B, S]
    pub stats: Tensor,
    /// sink mask the graph actually applied [B, S]
    pub active: Tensor,
    /// block inputs [L+1, B, S, D]
    pub captures: Tensor,
    /// fp KV tensors [L, B, H, S, dh]
    pub k_cache: Tensor,
    pub v_cache: Tensor,
}

#[derive(Debug, Clone)]
pub struct OutlierReport {
    /// [L][n_sites]
    pub site_stats: Vec<Vec<SiteStat>>,
    /// mean outlier-token count per sequence, per block (paper's O vector)
    pub o_per_block: Vec<f32>,
    /// o = ceil(max(O)) — the adaptive prefixed-token count
    pub o: usize,
    /// outlier token contents by frequency, initial positions excluded
    pub freq: Vec<(i32, usize)>,
    /// (batch, pos) of detected outliers at the detection layer
    pub positions: Vec<(usize, usize)>,
    /// total detected outlier-token instances (any layer, down_in site)
    pub total_outliers: usize,
    pub eta: f32,
}

/// Site index of down_proj inputs — the paper's detection site.
pub fn detect_site(model: &Model) -> Result<usize> {
    model
        .cfg
        .site_index("down_in")
        .ok_or_else(|| anyhow!("model config has no down_in site"))
}

/// Run `fwd_obs` on a [B,S] calibration batch with the model's current
/// rotation/prefix state.
pub fn observe(model: &Model, tokens: &IntTensor) -> Result<Observation> {
    let sig = model.exec(QuantMode::Fp.fwd_exec())?;
    let outs = model.forward(QuantMode::Fp, tokens)?;
    let pick = |name: &str| -> Result<usize> { sig.output_index(name) };
    let mut outs: Vec<Option<crate::runtime::Out>> = outs.into_iter().map(Some).collect();
    let mut take_f32 = |name: &str| -> Result<Tensor> {
        let i = pick(name)?;
        outs[i].take().ok_or_else(|| anyhow!("output {name} consumed twice"))?.f32()
    };
    let stats = take_f32("stats")?;
    let active = take_f32("active")?;
    let captures = take_f32("captures")?;
    let k_cache = take_f32("k_cache")?;
    let v_cache = take_f32("v_cache")?;
    Ok(Observation { tokens: tokens.clone(), stats, active, captures, k_cache, v_cache })
}

/// Compute the report from an observation (pure host math).
pub fn analyze(model: &Model, obs: &Observation, eta: f32) -> Result<OutlierReport> {
    let cfg = &model.cfg;
    let (l, n_sites) = (cfg.n_layers, cfg.n_sites());
    let (b, s) = (obs.active.shape[0], obs.active.shape[1]);
    let st = &obs.stats; // [L, n_sites, B, S]
    let at = |li: usize, site: usize, bi: usize, si: usize| -> f32 {
        st.data[((li * n_sites + site) * b + bi) * s + si]
    };

    let mut site_stats = Vec::with_capacity(l);
    for li in 0..l {
        let mut row = Vec::with_capacity(n_sites);
        for site in 0..n_sites {
            let vals: Vec<f32> =
                (0..b).flat_map(|bi| (0..s).map(move |si| (bi, si))).map(|(bi, si)| at(li, site, bi, si)).collect();
            let mut sorted = vals.clone();
            sorted.sort_by(|a, c| a.partial_cmp(c).unwrap());
            row.push(SiteStat {
                top1: *sorted.last().unwrap(),
                median: median(&vals),
                min1: sorted[0],
            });
        }
        site_stats.push(row);
    }

    // η-detection at down_in, per layer
    let dsite = detect_site(model)?;
    let mut o_per_block = Vec::with_capacity(l);
    let mut freq_map = std::collections::BTreeMap::<i32, usize>::new();
    let mut positions = Vec::new();
    let mut total = 0usize;
    for li in 0..l {
        let med = site_stats[li][dsite].median.max(1e-12);
        let mut count = 0usize;
        for bi in 0..b {
            for si in 0..s {
                if at(li, dsite, bi, si) / med > eta {
                    count += 1;
                    total += 1;
                    if li == 0 {
                        positions.push((bi, si));
                    }
                    if si != 0 {
                        // frequency excludes the initial token (paper fig 4a)
                        let tok = obs.tokens.data[bi * s + si];
                        *freq_map.entry(tok).or_insert(0) += 1;
                    }
                }
            }
        }
        o_per_block.push(count as f32 / b as f32);
    }
    let omax = o_per_block.iter().fold(0.0f32, |m, &v| m.max(v));
    // room for the [BOS] slot within the padded prefix capacity
    let o = (omax.ceil() as usize).min(cfg.max_prefix.saturating_sub(1));
    let mut freq: Vec<(i32, usize)> = freq_map.into_iter().collect();
    freq.sort_by(|a, c| c.1.cmp(&a.1).then(a.0.cmp(&c.0)));
    Ok(OutlierReport { site_stats, o_per_block, o, freq, positions, total_outliers: total, eta })
}

/// Observe + analyze in one call.
pub fn observe_and_analyze(
    model: &Model,
    tokens: &IntTensor,
    eta: f32,
) -> Result<(Observation, OutlierReport)> {
    let obs = observe(model, tokens)?;
    let rep = analyze(model, &obs, eta)?;
    Ok((obs, rep))
}
