//! Misc element-wise host kernels: row scaling, abs-max reductions, and the
//! fused Adam update of block fine-tuning.  All banded over outputs per the
//! layer's determinism contract (max is exactly associative/commutative and
//! the Adam update is element-independent, so any band split is
//! bit-identical).

use super::par_bands;

/// Scale row r of a row-major [rows, cols] buffer by `g[r]` (diag(g)·W).
pub fn scale_rows_nt(data: &mut [f32], rows: usize, cols: usize, g: &[f32], nthreads: usize) {
    assert_eq!(data.len(), rows * cols, "scale_rows element count");
    assert_eq!(g.len(), rows, "scale_rows gain count");
    let nt = super::useful_threads(nthreads, rows, rows * cols);
    par_bands(data, rows, cols, nt, |r0, band| {
        for (row, &gv) in band.chunks_mut(cols).zip(&g[r0..]) {
            for v in row {
                *v *= gv;
            }
        }
    });
}

/// Per-row abs-max of a row-major [rows, cols] buffer.
pub fn absmax_rows_nt(data: &[f32], rows: usize, cols: usize, nthreads: usize) -> Vec<f32> {
    assert_eq!(data.len(), rows * cols, "absmax_rows element count");
    let mut out = vec![0.0f32; rows];
    if cols == 0 {
        return out;
    }
    let nt = super::useful_threads(nthreads, rows, rows * cols);
    par_bands(&mut out, rows, 1, nt, |r0, oband| {
        for (o, row) in oband.iter_mut().zip(data[r0 * cols..].chunks(cols)) {
            *o = row.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        }
    });
    out
}

/// Hyperparameters of one fused Adam update (bias corrections precomputed
/// by the caller from the step counter).
#[derive(Debug, Clone, Copy)]
pub struct AdamStep {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    /// 1 − β₁ᵗ
    pub b1c: f32,
    /// 1 − β₂ᵗ
    pub b2c: f32,
}

/// Element-wise Adam update of `params` (with moments `m`/`v` and gradient
/// `grads`), parallelized over parameter bands.
pub fn adam_step_nt(
    params: &mut [f32],
    m: &mut [f32],
    v: &mut [f32],
    grads: &[f32],
    k: AdamStep,
    nthreads: usize,
) {
    let n = params.len();
    assert!(m.len() == n && v.len() == n && grads.len() == n, "adam buffer lengths");
    if n == 0 {
        return;
    }
    let nt = super::useful_threads(nthreads, n, n);
    if nt <= 1 {
        adam_band(params, m, v, grads, k);
        return;
    }
    let band = (n + nt - 1) / nt;
    std::thread::scope(|s| {
        let pm = params.chunks_mut(band).zip(m.chunks_mut(band));
        let vg = v.chunks_mut(band).zip(grads.chunks(band));
        for ((p, mm), (vv, g)) in pm.zip(vg) {
            s.spawn(move || adam_band(p, mm, vv, g, k));
        }
    });
}

/// Banded column-max reduce: split the `rows` of a row-major [rows, cols]
/// buffer into worker bands, run `f(band) -> Vec<f32>` (must return `cols`
/// values — e.g. a fused per-row transform + column abs-max), and merge
/// the per-band vectors with element-wise max.  Max is exactly associative
/// and commutative over non-NaN f32, so the merge is bit-identical for
/// every thread count.
pub fn rowband_max_nt<F>(data: &[f32], rows: usize, cols: usize, nthreads: usize, f: F) -> Vec<f32>
where
    F: Fn(&[f32]) -> Vec<f32> + Sync,
{
    assert_eq!(data.len(), rows * cols, "rowband_max element count");
    if rows == 0 || cols == 0 {
        return vec![0.0; cols];
    }
    let nt = super::useful_threads(nthreads, rows, rows * cols);
    if nt <= 1 {
        return f(data);
    }
    let band = (rows + nt - 1) / nt;
    let mut out = vec![0.0f32; cols];
    std::thread::scope(|s| {
        let handles: Vec<_> = data
            .chunks(band * cols)
            .map(|chunk| {
                let f = &f;
                s.spawn(move || f(chunk))
            })
            .collect();
        for handle in handles {
            let part = handle.join().expect("rowband_max worker panicked");
            for (o, p) in out.iter_mut().zip(part) {
                *o = o.max(p);
            }
        }
    });
    out
}

fn adam_band(params: &mut [f32], m: &mut [f32], v: &mut [f32], grads: &[f32], k: AdamStep) {
    for ((p, mm), (vv, &g)) in
        params.iter_mut().zip(m.iter_mut()).zip(v.iter_mut().zip(grads.iter()))
    {
        *mm = k.beta1 * *mm + (1.0 - k.beta1) * g;
        *vv = k.beta2 * *vv + (1.0 - k.beta2) * g * g;
        let mh = *mm / k.b1c;
        let vh = *vv / k.b2c;
        *p -= k.lr * mh / (vh.sqrt() + k.eps);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_and_absmax() {
        let mut d = vec![1.0f32, -2.0, 3.0, -4.0];
        scale_rows_nt(&mut d, 2, 2, &[2.0, 0.5], 2);
        assert_eq!(d, vec![2.0, -4.0, 1.5, -2.0]);
        assert_eq!(absmax_rows_nt(&d, 2, 2, 3), vec![4.0, 2.0]);
    }

    #[test]
    fn adam_matches_scalar_reference_for_any_thread_count() {
        // n above the serial-fallback work threshold so bands really split
        let n = 50_000;
        let k = AdamStep { lr: 0.1, beta1: 0.9, beta2: 0.95, eps: 1e-8, b1c: 0.1, b2c: 0.05 };
        let grads: Vec<f32> = (0..n).map(|i| (i as f32 * 0.13).sin()).collect();
        let init: Vec<f32> = (0..n).map(|i| (i % 1000) as f32 * 0.01).collect();
        let mut want = (init.clone(), vec![0.0f32; n], vec![0.0f32; n]);
        adam_band(&mut want.0, &mut want.1, &mut want.2, &grads, k);
        for nt in [1usize, 2, 3, 16] {
            let mut got = (init.clone(), vec![0.0f32; n], vec![0.0f32; n]);
            adam_step_nt(&mut got.0, &mut got.1, &mut got.2, &grads, k, nt);
            assert_eq!(got, want, "nt={nt}");
        }
    }
}
