//! Host kernel layer: the shared compute substrate of the quantize path.
//!
//! Everything the pipeline computes host-side — rotation folding, weight
//! quantization, scale search, smoothing statistics, the fine-tune
//! optimizer — routes through this module (see rust/DESIGN.md "Host kernel
//! layer"):
//!
//!   * [`gemm`]     — cache-blocked, multithreaded matmul and blocked
//!     transpose (the `Tensor::matmul` / `Tensor::transpose2` backends);
//!   * [`fwht`]     — in-place O(n log n) fast Walsh–Hadamard transform,
//!     row- and column-wise, replacing the explicit Hadamard-matrix
//!     products in rotation folding;
//!   * [`quantize`] — fused single-pass weight quantizer: scale search with
//!     a lossless clip-bound pruned γ grid + fake-quant over channel-major
//!     panels, reciprocal multiplies in the inner loop;
//!   * [`ops`]      — misc element-wise kernels (row scaling, abs-max
//!     reductions, the fused Adam update of block fine-tuning);
//!   * [`naive`]    — FROZEN pre-kernel-layer implementations, the golden
//!     references of `tests/kernel_parity.rs` and the baselines of
//!     `benches/quant_speed.rs`.
//!
//! ## Threading and determinism contract
//!
//! Worker count comes from the `PQ_THREADS` env var (default:
//! `available_parallelism`), re-read on every kernel call so tests can pin
//! it.  Threads only ever partition OUTPUT elements into disjoint
//! contiguous bands; no kernel splits a single output's reduction across
//! threads, and all blocking constants are fixed.  Every output element
//! therefore sees the exact same sequence of floating-point operations for
//! every thread count: results are bit-identical under any `PQ_THREADS`
//! (CI pins this by re-running the suite with `PQ_THREADS=1`).
//!
//! Both `pipeline::quantize_legacy` and the v2 recipe passes call these
//! kernels through the same shared entry points (`rotation::fold_rotations`,
//! `quantizer::quant_weight_*`, `calibrate`, `finetune`), so the golden
//! `recipe_parity` suite stays green by construction: legacy and v2 share
//! summation order, not just algorithms.

pub mod fwht;
pub mod gemm;
pub mod naive;
pub mod ops;
pub mod quantize;

/// Hard cap on worker threads (a `PQ_THREADS=100000` typo should not fork
/// bomb the host).
pub const MAX_THREADS: usize = 64;

/// Minimum elementary operations a band must amortize before another
/// worker thread pays for itself (spawn+join ≈ tens of µs).
const MIN_WORK_PER_THREAD: usize = 16 * 1024;

/// Cap a requested worker count by the problem size: at most one worker
/// per item, and at most one per [`MIN_WORK_PER_THREAD`] units of
/// `total_work` — small tensors run serial instead of paying spawn
/// overhead.  Purely a performance cap; results are identical for every
/// thread count (see the determinism contract above).
pub(crate) fn useful_threads(nthreads: usize, items: usize, total_work: usize) -> usize {
    let by_work = (total_work / MIN_WORK_PER_THREAD).max(1);
    nthreads.clamp(1, items.max(1)).min(by_work)
}

/// Worker-thread count for the host kernels: `PQ_THREADS` when set to a
/// positive integer, else `available_parallelism`, clamped to
/// [`MAX_THREADS`].  Read on every call (cheap next to any kernel) so the
/// knob works mid-process.
pub fn threads() -> usize {
    match std::env::var("PQ_THREADS") {
        Ok(v) => match v.trim().parse::<usize>() {
            Ok(t) if t >= 1 => t.min(MAX_THREADS),
            _ => default_threads(),
        },
        Err(_) => default_threads(),
    }
}

fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(MAX_THREADS)
}

/// Run `f(first_item, band)` over contiguous bands of `items` fixed-size
/// items (`item_len` elements each), one scoped worker per band.  Bands
/// partition the buffer, so this is safe-Rust data parallelism; per-element
/// work is unchanged by the banding, which is what makes every kernel's
/// output independent of the thread count.  This is the pure banding
/// mechanism — entry points pick `nthreads` via [`useful_threads`] so tiny
/// workloads stay serial.
pub(crate) fn par_bands<F>(data: &mut [f32], items: usize, item_len: usize, nthreads: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    debug_assert_eq!(data.len(), items * item_len);
    if items == 0 || item_len == 0 {
        return;
    }
    let nt = nthreads.clamp(1, items);
    if nt <= 1 {
        f(0, data);
        return;
    }
    let band = (items + nt - 1) / nt;
    std::thread::scope(|s| {
        for (bi, chunk) in data.chunks_mut(band * item_len).enumerate() {
            let f = &f;
            s.spawn(move || f(bi * band, chunk));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn threads_is_positive_and_capped() {
        let t = threads();
        assert!(t >= 1 && t <= MAX_THREADS);
    }

    #[test]
    fn par_bands_covers_every_item_once() {
        for items in [1usize, 2, 3, 7, 64] {
            for nt in [1usize, 2, 3, 16, 100] {
                let mut data = vec![0.0f32; items * 3];
                par_bands(&mut data, items, 3, nt, |i0, band| {
                    for (off, row) in band.chunks_mut(3).enumerate() {
                        for v in row {
                            *v += (i0 + off) as f32 + 1.0;
                        }
                    }
                });
                for (i, row) in data.chunks(3).enumerate() {
                    assert!(row.iter().all(|&v| v == (i + 1) as f32), "item {i} nt={nt}");
                }
            }
        }
    }

    #[test]
    fn par_bands_empty_is_noop() {
        let mut data: Vec<f32> = vec![];
        par_bands(&mut data, 0, 4, 8, |_, _| panic!("no bands expected"));
    }
}
