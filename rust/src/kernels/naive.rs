//! FROZEN naive host kernels — the pre-kernel-layer implementations.
//!
//! Kept verbatim (modulo the shared scalar quantization primitive, see
//! below) as (a) the golden references `tests/kernel_parity.rs` compares
//! the blocked / FWHT / fused kernels against, and (b) the baselines
//! `benches/quant_speed.rs` measures speedups over.  Do not optimize or
//! "fix" anything here: being slow and simple is the point.
//!
//! The quantizer reference intentionally shares
//! [`super::quantize::fq_scalar`] (reciprocal form) with the fused kernel
//! so parity over steps and codes is bit-exact; what is frozen is the
//! STRUCTURE — column-strided gather into a fresh `Vec` per channel,
//! full-grid O(grid·n) scale scan in γ order, second quantize pass.

use anyhow::Result;

use super::quantize::{self, candidate_step, STEP_FLOOR};
use crate::config::ModelConfig;
use crate::runtime::WeightStore;
use crate::tensor::Tensor;

/// The seed repo's triple-loop matmul (axpy inner loop, zero-skip branch).
pub fn matmul(a: &Tensor, rhs: &Tensor) -> Tensor {
    assert_eq!(a.rank(), 2);
    assert_eq!(rhs.rank(), 2);
    let (m, k) = (a.shape[0], a.shape[1]);
    let (k2, n) = (rhs.shape[0], rhs.shape[1]);
    assert_eq!(k, k2, "matmul inner dim");
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for p in 0..k {
            let av = a.data[i * k + p];
            if av == 0.0 {
                continue;
            }
            let row = &rhs.data[p * n..(p + 1) * n];
            let orow = &mut out[i * n..(i + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(row) {
                *o += av * bv;
            }
        }
    }
    Tensor { shape: vec![m, n], data: out }
}

/// The seed repo's element-at-a-time transpose.
pub fn transpose2(t: &Tensor) -> Tensor {
    assert_eq!(t.rank(), 2);
    let (m, n) = (t.shape[0], t.shape[1]);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            out[j * m + i] = t.data[i * n + j];
        }
    }
    Tensor { shape: vec![n, m], data: out }
}

/// Full-grid scale scan (no pruning, γ-index order, first strict minimum).
pub fn search_scale(xs: &[f32], qm: f32, grid: usize) -> f32 {
    let maxabs = xs.iter().fold(0.0f32, |m, &v| m.max(v.abs())).max(1e-8);
    let rtn = (maxabs / qm).max(STEP_FLOOR);
    if grid <= 1 {
        return rtn;
    }
    let mut best = (f64::INFINITY, rtn);
    for i in 0..grid {
        let s = candidate_step(maxabs, qm, grid, i);
        let e = quantize::sse(xs, s, 1.0 / s, qm);
        if e < best.0 {
            best = (e, s);
        }
    }
    best.1
}

/// The old two-pass per-channel weight quantizer: gather each column into a
/// fresh Vec, search, then re-walk the column to fake-quantize.
pub fn quant_weight_per_channel(w: &mut Tensor, qm: f32, grid: usize) -> Vec<f32> {
    assert_eq!(w.rank(), 2);
    let (rows, cols) = (w.shape[0], w.shape[1]);
    let mut steps = vec![0.0f32; cols];
    for j in 0..cols {
        let col: Vec<f32> = (0..rows).map(|i| w.data[i * cols + j]).collect();
        let s = search_scale(&col, qm, grid);
        steps[j] = s;
        let rinv = 1.0 / s;
        for i in 0..rows {
            let v = &mut w.data[i * cols + j];
            *v = quantize::fq_scalar(*v, s, rinv, qm);
        }
    }
    steps
}

/// The old two-pass per-group weight quantizer (groups along the input
/// dim); returns steps channel-major like the fused kernel.
pub fn quant_weight_per_group(w: &mut Tensor, qm: f32, group: usize, grid: usize) -> Vec<f32> {
    assert_eq!(w.rank(), 2);
    let (rows, cols) = (w.shape[0], w.shape[1]);
    let group = group.max(1);
    let mut steps = Vec::new();
    for j in 0..cols {
        let mut g0 = 0;
        while g0 < rows {
            let g1 = (g0 + group).min(rows);
            let seg: Vec<f32> = (g0..g1).map(|i| w.data[i * cols + j]).collect();
            let s = search_scale(&seg, qm, grid);
            steps.push(s);
            let rinv = 1.0 / s;
            for i in g0..g1 {
                let v = &mut w.data[i * cols + j];
                *v = quantize::fq_scalar(*v, s, rinv, qm);
            }
            g0 = g1;
        }
    }
    steps
}

/// Rotation folding via explicit Hadamard-matrix products (the old
/// `fold_rotations` body driven by the naive matmul).  Assumes norm gains
/// were already absorbed.
pub fn fold_rotations(cfg: &ModelConfig, ws: &mut WeightStore) -> Result<()> {
    let r1 = crate::quant::rotation::hadamard(cfg.d_model);
    let r1t = transpose2(&r1);
    let r2 = crate::quant::rotation::hadamard(cfg.d_head);
    let r2t = transpose2(&r2);
    let r4 = crate::quant::rotation::hadamard(cfg.d_ff);
    let r4t = transpose2(&r4);

    let emb = ws.get("emb").unwrap().clone();
    ws.set("emb", matmul(&emb, &r1));
    let head = ws.get("head").unwrap().clone();
    ws.set("head", matmul(&r1t, &head));

    for l in 0..cfg.n_layers {
        let name = |t: &str| format!("layers.{l}.{t}");
        for t in ["wq", "wk", "wv", "wg", "wu"] {
            let w = ws.get(&name(t)).unwrap().clone();
            ws.set(&name(t), matmul(&r1t, &w));
        }
        for t in ["wo", "wd"] {
            let w = ws.get(&name(t)).unwrap().clone();
            ws.set(&name(t), matmul(&w, &r1));
        }
        let (d, dh, h) = (cfg.d_model, cfg.d_head, cfg.n_heads);
        let mut wv = ws.get(&name("wv")).unwrap().clone();
        for head_i in 0..h {
            let mut block = Tensor::zeros(&[d, dh]);
            for i in 0..d {
                for j in 0..dh {
                    block.data[i * dh + j] = wv.data[i * d + head_i * dh + j];
                }
            }
            let rotated = matmul(&block, &r2);
            for i in 0..d {
                for j in 0..dh {
                    wv.data[i * d + head_i * dh + j] = rotated.data[i * dh + j];
                }
            }
        }
        ws.set(&name("wv"), wv);
        let mut wo = ws.get(&name("wo")).unwrap().clone();
        for head_i in 0..h {
            let mut block = Tensor::zeros(&[dh, d]);
            for i in 0..dh {
                for j in 0..d {
                    block.data[i * d + j] = wo.data[(head_i * dh + i) * d + j];
                }
            }
            let rotated = matmul(&r2t, &block);
            for i in 0..dh {
                for j in 0..d {
                    wo.data[(head_i * dh + i) * d + j] = rotated.data[i * d + j];
                }
            }
        }
        ws.set(&name("wo"), wo);
        let wd = ws.get(&name("wd")).unwrap().clone();
        ws.set(&name("wd"), matmul(&r4t, &wd));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn naive_matmul_identity() {
        let a = Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let eye = Tensor::new(vec![2, 2], vec![1.0, 0.0, 0.0, 1.0]).unwrap();
        assert_eq!(matmul(&a, &eye).data, a.data);
        assert_eq!(transpose2(&a).data, vec![1.0, 3.0, 2.0, 4.0]);
    }
}
