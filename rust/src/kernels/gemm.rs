//! Cache-blocked, multithreaded dense matmul + blocked transpose.
//!
//! The backend of `Tensor::matmul` / `Tensor::transpose2` and of the fused
//! weight quantizer's panel transposes.  Row-major f32 throughout.
//!
//! Blocking: the k and n loops are tiled ([`KC`] × [`NC`], ≈256 KB of B per
//! panel) so a band's active B panel stays cache-resident instead of being
//! re-streamed from memory for every output row — the naive kernel's
//! failure mode.  The inner loop is the axpy form (broadcast `a[i][p]`,
//! stream a contiguous B row slice), which auto-vectorizes and keeps each
//! output element's accumulation in strictly increasing-k order: the same
//! order as the naive triple loop and the same order for every thread
//! count / band split (see the determinism contract in [`super`]).

use super::par_bands;

/// k-dimension tile.
const KC: usize = 128;
/// n-dimension tile (KC×NC×4 bytes ≈ 256 KB B panel).
const NC: usize = 512;
/// Transpose tile edge.
const TB: usize = 32;

/// out[m,n] = a[m,k] · b[k,n], parallelized over row bands with the
/// session-default thread count.
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    matmul_nt(a, b, m, k, n, super::threads())
}

/// [`matmul`] with an explicit worker count (the parity tests sweep this to
/// pin thread-count independence).
pub fn matmul_nt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, nthreads: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k, "matmul lhs element count");
    assert_eq!(b.len(), k * n, "matmul rhs element count");
    let mut out = vec![0.0f32; m * n];
    if m == 0 || n == 0 || k == 0 {
        return out;
    }
    let nt = super::useful_threads(nthreads, m, m * k * n);
    par_bands(&mut out, m, n, nt, |r0, oband| {
        let rows = oband.len() / n;
        band_matmul(&a[r0 * k..(r0 + rows) * k], b, oband, k, n);
    });
    out
}

/// One row band: k/n-tiled axpy kernel (accumulation order fixed per
/// element regardless of tiling — tiles advance k monotonically).
fn band_matmul(a: &[f32], b: &[f32], out: &mut [f32], k: usize, n: usize) {
    let rows = out.len() / n;
    let mut k0 = 0;
    while k0 < k {
        let k1 = (k0 + KC).min(k);
        let mut n0 = 0;
        while n0 < n {
            let n1 = (n0 + NC).min(n);
            for r in 0..rows {
                let arow = &a[r * k..(r + 1) * k];
                let orow = &mut out[r * n + n0..r * n + n1];
                for p in k0..k1 {
                    let x = arow[p];
                    let brow = &b[p * n + n0..p * n + n1];
                    for (o, &bv) in orow.iter_mut().zip(brow) {
                        *o += x * bv;
                    }
                }
            }
            n0 = n1;
        }
        k0 = k1;
    }
}

/// Blocked transpose of a row-major [rows, cols] buffer → [cols, rows],
/// session-default thread count.
pub fn transpose2(src: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    transpose_nt(src, rows, cols, super::threads())
}

/// [`transpose2`] with an explicit worker count.  Tiled ([`TB`]²) so both
/// sides touch cache lines coherently; parallel over output row bands
/// (= source column bands).
pub fn transpose_nt(src: &[f32], rows: usize, cols: usize, nthreads: usize) -> Vec<f32> {
    assert_eq!(src.len(), rows * cols, "transpose element count");
    let mut out = vec![0.0f32; src.len()];
    if rows == 0 || cols == 0 {
        return out;
    }
    let nt = super::useful_threads(nthreads, cols, rows * cols);
    par_bands(&mut out, cols, rows, nt, |c0, oband| {
        let cn = oband.len() / rows;
        let mut r0 = 0;
        while r0 < rows {
            let r1 = (r0 + TB).min(rows);
            let mut cc = 0;
            while cc < cn {
                let cend = (cc + TB).min(cn);
                for r in r0..r1 {
                    let srow = &src[r * cols + c0 + cc..r * cols + c0 + cend];
                    for (ci, &v) in srow.iter().enumerate() {
                        oband[(cc + ci) * rows + r] = v;
                    }
                }
                cc = cend;
            }
            r0 = r1;
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity_and_shapes() {
        let a: Vec<f32> = vec![1.0, 2.0, 3.0, 4.0];
        let eye: Vec<f32> = vec![1.0, 0.0, 0.0, 1.0];
        for nt in [1usize, 2, 7] {
            assert_eq!(matmul_nt(&a, &eye, 2, 2, 2, nt), a);
        }
    }

    #[test]
    fn matmul_rectangular_known_values() {
        // [1 2 3; 4 5 6] · [1 0; 0 1; 1 1] = [4 5; 10 11]
        let a = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let b = vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0];
        assert_eq!(matmul_nt(&a, &b, 2, 3, 2, 3), vec![4.0, 5.0, 10.0, 11.0]);
    }

    #[test]
    fn transpose_roundtrip() {
        let src: Vec<f32> = (0..12).map(|x| x as f32).collect();
        for nt in [1usize, 2, 5] {
            let t = transpose_nt(&src, 3, 4, nt);
            assert_eq!(t[0], 0.0);
            assert_eq!(t[1], 4.0);
            let back = transpose_nt(&t, 4, 3, nt);
            assert_eq!(back, src);
        }
    }

    #[test]
    fn empty_dims_are_fine() {
        let b = vec![0.0f32; 12];
        assert!(matmul_nt(&[], &b, 0, 3, 4, 2).is_empty());
        assert!(transpose_nt(&[], 0, 5, 2).is_empty());
    }
}
