//! In-place fast Walsh–Hadamard transform (normalized, O(n log n)).
//!
//! The Sylvester–Hadamard matrix `H = hadamard(n)` (see `quant::rotation`)
//! is the Kronecker power of `[[1,1],[1,-1]]/√2`, which factors into log₂ n
//! butterfly stages; applying the stages in place replaces every
//! O(n²)-per-row explicit-matrix product of rotation folding with an
//! O(n log n) pass.  `H` is symmetric, so `x·H` (row transform) and `Hᵀ·W =
//! H·W` (column transform) are both the same per-vector butterfly.
//!
//! Parity with the explicit matrices is pinned by `tests/kernel_parity.rs`
//! (≤1e-5 max-normalized error; the FWHT is the *better*-conditioned side —
//! log-depth summation instead of length-n dot products).
//!
//! Threading follows the layer's determinism contract: workers partition
//! rows (or, for column transforms, the transposed rows), never a single
//! butterfly, so results are bit-identical for every `PQ_THREADS`.

use super::{gemm, par_bands};

/// Normalized in-place FWHT of the column sub-range [c0, c0+len) of every
/// row of a row-major [rows, cols] buffer — equivalent to right-multiplying
/// that column block by `hadamard(len)` (used per head for the R2 fold).
/// `len` must be a power of two.
pub fn fwht_rows_sub_nt(
    data: &mut [f32],
    rows: usize,
    cols: usize,
    c0: usize,
    len: usize,
    nthreads: usize,
) {
    assert!(len.is_power_of_two(), "fwht length {len} not a power of 2");
    assert!(c0 + len <= cols, "fwht column range out of bounds");
    assert_eq!(data.len(), rows * cols, "fwht element count");
    let norm = 1.0 / (len as f32).sqrt();
    let nt = super::useful_threads(nthreads, rows, rows * len);
    par_bands(data, rows, cols, nt, |_r0, band| {
        for row in band.chunks_mut(cols) {
            let x = &mut row[c0..c0 + len];
            fwht_inplace(x);
            for v in x.iter_mut() {
                *v *= norm;
            }
        }
    });
}

/// Normalized in-place FWHT of every full row — `W ← W·hadamard(cols)`.
pub fn fwht_rows_nt(data: &mut [f32], rows: usize, cols: usize, nthreads: usize) {
    fwht_rows_sub_nt(data, rows, cols, 0, cols, nthreads);
}

/// Normalized in-place FWHT down every column — `W ← hadamard(rows)ᵀ·W`
/// (= `hadamard(rows)·W`; H is symmetric).  Implemented as transpose →
/// row FWHT → transpose back, which keeps the butterflies contiguous and
/// the parallelism banded.
pub fn fwht_cols_nt(data: &mut [f32], rows: usize, cols: usize, nthreads: usize) {
    assert_eq!(data.len(), rows * cols, "fwht element count");
    let mut t = gemm::transpose_nt(data, rows, cols, nthreads);
    fwht_rows_nt(&mut t, cols, rows, nthreads);
    data.copy_from_slice(&gemm::transpose_nt(&t, cols, rows, nthreads));
}

/// Unnormalized butterfly (smallest stride first; stage order is
/// irrelevant because the per-stage factors I ⊗ H₂ ⊗ I commute).
fn fwht_inplace(x: &mut [f32]) {
    let n = x.len();
    let mut h = 1;
    while h < n {
        let step = h * 2;
        let mut i = 0;
        while i < n {
            for j in i..i + h {
                let a = x[j];
                let b = x[j + h];
                x[j] = a + b;
                x[j + h] = a - b;
            }
            i += step;
        }
        h = step;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fwht_length_two_matches_hand_math() {
        // [a b]·H₂ = [(a+b)/√2, (a−b)/√2]
        let mut d = vec![3.0f32, 1.0];
        fwht_rows_nt(&mut d, 1, 2, 1);
        let r = 1.0 / 2.0f32.sqrt();
        assert!((d[0] - 4.0 * r).abs() < 1e-6);
        assert!((d[1] - 2.0 * r).abs() < 1e-6);
    }

    #[test]
    fn fwht_is_involutive() {
        // H·H = I for the normalized symmetric H: applying twice restores.
        let orig: Vec<f32> = (0..32).map(|i| (i as f32 * 0.37).sin()).collect();
        let mut d = orig.clone();
        fwht_rows_nt(&mut d, 2, 16, 2);
        fwht_rows_nt(&mut d, 2, 16, 2);
        for (a, b) in d.iter().zip(&orig) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn fwht_preserves_energy() {
        let orig: Vec<f32> = (0..64).map(|i| ((i * 7 % 13) as f32) - 6.0).collect();
        let mut d = orig.clone();
        fwht_cols_nt(&mut d, 16, 4, 3);
        let e0: f64 = orig.iter().map(|&v| (v * v) as f64).sum();
        let e1: f64 = d.iter().map(|&v| (v * v) as f64).sum();
        assert!(((e0 - e1) / e0).abs() < 1e-5);
    }

    #[test]
    #[should_panic]
    fn fwht_rejects_non_pow2() {
        let mut d = vec![0.0f32; 12];
        fwht_rows_nt(&mut d, 1, 12, 1);
    }

    #[test]
    fn fwht_sub_range_leaves_rest_untouched() {
        let mut d = vec![1.0f32; 16]; // 2 rows × 8 cols
        fwht_rows_sub_nt(&mut d, 2, 8, 4, 4, 2);
        for row in d.chunks(8) {
            assert_eq!(&row[..4], &[1.0; 4]);
            // all-ones block: first WHT coefficient = 4/√4 = 2, rest 0
            assert!((row[4] - 2.0).abs() < 1e-6);
            assert!(row[5..].iter().all(|&v| v.abs() < 1e-6));
        }
    }
}
