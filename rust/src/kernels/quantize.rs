//! Fused single-pass weight quantizer + lossless pruned scale search.
//!
//! The old path walked each output channel column-strided, gathered it into
//! a fresh `Vec`, ran an O(grid·n) MSE scan, then re-walked the column to
//! fake-quantize.  This kernel transposes the weight ONCE into channel-major
//! panels (contiguous channels), fuses scale search + fake-quant into a
//! single pass per channel, multiplies by precomputed reciprocal steps
//! instead of dividing, and parallelizes across channels.
//!
//! The γ grid search is EXACT: it picks the same step the naive full scan
//! picks (first strict minimum in γ order), but evaluates candidates
//! coarse-to-fine and skips any candidate whose clip-error lower bound —
//! computed in O(log n) from sorted-magnitude prefix sums — already exceeds
//! the incumbent.  Elements with |x| > (qmax+1.5)·s quantize to magnitude
//! ≤ (qmax+1)·s, so Σ(|x|−(qmax+1)·s)² over them bounds the true SSE from
//! below; a qm-scaled slack on the comparison absorbs the floating-point
//! rounding on both sides (see `search_step`).
//! `tests/kernel_parity.rs` pins step/code identity against the frozen
//! two-pass reference.

use super::gemm;

/// Minimum step size — the old per-element `s.max(1e-8)` clamp of `fq`,
/// hoisted to step CONSTRUCTION so inner loops take pre-clamped steps and
/// their reciprocals.
pub const STEP_FLOOR: f32 = 1e-8;

/// Fake-quantize one value: round(x·rinv) clamped to [-qmax-1, qmax], times
/// s.  `rinv` must be `1.0 / s` for a pre-clamped positive `s`.
#[inline]
pub fn fq_scalar(x: f32, s: f32, rinv: f32, qm: f32) -> f32 {
    (x * rinv).round().clamp(-qm - 1.0, qm) * s
}

/// Fake-quant a slice in place; returns the summed squared error (f64,
/// accumulated in index order — part of the determinism contract).
pub fn fq_slice(xs: &mut [f32], s: f32, rinv: f32, qm: f32) -> f64 {
    let mut err = 0.0f64;
    for x in xs.iter_mut() {
        let q = fq_scalar(*x, s, rinv, qm);
        let d = (q - *x) as f64;
        err += d * d;
        *x = q;
    }
    err
}

/// Summed squared quantization error of a slice under step `s` (read-only
/// twin of [`fq_slice`]; same accumulation order).
pub fn sse(xs: &[f32], s: f32, rinv: f32, qm: f32) -> f64 {
    xs.iter()
        .map(|&x| {
            let d = (fq_scalar(x, s, rinv, qm) - x) as f64;
            d * d
        })
        .sum()
}

/// Candidate step i of the γ grid: γ·max|x|/qmax with γ ∈ [0.15, 1.0]
/// evenly spaced over `grid` points, floored to [`STEP_FLOOR`].
/// Requires `grid >= 2`.
#[inline]
pub fn candidate_step(maxabs: f32, qm: f32, grid: usize, i: usize) -> f32 {
    let gamma = 0.15 + 0.85 * (i as f32) / (grid - 1) as f32;
    (gamma * maxabs / qm).max(STEP_FLOOR)
}

/// Coarse-to-fine evaluation order over the γ grid: every 4th index (plus
/// the last) first — landing a strong incumbent early so the clip bound
/// prunes most of the fine pass — then the remaining indices.
fn eval_order(grid: usize) -> Vec<usize> {
    let mut order: Vec<usize> = (0..grid).step_by(4).collect();
    if grid > 0 && (grid - 1) % 4 != 0 {
        order.push(grid - 1);
    }
    let mut seen = vec![false; grid];
    for &i in &order {
        seen[i] = true;
    }
    for (i, s) in seen.iter().enumerate() {
        if !*s {
            order.push(i);
        }
    }
    order
}

/// Grid-search the step minimizing quantization SSE — exactly the step the
/// naive full scan returns (first strict minimum in γ order), with pruning.
/// `grid <= 1` degenerates to RTN (γ = 1).
pub fn search_step(xs: &[f32], qm: f32, grid: usize) -> f32 {
    let maxabs = xs.iter().fold(0.0f32, |m, &v| m.max(v.abs())).max(1e-8);
    let rtn = (maxabs / qm).max(STEP_FLOOR);
    if grid <= 1 || xs.is_empty() {
        return rtn;
    }
    // sorted-descending magnitudes + prefix sums Σ|x|, Σx² over the top-t
    // (total_cmp: a NaN weight must not panic a worker — like the old
    // scan, NaN SSEs lose every `<` comparison and the RTN default wins)
    let mut mags: Vec<f32> = xs.iter().map(|v| v.abs()).collect();
    mags.sort_unstable_by(|a, b| b.total_cmp(a));
    let mut ps1 = Vec::with_capacity(mags.len() + 1);
    let mut ps2 = Vec::with_capacity(mags.len() + 1);
    let (mut s1, mut s2) = (0.0f64, 0.0f64);
    ps1.push(0.0);
    ps2.push(0.0);
    for &m in &mags {
        let m = m as f64;
        s1 += m;
        s2 += m * m;
        ps1.push(s1);
        ps2.push(s2);
    }
    let mut best_err = f64::INFINITY;
    let mut best_i = usize::MAX;
    let mut best_s = rtn;
    // Pruning slack: the closed-form bound uses the exact (qm+1)·s dequant
    // magnitude while fq_scalar rounds it to f32, so a near-clip element's
    // true error term can undershoot the bound by up to ~2(qm+1)·2⁻²⁴
    // relative (≈3e-5 at 8-bit).  Scale the guard with qm, with ~4x
    // headroom on top — still prunes the low-γ candidates, whose bounds
    // exceed the incumbent by orders of magnitude, not parts per thousand.
    let slack = 4e-6 * (qm as f64 + 2.0);
    for i in eval_order(grid) {
        let s = candidate_step(maxabs, qm, grid, i);
        let clip = (qm as f64 + 1.5) * s as f64;
        let t = mags.partition_point(|&m| m as f64 > clip);
        let kk = (qm as f64 + 1.0) * s as f64;
        let lb = ps2[t] - 2.0 * kk * ps1[t] + t as f64 * kk * kk;
        if lb > best_err * (1.0 + slack) {
            continue; // provably cannot beat the incumbent
        }
        let e = sse(xs, s, 1.0 / s, qm);
        // lexicographic (error, γ index) min == the full scan's
        // first-strict-minimum winner, independent of evaluation order
        if e < best_err || (e == best_err && i < best_i) {
            best_err = e;
            best_i = i;
            best_s = s;
        }
    }
    best_s
}

#[derive(Clone, Copy)]
struct Spec {
    qm: f32,
    grid: usize,
    /// rows per group (== rows for per-channel)
    group: usize,
}

/// Per-channel (column) symmetric quantization of a row-major [rows, cols]
/// weight buffer; returns one step per channel.
pub fn quant_per_channel_nt(
    w: &mut [f32],
    rows: usize,
    cols: usize,
    qm: f32,
    grid: usize,
    nthreads: usize,
) -> Vec<f32> {
    let mut steps = vec![0.0f32; cols];
    quant_panels(w, rows, cols, Spec { qm, grid, group: rows.max(1) }, &mut steps, nthreads);
    steps
}

/// Per-group variant: `group` consecutive input rows per step within each
/// channel.  Steps are channel-major: all groups of channel 0, then
/// channel 1, …  (⌈rows/group⌉ steps per channel).
pub fn quant_per_group_nt(
    w: &mut [f32],
    rows: usize,
    cols: usize,
    qm: f32,
    group: usize,
    grid: usize,
    nthreads: usize,
) -> Vec<f32> {
    let group = group.max(1);
    let groups_per = ((rows + group - 1) / group).max(1);
    let mut steps = vec![0.0f32; cols * groups_per];
    quant_panels(w, rows, cols, Spec { qm, grid, group }, &mut steps, nthreads);
    steps
}

fn quant_panels(
    w: &mut [f32],
    rows: usize,
    cols: usize,
    spec: Spec,
    steps: &mut [f32],
    nthreads: usize,
) {
    assert_eq!(w.len(), rows * cols, "quant element count");
    if rows == 0 || cols == 0 {
        return;
    }
    let groups_per = (rows + spec.group - 1) / spec.group;
    debug_assert_eq!(steps.len(), cols * groups_per);
    let mut panel = gemm::transpose_nt(w, rows, cols, nthreads);
    let nt = super::useful_threads(nthreads, cols, rows * cols * spec.grid.max(1));
    if nt <= 1 {
        quant_band(&mut panel, rows, spec, steps);
    } else {
        let band = (cols + nt - 1) / nt;
        std::thread::scope(|s| {
            let sbands = steps.chunks_mut(band * groups_per);
            for (pband, sband) in panel.chunks_mut(band * rows).zip(sbands) {
                s.spawn(move || quant_band(pband, rows, spec, sband));
            }
        });
    }
    w.copy_from_slice(&gemm::transpose_nt(&panel, cols, rows, nthreads));
}

/// Search + fake-quant each (channel × group) segment of a channel-major
/// panel in one pass.
fn quant_band(panel: &mut [f32], rows: usize, spec: Spec, steps: &mut [f32]) {
    let groups_per = (rows + spec.group - 1) / spec.group;
    for (chan, srow) in panel.chunks_mut(rows).zip(steps.chunks_mut(groups_per)) {
        for (seg, st) in chan.chunks_mut(spec.group).zip(srow.iter_mut()) {
            let s = search_step(seg, spec.qm, spec.grid);
            fq_slice(seg, s, 1.0 / s, spec.qm);
            *st = s;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn full_scan(xs: &[f32], qm: f32, grid: usize) -> f32 {
        let maxabs = xs.iter().fold(0.0f32, |m, &v| m.max(v.abs())).max(1e-8);
        if grid <= 1 {
            return (maxabs / qm).max(STEP_FLOOR);
        }
        let mut best = (f64::INFINITY, (maxabs / qm).max(STEP_FLOOR));
        for i in 0..grid {
            let s = candidate_step(maxabs, qm, grid, i);
            let e = sse(xs, s, 1.0 / s, qm);
            if e < best.0 {
                best = (e, s);
            }
        }
        best.1
    }

    #[test]
    fn eval_order_is_a_permutation() {
        for grid in [1usize, 2, 3, 4, 5, 7, 40] {
            let mut o = eval_order(grid);
            o.sort_unstable();
            assert_eq!(o, (0..grid).collect::<Vec<_>>(), "grid={grid}");
        }
    }

    #[test]
    fn pruned_search_matches_full_scan() {
        let mut state = 0x12345u64;
        let mut rnd = move || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((state >> 33) as f32 / (1u64 << 31) as f32) - 0.5
        };
        for case in 0..60 {
            let n = 16 + (case * 37) % 500;
            let mut xs: Vec<f32> = (0..n).map(|_| rnd() * 3.0).collect();
            if case % 3 == 0 {
                xs[0] *= 40.0; // outlier
            }
            if case % 7 == 0 {
                xs.iter_mut().for_each(|v| *v = 0.0); // degenerate
            }
            for grid in [1usize, 7, 40] {
                let a = search_step(&xs, 7.0, grid);
                let b = full_scan(&xs, 7.0, grid);
                assert_eq!(a, b, "case {case} grid {grid}");
            }
        }
    }

    #[test]
    fn fq_scalar_clamps_asymmetrically() {
        // 4-bit: codes live in [-8, 7]
        assert_eq!(fq_scalar(100.0, 1.0, 1.0, 7.0), 7.0);
        assert_eq!(fq_scalar(-100.0, 1.0, 1.0, 7.0), -8.0);
        // round(0.26·10) = 3 → 3·0.1
        assert!((fq_scalar(0.26, 0.1, 10.0, 7.0) - 0.3).abs() < 1e-6);
    }

    #[test]
    fn per_group_step_layout_is_channel_major() {
        // 4 rows × 2 cols, groups of 2 → 2 steps per channel
        let mut w = vec![
            0.1, 8.0, //
            0.1, 8.0, //
            4.0, 0.2, //
            4.0, 0.2,
        ];
        let steps = quant_per_group_nt(&mut w, 4, 2, 7.0, 2, 1, 2);
        assert_eq!(steps.len(), 4);
        // channel 0: groups (0.1,0.1) then (4,4); channel 1: (8,8) then (0.2,0.2)
        assert!(steps[0] < steps[1]);
        assert!(steps[2] > steps[3]);
    }
}
