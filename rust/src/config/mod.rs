//! Configuration mirrored from artifacts/manifest.json.
//!
//! Rust never hardcodes model geometry — everything comes from the manifest
//! written by python/compile/aot.py, so the two sides cannot drift.

use anyhow::Result;

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub name: String,
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub d_ff: usize,
    pub o_model: usize,
    pub inject_amp: f32,
    pub inject_delta: f32,
    pub max_prefix: usize,
    pub train_seq: usize,
    pub eval_seq: usize,
    pub cache_max: usize,
    pub sites: Vec<String>,
}

impl ModelConfig {
    pub fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            name: j.get("name")?.as_str()?.to_string(),
            vocab_size: j.get("vocab_size")?.as_usize()?,
            d_model: j.get("d_model")?.as_usize()?,
            n_layers: j.get("n_layers")?.as_usize()?,
            n_heads: j.get("n_heads")?.as_usize()?,
            d_head: j.get("d_head")?.as_usize()?,
            d_ff: j.get("d_ff")?.as_usize()?,
            o_model: j.get("o_model")?.as_usize()?,
            inject_amp: j.get("inject_amp")?.as_f64()? as f32,
            inject_delta: j.get("inject_delta")?.as_f64()? as f32,
            max_prefix: j.get("max_prefix")?.as_usize()?,
            train_seq: j.get("train_seq")?.as_usize()?,
            eval_seq: j.get("eval_seq")?.as_usize()?,
            cache_max: j.get("cache_max")?.as_usize()?,
            sites: j
                .get("sites")?
                .as_arr()?
                .iter()
                .map(|s| Ok(s.as_str()?.to_string()))
                .collect::<Result<_>>()?,
        })
    }

    pub fn n_sites(&self) -> usize {
        self.sites.len()
    }

    pub fn site_index(&self, name: &str) -> Option<usize> {
        self.sites.iter().position(|s| s == name)
    }
}

#[derive(Debug, Clone)]
pub struct TokenizerSpec {
    pub pad: i32,
    pub bos: i32,
    pub eos: i32,
    pub byte_offset: i32,
    pub vocab_size: usize,
    pub delimiter_ids: Vec<i32>,
}

impl TokenizerSpec {
    pub fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            pad: j.get("pad")?.as_i64()? as i32,
            bos: j.get("bos")?.as_i64()? as i32,
            eos: j.get("eos")?.as_i64()? as i32,
            byte_offset: j.get("byte_offset")?.as_i64()? as i32,
            vocab_size: j.get("vocab_size")?.as_usize()?,
            delimiter_ids: j
                .get("delimiter_ids")?
                .as_arr()?
                .iter()
                .map(|v| Ok(v.as_i64()? as i32))
                .collect::<Result<_>>()?,
        })
    }
}

#[derive(Debug, Clone)]
pub struct CorpusSpec {
    pub n_words: usize,
    pub n_followers: usize,
    pub follow_prob10: u64,
    pub word_seed: u64,
    pub train_seed: u64,
    pub eval_seed: u64,
    pub train_chars: usize,
    pub eval_chars: usize,
}

impl CorpusSpec {
    pub fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            n_words: j.get("n_words")?.as_usize()?,
            n_followers: j.get("n_followers")?.as_usize()?,
            follow_prob10: j.get("follow_prob10")?.as_i64()? as u64,
            word_seed: j.get("word_seed")?.as_i64()? as u64,
            train_seed: j.get("train_seed")?.as_i64()? as u64,
            eval_seed: j.get("eval_seed")?.as_i64()? as u64,
            train_chars: j.get("train_chars")?.as_usize()?,
            eval_chars: j.get("eval_chars")?.as_usize()?,
        })
    }
}
