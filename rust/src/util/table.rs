//! Paper-style ASCII table printing for experiment reports and benches.

pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn rowv(&mut self, cells: Vec<String>) {
        self.row(&cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n== {} ==\n", self.title));
        let line = |out: &mut String, cells: &[String]| {
            let mut parts = Vec::new();
            for (i, c) in cells.iter().enumerate() {
                parts.push(format!("{:<w$}", c, w = widths[i]));
            }
            out.push_str(&format!("| {} |\n", parts.join(" | ")));
        };
        line(&mut out, &self.header);
        let total: usize = widths.iter().sum::<usize>() + 3 * widths.len() + 1;
        out.push_str(&format!("{}\n", "-".repeat(total)));
        for r in &self.rows {
            line(&mut out, r);
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a float with a sensible number of digits for PPL / accuracy tables.
pub fn f(x: f64) -> String {
    if x.abs() >= 1000.0 {
        format!("{x:.0}")
    } else if x.abs() >= 10.0 {
        format!("{x:.2}")
    } else {
        format!("{x:.3}")
    }
}

/// Format a duration in adaptive units.
pub fn dur(secs: f64) -> String {
    if secs < 1e-3 {
        format!("{:.1}us", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2}ms", secs * 1e3)
    } else if secs < 120.0 {
        format!("{secs:.2}s")
    } else {
        format!("{:.1}m", secs / 60.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders() {
        let mut t = Table::new("T", &["a", "bb"]);
        t.row(&["1".into(), "2".into()]);
        let s = t.render();
        assert!(s.contains("== T =="));
        assert!(s.contains("| 1 | 2  |"));
    }

    #[test]
    fn formats() {
        assert_eq!(f(5.4321), "5.432");
        assert_eq!(f(54.321), "54.32");
        assert_eq!(f(5432.1), "5432");
        assert_eq!(dur(0.5), "500.00ms");
        assert_eq!(dur(2.0), "2.00s");
    }

    #[test]
    #[should_panic]
    fn width_mismatch_panics() {
        let mut t = Table::new("T", &["a"]);
        t.row(&["1".into(), "2".into()]);
    }
}
