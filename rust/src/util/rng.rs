//! SplitMix64 — bit-exact twin of python/compile/data.py::SplitMix64.
//!
//! Used for the corpus generator (must match python exactly), for workload
//! generation in benches, and as the driver of the property-test runner.

#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, n) — matches python `next_u64() % n`.
    pub fn below(&mut self, n: u64) -> u64 {
        self.next_u64() % n
    }

    /// Uniform f64 in [0, 1).
    pub fn unit_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.unit_f64() as f32
    }

    /// Standard normal via Box-Muller.
    pub fn normal_f32(&mut self) -> f32 {
        let u1 = self.unit_f64().max(1e-12);
        let u2 = self.unit_f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    /// Golden values cross-checked against the python twin.
    #[test]
    fn matches_python_reference() {
        let mut r = SplitMix64::new(0x5EED_0001);
        let vals: Vec<u64> = (0..4).map(|_| r.next_u64()).collect();
        // python: SplitMix64(0x5EED0001); [next_u64() for _ in range(4)]
        assert_eq!(
            vals,
            vec![
                230101071268130872,
                15861643767604601036,
                8447366613921678455,
                3342784234598768517,
            ]
        );
    }

    #[test]
    fn below_bounds() {
        let mut r = SplitMix64::new(7);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }
}
