//! Hand-rolled substrates for the offline image (see DESIGN.md).

pub mod args;
pub mod json;
pub mod prop;
pub mod rng;
pub mod table;
