//! Hand-rolled property-testing runner (proptest is not cached offline).
//!
//! `check(name, cases, gen, prop)` runs `prop` against `cases` random inputs
//! drawn from `gen`; on failure it performs a simple halving shrink over the
//! generator seed-stream length when the input is a Vec, then panics with the
//! seed so the case can be replayed.

use crate::util::rng::SplitMix64;

pub struct Gen<'a> {
    pub rng: &'a mut SplitMix64,
}

impl<'a> Gen<'a> {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below((hi - lo + 1) as u64) as usize
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.range_f32(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.below(2) == 1
    }

    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32_in(lo, hi)).collect()
    }

    pub fn vec_normal(&mut self, len: usize, std: f32) -> Vec<f32> {
        (0..len).map(|_| self.rng.normal_f32() * std).collect()
    }

    pub fn choose<'b, T>(&mut self, items: &'b [T]) -> &'b T {
        &items[self.rng.below(items.len() as u64) as usize]
    }
}

/// Run a property over `cases` random inputs. `make` builds an input from a
/// Gen; `prop` returns Err(description) on violation.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    cases: u64,
    mut make: impl FnMut(&mut Gen) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = SplitMix64::new(seed);
        let mut g = Gen { rng: &mut rng };
        let input = make(&mut g);
        if let Err(msg) = prop(&input) {
            panic!(
                "property {name:?} failed on case {case} (seed {seed:#x}):\n  {msg}\n  input: {input:?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial() {
        check("abs-nonneg", 50, |g| g.f32_in(-5.0, 5.0), |x| {
            if x.abs() >= 0.0 { Ok(()) } else { Err("neg".into()) }
        });
    }

    #[test]
    #[should_panic(expected = "always-fails")]
    fn reports_failure() {
        check("always-fails", 5, |g| g.usize_in(0, 10), |_| Err("boom".into()));
    }
}
