//! Minimal JSON parser / writer (the image has no serde_json offline).
//!
//! Supports the full JSON grammar minus exotic number forms; good enough for
//! `artifacts/manifest.json` and the experiment reports we emit.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_i64(&self) -> Result<i64> {
        Ok(self.as_f64()? as i64)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object: {self:?}"),
        }
    }

    /// Serialize (compact).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected EOF"))
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|e| anyhow!("bad number {text:?}: {e}"))?))
    }

    fn string(&mut self) -> Result<String> {
        if self.peek()? != b'"' {
            bail!("expected string at byte {}", self.i);
        }
        self.i += 1;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c => {
                    // re-decode utf8 multibyte sequences
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = utf8_len(c);
                        let sl = &self.b[start..start + len];
                        out.push_str(std::str::from_utf8(sl)?);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.i += 1; // {
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            if self.peek()? != b':' {
                bail!("expected ':' at byte {}", self.i);
            }
            self.i += 1;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => bail!("expected ',' or '}}' at byte {}", self.i),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.i += 1; // [
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => bail!("expected ',' or ']' at byte {}", self.i),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    if first >= 0xF0 {
        4
    } else if first >= 0xE0 {
        3
    } else {
        2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3], "b": {"c": "x\ny"}, "d": true, "e": null}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_str().unwrap(), "x\ny");
        assert!(v.get("d").unwrap().as_bool().unwrap());
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{\"a\": }").is_err());
        assert!(Json::parse("[1,2,]").is_err());
        assert!(Json::parse("[1] trailing").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#"["A", "π", "\t"]"#).unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a[0].as_str().unwrap(), "A");
        assert_eq!(a[1].as_str().unwrap(), "π");
        assert_eq!(a[2].as_str().unwrap(), "\t");
    }

    #[test]
    fn numbers() {
        let v = Json::parse("[0, -1, 2.75, 1e3]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a[3].as_f64().unwrap(), 1000.0);
    }
}
