//! Tiny CLI argument parser (no clap offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Args {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(rest) = a.strip_prefix("--") {
                if let Some(eq) = rest.find('=') {
                    out.options.insert(rest[..eq].to_string(), rest[eq + 1..].to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    out.options.insert(rest.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        out
    }

    pub fn from_env() -> Args {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow!("--{name}: {e}")),
        }
    }

    pub fn f32_or(&self, name: &str, default: f32) -> Result<f32> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow!("--{name}: {e}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_mixed() {
        let a = Args::parse(&sv(&["eval", "--model", "pq-tiny", "--fast", "--n=4"]));
        assert_eq!(a.positional, vec!["eval"]);
        assert_eq!(a.get("model"), Some("pq-tiny"));
        assert!(a.flag("fast"));
        assert_eq!(a.usize_or("n", 0).unwrap(), 4);
    }

    #[test]
    fn flag_before_positional() {
        let a = Args::parse(&sv(&["--verbose", "run"]));
        // "--verbose run": "run" is consumed as the value of --verbose
        assert_eq!(a.get("verbose"), Some("run"));
        let b = Args::parse(&sv(&["run", "--verbose"]));
        assert!(b.flag("verbose"));
        assert_eq!(b.positional, vec!["run"]);
    }

    #[test]
    fn defaults() {
        let a = Args::parse(&sv(&[]));
        assert_eq!(a.get_or("x", "d"), "d");
        assert_eq!(a.f32_or("y", 1.5).unwrap(), 1.5);
    }
}
