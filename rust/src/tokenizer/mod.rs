//! Byte-level tokenizer — bit-exact twin of python/compile/tokenizer.py.

use crate::config::TokenizerSpec;

#[derive(Debug, Clone)]
pub struct Tokenizer {
    pub spec: TokenizerSpec,
}

impl Tokenizer {
    pub fn new(spec: TokenizerSpec) -> Self {
        Self { spec }
    }

    pub fn encode(&self, text: &str, add_bos: bool) -> Vec<i32> {
        let mut ids = Vec::with_capacity(text.len() + 1);
        if add_bos {
            ids.push(self.spec.bos);
        }
        ids.extend(text.bytes().map(|b| b as i32 + self.spec.byte_offset));
        ids
    }

    pub fn decode(&self, ids: &[i32]) -> String {
        let bytes: Vec<u8> = ids
            .iter()
            .filter(|&&i| i >= self.spec.byte_offset && i < self.spec.byte_offset + 256)
            .map(|&i| (i - self.spec.byte_offset) as u8)
            .collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }

    pub fn is_delimiter(&self, id: i32) -> bool {
        self.spec.delimiter_ids.contains(&id)
    }

    /// Human-readable rendering of a token id (outlier reports, Table 1).
    pub fn token_repr(&self, id: i32) -> String {
        if id == self.spec.pad {
            return "[PAD]".into();
        }
        if id == self.spec.bos {
            return "[BOS]".into();
        }
        if id == self.spec.eos {
            return "[EOS]".into();
        }
        if id >= self.spec.byte_offset && id < self.spec.byte_offset + 256 {
            let b = (id - self.spec.byte_offset) as u8;
            return match b {
                b'\n' => "\\n".into(),
                b' ' => "\u{2423}".into(), // ␣
                32..=126 => (b as char).to_string(),
                _ => format!("<0x{b:02x}>"),
            };
        }
        format!("<res{id}>")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tok() -> Tokenizer {
        Tokenizer::new(TokenizerSpec {
            pad: 0,
            bos: 1,
            eos: 2,
            byte_offset: 3,
            vocab_size: 272,
            delimiter_ids: vec![13, 49],
        })
    }

    #[test]
    fn roundtrip() {
        let t = tok();
        let ids = t.encode("ab.\n", true);
        assert_eq!(ids, vec![1, 100, 101, 49, 13]);
        assert_eq!(t.decode(&ids), "ab.\n");
    }

    #[test]
    fn delimiters_and_repr() {
        let t = tok();
        assert!(t.is_delimiter(49));
        assert!(t.is_delimiter(13));
        assert!(!t.is_delimiter(100));
        assert_eq!(t.token_repr(1), "[BOS]");
        assert_eq!(t.token_repr(49), ".");
        assert_eq!(t.token_repr(13), "\\n");
    }

    #[test]
    fn no_bos() {
        let t = tok();
        assert_eq!(t.encode("a", false), vec![100]);
    }
}
