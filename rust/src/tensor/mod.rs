//! Minimal host-side dense tensors (row-major f32 / i32).
//!
//! Just enough ndarray for the quantization pipeline: shaped storage, index
//! math, slicing along the leading axes, and the reductions the outlier
//! detector and host quantizer need.  Device math stays in the AOT
//! executables; these tensors are the host staging format.

use anyhow::{bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

#[derive(Debug, Clone, PartialEq)]
pub struct IntTensor {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

fn numel(shape: &[usize]) -> usize {
    shape.iter().product()
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        if numel(&shape) != data.len() {
            bail!("shape {:?} wants {} elements, got {}", shape, numel(&shape), data.len());
        }
        Ok(Self { shape, data })
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Self { shape: shape.to_vec(), data: vec![0.0; numel(shape)] }
    }

    pub fn full(shape: &[usize], v: f32) -> Self {
        Self { shape: shape.to_vec(), data: vec![v; numel(shape)] }
    }

    pub fn scalar(v: f32) -> Self {
        Self { shape: vec![], data: vec![v] }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Row-major strides.
    pub fn strides(&self) -> Vec<usize> {
        let mut s = vec![1usize; self.shape.len()];
        for i in (0..self.shape.len().saturating_sub(1)).rev() {
            s[i] = s[i + 1] * self.shape[i + 1];
        }
        s
    }

    pub fn at(&self, idx: &[usize]) -> f32 {
        debug_assert_eq!(idx.len(), self.shape.len());
        let st = self.strides();
        let off: usize = idx.iter().zip(&st).map(|(i, s)| i * s).sum();
        self.data[off]
    }

    pub fn set(&mut self, idx: &[usize], v: f32) {
        let st = self.strides();
        let off: usize = idx.iter().zip(&st).map(|(i, s)| i * s).sum();
        self.data[off] = v;
    }

    /// Reinterpret with a new shape (same element count).
    pub fn reshape(mut self, shape: Vec<usize>) -> Result<Self> {
        if numel(&shape) != self.data.len() {
            bail!("reshape {:?} -> {:?}: element count mismatch", self.shape, shape);
        }
        self.shape = shape;
        Ok(self)
    }

    /// Slice index `i` along axis 0 (returns an owned copy).
    pub fn index0(&self, i: usize) -> Tensor {
        let inner = numel(&self.shape[1..]);
        let data = self.data[i * inner..(i + 1) * inner].to_vec();
        Tensor { shape: self.shape[1..].to_vec(), data }
    }

    /// Slice a contiguous range along axis 0.
    pub fn slice0(&self, start: usize, end: usize) -> Tensor {
        let inner = numel(&self.shape[1..]);
        let mut shape = self.shape.clone();
        shape[0] = end - start;
        Tensor { shape, data: self.data[start * inner..end * inner].to_vec() }
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
    }

    /// max|x| along the last axis: result shape = shape[..-1].
    pub fn max_abs_lastdim(&self) -> Tensor {
        let c = *self.shape.last().expect("rank >= 1");
        let rows = self.data.len() / c;
        let mut out = Vec::with_capacity(rows);
        for r in 0..rows {
            out.push(self.data[r * c..(r + 1) * c].iter().fold(0.0f32, |m, &v| m.max(v.abs())));
        }
        Tensor { shape: self.shape[..self.shape.len() - 1].to_vec(), data: out }
    }

    pub fn mean(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().sum::<f32>() / self.data.len() as f32
    }

    /// Mean squared difference against another tensor of identical shape.
    pub fn mse(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        let n = self.data.len() as f64;
        let s: f64 = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| {
                let d = (*a - *b) as f64;
                d * d
            })
            .sum();
        (s / n) as f32
    }

    /// Matrix product for 2-D tensors (host-side weight folding only).
    /// Backed by the cache-blocked, multithreaded kernel layer
    /// (`kernels::gemm`, `PQ_THREADS` knob): same accumulation order as the
    /// frozen naive triple loop, so results are f32-equal to it and
    /// bit-identical across thread counts.
    pub fn matmul(&self, rhs: &Tensor) -> Tensor {
        assert_eq!(self.rank(), 2);
        assert_eq!(rhs.rank(), 2);
        let (m, k) = (self.shape[0], self.shape[1]);
        let (k2, n) = (rhs.shape[0], rhs.shape[1]);
        assert_eq!(k, k2, "matmul inner dim");
        let data = crate::kernels::gemm::matmul(&self.data, &rhs.data, m, k, n);
        Tensor { shape: vec![m, n], data }
    }

    pub fn transpose2(&self) -> Tensor {
        assert_eq!(self.rank(), 2);
        let (m, n) = (self.shape[0], self.shape[1]);
        let data = crate::kernels::gemm::transpose2(&self.data, m, n);
        Tensor { shape: vec![n, m], data }
    }

    pub fn scale_inplace(&mut self, s: f32) {
        for v in &mut self.data {
            *v *= s;
        }
    }
}

impl IntTensor {
    pub fn new(shape: Vec<usize>, data: Vec<i32>) -> Result<Self> {
        if numel(&shape) != data.len() {
            bail!("shape {:?} wants {} elements, got {}", shape, numel(&shape), data.len());
        }
        Ok(Self { shape, data })
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Self { shape: shape.to_vec(), data: vec![0; numel(shape)] }
    }

    pub fn scalar(v: i32) -> Self {
        Self { shape: vec![], data: vec![v] }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }
}

/// Percentile over a copy of the data (nearest-rank). p in [0, 100].
pub fn percentile(values: &[f32], p: f32) -> f32 {
    assert!(!values.is_empty());
    let mut v: Vec<f32> = values.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() - 1) as f32).round() as usize;
    v[rank.min(v.len() - 1)]
}

pub fn median(values: &[f32]) -> f32 {
    percentile(values, 50.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_checks() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn index_and_strides() {
        let t = Tensor::new(vec![2, 3], (0..6).map(|x| x as f32).collect()).unwrap();
        assert_eq!(t.at(&[1, 2]), 5.0);
        assert_eq!(t.strides(), vec![3, 1]);
        assert_eq!(t.index0(1).data, vec![3.0, 4.0, 5.0]);
    }

    #[test]
    fn reductions() {
        let t = Tensor::new(vec![2, 2], vec![1.0, -4.0, 2.0, 3.0]).unwrap();
        assert_eq!(t.max_abs(), 4.0);
        assert_eq!(t.max_abs_lastdim().data, vec![4.0, 3.0]);
        assert!((t.mean() - 0.5).abs() < 1e-6);
    }

    #[test]
    fn matmul_small() {
        let a = Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let b = Tensor::new(vec![2, 2], vec![1.0, 0.0, 0.0, 1.0]).unwrap();
        assert_eq!(a.matmul(&b).data, a.data);
        let t = a.transpose2();
        assert_eq!(t.data, vec![1.0, 3.0, 2.0, 4.0]);
    }

    #[test]
    fn mse_and_percentile() {
        let a = Tensor::new(vec![3], vec![1.0, 2.0, 3.0]).unwrap();
        let b = Tensor::new(vec![3], vec![1.0, 2.0, 5.0]).unwrap();
        assert!((a.mse(&b) - 4.0 / 3.0).abs() < 1e-6);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(percentile(&[1.0, 2.0, 3.0, 4.0], 100.0), 4.0);
    }
}
