//! Open-loop workload harness: seeded trace generation, an open-loop driver
//! that submits on the arrival clock (never back-pressured by completions),
//! and an offered-load sweep whose headline metric is **SLO goodput** —
//! completions per second that met their class's TTFT/TPOT budget.
//!
//! The pieces compose:
//!
//! - [`trace`]: [`Workload`] specs (arrival process × scenario mix × SLO
//!   targets) generate deterministic [`Trace`]s — pure functions of the
//!   seed, fingerprintable, and whole-ms-deadline-stamped so a captured run
//!   survives an oplog export → `pq replay` round trip.
//! - [`driver`]: [`run_trace`] fires a trace at a [`Target`] (single
//!   [`Server`](crate::coordinator::server::Server) or routed
//!   [`Router`](crate::coordinator::cluster::Router) fleet) and scores
//!   per-class attainment into a [`RunScore`].
//! - [`sweep`]: [`sweep_rates`] walks offered load past the saturation
//!   knee and reports the goodput curve.
//!
//! `pq loadgen` and `benches/goodput.rs` are thin shells over these.

pub mod driver;
pub mod sweep;
pub mod trace;

pub use driver::{run_trace, ClassScore, RequestOutcome, RunReport, RunScore, Target};
pub use sweep::{render_table, sweep_rates, SweepPoint, SweepReport};
pub use trace::{
    default_slo, ArrivalProcess, Scenario, ScenarioKind, SloTarget, Trace, TraceEvent, Workload,
};
