//! Seeded, deterministic trace generation: arrival processes × scenario
//! mixes → a [`Trace`] of timestamped [`GenRequest`]s with per-class SLO
//! targets attached.
//!
//! Generation is a pure function of the [`Workload`] spec (including its
//! seed): a single [`SplitMix64`] stream drives every draw in a fixed order,
//! no wall clock or thread pool is consulted, so the same spec produces a
//! byte-identical trace on any machine and under any `PQ_THREADS` setting.
//! [`Trace::fingerprint`] hashes the canonical encoding so benches and tests
//! can assert that in one comparison.
//!
//! Deadlines are stamped at whole-millisecond granularity on purpose: the
//! oplog journals `deadline` as integer milliseconds, so a generated trace
//! survives an export → `pq replay` round trip exactly.

use std::time::Duration;

use crate::coordinator::request::{GenRequest, Priority};
use crate::util::rng::SplitMix64;

/// Token values emitted into prompts: `PROMPT_BASE + [0, PROMPT_SPAN)`,
/// comfortably inside the sim backend's 271-token vocabulary and clear of
/// BOS/PAD.
const PROMPT_BASE: i32 = 5;
const PROMPT_SPAN: u64 = 200;

/// How a request stream arrives.  All processes share the workload's mean
/// rate; they differ in how the gaps are distributed.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalProcess {
    /// memoryless arrivals: exponential inter-arrival gaps
    Poisson,
    /// on/off bursts: arrivals only during `on_s`-long windows separated by
    /// `off_s`-long silences, at a within-burst rate inflated so the mean
    /// over wall time still matches the configured rate
    Bursty { on_s: f64, off_s: f64 },
    /// Pareto inter-arrival gaps with tail index `alpha` (> 1), scaled so
    /// the mean gap is `1/rate`; smaller `alpha` = heavier tail
    HeavyTail { alpha: f64 },
}

impl ArrivalProcess {
    pub fn name(&self) -> &'static str {
        match self {
            ArrivalProcess::Poisson => "poisson",
            ArrivalProcess::Bursty { .. } => "bursty",
            ArrivalProcess::HeavyTail { .. } => "heavy-tail",
        }
    }

    /// `n` arrival offsets (seconds from trace start), non-decreasing.
    fn times(&self, rate_rps: f64, n: usize, rng: &mut SplitMix64) -> Vec<f64> {
        let rate = rate_rps.max(1e-9);
        let mut out = Vec::with_capacity(n);
        match *self {
            ArrivalProcess::Poisson => {
                let mut t = 0.0;
                for _ in 0..n {
                    t += exp_gap(rate, rng);
                    out.push(t);
                }
            }
            ArrivalProcess::Bursty { on_s, off_s } => {
                // accumulate "on-time" at the inflated within-burst rate,
                // then map on-time to wall time by inserting the off windows
                let on = on_s.max(1e-6);
                let off = off_s.max(0.0);
                let rate_on = rate * (on + off) / on;
                let mut tau = 0.0;
                for _ in 0..n {
                    tau += exp_gap(rate_on, rng);
                    let bursts = (tau / on).floor();
                    out.push(bursts * (on + off) + (tau - bursts * on));
                }
            }
            ArrivalProcess::HeavyTail { alpha } => {
                let a = alpha.max(1.0 + 1e-6);
                // x_m chosen so the Pareto mean a*x_m/(a-1) equals 1/rate
                let x_m = (a - 1.0) / (a * rate);
                let mut t = 0.0;
                for _ in 0..n {
                    let u = rng.unit_f64();
                    t += x_m * (1.0 - u).powf(-1.0 / a);
                    out.push(t);
                }
            }
        }
        out
    }
}

/// Exponential gap with mean `1/rate` (`u` in [0,1) keeps `ln` finite).
fn exp_gap(rate: f64, rng: &mut SplitMix64) -> f64 {
    -(1.0 - rng.unit_f64()).ln() / rate
}

/// Scenario families the generator knows how to shape.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScenarioKind {
    ShortChat,
    LongDocument,
    AgentLoop,
    Interactive,
    BatchFill,
    BestEffort,
}

impl ScenarioKind {
    pub fn name(&self) -> &'static str {
        match self {
            ScenarioKind::ShortChat => "short-chat",
            ScenarioKind::LongDocument => "long-document",
            ScenarioKind::AgentLoop => "agent-loop",
            ScenarioKind::Interactive => "interactive",
            ScenarioKind::BatchFill => "batch-fill",
            ScenarioKind::BestEffort => "best-effort",
        }
    }
}

/// One request family: class, prompt/generation shape, shared-prefix
/// structure, cancellation behavior, and an optional deadline stamp.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub kind: ScenarioKind,
    pub priority: Priority,
    /// prompt length range, inclusive
    pub prompt_lo: usize,
    pub prompt_hi: usize,
    /// generation budget range, inclusive
    pub max_new_lo: usize,
    pub max_new_hi: usize,
    /// distinct shared prompt pools (0 = every prompt unique).  Requests in
    /// one pool share a common prompt prefix — the radix cache's food.
    pub prefix_groups: usize,
    /// probability a request is cancelled mid-stream by the driver
    pub cancel_rate: f64,
    /// cancel delay range after submission, seconds
    pub cancel_after_lo_s: f64,
    pub cancel_after_hi_s: f64,
    /// whole-millisecond latency budget stamped on every request (whole ms
    /// so the oplog's integer-ms encoding round-trips exactly)
    pub deadline_ms: Option<u64>,
}

impl Scenario {
    /// Small prompts, small replies, heavily shared openings.
    pub fn short_chat() -> Scenario {
        Scenario {
            kind: ScenarioKind::ShortChat,
            priority: Priority::Interactive,
            prompt_lo: 4,
            prompt_hi: 8,
            max_new_lo: 3,
            max_new_hi: 5,
            prefix_groups: 8,
            cancel_rate: 0.0,
            cancel_after_lo_s: 0.0,
            cancel_after_hi_s: 0.0,
            deadline_ms: None,
        }
    }

    /// Long shared-document prefills with batch-class replies.
    pub fn long_document() -> Scenario {
        Scenario {
            kind: ScenarioKind::LongDocument,
            priority: Priority::Batch,
            prompt_lo: 28,
            prompt_hi: 44,
            max_new_lo: 8,
            max_new_hi: 12,
            prefix_groups: 4,
            cancel_rate: 0.0,
            cancel_after_lo_s: 0.0,
            cancel_after_hi_s: 0.0,
            deadline_ms: None,
        }
    }

    /// Agent sessions: each group's context grows turn over turn (the new
    /// prompt extends the previous one, so the radix cache can serve the
    /// re-submitted history), with mid-stream cancellations.
    pub fn agent_loop() -> Scenario {
        Scenario {
            kind: ScenarioKind::AgentLoop,
            priority: Priority::Interactive,
            prompt_lo: 12,
            prompt_hi: 32,
            max_new_lo: 4,
            max_new_hi: 8,
            prefix_groups: 6,
            cancel_rate: 0.15,
            cancel_after_lo_s: 0.005,
            cancel_after_hi_s: 0.080,
            deadline_ms: None,
        }
    }

    /// Deadline-carrying interactive traffic (tight latency budget).
    pub fn interactive_deadline() -> Scenario {
        Scenario {
            kind: ScenarioKind::Interactive,
            priority: Priority::Interactive,
            prompt_lo: 3,
            prompt_hi: 6,
            max_new_lo: 2,
            max_new_hi: 3,
            prefix_groups: 0,
            cancel_rate: 0.0,
            cancel_after_lo_s: 0.0,
            cancel_after_hi_s: 0.0,
            deadline_ms: Some(80),
        }
    }

    /// Saturating batch wave (the `scheduler_policy` bench's background
    /// load: mid prompts, long generations).
    pub fn batch_fill() -> Scenario {
        Scenario {
            kind: ScenarioKind::BatchFill,
            priority: Priority::Batch,
            prompt_lo: 8,
            prompt_hi: 12,
            max_new_lo: 20,
            max_new_hi: 24,
            prefix_groups: 0,
            cancel_rate: 0.0,
            cancel_after_lo_s: 0.0,
            cancel_after_hi_s: 0.0,
            deadline_ms: None,
        }
    }

    /// Short deadline-stamped interactive burst (the `scheduler_policy`
    /// bench's foreground load: tiny prompts, two-token replies).
    pub fn interactive_burst() -> Scenario {
        Scenario {
            kind: ScenarioKind::Interactive,
            priority: Priority::Interactive,
            prompt_lo: 3,
            prompt_hi: 6,
            max_new_lo: 2,
            max_new_hi: 2,
            prefix_groups: 0,
            cancel_rate: 0.0,
            cancel_after_lo_s: 0.0,
            cancel_after_hi_s: 0.0,
            deadline_ms: Some(50),
        }
    }

    /// Background best-effort filler.
    pub fn best_effort() -> Scenario {
        Scenario {
            kind: ScenarioKind::BestEffort,
            priority: Priority::BestEffort,
            prompt_lo: 6,
            prompt_hi: 16,
            max_new_lo: 6,
            max_new_hi: 10,
            prefix_groups: 0,
            cancel_rate: 0.0,
            cancel_after_lo_s: 0.0,
            cancel_after_hi_s: 0.0,
            deadline_ms: None,
        }
    }

    fn sample_len(lo: usize, hi: usize, rng: &mut SplitMix64) -> usize {
        if hi <= lo {
            lo
        } else {
            lo + rng.below((hi - lo + 1) as u64) as usize
        }
    }
}

/// Per-class SLO target: a completion "counts" (goodput) only when its TTFT
/// and TPOT both land inside the class budget.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloTarget {
    pub ttft_s: f64,
    pub tpot_s: f64,
}

/// Default per-class SLO vector (index = [`Priority::index`]): tight for
/// Interactive, loose for Batch, looser for BestEffort.
pub fn default_slo() -> [SloTarget; Priority::COUNT] {
    let mut slo = [SloTarget { ttft_s: 1.0, tpot_s: 0.1 }; Priority::COUNT];
    slo[Priority::Interactive.index()] = SloTarget { ttft_s: 0.050, tpot_s: 0.025 };
    slo[Priority::Batch.index()] = SloTarget { ttft_s: 0.400, tpot_s: 0.050 };
    slo[Priority::BestEffort.index()] = SloTarget { ttft_s: 2.000, tpot_s: 0.100 };
    slo
}

/// A complete open-loop workload spec: arrival process + rate, request
/// count, weighted scenario mix, per-class SLOs, and the seed that makes the
/// whole thing reproducible.
#[derive(Debug, Clone)]
pub struct Workload {
    pub name: String,
    pub arrival: ArrivalProcess,
    pub rate_rps: f64,
    pub n_requests: usize,
    pub seed: u64,
    /// (scenario, weight) — weights need not sum to 1
    pub mix: Vec<(Scenario, f64)>,
    /// per-class SLO targets (index = [`Priority::index`])
    pub slo: [SloTarget; Priority::COUNT],
}

impl Workload {
    /// The standard mixed workload: shared-opening chat, long-document
    /// prefill, agent loops with cancellations, deadline-stamped
    /// interactive traffic, and best-effort filler.
    pub fn mixed(seed: u64) -> Workload {
        Workload {
            name: "mixed".into(),
            arrival: ArrivalProcess::Poisson,
            rate_rps: 100.0,
            n_requests: 200,
            seed,
            mix: vec![
                (Scenario::short_chat(), 0.20),
                (Scenario::long_document(), 0.40),
                (Scenario::agent_loop(), 0.10),
                (Scenario::interactive_deadline(), 0.10),
                (Scenario::best_effort(), 0.20),
            ],
            slo: default_slo(),
        }
    }

    /// Single-scenario workload (the `scheduler_policy` bench builds its
    /// two waves from these).
    pub fn single(name: &str, scenario: Scenario, seed: u64) -> Workload {
        Workload {
            name: name.into(),
            arrival: ArrivalProcess::Poisson,
            rate_rps: 100.0,
            n_requests: 100,
            seed,
            mix: vec![(scenario, 1.0)],
            slo: default_slo(),
        }
    }

    pub fn with_rate(mut self, rate_rps: f64) -> Workload {
        self.rate_rps = rate_rps;
        self
    }

    pub fn with_requests(mut self, n: usize) -> Workload {
        self.n_requests = n;
        self
    }

    pub fn with_arrival(mut self, arrival: ArrivalProcess) -> Workload {
        self.arrival = arrival;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Workload {
        self.seed = seed;
        self
    }

    /// Generate the trace.  Pure: same spec (same seed) → identical trace,
    /// independent of machine, run, or `PQ_THREADS`.
    pub fn generate(&self) -> Trace {
        let mut rng = SplitMix64::new(self.seed);
        let times = self.arrival.times(self.rate_rps, self.n_requests, &mut rng);

        // Shared prompt pools, generated up front in a fixed order.  Each
        // grouped scenario owns `prefix_groups` pools of `prompt_hi` tokens;
        // a request takes a prefix of its pool, so pool-mates share their
        // opening tokens (and agent sessions literally extend each other).
        let mut pools: Vec<Vec<Vec<i32>>> = Vec::with_capacity(self.mix.len());
        for (sc, _) in &self.mix {
            let mut groups = Vec::with_capacity(sc.prefix_groups);
            for _ in 0..sc.prefix_groups {
                let pool: Vec<i32> = (0..sc.prompt_hi)
                    .map(|_| PROMPT_BASE + rng.below(PROMPT_SPAN) as i32)
                    .collect();
                groups.push(pool);
            }
            pools.push(groups);
        }
        let mut agent_steps: Vec<Vec<usize>> =
            self.mix.iter().map(|(sc, _)| vec![0; sc.prefix_groups]).collect();

        let total_weight: f64 = self.mix.iter().map(|(_, w)| w.max(0.0)).sum();
        let mut events = Vec::with_capacity(self.n_requests);
        for (i, &at_s) in times.iter().enumerate() {
            // pick a scenario by weight
            let mut pick = rng.unit_f64() * total_weight.max(1e-12);
            let mut si = self.mix.len() - 1;
            for (j, (_, w)) in self.mix.iter().enumerate() {
                pick -= w.max(0.0);
                if pick < 0.0 {
                    si = j;
                    break;
                }
            }
            let sc = &self.mix[si].0;

            let (prompt, group) = if sc.prefix_groups > 0 {
                let g = rng.below(sc.prefix_groups as u64) as usize;
                let len = if sc.kind == ScenarioKind::AgentLoop {
                    // session context grows turn over turn
                    let step = agent_steps[si][g];
                    agent_steps[si][g] += 1;
                    (sc.prompt_lo + 4 * step).min(sc.prompt_hi)
                } else {
                    Scenario::sample_len(sc.prompt_lo, sc.prompt_hi, &mut rng)
                };
                (pools[si][g][..len.min(pools[si][g].len())].to_vec(), Some(g))
            } else {
                let len = Scenario::sample_len(sc.prompt_lo, sc.prompt_hi, &mut rng);
                let p = (0..len).map(|_| PROMPT_BASE + rng.below(PROMPT_SPAN) as i32).collect();
                (p, None)
            };
            let max_new = Scenario::sample_len(sc.max_new_lo, sc.max_new_hi, &mut rng);
            let sample_seed = rng.next_u64();
            let mut b = GenRequest::builder(i as u64)
                .prompt(prompt)
                .max_new(max_new)
                .priority(sc.priority)
                .seed(sample_seed);
            if let Some(ms) = sc.deadline_ms {
                b = b.deadline(Duration::from_millis(ms));
            }
            let cancel_after_s = if sc.cancel_rate > 0.0 && rng.unit_f64() < sc.cancel_rate {
                let u = rng.unit_f64();
                Some(sc.cancel_after_lo_s + u * (sc.cancel_after_hi_s - sc.cancel_after_lo_s))
            } else {
                None
            };
            events.push(TraceEvent {
                at_s,
                kind: sc.kind,
                group,
                req: b.build(),
                cancel_after_s,
            });
        }
        Trace {
            workload: self.name.clone(),
            seed: self.seed,
            rate_rps: self.rate_rps,
            slo: self.slo,
            events,
        }
    }
}

/// One scheduled submission.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    /// arrival offset from trace start, seconds
    pub at_s: f64,
    pub kind: ScenarioKind,
    /// shared-prefix pool index within the scenario, when grouped
    pub group: Option<usize>,
    pub req: GenRequest,
    /// when set, the driver cancels this request this long after submission
    pub cancel_after_s: Option<f64>,
}

/// A generated open-loop trace: the arrival schedule plus the SLO targets
/// outcomes are scored against.
#[derive(Debug, Clone, PartialEq)]
pub struct Trace {
    pub workload: String,
    pub seed: u64,
    pub rate_rps: f64,
    pub slo: [SloTarget; Priority::COUNT],
    pub events: Vec<TraceEvent>,
}

impl Trace {
    /// Span from trace start to the last arrival.
    pub fn duration_s(&self) -> f64 {
        self.events.last().map(|e| e.at_s).unwrap_or(0.0)
    }

    /// Realized mean arrival rate of the generated schedule.
    pub fn empirical_rate(&self) -> f64 {
        let d = self.duration_s();
        if d <= 0.0 {
            0.0
        } else {
            self.events.len() as f64 / d
        }
    }

    /// FNV-1a hash over the canonical encoding of everything that shapes an
    /// open-loop run: arrival times (exact bits), request contents, deadline
    /// stamps, cancellation schedule, and the SLO vector.  Two traces with
    /// equal fingerprints submit identical byte streams.
    pub fn fingerprint(&self) -> u64 {
        fn eat(h: &mut u64, v: u64) {
            *h ^= v;
            *h = h.wrapping_mul(0x100000001b3);
        }
        let mut h: u64 = 0xcbf29ce484222325;
        eat(&mut h, self.seed);
        eat(&mut h, self.rate_rps.to_bits());
        eat(&mut h, self.events.len() as u64);
        for t in &self.slo {
            eat(&mut h, t.ttft_s.to_bits());
            eat(&mut h, t.tpot_s.to_bits());
        }
        for e in &self.events {
            eat(&mut h, e.at_s.to_bits());
            for &b in e.kind.name().as_bytes() {
                eat(&mut h, b as u64);
            }
            eat(&mut h, e.req.id);
            eat(&mut h, e.req.prompt.len() as u64);
            for &t in &e.req.prompt {
                eat(&mut h, t as u64);
            }
            eat(&mut h, e.req.max_new as u64);
            eat(&mut h, e.req.priority.index() as u64);
            eat(&mut h, e.req.deadline.map_or(u64::MAX, |d| d.as_millis() as u64));
            eat(&mut h, e.req.seed);
            eat(&mut h, e.cancel_after_s.map_or(u64::MAX, f64::to_bits));
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrivals_are_nondecreasing_and_rate_scaled() {
        let mut rng = SplitMix64::new(7);
        for p in [
            ArrivalProcess::Poisson,
            ArrivalProcess::Bursty { on_s: 0.05, off_s: 0.05 },
            ArrivalProcess::HeavyTail { alpha: 2.5 },
        ] {
            let ts = p.times(200.0, 400, &mut rng);
            assert_eq!(ts.len(), 400);
            assert!(ts.windows(2).all(|w| w[0] <= w[1]), "{} not sorted", p.name());
            assert!(ts[0] >= 0.0);
        }
    }

    #[test]
    fn same_seed_same_trace() {
        let w = Workload::mixed(0xFEED).with_rate(250.0).with_requests(120);
        let a = w.generate();
        let b = w.generate();
        assert_eq!(a, b, "generation must be pure");
        assert_eq!(a.fingerprint(), b.fingerprint());
        let c = w.clone().with_seed(0xFEED ^ 1).generate();
        assert_ne!(a.fingerprint(), c.fingerprint(), "seed must matter");
    }

    #[test]
    fn mixed_trace_has_expected_structure() {
        let t = Workload::mixed(3).with_rate(400.0).with_requests(400).generate();
        assert_eq!(t.events.len(), 400);
        // every scenario family shows up in a 400-request draw
        for kind in [
            ScenarioKind::ShortChat,
            ScenarioKind::LongDocument,
            ScenarioKind::AgentLoop,
            ScenarioKind::Interactive,
            ScenarioKind::BestEffort,
        ] {
            assert!(t.events.iter().any(|e| e.kind == kind), "missing {}", kind.name());
        }
        // deadline stamps ride only on the interactive-deadline scenario,
        // at whole-ms granularity; cancellations only on agent loops
        for e in &t.events {
            if let Some(d) = e.req.deadline {
                assert_eq!(e.kind, ScenarioKind::Interactive);
                assert_eq!(d.as_micros() % 1000, 0, "deadline must be whole ms");
            }
            if e.cancel_after_s.is_some() {
                assert_eq!(e.kind, ScenarioKind::AgentLoop);
            }
        }
        assert!(t.events.iter().any(|e| e.cancel_after_s.is_some()), "agent cancels expected");
        // request ids are the event index (unique, replay-stable)
        for (i, e) in t.events.iter().enumerate() {
            assert_eq!(e.req.id, i as u64);
        }
    }

    #[test]
    fn agent_sessions_grow_their_context() {
        let t = Workload::single("agents", Scenario::agent_loop(), 11)
            .with_rate(500.0)
            .with_requests(60)
            .generate();
        // within a group, prompts must extend earlier prompts (prefix chain)
        use std::collections::HashMap;
        let mut last: HashMap<usize, Vec<i32>> = HashMap::new();
        let mut grew = false;
        for e in &t.events {
            let g = e.group.expect("agent events are grouped");
            if let Some(prev) = last.get(&g) {
                if e.req.prompt.len() >= prev.len() {
                    assert_eq!(&e.req.prompt[..prev.len()], &prev[..], "context must extend");
                    grew |= e.req.prompt.len() > prev.len();
                }
            }
            last.insert(g, e.req.prompt.clone());
        }
        assert!(grew, "at least one session should have grown");
    }

    #[test]
    fn shared_groups_share_their_opening_tokens() {
        let t = Workload::single("docs", Scenario::long_document(), 5)
            .with_rate(300.0)
            .with_requests(80)
            .generate();
        use std::collections::HashMap;
        let mut by_group: HashMap<usize, Vec<&TraceEvent>> = HashMap::new();
        for e in &t.events {
            by_group.entry(e.group.unwrap()).or_default().push(e);
        }
        for evs in by_group.values() {
            for pair in evs.windows(2) {
                let n = pair[0].req.prompt.len().min(pair[1].req.prompt.len());
                assert_eq!(pair[0].req.prompt[..n], pair[1].req.prompt[..n]);
            }
        }
    }
}
