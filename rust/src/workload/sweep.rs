//! Offered-load sweep: walk a workload's arrival rate across a ladder of
//! offered loads, score each run, and report the goodput-vs-offered-load
//! curve with its saturation knee.
//!
//! Each sweep point regenerates the trace at the new rate from the same
//! seed, so the request *population* (scenario mix, prompt shapes, seeds,
//! deadlines) is deterministic per rate while the arrival clock compresses.
//! The trace fingerprint at every rate is recorded so two sweeps of the same
//! spec can be byte-compared.
//!
//! The **knee** is the offered load that maximises goodput (first on ties):
//! past it, admitting more work completes fewer requests within SLO — the
//! open-loop signature of saturation.

use anyhow::Result;

use crate::coordinator::request::Priority;

use super::driver::{run_trace, RunScore, Target};
use super::trace::Workload;

/// One point on the goodput curve.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub offered_rps: f64,
    /// requests in the generated trace at this rate
    pub n_requests: usize,
    /// fingerprint of the generated trace (seed-deterministic per rate)
    pub trace_fingerprint: u64,
    pub score: RunScore,
}

/// A swept goodput-vs-offered-load curve.
#[derive(Debug, Clone)]
pub struct SweepReport {
    pub workload: String,
    pub points: Vec<SweepPoint>,
    /// index into `points` of the goodput-maximising offered load
    pub knee: usize,
}

impl SweepReport {
    pub fn knee_point(&self) -> &SweepPoint {
        &self.points[self.knee]
    }

    /// True when the curve bends: the last swept load achieves strictly
    /// less goodput than the knee, i.e. the sweep ran past saturation.
    pub fn saturated(&self) -> bool {
        match (self.points.last(), self.points.get(self.knee)) {
            (Some(last), Some(knee)) => {
                self.knee + 1 < self.points.len()
                    && last.score.goodput_rps < knee.score.goodput_rps
            }
            _ => false,
        }
    }
}

/// Index of the goodput-maximising point, first on ties.
fn knee_index(points: &[SweepPoint]) -> usize {
    let mut best = 0usize;
    for (i, p) in points.iter().enumerate().skip(1) {
        if p.score.goodput_rps > points[best].score.goodput_rps {
            best = i;
        }
    }
    best
}

/// Sweep `workload` across `rates` (requests-per-second offered load)
/// against targets built per point by `make_target`.
///
/// A fresh target per point keeps runs independent: no residual queue or
/// cache state leaks from an overloaded point into the next.  The request
/// count scales with the rate (`ceil(rate × duration_s)`, floored at
/// `min_requests`) so every point offers the same wall-clock window of
/// traffic and overload points pay their own drain time.
pub fn sweep_rates(
    workload: &Workload,
    rates: &[f64],
    duration_s: f64,
    min_requests: usize,
    mut make_target: impl FnMut() -> Result<Target>,
) -> Result<SweepReport> {
    let mut points = Vec::with_capacity(rates.len());
    for &rate in rates {
        let n = ((rate * duration_s).ceil() as usize).max(min_requests);
        let trace = workload.clone().with_rate(rate).with_requests(n).generate();
        let target = make_target()?;
        let report = run_trace(&trace, &target);
        target.shutdown();
        let report = report?;
        points.push(SweepPoint {
            offered_rps: rate,
            n_requests: n,
            trace_fingerprint: trace.fingerprint(),
            score: report.score,
        });
    }
    let knee = knee_index(&points);
    Ok(SweepReport { workload: workload.name.clone(), points, knee })
}

/// Render the sweep as an aligned text table (offered load, goodput,
/// attainment overall and for the Interactive class).
pub fn render_table(report: &SweepReport) -> String {
    let mut out = String::new();
    out.push_str(&format!("workload: {}\n", report.workload));
    out.push_str("offered_rps  goodput_rps  attainment  interactive  cancelled  errors\n");
    for (i, p) in report.points.iter().enumerate() {
        let inter = &p.score.per_class[Priority::Interactive.index()];
        let marker = if i == report.knee { "  <- knee" } else { "" };
        out.push_str(&format!(
            "{:>11.1}  {:>11.2}  {:>10.3}  {:>11.3}  {:>9}  {:>6}{}\n",
            p.offered_rps,
            p.score.goodput_rps,
            p.score.attainment,
            inter.attainment(),
            p.score.cancelled,
            p.score.errors,
            marker,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::FinishReason;
    use crate::workload::driver::{score_outcomes, RequestOutcome};
    use crate::workload::trace::ScenarioKind;

    fn point(rate: f64, goodput: f64) -> SweepPoint {
        let trace = Workload::mixed(7).with_rate(rate).with_requests(1).generate();
        let outcomes = vec![RequestOutcome {
            seq: 0,
            kind: ScenarioKind::ShortChat,
            priority: Priority::Interactive,
            tokens: 2,
            ttft_s: 0.001,
            tpot_s: 0.001,
            total_s: 0.002,
            finish: Some(FinishReason::Length),
            slo_ok: true,
        }];
        let mut score = score_outcomes(&trace, &outcomes, 1.0);
        score.goodput_rps = goodput;
        SweepPoint {
            offered_rps: rate,
            n_requests: 1,
            trace_fingerprint: trace.fingerprint(),
            score,
        }
    }

    #[test]
    fn knee_is_goodput_argmax_first_on_ties() {
        let points = vec![point(10.0, 8.0), point(20.0, 15.0), point(40.0, 15.0)];
        assert_eq!(knee_index(&points), 1);
        let report = SweepReport { workload: "t".into(), points, knee: 1 };
        assert!(!report.saturated(), "flat tail is not a bend");

        let points = vec![point(10.0, 8.0), point(20.0, 15.0), point(40.0, 9.0)];
        assert_eq!(knee_index(&points), 1);
        let report = SweepReport { workload: "t".into(), points, knee: 1 };
        assert!(report.saturated());
        assert!((report.knee_point().score.goodput_rps - 15.0).abs() < 1e-12);
    }

    #[test]
    fn table_marks_the_knee_row() {
        let points = vec![point(10.0, 8.0), point(20.0, 5.0)];
        let report = SweepReport { workload: "t".into(), points, knee: 0 };
        let table = render_table(&report);
        assert!(table.contains("<- knee"));
        assert!(table.lines().nth(2).unwrap().contains("<- knee"));
        assert_eq!(table.lines().count(), 4);
    }
}
