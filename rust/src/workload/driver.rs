//! Open-loop driver: submit a [`Trace`] against a [`Server`] or [`Router`]
//! on the generated arrival clock and score per-class SLO attainment.
//!
//! The defining property of an open-loop run is that the arrival clock never
//! waits for completions: the driver walks the trace's timestamps, fires
//! each submission (and each scheduled mid-stream cancellation) at its
//! appointed offset, and only *after* the last event does it drain the
//! response channels.  Under overload the queues grow and latency explodes —
//! which is exactly the signal a closed-loop harness hides.
//!
//! Scoring: a completion counts toward **goodput** when it finished normally
//! (`Length`/`Stop`) and met its class's TTFT and TPOT budgets.  Cancelled
//! requests leave the denominator (the client walked away); errors and
//! truncated finishes stay in it.  `goodput_rps` divides SLO-met completions
//! by the full wall time from first arrival to last drained terminal, so
//! post-overload drain time is paid, not hidden.

use std::collections::BinaryHeap;
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::cluster::{Router, RouterHandle};
use crate::coordinator::request::{FinishReason, Metrics, Priority, StreamEvent};
use crate::coordinator::server::{RequestHandle, Server};

use super::trace::{ScenarioKind, SloTarget, Trace};

/// How long the drain phase waits on one response channel before declaring
/// the request lost (a safety net — sim runs finish in milliseconds).
const DRAIN_TIMEOUT: Duration = Duration::from_secs(60);

/// What the driver submits against: a single worker or a routed fleet.
pub enum Target {
    Server(Server),
    Router(Router),
}

impl Target {
    fn submit(&self, req: crate::coordinator::request::GenRequest) -> Result<TargetHandle> {
        match self {
            Target::Server(s) => Ok(TargetHandle::Server(s.submit_stream(req)?)),
            Target::Router(r) => Ok(TargetHandle::Router(r.submit(req)?)),
        }
    }

    /// Merged serving-layer metrics (single worker, or fleet-wide merge).
    pub fn metrics(&self) -> Result<Metrics> {
        match self {
            Target::Server(s) => s.metrics(),
            Target::Router(r) => Ok(r.report()?.merged),
        }
    }

    pub fn shutdown(self) {
        match self {
            Target::Server(s) => s.shutdown(),
            Target::Router(r) => r.shutdown(),
        }
    }
}

enum TargetHandle {
    Server(RequestHandle<StreamEvent>),
    Router(RouterHandle),
}

impl TargetHandle {
    fn receiver(&self) -> &Receiver<StreamEvent> {
        match self {
            TargetHandle::Server(h) => h.receiver(),
            TargetHandle::Router(h) => h.receiver(),
        }
    }

    fn cancel(&self) {
        // best-effort: a cancel racing completion is fine either way
        let _ = match self {
            TargetHandle::Server(h) => h.cancel(),
            TargetHandle::Router(h) => h.cancel(),
        };
    }
}

/// Sleep (then spin, for sub-ms precision) until `t0 + at_s`.
fn wait_until(t0: Instant, at_s: f64) {
    let target = t0 + Duration::from_secs_f64(at_s.max(0.0));
    loop {
        let now = Instant::now();
        if now >= target {
            return;
        }
        let rem = target - now;
        if rem > Duration::from_millis(3) {
            std::thread::sleep(rem - Duration::from_millis(2));
        } else {
            std::hint::spin_loop();
        }
    }
}

/// Outcome of one traced request.
#[derive(Debug, Clone)]
pub struct RequestOutcome {
    /// index into the trace's event list (== `GenRequest::id`)
    pub seq: usize,
    pub kind: ScenarioKind,
    pub priority: Priority,
    pub tokens: usize,
    pub ttft_s: f64,
    /// time per output token past the first; 0 when fewer than 2 tokens
    pub tpot_s: f64,
    pub total_s: f64,
    /// `None` when the request errored (submit failure, stream error, or a
    /// dropped channel)
    pub finish: Option<FinishReason>,
    pub slo_ok: bool,
}

/// Per-class scoring rollup.
#[derive(Debug, Clone, Copy, Default)]
pub struct ClassScore {
    pub offered: usize,
    /// normal finishes (`Length`/`Stop`)
    pub completed: usize,
    pub slo_ok: usize,
    pub cancelled: usize,
    /// rejected by the admission controller (`FinishReason::Shed`)
    pub shed: usize,
    /// removed from dispatch after ≥2 worker deaths (`FinishReason::Quarantined`)
    pub quarantined: usize,
    pub errors: usize,
    pub p50_ttft_s: f64,
    pub p99_ttft_s: f64,
    pub p50_tpot_s: f64,
    pub p99_tpot_s: f64,
}

impl ClassScore {
    /// SLO attainment over the class's non-cancelled offered load.
    pub fn attainment(&self) -> f64 {
        let denom = self.offered.saturating_sub(self.cancelled);
        if denom == 0 {
            1.0
        } else {
            self.slo_ok as f64 / denom as f64
        }
    }
}

/// Scored result of one open-loop run.
#[derive(Debug, Clone)]
pub struct RunScore {
    pub offered_rps: f64,
    /// first arrival → last drained terminal
    pub wall_s: f64,
    pub submitted: usize,
    pub completed: usize,
    pub slo_ok: usize,
    pub cancelled: usize,
    /// rejected by the admission controller (`FinishReason::Shed`)
    pub shed: usize,
    /// removed from dispatch after ≥2 worker deaths (`FinishReason::Quarantined`)
    pub quarantined: usize,
    pub errors: usize,
    /// SLO-met completions per second of wall time — the headline metric
    pub goodput_rps: f64,
    /// SLO-met completions over non-cancelled offered load
    pub attainment: f64,
    pub per_class: [ClassScore; Priority::COUNT],
}

/// Full run report: the score plus every per-request outcome (seq order).
#[derive(Debug, Clone)]
pub struct RunReport {
    pub score: RunScore,
    pub outcomes: Vec<RequestOutcome>,
}

/// Does this outcome meet its class SLO?
fn meets_slo(
    slo: &SloTarget,
    finish: FinishReason,
    ttft_s: f64,
    tokens: usize,
    tpot_s: f64,
) -> bool {
    matches!(finish, FinishReason::Length | FinishReason::Stop)
        && ttft_s <= slo.ttft_s
        && (tokens < 2 || tpot_s <= slo.tpot_s)
}

/// Pop and fire every scheduled cancellation due strictly before
/// `due_before_s`, sleeping up to each one's due time.
fn fire_due(
    t0: Instant,
    due_before_s: f64,
    cancels: &mut BinaryHeap<std::cmp::Reverse<(u64, usize)>>,
    handles: &[Option<TargetHandle>],
) {
    while let Some(&std::cmp::Reverse((due_us, idx))) = cancels.peek() {
        let due_s = due_us as f64 * 1e-6;
        if due_s > due_before_s {
            break;
        }
        cancels.pop();
        wait_until(t0, due_s);
        if let Some(h) = &handles[idx] {
            h.cancel();
        }
    }
}

/// Run `trace` open-loop against `target`.
///
/// The submission loop interleaves arrivals with due cancellations on one
/// timeline; completions are never consulted until the drain phase.
pub fn run_trace(trace: &Trace, target: &Target) -> Result<RunReport> {
    let n = trace.events.len();
    let t0 = Instant::now();
    let mut handles: Vec<Option<TargetHandle>> = Vec::with_capacity(n);
    // min-heap of (due µs, event index) cancellations
    let mut cancels: BinaryHeap<std::cmp::Reverse<(u64, usize)>> = BinaryHeap::new();

    for (i, ev) in trace.events.iter().enumerate() {
        fire_due(t0, ev.at_s, &mut cancels, &handles);
        wait_until(t0, ev.at_s);
        match target.submit(ev.req.clone()) {
            Ok(h) => {
                handles.push(Some(h));
                if let Some(after_s) = ev.cancel_after_s {
                    let due_us = ((ev.at_s + after_s.max(0.0)) * 1e6) as u64;
                    cancels.push(std::cmp::Reverse((due_us, i)));
                }
            }
            Err(_) => handles.push(None),
        }
    }
    // cancellations scheduled past the last arrival
    fire_due(t0, f64::INFINITY, &mut cancels, &handles);

    // drain: collect every terminal (channels buffer, so late drain loses
    // nothing; the open-loop clock above never touched them)
    let mut outcomes = Vec::with_capacity(n);
    for (i, (ev, h)) in trace.events.iter().zip(&handles).enumerate() {
        let slo = &trace.slo[ev.req.priority.index()];
        let mut outcome = RequestOutcome {
            seq: i,
            kind: ev.kind,
            priority: ev.req.priority,
            tokens: 0,
            ttft_s: 0.0,
            tpot_s: 0.0,
            total_s: 0.0,
            finish: None,
            slo_ok: false,
        };
        if let Some(h) = h {
            loop {
                match h.receiver().recv_timeout(DRAIN_TIMEOUT) {
                    Ok(StreamEvent::Token(_)) => {}
                    Ok(StreamEvent::Done(resp)) => {
                        outcome.tokens = resp.tokens.len();
                        outcome.ttft_s = resp.ttft_s;
                        outcome.total_s = resp.total_s;
                        if resp.tokens.len() >= 2 {
                            outcome.tpot_s = (resp.total_s - resp.ttft_s).max(0.0)
                                / (resp.tokens.len() - 1) as f64;
                        }
                        outcome.finish = Some(resp.finish);
                        outcome.slo_ok = meets_slo(
                            slo,
                            resp.finish,
                            resp.ttft_s,
                            resp.tokens.len(),
                            outcome.tpot_s,
                        );
                        break;
                    }
                    Ok(StreamEvent::Error(_))
                    | Err(RecvTimeoutError::Disconnected)
                    | Err(RecvTimeoutError::Timeout) => break,
                }
            }
        }
        outcomes.push(outcome);
    }
    let wall_s = t0.elapsed().as_secs_f64().max(1e-9);
    Ok(RunReport { score: score_outcomes(trace, &outcomes, wall_s), outcomes })
}

/// Fold outcomes into a [`RunScore`] (pure; unit-testable without a fleet).
pub fn score_outcomes(trace: &Trace, outcomes: &[RequestOutcome], wall_s: f64) -> RunScore {
    let mut per_class = [ClassScore::default(); Priority::COUNT];
    let mut ttfts: Vec<Vec<f64>> = vec![Vec::new(); Priority::COUNT];
    let mut tpots: Vec<Vec<f64>> = vec![Vec::new(); Priority::COUNT];
    for o in outcomes {
        let c = &mut per_class[o.priority.index()];
        c.offered += 1;
        match o.finish {
            Some(FinishReason::Cancelled) => c.cancelled += 1,
            Some(FinishReason::Length) | Some(FinishReason::Stop) => {
                c.completed += 1;
                ttfts[o.priority.index()].push(o.ttft_s);
                if o.tokens >= 2 {
                    tpots[o.priority.index()].push(o.tpot_s);
                }
            }
            Some(FinishReason::Shed) => c.shed += 1,
            Some(FinishReason::Quarantined) => c.quarantined += 1,
            Some(_) => {}
            None => c.errors += 1,
        }
        if o.slo_ok {
            c.slo_ok += 1;
        }
    }
    for (i, c) in per_class.iter_mut().enumerate() {
        c.p50_ttft_s = percentile(&mut ttfts[i], 0.50);
        c.p99_ttft_s = percentile(&mut ttfts[i], 0.99);
        c.p50_tpot_s = percentile(&mut tpots[i], 0.50);
        c.p99_tpot_s = percentile(&mut tpots[i], 0.99);
    }
    let submitted = outcomes.len();
    let cancelled: usize = per_class.iter().map(|c| c.cancelled).sum();
    let completed: usize = per_class.iter().map(|c| c.completed).sum();
    let shed: usize = per_class.iter().map(|c| c.shed).sum();
    let quarantined: usize = per_class.iter().map(|c| c.quarantined).sum();
    let errors: usize = per_class.iter().map(|c| c.errors).sum();
    let slo_ok: usize = per_class.iter().map(|c| c.slo_ok).sum();
    let denom = submitted.saturating_sub(cancelled);
    RunScore {
        offered_rps: trace.rate_rps,
        wall_s,
        submitted,
        completed,
        slo_ok,
        cancelled,
        shed,
        quarantined,
        errors,
        goodput_rps: slo_ok as f64 / wall_s,
        attainment: if denom == 0 { 1.0 } else { slo_ok as f64 / denom as f64 },
        per_class,
    }
}

/// Exact percentile over the collected samples (sorts in place): the
/// `ceil(p·n)`-th smallest value.  0 when empty.
fn percentile(xs: &mut [f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.sort_by(|a, b| a.partial_cmp(b).expect("latencies are finite"));
    let rank = ((p.clamp(0.0, 1.0) * xs.len() as f64).ceil() as usize).clamp(1, xs.len());
    xs[rank - 1]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::trace::Workload;

    fn outcome(
        priority: Priority,
        finish: Option<FinishReason>,
        ttft: f64,
        ok: bool,
    ) -> RequestOutcome {
        RequestOutcome {
            seq: 0,
            kind: ScenarioKind::ShortChat,
            priority,
            tokens: 3,
            ttft_s: ttft,
            tpot_s: 0.001,
            total_s: ttft + 0.002,
            finish,
            slo_ok: ok,
        }
    }

    #[test]
    fn scoring_excludes_cancels_and_counts_errors() {
        let trace = Workload::mixed(1).with_rate(50.0).with_requests(6).generate();
        let outcomes = vec![
            outcome(Priority::Interactive, Some(FinishReason::Length), 0.010, true),
            outcome(Priority::Interactive, Some(FinishReason::Cancelled), 0.0, false),
            outcome(Priority::Batch, Some(FinishReason::Length), 0.900, false),
            outcome(Priority::Batch, None, 0.0, false),
            outcome(Priority::BestEffort, Some(FinishReason::Shed), 0.0, false),
            outcome(Priority::Batch, Some(FinishReason::Quarantined), 0.0, false),
        ];
        let s = score_outcomes(&trace, &outcomes, 2.0);
        assert_eq!(s.submitted, 6);
        assert_eq!(s.cancelled, 1);
        assert_eq!(s.errors, 1);
        assert_eq!(s.completed, 2);
        assert_eq!(s.slo_ok, 1);
        // shed/quarantined are tracked but never goodput, and they stay in
        // the attainment denominator (the fleet turned away real demand)
        assert_eq!((s.shed, s.quarantined), (1, 1));
        assert!((s.goodput_rps - 0.5).abs() < 1e-12);
        // attainment denominator drops the cancel: 1 ok / 5
        assert!((s.attainment - 1.0 / 5.0).abs() < 1e-12);
        let inter = &s.per_class[Priority::Interactive.index()];
        assert_eq!((inter.offered, inter.slo_ok, inter.cancelled), (2, 1, 1));
        assert!((inter.attainment() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn slo_check_requires_normal_finish_and_both_budgets() {
        let slo = SloTarget { ttft_s: 0.05, tpot_s: 0.02 };
        assert!(meets_slo(&slo, FinishReason::Length, 0.04, 3, 0.01));
        assert!(meets_slo(&slo, FinishReason::Stop, 0.04, 1, 99.0), "tpot waived under 2 tokens");
        assert!(!meets_slo(&slo, FinishReason::Length, 0.06, 3, 0.01), "ttft over budget");
        assert!(!meets_slo(&slo, FinishReason::Length, 0.04, 3, 0.03), "tpot over budget");
        assert!(!meets_slo(&slo, FinishReason::Cancelled, 0.01, 3, 0.01));
        assert!(!meets_slo(&slo, FinishReason::CacheFull, 0.01, 3, 0.01));
        assert!(!meets_slo(&slo, FinishReason::WorkerLost, 0.01, 3, 0.01));
    }

    #[test]
    fn percentiles_are_exact_order_statistics() {
        let mut xs = vec![0.4, 0.1, 0.3, 0.2];
        assert!((percentile(&mut xs, 0.50) - 0.2).abs() < 1e-12);
        assert!((percentile(&mut xs, 0.99) - 0.4).abs() < 1e-12);
        assert!((percentile(&mut xs, 0.0) - 0.1).abs() < 1e-12);
        assert_eq!(percentile(&mut [], 0.5), 0.0);
    }
}
