//! `pq` — PrefixQuant CLI (L3 leader entrypoint).
//!
//! Subcommands:
//!   info                         — artifacts / manifest summary
//!   outliers  [--model M] [--rotate] [--prefix]
//!                                — token-wise outlier report (Figs 2-4)
//!   quantize  [--model M] [--scheme S] [--eval] [--save DIR]
//!                                — run a quantization recipe; `--save`
//!                                  writes a versioned QuantArtifact
//!   eval      [--model M] [--scheme S] [--load DIR] [--tasks]
//!                                — PPL / zero-shot accuracy (from a fresh
//!                                  recipe run, or a saved artifact)
//!   gen       [--model M] [--scheme S] [--load DIR] [--prompt TEXT] [--n N]
//!                                — generate via the serving coordinator;
//!                                  the server always boots from an artifact
//!                                  (`--load`, or quantize-once + save)
//!   serve                        — pointer to the serve_batch example
//!
//! Schemes: fp16, rtn, quarot, smoothquant, atom, prefixquant-wo-ft,
//! prefixquant (default bit-widths W4A4KV4; --bits w,a,kv overrides).

use std::path::PathBuf;
use std::rc::Rc;
use std::time::Duration;

use anyhow::{anyhow, bail, Result};
use prefixquant::coordinator::{GenRequest, Server, ServerConfig};
use prefixquant::data::{self, Language};
use prefixquant::eval;
use prefixquant::model::Model;
use prefixquant::quant::{model_state, outlier, Precision, QuantArtifact, Recipe};
use prefixquant::runtime::Engine;
use prefixquant::tensor::IntTensor;
use prefixquant::tokenizer::Tokenizer;
use prefixquant::util::args::Args;
use prefixquant::util::table::{f as ff, Table};

fn parse_bits(args: &Args) -> Result<Precision> {
    match args.get("bits") {
        None => Ok(Precision::new(4, 4, 4)),
        Some(s) => {
            let parts: Vec<usize> = s
                .split(',')
                .map(|p| p.parse().map_err(|e| anyhow!("--bits: {e}")))
                .collect::<Result<_>>()?;
            if parts.len() != 3 {
                bail!("--bits wants w,a,kv");
            }
            Ok(Precision::new(parts[0], parts[1], parts[2]))
        }
    }
}

fn recipe_by_name(name: &str, p: Precision, ft_epochs: usize) -> Result<Recipe> {
    Ok(match name {
        "fp16" => Recipe::fp16(),
        "rtn" => Recipe::rtn(p),
        "quarot" => Recipe::quarot(p),
        "smoothquant" => Recipe::smoothquant(p),
        "atom" => Recipe::atom(p),
        "prefixquant-wo-ft" => Recipe::prefixquant_wo_ft(p),
        "prefixquant" => Recipe::prefixquant(p, ft_epochs),
        other => bail!("unknown scheme {other:?}"),
    })
}

struct Ctx {
    engine: Rc<Engine>,
    tok: Tokenizer,
    lang: Language,
}

fn ctx() -> Result<Ctx> {
    let dir = prefixquant::artifacts_dir();
    let engine = Rc::new(Engine::new(&dir)?);
    let tok = Tokenizer::new(engine.manifest.tokenizer.clone());
    let lang = Language::new(engine.manifest.corpus.clone());
    Ok(Ctx { engine, tok, lang })
}

fn calib_batch(c: &Ctx, model: &Model) -> Result<IntTensor> {
    let (b, s) = model.fwd_geom()?;
    let windows =
        data::calibration_windows(&c.lang, |t| c.tok.encode(t, false), s, b, c.tok.spec.bos);
    let data: Vec<i32> = windows.into_iter().flatten().collect();
    Ok(IntTensor::new(vec![b, s], data)?)
}

fn eval_windows(c: &Ctx, model: &Model, max: usize) -> Result<Vec<Vec<i32>>> {
    let (_b, s) = model.fwd_geom()?;
    let ids = c.tok.encode(&c.lang.eval_text(), false);
    Ok(data::windows(&ids, s, c.tok.spec.bos, max))
}

fn quantize_model(
    c: &Ctx,
    args: &Args,
) -> Result<(Model, Recipe, prefixquant::quant::RecipeReport)> {
    let mname = args.get_or("model", "pq-tiny").to_string();
    let sname = args.get_or("scheme", "prefixquant-wo-ft").to_string();
    let ft = args.usize_or("ft-epochs", 10)?;
    let recipe = recipe_by_name(&sname, parse_bits(args)?, ft)?;
    let mut model = Model::load(c.engine.clone(), &mname)?;
    let calib = calib_batch(c, &model)?;
    eprintln!(
        "quantizing {mname} with {} (passes: {})...",
        recipe.name,
        recipe.pass_names().join(" → ")
    );
    let rep = recipe.run(&mut model, &calib, &c.tok)?;
    eprintln!("  prefix={:?} | {}", rep.prefix_rendered, rep.timing_summary());
    Ok((model, recipe, rep))
}

fn cmd_info(c: &Ctx) -> Result<()> {
    let m = &c.engine.manifest;
    println!("artifacts: {:?}", m.dir);
    println!(
        "tokenizer: vocab={} delims={:?}",
        m.tokenizer.vocab_size, m.tokenizer.delimiter_ids
    );
    for (name, mm) in &m.models {
        println!(
            "model {name}: d={} L={} H={} ff={} | pretrain loss={:?} | {} executables",
            mm.config.d_model,
            mm.config.n_layers,
            mm.config.n_heads,
            mm.config.d_ff,
            mm.pretrain_final_loss,
            mm.executables.len()
        );
    }
    println!("{} kernel executables", m.kernels.len());
    Ok(())
}

fn cmd_outliers(c: &Ctx, args: &Args) -> Result<()> {
    let mname = args.get_or("model", "pq-tiny").to_string();
    let mut model = Model::load(c.engine.clone(), &mname)?;
    if args.flag("rotate") {
        let cfg = model.cfg.clone();
        prefixquant::quant::rotation::absorb_norm_gains(&cfg, &mut model.weights)?;
        prefixquant::quant::rotation::fold_rotations(&cfg, &mut model.weights)?;
        let (r3, r4) = prefixquant::quant::rotation::online_matrices(&model.cfg, true);
        model.quant.r3 = r3;
        model.quant.r4 = r4;
        model.refresh_weights()?;
    }
    let calib = calib_batch(c, &model)?;
    if args.flag("prefix") {
        let (_obs, rep) = outlier::observe_and_analyze(&model, &calib, outlier::ETA)?;
        let toks = prefixquant::quant::prefix::select_tokens(&rep, &c.tok);
        prefixquant::quant::prefix::install(&mut model, &toks, c.tok.spec.pad)?;
        println!("installed {}", prefixquant::quant::prefix::describe(&model, &c.tok)?);
    }
    let (_obs2, rep2) = outlier::observe_and_analyze(&model, &calib, outlier::ETA)?;
    let mut t = Table::new(
        &format!(
            "token-wise max ratios ({mname}{}{})",
            if args.flag("rotate") { " +rotate" } else { "" },
            if args.flag("prefix") { " +prefix" } else { "" }
        ),
        &["layer", "site", "top1", "median", "min1", "top1/med", "med/min1"],
    );
    for (li, row) in rep2.site_stats.iter().enumerate() {
        for (si, st) in row.iter().enumerate() {
            t.rowv(vec![
                li.to_string(),
                model.cfg.sites[si].clone(),
                ff(st.top1 as f64),
                ff(st.median as f64),
                ff(st.min1 as f64),
                ff(st.upper_ratio() as f64),
                ff(st.lower_ratio() as f64),
            ]);
        }
    }
    t.print();
    println!(
        "\noutliers detected (down_in, eta={}): total={} o_per_block={:?} -> o={}",
        rep2.eta, rep2.total_outliers, rep2.o_per_block, rep2.o
    );
    println!(
        "outlier token frequency (non-initial): {:?}",
        rep2.freq.iter().map(|&(id, n)| (c.tok.token_repr(id), n)).collect::<Vec<_>>()
    );
    Ok(())
}

fn cmd_quantize(c: &Ctx, args: &Args) -> Result<()> {
    let (model, recipe, rep) = quantize_model(c, args)?;
    if args.flag("eval") {
        let windows = eval_windows(c, &model, args.usize_or("windows", 24)?)?;
        let ppl = eval::perplexity(&model, recipe.mode, &windows)?;
        println!("{}: eval PPL = {:.4}", recipe.name, ppl);
    }
    if let Some(dir) = args.get("save") {
        let hash =
            QuantArtifact::save_model(&model, recipe.mode, Some(&rep), std::path::Path::new(dir))?;
        println!(
            "artifact v{} saved to {dir} (recipe {:?}, {} passes, hash {hash:016x})",
            prefixquant::quant::FORMAT_VERSION,
            rep.recipe,
            rep.stages.len()
        );
    }
    Ok(())
}

fn cmd_eval(c: &Ctx, args: &Args) -> Result<()> {
    // either load a saved artifact (O(read), no pipeline) or run a recipe
    let (model, mode, label) = if let Some(dir) = args.get("load") {
        let (model, mode) = model_state::load(c.engine.clone(), std::path::Path::new(dir))?;
        (model, mode, format!("loaded {dir}"))
    } else {
        let (model, recipe, _rep) = quantize_model(c, args)?;
        let label = recipe.name.clone();
        (model, recipe.mode, label)
    };
    let windows = eval_windows(c, &model, args.usize_or("windows", 24)?)?;
    let ppl = eval::perplexity(&model, mode, &windows)?;
    println!("{label}: PPL = {ppl:.4}");
    // --tasks runs for BOTH paths (the --load early-return used to skip it)
    if args.flag("tasks") {
        let scores = eval::run_all_tasks(
            &model,
            mode,
            &c.lang,
            &c.tok,
            args.usize_or("items", 32)?,
        )?;
        let mut t = Table::new("zero-shot tasks", &["task", "acc %", "items"]);
        for s in &scores {
            t.rowv(vec![s.name.clone(), format!("{:.2}", s.accuracy), s.items.to_string()]);
        }
        t.print();
    }
    Ok(())
}

fn cmd_gen(c: &Ctx, args: &Args) -> Result<()> {
    let prompt_text = args.get_or("prompt", "the quick").to_string();
    let n = args.usize_or("n", 32)?;
    // the server always boots from a QuantArtifact: either one saved earlier
    // (--load) or one produced right now by a single offline recipe run —
    // the worker (and any post-failure model reload) only ever pays O(read)
    let artifact_dir: PathBuf = match args.get("load") {
        Some(dir) => PathBuf::from(dir),
        None => {
            let (model, recipe, rep) = quantize_model(c, args)?;
            let dir = match args.get("save") {
                Some(d) => PathBuf::from(d),
                None => std::env::temp_dir().join(format!("pq_gen_art_{}", std::process::id())),
            };
            QuantArtifact::save_model(&model, recipe.mode, Some(&rep), &dir)?;
            eprintln!("quantized once → artifact at {dir:?}; serving boots from it");
            dir
        }
    };
    let tok = c.tok.clone();
    // the serving mode comes from the artifact itself: start_from_artifact
    // peeks the metadata and overrides the builder's mode seed
    let server = Server::start_from_artifact(
        prefixquant::artifacts_dir(),
        artifact_dir,
        ServerConfig::builder(prefixquant::model::QuantMode::Static)
            .engine(prefixquant::coordinator::EngineKind::Continuous)
            .max_batch(8)
            .batch_window(Duration::from_millis(5))
            .bos(tok.spec.bos)
            .pad(tok.spec.pad)
            // paged KV with a dense-equivalent auto-sized pool
            .kv(prefixquant::coordinator::KvLayout::Paged { page_size: 16, n_pages: 0 })
            .build(),
    )?;
    let req = GenRequest::builder(1)
        .prompt(tok.encode(&prompt_text, false))
        .max_new(n)
        .priority(prefixquant::coordinator::Priority::Interactive)
        .build();
    let resp = server.generate(req)?;
    println!("prompt: {prompt_text:?}");
    println!("output: {:?}", tok.decode(&resp.tokens));
    println!(
        "ttft={:.1}ms total={:.1}ms finish={}",
        resp.ttft_s * 1e3,
        resp.total_s * 1e3,
        resp.finish.name()
    );
    server.shutdown();
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("info");
    let c = ctx()?;
    match cmd {
        "info" => cmd_info(&c),
        "outliers" => cmd_outliers(&c, &args),
        "quantize" => cmd_quantize(&c, &args),
        "eval" => cmd_eval(&c, &args),
        "gen" => cmd_gen(&c, &args),
        "serve" => {
            println!("see `cargo run --release --example serve_batch`");
            Ok(())
        }
        other => bail!("unknown command {other:?} (info|outliers|quantize|eval|gen|serve)"),
    }
}
