//! `pq` — PrefixQuant CLI (L3 leader entrypoint).
//!
//! Subcommands:
//!   info                         — artifacts / manifest summary
//!   outliers  [--model M] [--rotate] [--prefix]
//!                                — token-wise outlier report (Figs 2-4)
//!   quantize  [--model M] [--scheme S] [--eval] [--save DIR]
//!                                — run a quantization recipe; `--save`
//!                                  writes a versioned QuantArtifact
//!   eval      [--model M] [--scheme S] [--load DIR] [--tasks]
//!                                — PPL / zero-shot accuracy (from a fresh
//!                                  recipe run, or a saved artifact)
//!   gen       [--model M] [--scheme S] [--load DIR] [--prompt TEXT] [--n N]
//!                                — generate via the serving coordinator;
//!                                  the server always boots from an artifact
//!                                  (`--load`, or quantize-once + save)
//!   serve     [--model M] [--scheme S] [--load DIR] [--workers N]
//!             [--policy P] [--requests R] [--max-new T] [--oplog PATH]
//!             [--supervise] [--restart-budget N] [--backoff-ms B]
//!             [--backoff-max-ms B] [--admission] [--admit-queue-depth N]
//!             [--admit-backlog-tokens N] [--no-shed-infeasible]
//!             [--retry-budget N] [--retry-refill R]
//!                                — boot a router-fronted worker fleet from
//!                                  one artifact and drive a demo workload;
//!                                  policies: round-robin, least-loaded,
//!                                  prefix-affinity (default); `--oplog`
//!                                  journals every admission/token/outcome
//!                                  to PATH and turns stream resume on;
//!                                  `--supervise` reboots lost workers from
//!                                  the same artifact under a seeded backoff
//!                                  schedule and a capped restart budget
//!   loadgen   [--rate R] [--requests N] [--seed S] [--workers W]
//!             [--policy fcfs|priority] [--dispatch D] [--no-radix]
//!             [--arrival poisson|bursty|heavy-tail] [--duration SECS]
//!             [--sweep] [--rates R1,R2,..] [--oplog PATH] [--json]
//!             [--admission] [--admit-queue-depth N]
//!             [--admit-backlog-tokens N] [--no-shed-infeasible]
//!             [--retry-budget N] [--retry-refill R]
//!                                — open-loop workload against a sim-backed
//!                                  fleet (no artifacts needed): seeded
//!                                  deterministic trace, per-class SLO
//!                                  attainment, goodput; `--sweep` walks
//!                                  offered load past the saturation knee;
//!                                  `--oplog` captures the run for replay;
//!                                  the admission knobs shed infeasible or
//!                                  over-backlog requests instead of letting
//!                                  the queue collapse the SLOs
//!   replay    <oplog> [--workers N]
//!                                — re-execute a captured trace on a fresh
//!                                  fleet (booted per the journal's backend
//!                                  header; sim traces need no artifacts)
//!                                  and verify the streams bit-identically
//!   oplog     compact <path>     — rewrite a journal in place, dropping the
//!                                  records of fully-finished requests
//!                                  (recovery resumes identically from the
//!                                  compacted log)
//!
//! Schemes: fp16, rtn, quarot, smoothquant, atom, prefixquant-wo-ft,
//! prefixquant (default bit-widths W4A4KV4; --bits w,a,kv overrides).

use std::path::PathBuf;
use std::rc::Rc;
use std::time::Duration;

use anyhow::{anyhow, bail, Result};
use prefixquant::coordinator::{
    compact, read_log, replay, AdmissionConfig, BackendDesc, DispatchPolicy, Fcfs, GenRequest,
    KvLayout, LeastLoaded, Oplog, PrefixAffinity, Priority, PriorityPreempt, RoundRobin, Router,
    RouterConfig, SchedulePolicy, Server, ServerConfig, SimBackend, SupervisorConfig, TraceView,
};
use prefixquant::data::{self, Language};
use prefixquant::eval;
use prefixquant::model::Model;
use prefixquant::quant::{model_state, outlier, Precision, QuantArtifact, Recipe};
use prefixquant::runtime::Engine;
use prefixquant::tensor::IntTensor;
use prefixquant::tokenizer::Tokenizer;
use prefixquant::util::args::Args;
use prefixquant::util::table::{f as ff, Table};

fn parse_bits(args: &Args) -> Result<Precision> {
    match args.get("bits") {
        None => Ok(Precision::new(4, 4, 4)),
        Some(s) => {
            let parts: Vec<usize> = s
                .split(',')
                .map(|p| p.parse().map_err(|e| anyhow!("--bits: {e}")))
                .collect::<Result<_>>()?;
            if parts.len() != 3 {
                bail!("--bits wants w,a,kv");
            }
            Ok(Precision::new(parts[0], parts[1], parts[2]))
        }
    }
}

fn recipe_by_name(name: &str, p: Precision, ft_epochs: usize) -> Result<Recipe> {
    Ok(match name {
        "fp16" => Recipe::fp16(),
        "rtn" => Recipe::rtn(p),
        "quarot" => Recipe::quarot(p),
        "smoothquant" => Recipe::smoothquant(p),
        "atom" => Recipe::atom(p),
        "prefixquant-wo-ft" => Recipe::prefixquant_wo_ft(p),
        "prefixquant" => Recipe::prefixquant(p, ft_epochs),
        other => bail!("unknown scheme {other:?}"),
    })
}

struct Ctx {
    engine: Rc<Engine>,
    tok: Tokenizer,
    lang: Language,
}

fn ctx() -> Result<Ctx> {
    let dir = prefixquant::artifacts_dir();
    let engine = Rc::new(Engine::new(&dir)?);
    let tok = Tokenizer::new(engine.manifest.tokenizer.clone());
    let lang = Language::new(engine.manifest.corpus.clone());
    Ok(Ctx { engine, tok, lang })
}

fn calib_batch(c: &Ctx, model: &Model) -> Result<IntTensor> {
    let (b, s) = model.fwd_geom()?;
    let windows =
        data::calibration_windows(&c.lang, |t| c.tok.encode(t, false), s, b, c.tok.spec.bos);
    let data: Vec<i32> = windows.into_iter().flatten().collect();
    Ok(IntTensor::new(vec![b, s], data)?)
}

fn eval_windows(c: &Ctx, model: &Model, max: usize) -> Result<Vec<Vec<i32>>> {
    let (_b, s) = model.fwd_geom()?;
    let ids = c.tok.encode(&c.lang.eval_text(), false);
    Ok(data::windows(&ids, s, c.tok.spec.bos, max))
}

fn quantize_model(
    c: &Ctx,
    args: &Args,
) -> Result<(Model, Recipe, prefixquant::quant::RecipeReport)> {
    let mname = args.get_or("model", "pq-tiny").to_string();
    let sname = args.get_or("scheme", "prefixquant-wo-ft").to_string();
    let ft = args.usize_or("ft-epochs", 10)?;
    let recipe = recipe_by_name(&sname, parse_bits(args)?, ft)?;
    let mut model = Model::load(c.engine.clone(), &mname)?;
    let calib = calib_batch(c, &model)?;
    eprintln!(
        "quantizing {mname} with {} (passes: {})...",
        recipe.name,
        recipe.pass_names().join(" → ")
    );
    let rep = recipe.run(&mut model, &calib, &c.tok)?;
    eprintln!("  prefix={:?} | {}", rep.prefix_rendered, rep.timing_summary());
    Ok((model, recipe, rep))
}

fn cmd_info(c: &Ctx) -> Result<()> {
    let m = &c.engine.manifest;
    println!("artifacts: {:?}", m.dir);
    println!(
        "tokenizer: vocab={} delims={:?}",
        m.tokenizer.vocab_size, m.tokenizer.delimiter_ids
    );
    for (name, mm) in &m.models {
        println!(
            "model {name}: d={} L={} H={} ff={} | pretrain loss={:?} | {} executables",
            mm.config.d_model,
            mm.config.n_layers,
            mm.config.n_heads,
            mm.config.d_ff,
            mm.pretrain_final_loss,
            mm.executables.len()
        );
    }
    println!("{} kernel executables", m.kernels.len());
    Ok(())
}

fn cmd_outliers(c: &Ctx, args: &Args) -> Result<()> {
    let mname = args.get_or("model", "pq-tiny").to_string();
    let mut model = Model::load(c.engine.clone(), &mname)?;
    if args.flag("rotate") {
        let cfg = model.cfg.clone();
        prefixquant::quant::rotation::absorb_norm_gains(&cfg, &mut model.weights)?;
        prefixquant::quant::rotation::fold_rotations(&cfg, &mut model.weights)?;
        let (r3, r4) = prefixquant::quant::rotation::online_matrices(&model.cfg, true);
        model.quant.r3 = r3;
        model.quant.r4 = r4;
        model.refresh_weights()?;
    }
    let calib = calib_batch(c, &model)?;
    if args.flag("prefix") {
        let (_obs, rep) = outlier::observe_and_analyze(&model, &calib, outlier::ETA)?;
        let toks = prefixquant::quant::prefix::select_tokens(&rep, &c.tok);
        prefixquant::quant::prefix::install(&mut model, &toks, c.tok.spec.pad)?;
        println!("installed {}", prefixquant::quant::prefix::describe(&model, &c.tok)?);
    }
    let (_obs2, rep2) = outlier::observe_and_analyze(&model, &calib, outlier::ETA)?;
    let mut t = Table::new(
        &format!(
            "token-wise max ratios ({mname}{}{})",
            if args.flag("rotate") { " +rotate" } else { "" },
            if args.flag("prefix") { " +prefix" } else { "" }
        ),
        &["layer", "site", "top1", "median", "min1", "top1/med", "med/min1"],
    );
    for (li, row) in rep2.site_stats.iter().enumerate() {
        for (si, st) in row.iter().enumerate() {
            t.rowv(vec![
                li.to_string(),
                model.cfg.sites[si].clone(),
                ff(st.top1 as f64),
                ff(st.median as f64),
                ff(st.min1 as f64),
                ff(st.upper_ratio() as f64),
                ff(st.lower_ratio() as f64),
            ]);
        }
    }
    t.print();
    println!(
        "\noutliers detected (down_in, eta={}): total={} o_per_block={:?} -> o={}",
        rep2.eta, rep2.total_outliers, rep2.o_per_block, rep2.o
    );
    println!(
        "outlier token frequency (non-initial): {:?}",
        rep2.freq.iter().map(|&(id, n)| (c.tok.token_repr(id), n)).collect::<Vec<_>>()
    );
    Ok(())
}

fn cmd_quantize(c: &Ctx, args: &Args) -> Result<()> {
    let (model, recipe, rep) = quantize_model(c, args)?;
    if args.flag("eval") {
        let windows = eval_windows(c, &model, args.usize_or("windows", 24)?)?;
        let ppl = eval::perplexity(&model, recipe.mode, &windows)?;
        println!("{}: eval PPL = {:.4}", recipe.name, ppl);
    }
    if let Some(dir) = args.get("save") {
        let hash =
            QuantArtifact::save_model(&model, recipe.mode, Some(&rep), std::path::Path::new(dir))?;
        println!(
            "artifact v{} saved to {dir} (recipe {:?}, {} passes, hash {hash:016x})",
            prefixquant::quant::FORMAT_VERSION,
            rep.recipe,
            rep.stages.len()
        );
    }
    Ok(())
}

fn cmd_eval(c: &Ctx, args: &Args) -> Result<()> {
    // either load a saved artifact (O(read), no pipeline) or run a recipe
    let (model, mode, label) = if let Some(dir) = args.get("load") {
        let (model, mode) = model_state::load(c.engine.clone(), std::path::Path::new(dir))?;
        (model, mode, format!("loaded {dir}"))
    } else {
        let (model, recipe, _rep) = quantize_model(c, args)?;
        let label = recipe.name.clone();
        (model, recipe.mode, label)
    };
    let windows = eval_windows(c, &model, args.usize_or("windows", 24)?)?;
    let ppl = eval::perplexity(&model, mode, &windows)?;
    println!("{label}: PPL = {ppl:.4}");
    // --tasks runs for BOTH paths (the --load early-return used to skip it)
    if args.flag("tasks") {
        let scores = eval::run_all_tasks(
            &model,
            mode,
            &c.lang,
            &c.tok,
            args.usize_or("items", 32)?,
        )?;
        let mut t = Table::new("zero-shot tasks", &["task", "acc %", "items"]);
        for s in &scores {
            t.rowv(vec![s.name.clone(), format!("{:.2}", s.accuracy), s.items.to_string()]);
        }
        t.print();
    }
    Ok(())
}

/// The serving commands always boot from a QuantArtifact: either one saved
/// earlier (--load) or one produced right now by a single offline recipe run
/// — workers (and any post-failure model reload) only ever pay O(read).
fn artifact_for_serving(c: &Ctx, args: &Args) -> Result<PathBuf> {
    if let Some(dir) = args.get("load") {
        return Ok(PathBuf::from(dir));
    }
    let (model, recipe, rep) = quantize_model(c, args)?;
    let dir = match args.get("save") {
        Some(d) => PathBuf::from(d),
        None => std::env::temp_dir().join(format!("pq_gen_art_{}", std::process::id())),
    };
    QuantArtifact::save_model(&model, recipe.mode, Some(&rep), &dir)?;
    eprintln!("quantized once → artifact at {dir:?}; serving boots from it");
    Ok(dir)
}

/// One worker's server config for artifact-booted serving.  Takes the bos/pad
/// ids by value (not `&Ctx`) so a supervisor's restart factory can rebuild the
/// config from captured primitives.
fn worker_config(bos: i32, pad: i32, max_batch: usize) -> ServerConfig {
    ServerConfig::builder(prefixquant::model::QuantMode::Static)
        .engine(prefixquant::coordinator::EngineKind::Continuous)
        .max_batch(max_batch)
        .batch_window(Duration::from_millis(5))
        .bos(bos)
        .pad(pad)
        // paged KV with a dense-equivalent auto-sized pool
        .kv(prefixquant::coordinator::KvLayout::Paged { page_size: 16, n_pages: 0 })
        // shared-prefix pages are mapped, not re-prefilled
        .radix_cache(true)
        .build()
}

fn dispatch_policy(name: &str) -> Result<Box<dyn DispatchPolicy>> {
    Ok(match name {
        "round-robin" => Box::new(RoundRobin::new()),
        "least-loaded" => Box::new(LeastLoaded::new()),
        "prefix-affinity" => Box::new(PrefixAffinity::new()),
        other => {
            bail!("unknown dispatch policy {other:?} (round-robin|least-loaded|prefix-affinity)")
        }
    })
}

fn schedule_policy(name: &str) -> Result<Box<dyn SchedulePolicy>> {
    Ok(match name {
        "fcfs" => Box::new(Fcfs),
        "priority" => Box::new(PriorityPreempt::default()),
        other => bail!("unknown schedule policy {other:?} (fcfs|priority)"),
    })
}

/// Apply the shared overload-protection CLI knobs to a router config:
/// `--admission` (or `--admit-queue-depth N` / `--admit-backlog-tokens N`,
/// 0 = unlimited) engages the admission controller, `--no-shed-infeasible`
/// keeps deadline-doomed requests instead of shedding them, and
/// `--retry-budget N` (+ `--retry-refill R` tokens/s) bounds fleet-wide
/// redispatch storms.
fn overload_flags(mut rcfg: RouterConfig, args: &Args) -> Result<RouterConfig> {
    let depth = args.usize_or("admit-queue-depth", 0)?;
    let backlog = args.usize_or("admit-backlog-tokens", 0)?;
    if depth > 0 || backlog > 0 || args.flag("admission") {
        rcfg = rcfg.admission(
            AdmissionConfig::default()
                .max_queue_depth(depth)
                .max_backlog_tokens(backlog)
                .shed_infeasible(!args.flag("no-shed-infeasible")),
        );
    }
    if let Some(cap) = args.get("retry-budget") {
        let cap: usize = cap.parse().map_err(|e| anyhow!("--retry-budget: {e}"))?;
        let refill = args.f32_or("retry-refill", 32.0)? as f64;
        rcfg = rcfg.retry_budget(cap, refill);
    }
    Ok(rcfg)
}

fn sweep_json(r: &prefixquant::workload::SweepReport) -> prefixquant::util::json::Json {
    use prefixquant::util::json::{num, obj, s, Json};
    let points: Vec<Json> = r
        .points
        .iter()
        .map(|p| {
            let inter = &p.score.per_class[Priority::Interactive.index()];
            obj(vec![
                ("offered_rps", num(p.offered_rps)),
                ("n_requests", num(p.n_requests as f64)),
                ("trace_fingerprint", s(&format!("{:016x}", p.trace_fingerprint))),
                ("goodput_rps", num(p.score.goodput_rps)),
                ("attainment", num(p.score.attainment)),
                ("interactive_attainment", num(inter.attainment())),
                ("cancelled", num(p.score.cancelled as f64)),
                ("errors", num(p.score.errors as f64)),
            ])
        })
        .collect();
    obj(vec![
        ("workload", s(&r.workload)),
        ("knee_offered_rps", num(r.knee_point().offered_rps)),
        ("knee_goodput_rps", num(r.knee_point().score.goodput_rps)),
        ("saturated", Json::Bool(r.saturated())),
        ("points", Json::Arr(points)),
    ])
}

/// Open-loop load generation against a sim-backed fleet.  Like `replay`,
/// this needs no artifacts on disk, so it runs before the Engine context is
/// created.  The sim backend carries fixed per-call costs, which makes the
/// fleet's capacity a property of the cost model rather than the host.
fn cmd_loadgen(args: &Args) -> Result<()> {
    use prefixquant::workload::{
        render_table, run_trace, sweep_rates, ArrivalProcess, Target, Workload,
    };
    // sim fleet geometry (journaled in the oplog header so captures replay)
    const B_EXEC: usize = 4;
    const S_EXEC: usize = 96;
    const N_PREFIX: usize = 1;
    const CACHE_MAX: usize = 192;

    let seed = args.usize_or("seed", 17)? as u64;
    let n_workers = args.usize_or("workers", 2)?.max(1);
    let rate = args.f32_or("rate", 300.0)? as f64;
    let duration_s = args.f32_or("duration", 1.0)? as f64;
    let policy_name = args.get_or("policy", "priority").to_string();
    let dispatch_name = args.get_or("dispatch", "least-loaded").to_string();
    let radix = !args.flag("no-radix");
    let arrival = match args.get_or("arrival", "poisson") {
        "poisson" => ArrivalProcess::Poisson,
        "bursty" => ArrivalProcess::Bursty { on_s: 0.050, off_s: 0.050 },
        "heavy-tail" => ArrivalProcess::HeavyTail { alpha: 2.0 },
        other => bail!("unknown arrival process {other:?} (poisson|bursty|heavy-tail)"),
    };
    let workload = Workload::mixed(seed).with_arrival(arrival);

    let build_target = |oplog: Option<Oplog>| -> Result<Target> {
        let workers = (0..n_workers)
            .map(|_| {
                Server::start_sim(
                    move || {
                        Ok(SimBackend::new(B_EXEC, S_EXEC, N_PREFIX, CACHE_MAX)
                            .with_costs(Duration::from_micros(500), Duration::from_millis(1)))
                    },
                    ServerConfig::builder(prefixquant::model::QuantMode::Static)
                        .max_batch(B_EXEC)
                        .batch_window(Duration::from_millis(1))
                        .policy(schedule_policy(&policy_name)?)
                        .kv(KvLayout::Paged { page_size: 8, n_pages: 0 })
                        .radix_cache(radix)
                        .build(),
                )
            })
            .collect::<Result<Vec<_>>>()?;
        let mut rcfg = RouterConfig::default().policy(dispatch_policy(&dispatch_name)?);
        rcfg = overload_flags(rcfg, args)?;
        if let Some(log) = oplog {
            rcfg = rcfg.oplog(log);
        }
        Ok(Target::Router(Router::new(workers, rcfg)?))
    };

    if args.flag("sweep") {
        let rates: Vec<f64> = match args.get("rates") {
            Some(list) => list
                .split(',')
                .map(|r| r.trim().parse::<f64>().map_err(|e| anyhow!("--rates: {e}")))
                .collect::<Result<_>>()?,
            None => vec![rate * 0.25, rate * 0.5, rate, rate * 2.0, rate * 4.0, rate * 8.0],
        };
        let min_requests = args.usize_or("requests", 40)?.max(1);
        eprintln!(
            "sweeping {} offered loads ({} workers, {policy_name}/{dispatch_name}{})...",
            rates.len(),
            n_workers,
            if radix { "" } else { ", radix off" }
        );
        let report = sweep_rates(&workload, &rates, duration_s, min_requests, || {
            build_target(None)
        })?;
        print!("{}", render_table(&report));
        let knee = report.knee_point();
        println!(
            "knee: {:.1} rps offered -> {:.2} rps goodput ({})",
            knee.offered_rps,
            knee.score.goodput_rps,
            if report.saturated() { "swept past saturation" } else { "no bend in swept range" }
        );
        if args.flag("json") {
            println!("{}", sweep_json(&report).to_string());
        }
        return Ok(());
    }

    let n = match args.usize_or("requests", 0)? {
        0 => ((rate * duration_s).ceil() as usize).max(1),
        n => n,
    };
    let trace = workload.clone().with_rate(rate).with_requests(n).generate();
    let oplog = match args.get("oplog") {
        Some(path) => {
            eprintln!("journaling to {path}; replay with: pq replay {path}");
            Some(Oplog::create(
                std::path::Path::new(path),
                &BackendDesc::Sim {
                    b_exec: B_EXEC as u32,
                    s_exec: S_EXEC as u32,
                    n_prefix: N_PREFIX as u32,
                    cache_max: CACHE_MAX as u32,
                },
            )?)
        }
        None => None,
    };
    eprintln!(
        "loadgen: {n} request(s) at {rate:.1} rps ({} arrivals, {n_workers} worker(s), \
         {policy_name}/{dispatch_name}{}), trace fingerprint {:016x}",
        workload.arrival.name(),
        if radix { "" } else { ", radix off" },
        trace.fingerprint()
    );
    let target = build_target(oplog)?;
    let report = run_trace(&trace, &target);
    let engine_metrics = target.metrics();
    target.shutdown();
    let report = report?;

    let sc = &report.score;
    let mut t = Table::new(
        &format!("loadgen ({}, {rate:.0} rps offered)", trace.workload),
        &[
            "class", "offered", "done", "slo ok", "attain", "p50 ttft", "p99 ttft", "p99 tpot",
            "cancel", "shed", "err",
        ],
    );
    for p in Priority::all() {
        let c = &sc.per_class[p.index()];
        if c.offered == 0 {
            continue;
        }
        t.rowv(vec![
            p.name().to_string(),
            c.offered.to_string(),
            c.completed.to_string(),
            c.slo_ok.to_string(),
            format!("{:.3}", c.attainment()),
            format!("{:.1}ms", c.p50_ttft_s * 1e3),
            format!("{:.1}ms", c.p99_ttft_s * 1e3),
            format!("{:.1}ms", c.p99_tpot_s * 1e3),
            c.cancelled.to_string(),
            c.shed.to_string(),
            c.errors.to_string(),
        ]);
    }
    t.print();
    println!(
        "goodput: {:.2} rps ({} SLO-met of {} submitted in {:.2}s wall, attainment {:.3}{})",
        sc.goodput_rps,
        sc.slo_ok,
        sc.submitted,
        sc.wall_s,
        sc.attainment,
        if sc.shed + sc.quarantined > 0 {
            format!("; {} shed, {} quarantined", sc.shed, sc.quarantined)
        } else {
            String::new()
        }
    );
    if let Ok(m) = engine_metrics {
        println!(
            "engine: {} deadline miss(es); merged ttft p50={:.1}ms p99={:.1}ms",
            m.deadline_misses,
            m.ttft_hist().p50() * 1e3,
            m.ttft_hist().p99() * 1e3
        );
    }
    if args.flag("json") {
        use prefixquant::util::json::{num, obj, s, Json};
        let classes: Vec<Json> = Priority::all()
            .iter()
            .map(|p| {
                let c = &sc.per_class[p.index()];
                obj(vec![
                    ("class", s(p.name())),
                    ("offered", num(c.offered as f64)),
                    ("completed", num(c.completed as f64)),
                    ("slo_ok", num(c.slo_ok as f64)),
                    ("attainment", num(c.attainment())),
                    ("p50_ttft_s", num(c.p50_ttft_s)),
                    ("p99_ttft_s", num(c.p99_ttft_s)),
                    ("p50_tpot_s", num(c.p50_tpot_s)),
                    ("p99_tpot_s", num(c.p99_tpot_s)),
                    ("cancelled", num(c.cancelled as f64)),
                    ("shed", num(c.shed as f64)),
                    ("quarantined", num(c.quarantined as f64)),
                    ("errors", num(c.errors as f64)),
                ])
            })
            .collect();
        let j = obj(vec![
            ("workload", s(&trace.workload)),
            ("seed", num(seed as f64)),
            ("offered_rps", num(rate)),
            ("trace_fingerprint", s(&format!("{:016x}", trace.fingerprint()))),
            ("goodput_rps", num(sc.goodput_rps)),
            ("attainment", num(sc.attainment)),
            ("wall_s", num(sc.wall_s)),
            ("submitted", num(sc.submitted as f64)),
            ("slo_ok", num(sc.slo_ok as f64)),
            ("cancelled", num(sc.cancelled as f64)),
            ("shed", num(sc.shed as f64)),
            ("quarantined", num(sc.quarantined as f64)),
            ("errors", num(sc.errors as f64)),
            ("per_class", Json::Arr(classes)),
        ]);
        println!("{}", j.to_string());
    }
    Ok(())
}

fn cmd_gen(c: &Ctx, args: &Args) -> Result<()> {
    let prompt_text = args.get_or("prompt", "the quick").to_string();
    let n = args.usize_or("n", 32)?;
    let artifact_dir = artifact_for_serving(c, args)?;
    let tok = c.tok.clone();
    // the serving mode comes from the artifact itself: start_from_artifact
    // peeks the metadata and overrides the builder's mode seed
    let server = Server::start_from_artifact(
        prefixquant::artifacts_dir(),
        artifact_dir,
        worker_config(c.tok.spec.bos, c.tok.spec.pad, 8),
    )?;
    let req = GenRequest::builder(1)
        .prompt(tok.encode(&prompt_text, false))
        .max_new(n)
        .priority(prefixquant::coordinator::Priority::Interactive)
        .build();
    let resp = server.generate(req)?;
    println!("prompt: {prompt_text:?}");
    println!("output: {:?}", tok.decode(&resp.tokens));
    println!(
        "ttft={:.1}ms total={:.1}ms finish={}",
        resp.ttft_s * 1e3,
        resp.total_s * 1e3,
        resp.finish.name()
    );
    server.shutdown();
    Ok(())
}

fn cmd_serve(c: &Ctx, args: &Args) -> Result<()> {
    let n_workers = args.usize_or("workers", 2)?.max(1);
    let policy_name = args.get_or("policy", "prefix-affinity").to_string();
    let n_requests = args.usize_or("requests", 24)?;
    let max_new = args.usize_or("max-new", 16)?;
    let artifact_dir = artifact_for_serving(c, args)?;

    // one shared artifact, N workers: every boot is an O(read) of the same
    // quantized state, so the fleet is interchangeable by construction
    eprintln!("booting {n_workers} worker(s) from {artifact_dir:?} (policy: {policy_name})...");
    let (bos, pad) = (c.tok.spec.bos, c.tok.spec.pad);
    let workers = (0..n_workers)
        .map(|_| {
            Server::start_from_artifact(
                prefixquant::artifacts_dir(),
                artifact_dir.clone(),
                worker_config(bos, pad, 4),
            )
        })
        .collect::<Result<Vec<_>>>()?;
    let policy = dispatch_policy(&policy_name)?;
    let mut rcfg = RouterConfig::default().policy(policy);
    if let Some(log_path) = args.get("oplog") {
        let log = Oplog::create(
            std::path::Path::new(log_path),
            &BackendDesc::Artifact { path: artifact_dir.to_string_lossy().into_owned() },
        )?;
        eprintln!("journaling to {log_path} (stream resume on); replay with: pq replay {log_path}");
        rcfg = rcfg.oplog(log);
    }
    if args.flag("supervise") {
        let budget = args.usize_or("restart-budget", 3)?;
        let backoff_ms = args.usize_or("backoff-ms", 50)? as u64;
        let backoff_max_ms = args.usize_or("backoff-max-ms", 2000)? as u64;
        eprintln!(
            "supervising: restart budget {budget} per window, \
             backoff {backoff_ms}..{backoff_max_ms}ms"
        );
        // the factory reboots a lost slot from the same shared artifact; it
        // captures only owned values so restarts need no live `Ctx`
        let dir = artifact_dir.clone();
        rcfg = rcfg.supervise(
            SupervisorConfig::default()
                .backoff_base(Duration::from_millis(backoff_ms))
                .backoff_max(Duration::from_millis(backoff_max_ms))
                .max_restarts(budget),
            Box::new(move |_w| {
                Server::start_from_artifact(
                    prefixquant::artifacts_dir(),
                    dir.clone(),
                    worker_config(bos, pad, 4),
                )
            }),
        );
    }
    rcfg = overload_flags(rcfg, args)?;
    let router = Router::new(workers, rcfg)?;

    // demo workload with shared prompt prefixes: requests cycle through a few
    // conversation groups, each group sharing a long prefix with unique tails
    // — the shape prefix-affinity routing exists for
    let ids = c.tok.encode(&c.lang.eval_text(), false);
    let groups = 4.min(n_requests.max(1));
    let prefix_len = 24.min(ids.len() / 2).max(1);
    let handles: Vec<_> = (0..n_requests)
        .map(|i| {
            let g = i % groups;
            let start = (g * 8).min(ids.len().saturating_sub(prefix_len));
            let mut prompt: Vec<i32> = ids[start..start + prefix_len].to_vec();
            let tail_len = 4.min(ids.len());
            let tail = (start + prefix_len + 4 * (i / groups))
                % (ids.len().saturating_sub(tail_len) + 1).max(1);
            prompt.extend_from_slice(&ids[tail..tail + tail_len]);
            router.submit(GenRequest::new(0, prompt, max_new))
        })
        .collect::<Result<Vec<_>>>()?;
    let mut ok = 0usize;
    for h in handles {
        let seq = h.id();
        match h.collect() {
            Ok(resp) => {
                ok += 1;
                println!(
                    "req {seq}: {} tokens, ttft={:.1}ms, finish={}",
                    resp.tokens.len(),
                    resp.ttft_s * 1e3,
                    resp.finish.name()
                );
            }
            Err(e) => println!("req {seq}: error: {e:#}"),
        }
    }

    let report = router.report()?;
    let mut t = Table::new(
        &format!("fleet ({policy_name})"),
        &[
            "worker",
            "state",
            "cause",
            "restarts",
            "dispatched",
            "affinity",
            "absorbed",
            "completed",
            "saturation",
            "ttft p50",
            "ttft p99",
            "ddl miss",
            "rdx pages",
            "rdx hit tok",
        ],
    );
    for w in &report.workers {
        let state = if w.retired {
            format!("{} (retired)", w.state.name())
        } else {
            w.state.name().to_string()
        };
        let cause = match &w.cause {
            Some(c) => c.name().to_string(),
            None => "-".to_string(),
        };
        t.rowv(vec![
            w.worker.to_string(),
            state,
            cause,
            w.restarts.to_string(),
            w.dispatched.to_string(),
            w.affinity_hits.to_string(),
            w.redistributions_absorbed.to_string(),
            w.completed.to_string(),
            format!("{:.2}", w.saturation),
            format!("{:.1}ms", w.ttft_p50_s * 1e3),
            format!("{:.1}ms", w.ttft_p99_s * 1e3),
            w.deadline_misses.to_string(),
            w.radix_shared_pages.to_string(),
            w.radix_hit_tokens.to_string(),
        ]);
    }
    t.print();
    let f = &report.fleet;
    println!(
        "fleet: submitted={} completed={} errors={} redistributed={} shed={} \
         quarantined={} restarts={} retired={} prefix-hit-rate={:.1}% \
         net-prefill={} tokens",
        f.submitted,
        f.completed,
        f.errors,
        f.redistributed,
        f.shed,
        f.quarantined,
        f.workers_restarted,
        f.workers_retired,
        f.prefix_hit_rate() * 100.0,
        f.net_prefill_tokens()
    );
    println!(
        "merged engine metrics: {} requests, {} generated tokens, {} prefill tokens",
        report.merged.requests, report.merged.generated_tokens, report.merged.prefill_tokens
    );
    let m = &report.merged;
    if m.radix_lookups > 0 {
        println!(
            "radix cache: {}/{} admissions hit, {} tokens served from cache, \
             {} CoW split(s), {} page(s) evicted, {} shared page(s) resident ({} KiB)",
            m.radix_hits,
            m.radix_lookups,
            m.radix_hit_tokens,
            m.radix_cow_splits,
            m.radix_evicted_pages,
            m.radix_shared_pages,
            m.radix_shared_bytes / 1024
        );
    }
    router.shutdown();
    if ok < n_requests {
        bail!("{} of {n_requests} requests failed", n_requests - ok);
    }
    Ok(())
}

/// Re-execute a captured oplog trace on a fresh fleet and verify it (see
/// the `replay` entry in the module docs).  The fleet is booted from the
/// journal's own backend header: sim traces need no artifacts at all, so
/// this runs BEFORE the artifact context is created.
fn cmd_replay(args: &Args) -> Result<()> {
    let path = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow!("usage: pq replay <oplog> [--workers N]"))?
        .clone();
    let rec = read_log(std::path::Path::new(&path))?;
    if rec.dropped_bytes > 0 {
        eprintln!("{path}: ignoring a torn tail of {} byte(s)", rec.dropped_bytes);
    }
    let view = TraceView::from_entries(&rec.entries);
    let n_workers = args.usize_or("workers", 2)?.max(1);
    let workers: Vec<Server> = match &view.backend {
        Some(BackendDesc::Sim { b_exec, s_exec, n_prefix, cache_max }) => {
            let (b, s, p, m) =
                (*b_exec as usize, *s_exec as usize, *n_prefix as usize, *cache_max as usize);
            (0..n_workers)
                .map(|_| {
                    Server::start_sim(
                        move || Ok(SimBackend::new(b, s, p, m)),
                        ServerConfig::builder(prefixquant::model::QuantMode::Static)
                            .batch_window(Duration::from_millis(1))
                            .build(),
                    )
                })
                .collect::<Result<_>>()?
        }
        Some(BackendDesc::Artifact { path: artifact_dir }) => {
            let c = ctx()?;
            (0..n_workers)
                .map(|_| {
                    Server::start_from_artifact(
                        prefixquant::artifacts_dir(),
                        PathBuf::from(artifact_dir),
                        worker_config(c.tok.spec.bos, c.tok.spec.pad, 4),
                    )
                })
                .collect::<Result<_>>()?
        }
        None => bail!("{path}: journal has no backend header — nothing to boot for replay"),
    };
    let router = Router::new(workers, RouterConfig::default())?;
    eprintln!(
        "replaying {} journaled request(s) on {n_workers} fresh worker(s) \
         ({} worker-loss event(s) in the original run)...",
        view.records.len(),
        view.worker_events
    );
    let report = replay(&view, &router)?;
    router.shutdown();
    println!(
        "replay: {} request(s), {} exact, {} prefix-consistent, {} mismatched, \
         {} token(s) in {:.2}s",
        report.total,
        report.exact,
        report.prefix_ok,
        report.mismatched.len(),
        report.replayed_tokens,
        report.wall_s
    );
    if !report.ok() {
        bail!("replay diverged from the journal on seq(s) {:?}", report.mismatched);
    }
    println!("replay is consistent with the journal");
    Ok(())
}

/// Journal maintenance.  `pq oplog compact <path>` rewrites the journal
/// without the records of fully-finished requests; recovery on the
/// compacted log resumes exactly what it would have resumed before.
fn cmd_oplog(args: &Args) -> Result<()> {
    match args.positional.get(1).map(|s| s.as_str()) {
        Some("compact") => {
            let path = args
                .positional
                .get(2)
                .ok_or_else(|| anyhow!("usage: pq oplog compact <path>"))?;
            let r = compact(std::path::Path::new(path))?;
            println!(
                "compacted {path}: dropped {} finished request(s) / {} entries, \
                 {} → {} bytes (kept {} entries)",
                r.dropped_requests, r.dropped_entries, r.bytes_before, r.bytes_after, r.kept_entries
            );
            Ok(())
        }
        _ => bail!("usage: pq oplog compact <path>"),
    }
}

fn main() -> Result<()> {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("info");
    // replay and oplog maintenance work from the journal alone; a sim trace
    // must work with no artifacts on disk, so the Engine context is not
    // created up front
    if cmd == "loadgen" {
        return cmd_loadgen(&args);
    }
    if cmd == "replay" {
        return cmd_replay(&args);
    }
    if cmd == "oplog" {
        return cmd_oplog(&args);
    }
    let c = ctx()?;
    match cmd {
        "info" => cmd_info(&c),
        "outliers" => cmd_outliers(&c, &args),
        "quantize" => cmd_quantize(&c, &args),
        "eval" => cmd_eval(&c, &args),
        "gen" => cmd_gen(&c, &args),
        "serve" => cmd_serve(&c, &args),
        other => {
            bail!(
                "unknown command {other:?} \
                 (info|outliers|quantize|eval|gen|serve|loadgen|replay|oplog)"
            )
        }
    }
}
