//! PrefixQuant reproduction — rust L3 coordinator + quantization pipeline.
//!
//! Three-layer architecture (see rust/DESIGN.md for the full picture,
//! including the continuous-batching engine's slot state machine):
//!   L1  Pallas kernels  (python, build time, interpret=True)
//!   L2  JAX model       (python, build time, AOT-lowered to HLO text)
//!   L3  this crate      (request path: PJRT runtime, quant pipeline,
//!                        serving coordinator, eval harness)
//!
//! Entry points: [`runtime::Engine`] loads artifacts, [`model::Model`] binds a
//! checkpoint, [`quant::pipeline`] runs the PrefixQuant quantization flow,
//! [`coordinator`] serves generation requests (run-to-completion or
//! continuous batching), [`workload`] drives open-loop load against the
//! serving layer and scores SLO goodput, [`eval`] scores models.  All
//! host-side compute of the quantize path (matmul, rotation folding, weight
//! quantization, …) routes through the threaded [`kernels`] layer
//! (`PQ_THREADS` knob).

pub mod bench_support;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod kernels;
pub mod model;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod tensor;
pub mod tokenizer;
pub mod util;
pub mod workload;

pub use anyhow::Result;

/// Default artifacts directory (relative to the repo root).
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var("PQ_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}
