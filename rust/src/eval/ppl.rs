//! Perplexity over the held-out synthetic split (the WikiText2 analog).
//!
//! Protocol mirrors the paper: non-overlapping windows at the eval context
//! length, next-token NLL averaged over all predicted positions, PPL = e^nll.

use anyhow::Result;

use crate::model::{Model, QuantMode};
use crate::tensor::IntTensor;

/// Host log-softmax NLL for a [B,S,V] logits tensor against [B,S] targets
/// shifted by one. Returns (sum_nll, count).
fn batch_nll(logits: &crate::tensor::Tensor, tokens: &IntTensor, rows: usize) -> (f64, usize) {
    let (b, s, v) = (logits.shape[0], logits.shape[1], logits.shape[2]);
    debug_assert_eq!(tokens.shape, vec![b, s]);
    let mut sum = 0.0f64;
    let mut count = 0usize;
    for bi in 0..rows.min(b) {
        for si in 0..s - 1 {
            let target = tokens.data[bi * s + si + 1];
            let row = &logits.data[(bi * s + si) * v..(bi * s + si + 1) * v];
            // stable log-softmax
            let m = row.iter().fold(f32::NEG_INFINITY, |a, &x| a.max(x));
            let lse: f64 =
                row.iter().map(|&x| ((x - m) as f64).exp()).sum::<f64>().ln() + m as f64;
            sum += lse - row[target as usize] as f64;
            count += 1;
        }
    }
    (sum, count)
}

/// Perplexity of `model` under `mode` over pre-tokenized eval windows.
/// Windows must match the fwd executable's seq length; they are batched into
/// the executable's fixed batch dimension (last partial batch row-padded by
/// repeating window 0, padding rows excluded from the NLL).
pub fn perplexity(model: &Model, mode: QuantMode, windows: &[Vec<i32>]) -> Result<f64> {
    let (b, s) = model.fwd_geom()?;
    anyhow::ensure!(!windows.is_empty(), "no eval windows");
    anyhow::ensure!(windows[0].len() == s, "window length {} != exec seq {s}", windows[0].len());
    let mut sum = 0.0f64;
    let mut count = 0usize;
    let mut i = 0;
    while i < windows.len() {
        let rows = (windows.len() - i).min(b);
        let mut data = Vec::with_capacity(b * s);
        for r in 0..b {
            let w = if r < rows { &windows[i + r] } else { &windows[i] };
            data.extend_from_slice(w);
        }
        let toks = IntTensor::new(vec![b, s], data)?;
        let logits = model.logits(mode, &toks)?;
        let (bs, bc) = batch_nll(&logits, &toks, rows);
        sum += bs;
        count += bc;
        i += rows;
    }
    Ok((sum / count.max(1) as f64).exp())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    #[test]
    fn nll_of_uniform_logits_is_logv() {
        let (b, s, v) = (1, 3, 8);
        let logits = Tensor::zeros(&[b, s, v]);
        let toks = IntTensor::new(vec![b, s], vec![1, 2, 3]).unwrap();
        let (sum, count) = batch_nll(&logits, &toks, 1);
        assert_eq!(count, 2);
        assert!((sum / count as f64 - (v as f64).ln()).abs() < 1e-6);
    }

    #[test]
    fn nll_prefers_correct_logit() {
        let (b, s, v) = (1, 2, 4);
        let mut logits = Tensor::zeros(&[b, s, v]);
        logits.data[2] = 10.0; // position 0 predicts token 2 strongly
        let good = IntTensor::new(vec![b, s], vec![0, 2]).unwrap();
        let bad = IntTensor::new(vec![b, s], vec![0, 3]).unwrap();
        let (g, _) = batch_nll(&logits, &good, 1);
        let (w, _) = batch_nll(&logits, &bad, 1);
        assert!(g < w);
    }
}
