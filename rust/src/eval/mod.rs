//! Evaluation harness: perplexity (WikiText2-analog) and five synthetic
//! zero-shot tasks scored lm-eval style (length-normalized logprob over
//! candidate continuations — the paper's acc/acc_norm protocol).

pub mod ppl;
pub mod tasks;

pub use ppl::perplexity;
pub use tasks::{run_all_tasks, TaskScore};
