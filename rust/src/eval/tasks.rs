//! Five synthetic zero-shot tasks (the PIQA/ARC/HellaSwag/WinoGrande analog).
//!
//! Each task is a 2-way multiple choice grounded in the synthetic language's
//! learnable structure; scored lm-eval style: pick the candidate continuation
//! with the higher length-normalized logprob (acc_norm).  Items are packed
//! several-per-row (newline separated) so one fixed-geometry forward scores a
//! whole task — the packing is identical across schemes, so comparisons are
//! apples-to-apples.
//!
//! Tasks:
//!   * `completion` — real word completion vs corrupted tail;
//!   * `bigram`     — true follower word vs random word after "w ";
//!   * `delimiter`  — "." vs letter at a sentence boundary;
//!   * `spelling`   — correct final character vs off-by-one character;
//!   * `next-word`  — real vocabulary word vs shuffled letters after ". ".

use anyhow::Result;

use crate::data::Language;
use crate::model::{Model, QuantMode};
use crate::tensor::IntTensor;
use crate::tokenizer::Tokenizer;
use crate::util::rng::SplitMix64;

#[derive(Debug, Clone)]
pub struct TaskScore {
    pub name: String,
    pub accuracy: f64,
    pub items: usize,
}

/// One scored segment: candidate continuation at a known position in a row.
struct Segment {
    row: usize,
    /// continuation token positions [start, end) within the row
    start: usize,
    end: usize,
    item: usize,
    candidate: usize,
}

struct Packed {
    tokens: IntTensor, // [B, S]
    segments: Vec<Segment>,
    n_items: usize,
}

/// An item: shared context + per-candidate continuations (candidate 0 = gold).
struct Item {
    context: String,
    candidates: Vec<String>,
}

fn pack(items: &[Item], tok: &Tokenizer, b: usize, s: usize) -> Packed {
    let mut rows: Vec<Vec<i32>> = vec![vec![tok.spec.bos]; b];
    let mut segments = Vec::new();
    let mut row = 0usize;
    for (ii, item) in items.iter().enumerate() {
        for (ci, cand) in item.candidates.iter().enumerate() {
            let ctx = tok.encode(&item.context, false);
            let cont = tok.encode(cand, false);
            // move to the next row if this segment would overflow
            if rows[row].len() + ctx.len() + cont.len() + 1 >= s {
                row = (row + 1) % b;
                if rows[row].len() + ctx.len() + cont.len() + 1 >= s {
                    break; // batch full — stop packing
                }
            }
            let r = &mut rows[row];
            r.extend_from_slice(&ctx);
            let start = r.len();
            r.extend_from_slice(&cont);
            let end = r.len();
            segments.push(Segment { row, start, end, item: ii, candidate: ci });
            r.push(tok.spec.byte_offset + b'\n' as i32);
            row = (row + 1) % b;
        }
    }
    let mut data = Vec::with_capacity(b * s);
    for mut r in rows {
        r.resize(s, tok.spec.pad);
        data.extend_from_slice(&r);
    }
    let n_items = segments.iter().map(|sg| sg.item + 1).max().unwrap_or(0);
    Packed { tokens: IntTensor::new(vec![b, s], data).unwrap(), segments, n_items }
}

/// Score packed items: gold (candidate 0) must have the best normalized
/// logprob among its item's candidates.
fn score(model: &Model, mode: QuantMode, packed: &Packed) -> Result<(usize, usize)> {
    let logits = model.logits(mode, &packed.tokens)?;
    let (_b, s, v) = (logits.shape[0], logits.shape[1], logits.shape[2]);
    let toks = &packed.tokens;
    let lp = |row: usize, start: usize, end: usize| -> f64 {
        // logprob of tokens[start..end) given the preceding context
        let mut total = 0.0f64;
        for pos in start..end {
            let pred_pos = pos - 1; // logits at pos-1 predict token at pos
            let target = toks.data[row * s + pos];
            let lrow = &logits.data[(row * s + pred_pos) * v..(row * s + pred_pos + 1) * v];
            let m = lrow.iter().fold(f32::NEG_INFINITY, |a, &x| a.max(x));
            let lse: f64 =
                lrow.iter().map(|&x| ((x - m) as f64).exp()).sum::<f64>().ln() + m as f64;
            total += lrow[target as usize] as f64 - lse;
        }
        total / (end - start).max(1) as f64
    };
    let mut best: Vec<(f64, usize)> = vec![(f64::NEG_INFINITY, usize::MAX); packed.n_items];
    for sg in &packed.segments {
        let val = lp(sg.row, sg.start, sg.end);
        if val > best[sg.item].0 {
            best[sg.item] = (val, sg.candidate);
        }
    }
    let scored = best.iter().filter(|(_, c)| *c != usize::MAX).count();
    let correct = best.iter().filter(|(_, c)| *c == 0).count();
    Ok((correct, scored))
}

fn corrupt(word: &str, rng: &mut SplitMix64) -> String {
    let mut b: Vec<u8> = word.bytes().collect();
    let i = rng.below(b.len() as u64) as usize;
    b[i] = b'a' + ((b[i] - b'a' + 1 + rng.below(24) as u8) % 26);
    String::from_utf8(b).unwrap()
}

fn shuffled(word: &str, rng: &mut SplitMix64) -> String {
    let mut b: Vec<u8> = word.bytes().collect();
    for i in (1..b.len()).rev() {
        let j = rng.below((i + 1) as u64) as usize;
        b.swap(i, j);
    }
    let s = String::from_utf8(b).unwrap();
    if s == word {
        // force a difference
        corrupt(word, rng)
    } else {
        s
    }
}

fn sentence(lang: &Language, rng: &mut SplitMix64, n: usize) -> (Vec<usize>, String) {
    let mut idx = lang.zipf_sample(rng);
    let mut ids = Vec::with_capacity(n);
    let mut parts = Vec::with_capacity(n);
    for _ in 0..n {
        idx = if rng.below(10) < 7 {
            lang.followers[idx][rng.below(lang.followers[idx].len() as u64) as usize]
        } else {
            lang.zipf_sample(rng)
        };
        ids.push(idx);
        parts.push(lang.words[idx].clone());
    }
    (ids, parts.join(" "))
}

fn gen_items(lang: &Language, task: &str, n: usize, seed: u64) -> Vec<Item> {
    let mut rng = SplitMix64::new(seed);
    let mut items = Vec::with_capacity(n);
    for _ in 0..n {
        let n_words = 4 + rng.below(4) as usize;
        let (ids, text) = sentence(lang, &mut rng, n_words);
        let last = *ids.last().unwrap();
        let word = lang.words[last].clone();
        let item = match task {
            "completion" => {
                // context ends mid-word; gold = true tail
                let split = 1 + rng.below((word.len() - 1) as u64) as usize;
                let ctx_head: String =
                    text[..text.len() - word.len() + split].to_string();
                let gold = word[split..].to_string();
                let alt = corrupt(&word, &mut rng)[split..].to_string();
                if gold == alt {
                    continue;
                }
                Item { context: ctx_head, candidates: vec![gold, alt] }
            }
            "bigram" => {
                let fol = lang.followers[last][rng.below(8) as usize];
                let mut other = lang.zipf_sample(&mut rng);
                while lang.followers[last].contains(&other) {
                    other = lang.zipf_sample(&mut rng);
                }
                Item {
                    context: format!("{text} "),
                    candidates: vec![lang.words[fol].clone(), lang.words[other].clone()],
                }
            }
            "delimiter" => Item {
                context: text,
                candidates: vec![".".into(), "q".into()],
            },
            "spelling" => {
                let gold = word.clone();
                let alt = corrupt(&word, &mut rng);
                let ctx = text[..text.len() - word.len()].to_string();
                Item { context: ctx, candidates: vec![gold, alt] }
            }
            "next-word" => {
                let (ids2, _) = sentence(lang, &mut rng, 1);
                let w = lang.words[ids2[0]].clone();
                let alt = shuffled(&w, &mut rng);
                Item { context: format!("{text}. "), candidates: vec![w, alt] }
            }
            other => panic!("unknown task {other}"),
        };
        items.push(item);
    }
    items
}

pub const TASK_NAMES: [&str; 5] =
    ["completion", "bigram", "delimiter", "spelling", "next-word"];

/// Run all five tasks; returns per-task scores (and the macro average last).
pub fn run_all_tasks(
    model: &Model,
    mode: QuantMode,
    lang: &Language,
    tok: &Tokenizer,
    items_per_task: usize,
) -> Result<Vec<TaskScore>> {
    let (b, s) = model.fwd_geom()?;
    let mut out = Vec::new();
    for (ti, name) in TASK_NAMES.iter().enumerate() {
        let items = gen_items(lang, name, items_per_task, 0xEA57 + ti as u64);
        let packed = pack(&items, tok, b, s);
        let (correct, scored) = score(model, mode, &packed)?;
        out.push(TaskScore {
            name: name.to_string(),
            accuracy: 100.0 * correct as f64 / scored.max(1) as f64,
            items: scored,
        });
    }
    let avg = out.iter().map(|t| t.accuracy).sum::<f64>() / out.len() as f64;
    out.push(TaskScore { name: "Avg. Acc.".into(), accuracy: avg, items: 0 });
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CorpusSpec;

    fn lang() -> Language {
        Language::new(CorpusSpec {
            n_words: 64,
            n_followers: 8,
            follow_prob10: 7,
            word_seed: 1,
            train_seed: 2,
            eval_seed: 3,
            train_chars: 1000,
            eval_chars: 1000,
        })
    }

    #[test]
    fn items_have_two_distinct_candidates() {
        let l = lang();
        for name in TASK_NAMES {
            let items = gen_items(&l, name, 20, 7);
            assert!(!items.is_empty(), "{name} generated nothing");
            for it in &items {
                assert_eq!(it.candidates.len(), 2, "{name}");
                assert_ne!(it.candidates[0], it.candidates[1], "{name}");
            }
        }
    }

    #[test]
    fn corrupt_changes_word() {
        let mut rng = SplitMix64::new(3);
        for w in ["ab", "hello", "zz"] {
            assert_ne!(corrupt(w, &mut rng), w);
        }
    }

    #[test]
    fn packing_respects_geometry() {
        let l = lang();
        let tok = Tokenizer::new(crate::config::TokenizerSpec {
            pad: 0,
            bos: 1,
            eos: 2,
            byte_offset: 3,
            vocab_size: 272,
            delimiter_ids: vec![13, 49],
        });
        let items = gen_items(&l, "bigram", 16, 7);
        let p = pack(&items, &tok, 8, 256);
        assert_eq!(p.tokens.shape, vec![8, 256]);
        for sg in &p.segments {
            assert!(sg.start > 0 && sg.end <= 256 && sg.start < sg.end);
        }
        assert!(p.n_items >= 8);
    }
}
