//! Radix prefix cache: a tree over token sequences whose nodes map to
//! refcounted [`crate::coordinator::PagePool`] pages.
//!
//! This generalizes the paper's mechanism.  PrefixQuant writes the
//! outlier-prefix K/V once and maps it into every sequence; the radix tree
//! extends that economics to ARBITRARY prompt prefixes: thousands of
//! requests sharing a system prompt or few-shot template pay for the shared
//! K/V exactly once (IntactKV makes the quantization-side argument that
//! pivot-token K/V is worth caching losslessly).
//!
//! Layout invariants the tree relies on:
//!
//! - Nodes are keyed by whole `page_size` token chunks, so a node IS one
//!   page: the K/V for cache positions `[n_prefix + depth*page_size,
//!   n_prefix + (depth+1)*page_size)` of any row whose token sequence starts
//!   with the node's root-path.  Causal attention makes K/V at a position a
//!   function of the tokens at and before it, and every slot shares the same
//!   `n_prefix` offset — so equal root-paths imply byte-identical page
//!   contents, and a cached page can be MAPPED (not copied) into any
//!   matching slot.
//! - The tree holds exactly ONE pool reference per cached page (taken when a
//!   node adopts the page, dropped when the node is evicted or flushed).  A
//!   page mapped into live slots carries additional references, so
//!   `refcount == 1` identifies a run only the cache remembers — the only
//!   thing eviction is allowed to take.
//! - Eviction is leaf-first LRU on a monotone logical clock (bumped per
//!   lookup/insert, never wall time, so behaviour is deterministic).
//!   Removing a leaf can expose its parent as the next leaf, which is how
//!   unreferenced interior runs drain under sustained page pressure.
//!
//! The tree itself is storage-agnostic bookkeeping: [`RadixTree`] never
//! touches K/V bytes or refcounts.  `KvCache::admit_radix` (kvcache.rs) owns
//! the transactional part — mapping matched pages into a slot's page table,
//! copy-on-write of the first divergent partial page, and eviction under
//! reservation pressure — so tree state and pool state can never disagree.

use std::collections::HashSet;

/// Prefix-cache observability counters plus point-in-time gauges, exported
/// through `Metrics` and merged fleet-wide.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RadixStats {
    /// admission-time lookups against the tree
    pub lookups: usize,
    /// lookups that matched at least one token
    pub hits: usize,
    /// total tokens served from cached pages instead of prefill
    pub hit_tokens: usize,
    /// copy-on-write page splits (divergent partial page at admission, or a
    /// write into a still-shared page)
    pub cow_splits: usize,
    /// pages evicted from the tree under page pressure
    pub evicted_pages: usize,
    /// gauge: pages currently held by the tree
    pub shared_pages: usize,
    /// gauge: K/V bytes of the pages currently held by the tree
    pub shared_bytes: usize,
}

#[derive(Debug)]
struct Node {
    /// exactly `page_size` tokens — the chunk this node appends to its
    /// parent's root-path
    chunk: Vec<i32>,
    /// pool page holding this chunk's K/V (the tree owns one reference)
    page: u32,
    children: Vec<u32>,
    /// parent node id; `None` for children of the virtual root
    parent: Option<u32>,
    /// logical-clock timestamp of the last lookup/insert touching this node
    last_use: u64,
}

/// What a lookup matched: whole cached pages plus, when the walk ended at a
/// partial overlap, the divergent child to copy-on-write from.
#[derive(Debug, Clone, Default)]
pub struct RadixMatch {
    /// fully matched pages, in root-path order
    pub pages: Vec<u32>,
    /// `(page, shared_tokens)` of the child sharing the longest strict
    /// prefix (≥ 1, < page_size tokens) with the remaining tokens — the
    /// CoW-split source
    pub partial: Option<(u32, usize)>,
}

impl RadixMatch {
    /// Tokens covered by the full-page matches (partial excluded).
    pub fn full_tokens(&self, page_size: usize) -> usize {
        self.pages.len() * page_size
    }
}

/// Radix tree over token sequences at page granularity (see module docs).
#[derive(Debug)]
pub struct RadixTree {
    page_size: usize,
    /// slab of nodes; freed ids are recycled via `free_ids`
    nodes: Vec<Option<Node>>,
    free_ids: Vec<u32>,
    /// children of the virtual root
    roots: Vec<u32>,
    /// monotone logical clock for LRU ordering
    clock: u64,
    /// cumulative counters (gauges are filled by [`RadixTree::stats`])
    pub counters: RadixStats,
}

impl RadixTree {
    pub fn new(page_size: usize) -> Self {
        RadixTree {
            page_size: page_size.max(1),
            nodes: Vec::new(),
            free_ids: Vec::new(),
            roots: Vec::new(),
            clock: 0,
            counters: RadixStats::default(),
        }
    }

    pub fn page_size(&self) -> usize {
        self.page_size
    }

    /// Live nodes (== pages held by the tree).
    pub fn len(&self) -> usize {
        self.nodes.iter().filter(|n| n.is_some()).count()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Counters plus current gauges (`page_bytes` converts pages to bytes).
    pub fn stats(&self, page_bytes: usize) -> RadixStats {
        let mut s = self.counters;
        s.shared_pages = self.len();
        s.shared_bytes = s.shared_pages * page_bytes;
        s
    }

    fn node(&self, id: u32) -> &Node {
        self.nodes[id as usize].as_ref().expect("live radix node")
    }

    fn node_mut(&mut self, id: u32) -> &mut Node {
        self.nodes[id as usize].as_mut().expect("live radix node")
    }

    fn child_matching(&self, children: &[u32], chunk: &[i32]) -> Option<u32> {
        children.iter().copied().find(|&c| self.node(c).chunk == chunk)
    }

    /// Walk `tokens` (capped at `max_tokens`) matching whole chunks, bumping
    /// the LRU clock along the path; also reports the best partial overlap at
    /// the divergence point.  Read-modify (LRU only) — no structural change.
    pub fn lookup(&mut self, tokens: &[i32], max_tokens: usize) -> RadixMatch {
        self.clock += 1;
        let now = self.clock;
        let ps = self.page_size;
        let limit = tokens.len().min(max_tokens);
        let mut m = RadixMatch::default();
        let mut children: Vec<u32> = self.roots.clone();
        let mut consumed = 0usize;
        while consumed + ps <= limit {
            let Some(id) = self.child_matching(&children, &tokens[consumed..consumed + ps])
            else {
                break;
            };
            self.node_mut(id).last_use = now;
            m.pages.push(self.node(id).page);
            consumed += ps;
            children = self.node(id).children.clone();
        }
        // divergence point: the child sharing the longest strict token prefix
        // with what remains is the copy-on-write source
        let remain = limit - consumed;
        if remain > 0 {
            let mut best: Option<(u32, usize)> = None;
            for &c in &children {
                let chunk = &self.node(c).chunk;
                let shared = chunk
                    .iter()
                    .zip(&tokens[consumed..limit])
                    .take_while(|(a, b)| a == b)
                    .count();
                if shared > 0 && best.map_or(true, |(_, k)| shared > k) {
                    best = Some((c, shared));
                }
            }
            if let Some((id, shared)) = best {
                self.node_mut(id).last_use = now;
                m.partial = Some((self.node(id).page, shared.min(remain)));
            }
        }
        m
    }

    /// Read-only variant of [`RadixTree::lookup`]: full-page matches only, no
    /// LRU bump, no counters — the admission pre-check peek.
    pub fn peek(&self, tokens: &[i32], max_tokens: usize) -> Vec<u32> {
        let ps = self.page_size;
        let limit = tokens.len().min(max_tokens);
        let mut pages = Vec::new();
        let mut children: &[u32] = &self.roots;
        let mut consumed = 0usize;
        while consumed + ps <= limit {
            let Some(id) = self.child_matching(children, &tokens[consumed..consumed + ps])
            else {
                break;
            };
            pages.push(self.node(id).page);
            consumed += ps;
            children = &self.node(id).children;
        }
        pages
    }

    /// Insert the full-page chunks of `tokens`, adopting `pages[i]` for every
    /// chunk not already cached.  Returns the pages the tree ADOPTED (the
    /// caller must add one pool reference to each); chunks that already have
    /// a node are skipped — first writer wins, contents are identical by the
    /// root-path invariant.
    pub fn insert(&mut self, tokens: &[i32], pages: &[u32]) -> Vec<u32> {
        self.clock += 1;
        let now = self.clock;
        let ps = self.page_size;
        let n_full = (tokens.len() / ps).min(pages.len());
        let mut adopted = Vec::new();
        let mut parent: Option<u32> = None;
        for i in 0..n_full {
            let chunk = &tokens[i * ps..(i + 1) * ps];
            let siblings: Vec<u32> = match parent {
                None => self.roots.clone(),
                Some(p) => self.node(p).children.clone(),
            };
            if let Some(id) = self.child_matching(&siblings, chunk) {
                self.node_mut(id).last_use = now;
                parent = Some(id);
                continue;
            }
            let id = self.alloc_node(Node {
                chunk: chunk.to_vec(),
                page: pages[i],
                children: Vec::new(),
                parent,
                last_use: now,
            });
            match parent {
                None => self.roots.push(id),
                Some(p) => self.node_mut(p).children.push(id),
            }
            adopted.push(pages[i]);
            parent = Some(id);
        }
        adopted
    }

    fn alloc_node(&mut self, n: Node) -> u32 {
        if let Some(id) = self.free_ids.pop() {
            self.nodes[id as usize] = Some(n);
            id
        } else {
            self.nodes.push(Some(n));
            (self.nodes.len() - 1) as u32
        }
    }

    fn remove_node(&mut self, id: u32) -> u32 {
        let node = self.nodes[id as usize].take().expect("evicting a live node");
        match node.parent {
            None => self.roots.retain(|&c| c != id),
            Some(p) => self.node_mut(p).children.retain(|&c| c != id),
        }
        self.free_ids.push(id);
        node.page
    }

    /// Evict up to `want` pages, leaf-first in LRU order (ties break on the
    /// lower node id, keeping eviction deterministic).  A leaf is only taken
    /// when `evictable(page)` holds (the cache passes `refcount == 1`: only
    /// the tree remembers it) and its page is not in `exclude` (pages just
    /// matched for the admission in progress).  Returns the evicted pages;
    /// the caller drops the tree's pool reference on each.
    pub fn evict_lru(
        &mut self,
        want: usize,
        exclude: &HashSet<u32>,
        mut evictable: impl FnMut(u32) -> bool,
    ) -> Vec<u32> {
        let mut out = Vec::new();
        while out.len() < want {
            let mut best: Option<(u64, u32)> = None;
            for (idx, slot) in self.nodes.iter().enumerate() {
                let Some(n) = slot else { continue };
                if !n.children.is_empty() || exclude.contains(&n.page) || !evictable(n.page) {
                    continue;
                }
                let key = (n.last_use, idx as u32);
                if best.map_or(true, |b| key < b) {
                    best = Some(key);
                }
            }
            let Some((_, id)) = best else { break };
            out.push(self.remove_node(id));
        }
        self.counters.evicted_pages += out.len();
        out
    }

    /// Pages that sustained eviction could free for an admission that has
    /// `exclude` matched: nodes whose ENTIRE subtree is evictable (every
    /// descendant passes `evictable` and none is excluded) — exactly what
    /// cascading leaf-first eviction can reach.
    pub fn evictable_pages(
        &self,
        exclude: &HashSet<u32>,
        mut evictable: impl FnMut(u32) -> bool,
    ) -> usize {
        // post-order over every root: a node counts iff all children count
        // and its own page is evictable
        fn walk(
            tree: &RadixTree,
            id: u32,
            exclude: &HashSet<u32>,
            evictable: &mut dyn FnMut(u32) -> bool,
            count: &mut usize,
        ) -> bool {
            let node = tree.node(id);
            let mut all = true;
            for &c in &node.children {
                all &= walk(tree, c, exclude, evictable, count);
            }
            let ok = all && !exclude.contains(&node.page) && evictable(node.page);
            if ok {
                *count += 1;
            }
            ok
        }
        let mut count = 0;
        for &r in &self.roots {
            walk(self, r, exclude, &mut evictable, &mut count);
        }
        count
    }

    /// Drop every node and return all held pages (the caller releases the
    /// tree's pool reference on each) — post-mortem accounting and tests.
    pub fn flush(&mut self) -> Vec<u32> {
        let pages =
            self.nodes.iter().flatten().map(|n| n.page).collect::<Vec<_>>();
        self.nodes.clear();
        self.free_ids.clear();
        self.roots.clear();
        pages
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(n: usize, base: i32) -> Vec<i32> {
        (0..n as i32).map(|i| base + i).collect()
    }

    #[test]
    fn insert_then_lookup_matches_full_pages_only() {
        let mut t = RadixTree::new(4);
        let seq = toks(10, 100); // 2 full chunks + 2 spare tokens
        let adopted = t.insert(&seq, &[7, 8]);
        assert_eq!(adopted, vec![7, 8], "both chunks are new");
        assert_eq!(t.len(), 2);

        let m = t.lookup(&seq, seq.len());
        assert_eq!(m.pages, vec![7, 8]);
        assert_eq!(m.full_tokens(4), 8);
        assert!(m.partial.is_none(), "no cached child past the matched path");

        // a cap below a chunk boundary stops the match early
        let m = t.lookup(&seq, 7);
        assert_eq!(m.pages, vec![7], "second chunk needs 8 tokens, cap is 7");
    }

    #[test]
    fn shared_prefixes_share_nodes_and_diverge_with_partials() {
        let mut t = RadixTree::new(4);
        let a = vec![1, 2, 3, 4, 5, 6, 7, 8];
        let b = vec![1, 2, 3, 4, 5, 6, 9, 9]; // diverges inside chunk 2
        assert_eq!(t.insert(&a, &[0, 1]).len(), 2);
        let adopted = t.insert(&b, &[2, 3]);
        assert_eq!(adopted, vec![3], "shared first chunk is reused, not re-adopted");
        assert_eq!(t.len(), 3);

        // c shares chunk 1 fully and 2 leading tokens of a's chunk 2
        let c = vec![1, 2, 3, 4, 5, 6, 0, 0];
        let m = t.lookup(&c, c.len());
        assert_eq!(m.pages, vec![0]);
        let (page, shared) = m.partial.expect("divergent child reported for CoW");
        assert_eq!(shared, 2);
        assert!(page == 1 || page == 3, "either divergent sibling is a valid CoW source");
    }

    #[test]
    fn eviction_is_leaf_first_lru_and_respects_the_guard() {
        let mut t = RadixTree::new(2);
        t.insert(&[1, 2, 3, 4], &[0, 1]); // path 0 -> 1
        t.insert(&[5, 6], &[2]); // sibling leaf
        t.lookup(&[5, 6], 2); // bump page 2: now the LRU leaf is page 1

        // page 1 is pinned (still referenced): eviction must skip it and the
        // interior page 0 is unreachable while its child lives
        let none = t.evict_lru(2, &HashSet::new(), |p| p != 1);
        assert_eq!(none, vec![2], "only the unpinned leaf can go");

        // unpinned: leaf 1 goes first, THEN its parent becomes a leaf
        let rest = t.evict_lru(2, &HashSet::new(), |_| true);
        assert_eq!(rest, vec![1, 0], "leaf-first cascade reaches the interior node");
        assert!(t.is_empty());
        assert_eq!(t.counters.evicted_pages, 3);
    }

    #[test]
    fn exclusion_protects_the_admission_in_flight() {
        let mut t = RadixTree::new(2);
        t.insert(&[1, 2], &[0]);
        t.insert(&[3, 4], &[1]);
        let exclude: HashSet<u32> = [0].into_iter().collect();
        let got = t.evict_lru(2, &exclude, |_| true);
        assert_eq!(got, vec![1], "the matched page is untouchable this admission");
    }

    #[test]
    fn evictable_pages_counts_whole_free_subtrees_only() {
        let mut t = RadixTree::new(2);
        t.insert(&[1, 2, 3, 4], &[0, 1]); // 0 interior, 1 leaf
        t.insert(&[5, 6], &[2]);
        // leaf 1 pinned: its parent 0 cannot drain either, only 2 can
        assert_eq!(t.evictable_pages(&HashSet::new(), |p| p != 1), 1);
        assert_eq!(t.evictable_pages(&HashSet::new(), |_| true), 3);
        let exclude: HashSet<u32> = [2].into_iter().collect();
        assert_eq!(t.evictable_pages(&exclude, |_| true), 2);
    }

    #[test]
    fn flush_returns_every_held_page() {
        let mut t = RadixTree::new(2);
        t.insert(&[1, 2, 3, 4], &[5, 6]);
        let mut pages = t.flush();
        pages.sort_unstable();
        assert_eq!(pages, vec![5, 6]);
        assert!(t.is_empty());
        // reusable after a flush
        assert_eq!(t.insert(&[9, 9], &[3]), vec![3]);
    }

    #[test]
    fn peek_is_pure() {
        let mut t = RadixTree::new(2);
        t.insert(&[1, 2, 3, 4], &[0, 1]);
        let clock_before = t.clock;
        assert_eq!(t.peek(&[1, 2, 3, 4], 4), vec![0, 1]);
        assert_eq!(t.peek(&[1, 2, 3, 4], 3), vec![0], "cap respected");
        assert_eq!(t.clock, clock_before, "peek must not disturb LRU order");
    }
}
