//! Continuous-batching serving engine with slot-based KV admission.
//!
//! The run-to-completion scheduler executes one batch end to end: a new
//! request waits for the whole previous decode loop, and short requests are
//! held hostage by the longest `max_new` in their batch.  PrefixQuant makes
//! continuous batching unusually cheap: the prefixed-outlier K/V entries are
//! computed once and are identical across sequences, so admitting a sequence
//! mid-flight is just a prefill plus a copy into its cache slot — the shared
//! prefix rows are already resident in every slot.
//!
//! Since the paged KV cache landed, the prefix is not even copied per slot:
//! it lives in refcounted pages mapped into every slot's page table, and
//! admission is a page-availability check, so long-tail sequences stop
//! pinning dense worst-case capacity (see `coordinator::kvcache`).
//!
//! Pieces:
//! - [`backend`]: the [`backend::DecodeBackend`] trait (prefill a set of
//!   slots, decode a same-length group), [`backend::ModelBackend`] over the
//!   real executables (with the dense gather/scatter shim for the paged
//!   layout), and [`backend::run_to_completion`] — the baseline policy,
//!   generic over the backend so parity can be asserted.
//! - [`engine`]: [`engine::ContinuousEngine`], the persistent decode loop
//!   that owns the slot table, admits pending requests into free slots
//!   between decode rounds, retires finished slots immediately, and streams
//!   tokens per request as they are produced.
//! - [`sim`]: a deterministic artifact-free backend whose next token is a
//!   hash of the stored cache contents, turning stream parity into a cache
//!   lifecycle correctness check (used by tests and the throughput bench).

pub mod backend;
pub mod engine;
pub mod sim;

pub use backend::{
    run_to_completion, DecodeBackend, DecodeGroup, DecodeOut, ModelBackend, PrefillJob, PrefillOut,
};
pub use engine::{ContinuousEngine, EngineStats, RetryReq, SlotPhase};
pub use sim::SimBackend;
