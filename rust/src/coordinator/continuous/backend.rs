//! Backend abstraction for the serving engines.
//!
//! Both the run-to-completion baseline and the continuous-batching engine
//! drive generation through [`DecodeBackend`]: prefill a set of slots (mixed
//! prompt lengths allowed — rows attend only within themselves), then decode
//! per length-group.  [`ModelBackend`] implements it over the real AOT
//! executables; `sim::SimBackend` implements it host-side so scheduling
//! logic, cache lifecycle, and parity can be tested without artifacts.
//!
//! The decode executable takes ONE shared `cache_len`, so a decode call
//! serves the group of rows currently at that length.  The graph writes K/V
//! at position `cache_len` of EVERY row; [`crate::coordinator::KvCache`]
//! `append_rows` copies back only the rows that own that position, which is
//! what makes mixed-length slots safe on a fixed-geometry executable.
//!
//! The executables only understand dense `[L, B, H, Smax, dh]` buffers, so
//! when the cache is PAGED, [`ModelBackend`] runs a gather/scatter shim at
//! this boundary: `KvCache::gather_dense` materializes an incrementally
//! mirrored dense view for the decode group (only positions written since the
//! row's last gather are copied), and `KvCache::append_rows` scatters back
//! just the newly written position.  The simulation backend needs no shim —
//! it reads pages directly through `KvCache::k_at`/`v_at`.

use std::collections::BTreeMap;
use std::ops::Deref;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::coordinator::kvcache::{KvCache, KvLayout};
use crate::coordinator::request::{FinishReason, GenRequest, GenResponse};
use crate::model::{Model, QuantMode};
use crate::runtime::Value;
use crate::tensor::IntTensor;

/// Greedy sampling: index of the largest logit.
pub fn argmax(row: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = i;
        }
    }
    best as i32
}

/// One prefill assignment: request → cache slot, over a token span.
///
/// The row's full token sequence is `BOS + prompt + resumed` (`resumed`
/// holds tokens generated before a preemption, so re-admission reconstructs
/// the exact cache state without recomputing the shared prefix).  `start..
/// end` selects the span written by THIS call — chunked prefill issues one
/// contiguous span per engine step so a long prompt cannot stall decode
/// rounds for its whole length.  Only the call whose `end` reaches
/// [`PrefillJob::total_tokens`] yields a first token.
pub struct PrefillJob<'a> {
    pub slot: usize,
    pub req: &'a GenRequest,
    /// tokens generated before a preemption, re-prefilled after the prompt
    pub resumed: &'a [i32],
    /// first token position (of the full sequence) written by this call
    pub start: usize,
    /// one past the last token position written by this call
    pub end: usize,
}

impl<'a> PrefillJob<'a> {
    /// Whole-sequence job for `req` in `slot` (no chunking, no resume).
    pub fn full(slot: usize, req: &'a GenRequest) -> Self {
        PrefillJob { slot, req, resumed: &[], start: 0, end: req.prompt.len() + 1 }
    }

    /// Tokens in the row's full sequence: BOS + prompt + resumed.
    pub fn total_tokens(&self) -> usize {
        1 + self.req.prompt.len() + self.resumed.len()
    }
}

/// Prefill result for one slot.
#[derive(Debug, Clone)]
pub struct PrefillOut {
    pub slot: usize,
    /// greedy token at the last prompt position; `None` while the job's span
    /// has not yet reached the end of the sequence (chunked prefill)
    pub first_token: Option<i32>,
    /// materialized sinks (prefix + in-prompt) for the decode path; only
    /// meaningful when `first_token` is `Some`
    pub n_sinks: i32,
}

/// A decode step for all rows currently at the same cache length.
#[derive(Debug, Clone)]
pub struct DecodeGroup {
    /// shared cache length of the group's rows
    pub len: usize,
    pub rows: Vec<usize>,
    /// last generated token per row (aligned with `rows`)
    pub tokens: Vec<i32>,
    /// materialized sink count per row (aligned with `rows`)
    pub n_sinks: Vec<i32>,
    /// per-row sampling seed (aligned with `rows`), from `GenRequest::seed`.
    /// Greedy backends ignore it; the sim backend mixes it into its token
    /// hash so seeded streams are distinguishable yet fully deterministic —
    /// the property oplog replay relies on (seed 0 leaves the hash untouched)
    pub seeds: Vec<u64>,
}

#[derive(Debug, Clone)]
pub struct DecodeOut {
    pub row: usize,
    pub next_token: i32,
    pub n_sinks: i32,
}

/// What an engine needs from a model to serve generation requests.
pub trait DecodeBackend {
    /// Batch rows (= cache slots) of the fixed-geometry executables.
    fn batch_slots(&self) -> usize;
    /// Longest tokenized prompt incl. BOS the prefill pass accepts.
    fn max_prompt_tokens(&self) -> usize;
    /// Positions per cache row (incl. prefix).
    fn cache_capacity(&self) -> usize;
    /// BOS token prepended to every row (the engine reconstructs each row's
    /// own-region token sequence — BOS + prompt + generated — to key the
    /// radix prefix cache).  Defaults to 1, the convention every current
    /// backend follows.
    fn bos(&self) -> i32 {
        1
    }
    /// Fresh cache with the shared prefixed K/V installed in every row.
    fn new_cache(&self) -> Result<KvCache>;
    /// Prefill `jobs` (mixed prompt lengths and mixed spans allowed) in one
    /// pass: write each job's token span into its slot, and return the first
    /// greedy token for every job whose span completes its sequence.
    fn prefill(&self, kv: &mut KvCache, jobs: &[PrefillJob]) -> Result<Vec<PrefillOut>>;
    /// One decode step for a same-length group of rows.
    fn decode(&self, kv: &mut KvCache, group: &DecodeGroup) -> Result<Vec<DecodeOut>>;
}

/// [`DecodeBackend`] over the real model executables (prefill runs the
/// mode-selected forward; decode always runs the static executable, as in the
/// original scheduler).
///
/// Generic over how the model is held: `&Model` for the borrowing callers
/// (run-to-completion batch path, tests) and `Rc<Model>` for the serving
/// worker, whose engine must outlive any one borrow so the worker loop can
/// swap models on reload without a self-referential struct.
pub struct ModelBackend<M: Deref<Target = Model>> {
    pub model: M,
    pub mode: QuantMode,
    pub bos: i32,
    pub pad: i32,
    b_exec: usize,
    s_exec: usize,
    kv_layout: KvLayout,
}

impl<M: Deref<Target = Model>> ModelBackend<M> {
    /// Dense-layout backend (the run-to-completion baseline keeps this; the
    /// serving path selects paged via [`ModelBackend::with_kv_layout`]).
    pub fn new(model: M, mode: QuantMode, bos: i32, pad: i32) -> Result<Self> {
        let (b_exec, s_exec) = model.fwd_geom()?;
        Ok(Self { model, mode, bos, pad, b_exec, s_exec, kv_layout: KvLayout::Dense })
    }

    pub fn with_kv_layout(mut self, layout: KvLayout) -> Self {
        self.kv_layout = layout;
        self
    }
}

impl<M: Deref<Target = Model>> DecodeBackend for ModelBackend<M> {
    fn batch_slots(&self) -> usize {
        self.b_exec
    }

    fn max_prompt_tokens(&self) -> usize {
        self.s_exec
    }

    fn cache_capacity(&self) -> usize {
        self.model.cfg.cache_max
    }

    fn bos(&self) -> i32 {
        self.bos
    }

    fn new_cache(&self) -> Result<KvCache> {
        let mut kv = KvCache::with_layout(&self.model.cfg, self.b_exec, self.kv_layout);
        kv.install_prefix(&self.model.prefix)?;
        Ok(kv)
    }

    fn prefill(&self, kv: &mut KvCache, jobs: &[PrefillJob]) -> Result<Vec<PrefillOut>> {
        if jobs.is_empty() {
            return Ok(Vec::new());
        }
        if jobs.len() > self.b_exec {
            bail!("prefill wave {} exceeds executable batch {}", jobs.len(), self.b_exec);
        }
        for j in jobs {
            let total = j.total_tokens();
            if total > self.s_exec {
                bail!("prompt length {total} exceeds executable seq {}", self.s_exec);
            }
            if kv.n_prefix + total > kv.s_max {
                bail!("prompt length {total} exceeds cache capacity {}", kv.s_max);
            }
            if j.start >= j.end || j.end > total {
                bail!("invalid prefill span [{}, {}) of {total} tokens", j.start, j.end);
            }
        }
        // [B, S] token batch: each row BOS + prompt (+ resumed tokens when
        // re-admitting a preempted request) + pad; spare rows replicate the
        // last job (rows attend only within themselves, so filler rows cannot
        // perturb real rows).  The fixed-geometry forward has no partial
        // variant, so a chunked job re-runs the whole row and commits only
        // its span — chunking bounds the per-step K/V WRITE and the decode
        // stall, not the FLOPs (causal attention makes positions [0, end)
        // independent of later tokens, so every chunk's K/V is final).
        let mut data = Vec::with_capacity(self.b_exec * self.s_exec);
        for row in 0..self.b_exec {
            let j = &jobs[row.min(jobs.len() - 1)];
            data.push(self.bos);
            data.extend_from_slice(&j.req.prompt);
            data.extend_from_slice(j.resumed);
            data.resize((row + 1) * self.s_exec, self.pad);
        }
        let tokens = IntTensor::new(vec![self.b_exec, self.s_exec], data)?;
        let sig = self.model.exec(self.mode.fwd_exec())?;
        let outs = self.model.forward(self.mode, &tokens)?;
        let logits = outs[sig.output_index("logits")?].clone().f32()?;
        let k_cache = outs[sig.output_index("k_cache")?].clone().f32()?;
        let v_cache = outs[sig.output_index("v_cache")?].clone().f32()?;
        let active = outs[sig.output_index("active")?].clone().f32()?;

        let v_dim = logits.shape[2];
        let mut results = Vec::with_capacity(jobs.len());
        for (i, j) in jobs.iter().enumerate() {
            let total = j.total_tokens();
            kv.write_prefill_span(j.slot, &k_cache, &v_cache, i, j.start, j.end)?;
            if j.end < total {
                results.push(PrefillOut { slot: j.slot, first_token: None, n_sinks: 0 });
                continue;
            }
            let off = (i * self.s_exec + total - 1) * v_dim;
            let first_token = argmax(&logits.data[off..off + v_dim]);
            let in_prompt: f32 =
                active.data[i * self.s_exec..i * self.s_exec + total].iter().sum();
            results.push(PrefillOut {
                slot: j.slot,
                first_token: Some(first_token),
                n_sinks: self.model.prefix.n_ctx_sinks + in_prompt as i32,
            });
        }
        Ok(results)
    }

    fn decode(&self, kv: &mut KvCache, group: &DecodeGroup) -> Result<Vec<DecodeOut>> {
        if group.rows.is_empty() {
            return Ok(Vec::new());
        }
        let b = kv.batch;
        let mut toks = vec![self.pad; b];
        let mut sinks = vec![0i32; b];
        for (i, &row) in group.rows.iter().enumerate() {
            toks[row] = group.tokens[i];
            sinks[row] = group.n_sinks[i];
        }
        let dsig = self.model.exec("decode_static")?;
        let toks_t = IntTensor::new(vec![b, 1], toks)?;
        let cache_len = IntTensor::scalar(group.len as i32);
        let sinks_t = IntTensor::new(vec![b], sinks)?;
        let outs = {
            // gather: dense layout hands over its storage; paged layout
            // materializes the incrementally-mirrored dense view for the
            // group's rows (only newly written positions are copied)
            let (kt, vt) = kv.gather_dense(&group.rows)?;
            let inputs = self.model.bind(
                &dsig,
                &[
                    ("tokens", Value::I32(&toks_t)),
                    ("cache_len", Value::I32(&cache_len)),
                    ("n_sinks", Value::I32(&sinks_t)),
                    ("k_cache", Value::F32(kt)),
                    ("v_cache", Value::F32(vt)),
                ],
            )?;
            self.model.engine.run(&dsig, &inputs)?
        };
        let logits = outs[dsig.output_index("logits")?].clone().f32()?;
        let new_k = outs[dsig.output_index("k_cache")?].clone().f32()?;
        let new_v = outs[dsig.output_index("v_cache")?].clone().f32()?;
        let new_sinks = outs[dsig.output_index("n_sinks")?].clone().i32()?;
        if !kv.is_paged() && group.rows.len() == b {
            // whole dense batch advanced together: adopt the output wholesale
            kv.adopt(new_k, new_v)?;
        } else {
            // scatter back only the newly written position of the group rows
            kv.append_rows(&new_k, &new_v, &group.rows, group.len)?;
        }
        let v_dim = logits.data.len() / b;
        Ok(group
            .rows
            .iter()
            .map(|&row| {
                let off = row * v_dim;
                DecodeOut {
                    row,
                    next_token: argmax(&logits.data[off..off + v_dim]),
                    n_sinks: new_sinks.data[row],
                }
            })
            .collect())
    }
}

/// Run a wave of requests to completion (the baseline scheduling policy):
/// prefill everything at once, decode until every row has its tokens, no
/// mid-flight admission.  Mixed prompt lengths and mixed `max_new` are
/// handled via per-length-group decode calls; a row stops as soon as it has
/// `max_new` tokens (identical streams to decoding longer and truncating),
/// emits a stop token (`FinishReason::Stop`, token included), or fills its
/// cache row (`FinishReason::CacheFull`).
pub fn run_to_completion<B: DecodeBackend>(
    be: &B,
    reqs: &[GenRequest],
) -> Result<Vec<GenResponse>> {
    if reqs.is_empty() {
        return Ok(Vec::new());
    }
    if reqs.len() > be.batch_slots() {
        bail!("batch {} exceeds executable batch {}", reqs.len(), be.batch_slots());
    }
    let t0 = Instant::now();
    let mut kv = be.new_cache()?;
    let jobs: Vec<PrefillJob> =
        reqs.iter().enumerate().map(|(i, req)| PrefillJob::full(i, req)).collect();
    let pre = be.prefill(&mut kv, &jobs)?;
    let ttft = t0.elapsed().as_secs_f64();

    let n = reqs.len();
    let mut tokens: Vec<Vec<i32>> = vec![Vec::new(); n];
    let mut next = vec![0i32; n];
    let mut sinks = vec![0i32; n];
    let mut done = vec![false; n];
    let mut finish = vec![FinishReason::Length; n];
    let mut total = vec![ttft; n];
    for o in pre {
        let Some(first) = o.first_token else {
            bail!("full prefill returned no first token for slot {}", o.slot);
        };
        next[o.slot] = first;
        sinks[o.slot] = o.n_sinks;
        if reqs[o.slot].max_new > 0 {
            tokens[o.slot].push(first);
            if reqs[o.slot].stop_tokens.contains(&first) {
                done[o.slot] = true;
                finish[o.slot] = FinishReason::Stop;
            }
        }
    }
    for i in 0..n {
        if tokens[i].len() >= reqs[i].max_new {
            done[i] = true;
        }
    }

    loop {
        let mut groups: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        let now = t0.elapsed().as_secs_f64();
        for i in 0..n {
            if done[i] {
                continue;
            }
            let len = kv.row_len(i);
            if len >= kv.s_max {
                done[i] = true; // cache full: stop with what we have
                finish[i] = FinishReason::CacheFull;
                total[i] = now;
                continue;
            }
            groups.entry(len).or_default().push(i);
        }
        if groups.is_empty() {
            break;
        }
        for (len, rows) in groups {
            let group = DecodeGroup {
                len,
                tokens: rows.iter().map(|&r| next[r]).collect(),
                n_sinks: rows.iter().map(|&r| sinks[r]).collect(),
                seeds: rows.iter().map(|&r| reqs[r].seed).collect(),
                rows,
            };
            for o in be.decode(&mut kv, &group)? {
                next[o.row] = o.next_token;
                sinks[o.row] = o.n_sinks;
                tokens[o.row].push(o.next_token);
                if reqs[o.row].stop_tokens.contains(&o.next_token) {
                    done[o.row] = true;
                    finish[o.row] = FinishReason::Stop;
                    total[o.row] = t0.elapsed().as_secs_f64();
                } else if tokens[o.row].len() >= reqs[o.row].max_new {
                    done[o.row] = true;
                    total[o.row] = t0.elapsed().as_secs_f64();
                }
            }
        }
    }

    Ok(reqs
        .iter()
        .enumerate()
        .map(|(i, r)| {
            let mut toks = std::mem::take(&mut tokens[i]);
            toks.truncate(r.max_new);
            GenResponse {
                id: r.id,
                tokens: toks,
                ttft_s: ttft,
                total_s: total[i].max(ttft),
                queue_s: 0.0,
                finish: finish[i],
            }
        })
        .collect())
}
