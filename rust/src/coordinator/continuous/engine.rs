//! Continuous-batching engine: a persistent decode loop over a slot table,
//! with scheduling decisions delegated to a pluggable
//! [`SchedulePolicy`](crate::coordinator::policy::SchedulePolicy).
//!
//! Slot state machine (see rust/DESIGN.md):
//!
//!   Empty ──admit (reserve + first chunk)──▶ Prefilling ──last chunk──▶ Decoding
//!     ▲                                          │                        │
//!     │                                  cancel  │     budget / stop /    │
//!     │                                          ▼     cache full /       │
//!     └───────── reset_slot (pages released) ◀── Done ◀── cancel ─────────┘
//!                                                ▲
//!                 Decoding ──preempt (pages released, requeue with
//!                 generated tokens)──▶ pending ──resume (re-prefill
//!                 prompt + generated)──▶ Prefilling
//!
//! Between decode rounds the engine admits pending requests into free slots.
//! WHICH request is admitted next, WHETHER a Decoding slot is preempted to
//! make room, and HOW MANY prompt tokens one step may prefill are all policy
//! decisions; the engine owns the mechanism.  One prefill pass serves a
//! whole admission wave (mixed prompt lengths are fine — rows attend only
//! within themselves), and the shared prefixed K/V is already resident in
//! every slot, so admission never recomputes it (the paper's invariant is
//! what makes mid-flight admission — and cheap preemption resume — work:
//! the outlier prefix survives slot churn untouched).
//!
//! Preemption resume re-prefills `BOS + prompt + generated tokens`; causal
//! attention makes the reconstructed cache identical to the evicted one, so
//! the resumed stream continues exactly where it stopped (asserted by the
//! scheduler_policy test suite on the simulation backend).
//!
//! On a paged cache, admission is additionally a PAGE-availability check:
//! each admitted request reserves its worst-case page count (prompt + budget,
//! capped by row capacity) so mid-flight appends can never fail, a request
//! that doesn't fit the free pool WAITS in the policy's order (it is not
//! skipped), and retirement/preemption releases the slot's pages in O(pages)
//! with no memset.

use std::collections::{BTreeMap, HashSet, VecDeque};
use std::sync::mpsc::{channel, Receiver};
use std::time::Instant;

use anyhow::{bail, Result};

use crate::coordinator::kvcache::KvCache;
use crate::coordinator::policy::{Fcfs, QueueView, SchedulePolicy, SlotView};
use crate::coordinator::request::{
    ClassMetrics, DrainReport, FinishReason, GenRequest, GenResponse, Metrics, Priority,
    ProbeState, Reply, StreamEvent, WorkerPostMortem, WorkerProbe,
};

use super::backend::{DecodeBackend, DecodeGroup, PrefillJob};

/// Observable lifecycle phase of a slot.  `Prefilling` is observable only
/// under a chunking policy (an unchunked admission completes its prefill
/// inside the same `step()`); `Done` names the terminal state of the machine
/// in rust/DESIGN.md — a finished slot is retired to Empty within the same
/// call, so [`ContinuousEngine::phases`] never reports it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SlotPhase {
    Empty,
    Prefilling,
    Decoding,
    Done,
}

/// A queued request with its reply channel and scheduling bookkeeping.
struct PendingReq {
    req: GenRequest,
    reply: Reply,
    submitted: Instant,
    /// tokens generated before a preemption (re-prefilled on resume)
    generated: Vec<i32>,
    /// TTFT recorded at the first emitted token (survives preemption)
    ttft_s: Option<f64>,
    /// queue wait recorded at the first admission (survives preemption)
    queue_s: Option<f64>,
    /// engine-rebuild resubmissions so far
    attempts: usize,
    /// preemptions suffered so far (the policy's thrash guard reads this)
    times_preempted: usize,
    /// arrival order, monotone across the engine's lifetime
    seq: u64,
    /// engine round at which the request (re)entered the queue
    enqueued_round: u64,
}

struct Active {
    req: GenRequest,
    /// ALL generated tokens, including those produced before a preemption
    tokens: Vec<i32>,
    next_token: i32,
    n_sinks: i32,
    reply: Reply,
    submitted: Instant,
    queue_s: f64,
    /// set when the first token was emitted (possibly a previous occupancy)
    ttft: Option<f64>,
    attempts: usize,
    times_preempted: usize,
    seq: u64,
    admitted_round: u64,
    /// tokens of (BOS + prompt + resumed) written so far (chunked prefill)
    prefill_written: usize,
    prefill_total: usize,
    finish: Option<FinishReason>,
}

impl Active {
    fn decoding(&self) -> bool {
        self.prefill_written >= self.prefill_total
    }
}

/// A request handed back by [`ContinuousEngine::drain_for_recovery`] for
/// resubmission into a rebuilt engine.
pub struct RetryReq {
    pub req: GenRequest,
    pub reply: Reply,
    pub submitted: Instant,
    /// resubmissions so far (incremented by the drain)
    pub attempts: usize,
    /// queue wait recorded at the first admission, when the request had
    /// already been admitted before the failure — preserved so re-admission
    /// does not double-count it in `admitted`/`sum_queue_s`
    pub queue_s: Option<f64>,
    /// tokens generated before a preemption (a preempted request drained
    /// from the QUEUE resumes in the fresh engine exactly like a normal
    /// preemption resume — re-prefill does not depend on the dead cache)
    pub generated: Vec<i32>,
    /// TTFT recorded at the first emitted token, preserved across rebuilds
    pub ttft_s: Option<f64>,
}

/// Counters the engine accumulates while serving.
#[derive(Debug, Clone, Default)]
pub struct EngineStats {
    /// first admissions (a preemption resume is counted in `resumed`, not here)
    pub admitted: usize,
    pub completed: usize,
    /// requests dropped at admission (prompt too long for the geometry, or a
    /// shape the page pool could never hold)
    pub rejected: usize,
    /// requests that waited in the queue for free pages (each throttled
    /// request counts once, however many rounds it waited)
    pub deferred_admissions: usize,
    /// most slots simultaneously occupied (admission capacity actually used)
    pub peak_active_slots: usize,
    pub prefill_calls: usize,
    /// decode executions (one per length-group per round)
    pub decode_calls: usize,
    /// decode rounds (one per step with any active slot)
    pub decode_rounds: usize,
    /// requests admitted while at least one other slot was mid-decode
    pub mid_decode_admissions: usize,
    /// Decoding slots evicted for a higher class (pages released, requeued)
    pub preemptions: usize,
    /// re-admissions: preemption resumes (re-prefill of prompt + generated)
    /// and rebuild retries of previously-admitted requests
    pub resumed: usize,
    /// requests cancelled via [`ContinuousEngine::cancel`]
    pub cancelled: usize,
    /// token-less requests resubmitted after an engine rebuild
    pub retries: usize,
    /// model-level reloads by the server on this engine's lineage (stats are
    /// carried across rebuilds, so the counter survives the engine swap)
    pub model_reloads: usize,
    pub generated_tokens: usize,
    pub prefill_tokens: usize,
    pub sum_ttft_s: f64,
    pub sum_queue_s: f64,
    pub sum_total_s: f64,
    /// non-cancelled terminals delivered after the request's deadline budget
    pub deadline_misses: usize,
    /// per admission wave: the longest submit→dispatch wait in the wave
    pub sum_dispatch_skew_s: f64,
    pub t_prefill_s: f64,
    pub t_decode_s: f64,
    /// per-priority-class counters (index = `Priority::index()`)
    pub per_class: [ClassMetrics; Priority::COUNT],
}

impl EngineStats {
    /// Server-facing snapshot of the accumulated counters.  Live-engine
    /// fields (`active_slots`, KV byte gauges) are zero here — only
    /// [`ContinuousEngine::metrics`] can fill them; this is the single
    /// mapping both it and the server's no-engine paths share.
    pub fn to_metrics(&self) -> Metrics {
        Metrics {
            requests: self.admitted,
            batches: self.prefill_calls,
            generated_tokens: self.generated_tokens,
            prefill_tokens: self.prefill_tokens,
            sum_ttft_s: self.sum_ttft_s,
            sum_queue_s: self.sum_queue_s,
            sum_prefill_s: self.t_prefill_s,
            sum_decode_s: self.t_decode_s,
            sum_busy_s: self.t_prefill_s + self.t_decode_s,
            sum_dispatch_skew_s: self.sum_dispatch_skew_s,
            active_slots: 0,
            kv_resident_bytes: 0,
            kv_used_bytes: 0,
            deferred_admissions: self.deferred_admissions,
            preemptions: self.preemptions,
            cancelled: self.cancelled,
            retries: self.retries,
            model_reloads: self.model_reloads,
            radix_lookups: 0,
            radix_hits: 0,
            radix_hit_tokens: 0,
            radix_cow_splits: 0,
            radix_evicted_pages: 0,
            radix_shared_pages: 0,
            radix_shared_bytes: 0,
            deadline_misses: self.deadline_misses,
            by_class: self.per_class,
        }
    }
}

/// Backend prefill contract check, shared by the admission wave and the
/// chunk-continuation path so the two can never drift: every expected slot
/// has an output, and a span that completes its sequence carries a first
/// token.  `spans` yields `(slot, end, total)`.
fn prefill_covers(
    first: &BTreeMap<usize, (Option<i32>, i32)>,
    spans: impl IntoIterator<Item = (usize, usize, usize)>,
) -> bool {
    spans.into_iter().all(|(slot, end, total)| match first.get(&slot) {
        None => false,
        Some(&(ft, _)) => end < total || ft.is_some(),
    })
}

pub struct ContinuousEngine<B: DecodeBackend> {
    backend: B,
    kv: KvCache,
    slots: Vec<Option<Active>>,
    pending: VecDeque<PendingReq>,
    policy: Box<dyn SchedulePolicy>,
    /// ids counted in `deferred_admissions` during their CURRENT stay in the
    /// queue, so the counter is once per throttled queue episode, not per
    /// poll — a set (not just the last id) because a non-FCFS policy can
    /// interleave blocked picks; ids are removed when the request leaves the
    /// queue, so the set is bounded by the pending-queue length
    deferred_ids: HashSet<u64>,
    next_seq: u64,
    /// engine rounds so far (drives policy aging deterministically)
    round: u64,
    pub stats: EngineStats,
}

impl<B: DecodeBackend> ContinuousEngine<B> {
    /// Engine with the [`Fcfs`] policy (the pre-policy behavior).
    pub fn new(backend: B) -> Result<Self> {
        let kv = backend.new_cache()?;
        if kv.batch != backend.batch_slots() {
            bail!("backend cache batch {} != slots {}", kv.batch, backend.batch_slots());
        }
        let slots = (0..backend.batch_slots()).map(|_| None).collect();
        Ok(Self {
            backend,
            kv,
            slots,
            pending: VecDeque::new(),
            policy: Box::new(Fcfs),
            deferred_ids: HashSet::new(),
            next_seq: 0,
            round: 0,
            stats: EngineStats::default(),
        })
    }

    /// Replace the scheduling policy (admission order, preemption, prefill
    /// chunking).  Call before submitting work.
    pub fn with_policy(mut self, policy: Box<dyn SchedulePolicy>) -> Self {
        self.policy = policy;
        self
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    /// Enable the generalized radix prefix cache (paged KV layout only):
    /// admission walks a radix tree over token sequences and maps matched
    /// shared pages instead of prefilling them; retirement inserts completed
    /// sequences back.  Call before submitting work.
    pub fn with_radix_cache(mut self) -> Result<Self> {
        self.kv.enable_radix()?;
        Ok(self)
    }

    /// Queue a request; its output goes to `reply`.  `submitted` anchors the
    /// queue-wait / TTFT clocks (pass the time the client handed it over).
    pub fn submit(&mut self, req: GenRequest, reply: Reply, submitted: Instant) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.pending.push_back(PendingReq {
            req,
            reply,
            submitted,
            generated: Vec::new(),
            ttft_s: None,
            queue_s: None,
            attempts: 0,
            times_preempted: 0,
            seq,
            enqueued_round: self.round,
        });
    }

    /// Queue a request that already delivered `generated` tokens on another
    /// worker (cluster crash-recovery path).  Admission re-prefills
    /// `BOS + prompt + generated` exactly like a preemption resume, so the
    /// stream continues from its last delivered token; only NEW tokens are
    /// emitted on `reply`, and the terminal response carries the full token
    /// list.  Counted in `stats.resumed`, not `admitted` — the request was
    /// admitted once already, on the worker that lost it.
    pub fn submit_resumed(
        &mut self,
        req: GenRequest,
        generated: Vec<i32>,
        reply: Reply,
        submitted: Instant,
    ) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.pending.push_back(PendingReq {
            req,
            reply,
            submitted,
            generated,
            // first-admission markers pre-set: queue wait and TTFT were
            // spent (and recorded) on the original worker
            ttft_s: Some(0.0),
            queue_s: Some(0.0),
            attempts: 0,
            times_preempted: 0,
            seq,
            enqueued_round: self.round,
        });
    }

    /// Queue a request and stream its tokens over a fresh channel.
    pub fn submit_stream(&mut self, req: GenRequest) -> Receiver<StreamEvent> {
        let (tx, rx) = channel();
        self.submit(req, Reply::Stream(tx), Instant::now());
        rx
    }

    /// Resubmit a request drained by [`ContinuousEngine::drain_for_recovery`]
    /// (server engine-rebuild path).  A previously-admitted request keeps its
    /// first-admission markers so it is not counted as admitted twice.
    pub fn resubmit(&mut self, r: RetryReq) {
        self.stats.retries += 1;
        let seq = self.next_seq;
        self.next_seq += 1;
        self.pending.push_back(PendingReq {
            req: r.req,
            reply: r.reply,
            submitted: r.submitted,
            generated: r.generated,
            ttft_s: r.ttft_s,
            queue_s: r.queue_s,
            attempts: r.attempts,
            times_preempted: 0,
            seq,
            enqueued_round: self.round,
        });
    }

    /// Cancel a request wherever it is: pending (removed from the queue) or
    /// occupying a slot (slot retired, pages released).  The client receives
    /// a normal `Done` response with `FinishReason::Cancelled` and the tokens
    /// generated so far.  Returns false when the id is unknown (already
    /// completed, or never submitted).
    pub fn cancel(&mut self, id: u64) -> Result<bool> {
        for i in 0..self.slots.len() {
            let hit = matches!(&self.slots[i], Some(a) if a.req.id == id);
            if hit {
                if let Some(a) = self.slots[i].as_mut() {
                    a.finish = Some(FinishReason::Cancelled);
                }
                self.finish(i)?;
                return Ok(true);
            }
        }
        if let Some(pos) = self.pending.iter().position(|p| p.req.id == id) {
            let p = self.pending.remove(pos).expect("position is in range");
            self.stats.cancelled += 1;
            self.stats.per_class[p.req.priority.index()].cancelled += 1;
            let total_s = p.submitted.elapsed().as_secs_f64();
            if p.queue_s.is_some() && p.ttft_s.is_none() {
                // admitted in a past epoch but never reached a first token
                // (rebuild-retried mid-prefill): keep sum_ttft_s paired with
                // stats.admitted by recording the termination time
                self.stats.sum_ttft_s += total_s;
                let cls = &mut self.stats.per_class[p.req.priority.index()];
                cls.sum_ttft_s += total_s;
                cls.ttft_hist.record(total_s);
            }
            let resp = GenResponse {
                id: p.req.id,
                tokens: p.generated,
                ttft_s: p.ttft_s.unwrap_or(0.0),
                total_s,
                queue_s: p.queue_s.unwrap_or(total_s),
                finish: FinishReason::Cancelled,
            };
            p.reply.done(resp);
            self.deferred_ids.remove(&id);
            return Ok(true);
        }
        Ok(false)
    }

    pub fn free_slots(&self) -> usize {
        self.slots.iter().filter(|s| s.is_none()).count()
    }

    /// The engine's KV cache (capacity reporting, benches).
    pub fn kv(&self) -> &KvCache {
        &self.kv
    }

    pub fn has_work(&self) -> bool {
        !self.pending.is_empty() || self.slots.iter().any(|s| s.is_some())
    }

    /// Ids of the requests currently occupying slots (slot order) — test and
    /// operator observability for preemption/cancellation.
    pub fn active_ids(&self) -> Vec<u64> {
        self.slots.iter().filter_map(|s| s.as_ref().map(|a| a.req.id)).collect()
    }

    /// Ids of the requests waiting in the queue (queue order).
    pub fn pending_ids(&self) -> Vec<u64> {
        self.pending.iter().map(|p| p.req.id).collect()
    }

    pub fn phases(&self) -> Vec<SlotPhase> {
        self.slots
            .iter()
            .map(|s| match s {
                None => SlotPhase::Empty,
                Some(a) if !a.decoding() => SlotPhase::Prefilling,
                Some(_) => SlotPhase::Decoding,
            })
            .collect()
    }

    /// Retire slot `i`: deliver the response (with the slot's recorded
    /// finish reason), release its pages, free the slot.
    fn finish(&mut self, i: usize) -> Result<()> {
        let Some(mut a) = self.slots[i].take() else {
            return Ok(());
        };
        let total_s = a.submitted.elapsed().as_secs_f64();
        let reason = a.finish.unwrap_or(FinishReason::Length);
        if a.ttft.is_none() {
            // admitted but terminated before its first token (a cancel
            // mid-chunked-prefill): record termination time as the TTFT
            // entry so sum_ttft_s keeps pairing 1:1 with stats.admitted
            a.ttft = Some(total_s);
            self.stats.sum_ttft_s += total_s;
            let cls = &mut self.stats.per_class[a.req.priority.index()];
            cls.sum_ttft_s += total_s;
            cls.ttft_hist.record(total_s);
        }
        if reason == FinishReason::Cancelled {
            self.stats.cancelled += 1;
            self.stats.per_class[a.req.priority.index()].cancelled += 1;
        } else {
            self.stats.completed += 1;
            self.stats.sum_total_s += total_s;
            let cls = &mut self.stats.per_class[a.req.priority.index()];
            cls.completed += 1;
            if a.tokens.len() >= 2 {
                let ttft = a.ttft.unwrap_or(0.0);
                cls.tpot_hist.record((total_s - ttft).max(0.0) / (a.tokens.len() - 1) as f64);
            }
            if let Some(d) = a.req.deadline {
                if total_s > d.as_secs_f64() {
                    self.stats.deadline_misses += 1;
                }
            }
        }
        if self.kv.radix_enabled() {
            // Offer the retiring row's pages to the prefix cache before
            // reset_slot releases them.  Any finish reason qualifies — the
            // K/V written so far is valid for future prefix matches whether
            // the request completed, hit a stop token, or was cancelled.
            let mut seq = Vec::with_capacity(1 + a.req.prompt.len() + a.tokens.len());
            seq.push(self.backend.bos());
            seq.extend_from_slice(&a.req.prompt);
            seq.extend_from_slice(&a.tokens);
            self.kv.radix_insert(i, &seq)?;
        }
        let resp = GenResponse {
            id: a.req.id,
            tokens: a.tokens,
            ttft_s: a.ttft.unwrap_or(0.0),
            total_s,
            queue_s: a.queue_s,
            finish: reason,
        };
        a.reply.done(resp);
        self.kv.reset_slot(i)?;
        Ok(())
    }

    /// Evict a Decoding slot: release its pages and requeue the request with
    /// its generated tokens preserved.  Resume re-prefills prompt + generated
    /// and continues the stream exactly where it stopped.
    fn preempt(&mut self, slot: usize) -> Result<()> {
        let Some(a) = self.slots[slot].take() else {
            return Ok(());
        };
        self.stats.preemptions += 1;
        self.stats.per_class[a.req.priority.index()].preemptions += 1;
        self.kv.reset_slot(slot)?;
        self.pending.push_back(PendingReq {
            req: a.req,
            reply: a.reply,
            submitted: a.submitted,
            generated: a.tokens,
            ttft_s: a.ttft,
            queue_s: Some(a.queue_s),
            attempts: a.attempts,
            times_preempted: a.times_preempted + 1,
            seq: a.seq,
            enqueued_round: self.round,
        });
        Ok(())
    }

    /// `now` is the admission wave's single clock snapshot — one read per
    /// wave, not one per pending request per loop iteration.
    fn queue_view(&self, now: Instant, p: &PendingReq) -> QueueView {
        QueueView {
            id: p.req.id,
            priority: p.req.priority,
            waited_rounds: self.round.saturating_sub(p.enqueued_round),
            deadline_remaining_s: p.req.deadline.map(|d| {
                d.as_secs_f64() - now.saturating_duration_since(p.submitted).as_secs_f64()
            }),
            seq: p.seq,
            prompt_tokens: 1 + p.req.prompt.len() + p.generated.len(),
            remaining_new: p.req.max_new.saturating_sub(p.generated.len()),
            resumed: !p.generated.is_empty(),
        }
    }

    /// Decoding slots a policy may preempt: mid-prefill slots are excluded,
    /// as is any slot whose resume could not fit the prefill geometry again.
    fn evictable_views(&self) -> Vec<SlotView> {
        let mut v = Vec::new();
        for (i, s) in self.slots.iter().enumerate() {
            let Some(a) = s else { continue };
            if !a.decoding() {
                continue;
            }
            let resume_total = 1 + a.req.prompt.len() + a.tokens.len();
            if resume_total > self.backend.max_prompt_tokens()
                || self.kv.n_prefix + resume_total > self.backend.cache_capacity()
            {
                continue;
            }
            v.push(SlotView {
                slot: i,
                id: a.req.id,
                priority: a.req.priority,
                generated: a.tokens.len(),
                remaining_new: a.req.max_new.saturating_sub(a.tokens.len()),
                admitted_round: a.admitted_round,
                decoding: true,
                times_preempted: a.times_preempted,
            });
        }
        v
    }

    /// Complete a slot's prefill: record TTFT (first admission only), emit
    /// the first token, and return whether the request is already done.
    fn complete_prefill(&mut self, slot: usize, first_token: i32, n_sinks: i32) -> bool {
        let Some(a) = self.slots[slot].as_mut() else {
            return false;
        };
        a.next_token = first_token;
        a.n_sinks = n_sinks;
        if a.ttft.is_none() {
            // TTFT is recorded for every admitted request (prefill completion
            // even when max_new == 0) so its sum pairs with stats.admitted
            let ttft_s = a.submitted.elapsed().as_secs_f64();
            a.ttft = Some(ttft_s);
            self.stats.sum_ttft_s += ttft_s;
            let cls = &mut self.stats.per_class[a.req.priority.index()];
            cls.sum_ttft_s += ttft_s;
            cls.ttft_hist.record(ttft_s);
        }
        let mut done = false;
        if a.tokens.len() < a.req.max_new {
            a.tokens.push(first_token);
            a.reply.token(first_token);
            self.stats.generated_tokens += 1;
            if a.req.stop_tokens.contains(&first_token) {
                a.finish = Some(FinishReason::Stop);
                done = true;
            } else if a.tokens.len() >= a.req.max_new {
                a.finish = Some(FinishReason::Length);
                done = true;
            }
        } else {
            // max_new == 0, or a resume raced budget exhaustion
            a.finish = Some(FinishReason::Length);
            done = true;
        }
        done
    }

    /// Admit pending requests into free slots in the policy's order,
    /// preempting Decoding slots when the policy asks for it.  One prefill
    /// pass serves the whole wave; each admitted request prefills at most
    /// one policy chunk here (the rest continues across later steps).
    fn admit(&mut self) -> Result<()> {
        if self.pending.is_empty() {
            return Ok(());
        }
        let decoding_before = self.slots.iter().any(|s| s.is_some());
        let chunk = self.policy.prefill_chunk().max(1);
        let wave_start = Instant::now();
        let mut claimed = vec![false; self.slots.len()];
        // (slot, request, cache positions served by the radix prefix cache —
        // prefill starts there; 0 without a radix match)
        let mut wave: Vec<(usize, PendingReq, usize)> = Vec::new();

        loop {
            if self.pending.is_empty() {
                break;
            }
            // views are rebuilt per iteration because every continue-path
            // mutates pending; iterations are bounded by slots + rejections
            // + preemptions, so a wave is O(that × pending).  If backlogs
            // ever reach the tens of thousands, patch the vec incrementally
            // instead of rebuilding.
            let views: Vec<QueueView> =
                self.pending.iter().map(|p| self.queue_view(wave_start, p)).collect();
            let Some(pick) = self.policy.next_candidate(self.round, &views) else {
                break;
            };
            if pick >= self.pending.len() {
                break; // defensive: a policy returned a stale index
            }
            let total = views[pick].prompt_tokens;
            let remaining = views[pick].remaining_new;
            if total > self.backend.max_prompt_tokens()
                || self.kv.n_prefix + total > self.backend.cache_capacity()
            {
                let p = self.pending.remove(pick).expect("pick is in range");
                self.deferred_ids.remove(&p.req.id);
                self.stats.rejected += 1;
                p.reply.error(format!(
                    "prompt of {} tokens exceeds serving geometry (max prompt {}, cache {})",
                    total,
                    self.backend.max_prompt_tokens(),
                    self.backend.cache_capacity()
                ));
                continue; // no slot consumed; try the next candidate
            }
            if !self.kv.admission_feasible(total, remaining) {
                let p = self.pending.remove(pick).expect("pick is in range");
                self.deferred_ids.remove(&p.req.id);
                self.stats.rejected += 1;
                p.reply.error(format!(
                    "request needs more KV pages than the pool holds \
                     (prompt {} + max_new {} exceeds pool capacity): \
                     lower max_new or grow the page pool",
                    total, remaining
                ));
                continue; // waiting would wedge the queue forever
            }
            let free_slot =
                (0..self.slots.len()).find(|&i| self.slots[i].is_none() && !claimed[i]);
            // With the radix cache on, admission math is match-aware: pages
            // already resident for this row's prefix shrink the reservation,
            // and cache-only runs count as reclaimable headroom.
            let row_toks: Option<Vec<i32>> = if self.kv.radix_enabled() {
                let p = &self.pending[pick];
                let mut t = Vec::with_capacity(total);
                t.push(self.backend.bos());
                t.extend_from_slice(&p.req.prompt);
                t.extend_from_slice(&p.generated);
                Some(t)
            } else {
                None
            };
            let blocked_pages = match &row_toks {
                Some(t) => !self.kv.radix_can_admit(total, remaining, t),
                None => !self.kv.can_admit(total, remaining),
            };
            if free_slot.is_none() || blocked_pages {
                // ask the policy for a preemption victim to make room; when
                // the blocker is PAGES, the eviction must actually cover the
                // shortfall — destroying a victim's progress without
                // unblocking the candidate would be pure lost work (a
                // multi-victim eviction chain is deliberately not attempted:
                // the candidate waits instead, losing nothing)
                let busy = self.evictable_views();
                let victim = self
                    .policy
                    .preempt_victim(&views[pick], &busy)
                    .filter(|&v| v < self.slots.len() && !claimed[v])
                    .filter(|&v| matches!(&self.slots[v], Some(a) if a.decoding()))
                    .filter(|&v| {
                        !blocked_pages
                            || match &row_toks {
                                Some(t) => {
                                    self.kv.radix_can_admit_after_evicting(v, total, remaining, t)
                                }
                                None => self.kv.can_admit_after_evicting(v, total, remaining),
                            }
                    });
                if let Some(v) = victim {
                    self.preempt(v)?;
                    continue; // re-evaluate the same candidate with freed room
                }
                // blocked with no victim: the candidate waits in the queue
                // (the policy's order is its head-of-line discipline).
                // Counted once per throttled REQUEST, not once per poll.
                if blocked_pages && self.deferred_ids.insert(views[pick].id) {
                    self.stats.deferred_admissions += 1;
                }
                break;
            }
            let slot = free_slot.expect("checked above");
            let matched = if let Some(t) = &row_toks {
                match self.kv.admit_radix(slot, total, remaining, t) {
                    Ok(Some(m)) => m,
                    Ok(None) => {
                        // the match-aware peek passed but the transactional
                        // admission could not cover the reservation (an
                        // eviction candidate got pinned in between): safe
                        // fallback — the candidate waits in the queue
                        if self.deferred_ids.insert(views[pick].id) {
                            self.stats.deferred_admissions += 1;
                        }
                        break;
                    }
                    Err(e) => {
                        let msg = format!("radix admission failed: {e:#}");
                        let p = self.pending.remove(pick).expect("pick is in range");
                        self.deferred_ids.remove(&p.req.id);
                        p.reply.error(msg.clone());
                        for (_, w, _) in &wave {
                            w.reply.error(msg.clone());
                        }
                        return Err(e);
                    }
                }
            } else {
                0
            };
            let p = self.pending.remove(pick).expect("pick is in range");
            self.deferred_ids.remove(&p.req.id);
            if row_toks.is_none() {
                if let Err(e) = self.kv.reserve(slot, total, remaining) {
                    // can_admit passed, so this is an engine invariant
                    // violation; fail the wave the way a prefill error would
                    let msg = format!("page reservation failed: {e:#}");
                    p.reply.error(msg.clone());
                    for (_, w, _) in &wave {
                        w.reply.error(msg.clone());
                    }
                    return Err(e);
                }
            }
            claimed[slot] = true;
            wave.push((slot, p, matched));
        }
        if wave.is_empty() {
            return Ok(());
        }

        let jobs: Vec<PrefillJob> = wave
            .iter()
            .map(|(slot, p, matched)| {
                let total = 1 + p.req.prompt.len() + p.generated.len();
                PrefillJob {
                    slot: *slot,
                    req: &p.req,
                    resumed: &p.generated,
                    start: *matched,
                    end: (matched + chunk).min(total),
                }
            })
            .collect();
        let pre = match self.backend.prefill(&mut self.kv, &jobs) {
            Ok(p) => p,
            Err(e) => {
                // a failed wave is requeued (order preserved) so the server's
                // recovery path can retry token-less requests after a rebuild
                drop(jobs);
                for (slot, p, _) in wave.into_iter().rev() {
                    let _ = self.kv.reset_slot(slot);
                    self.pending.push_front(p);
                }
                return Err(e);
            }
        };
        drop(jobs);
        let t_prefill = wave_start.elapsed().as_secs_f64();
        self.stats.prefill_calls += 1;
        self.stats.t_prefill_s += t_prefill;
        if decoding_before {
            self.stats.mid_decode_admissions += wave.len();
        }

        let mut first = BTreeMap::new();
        for o in pre {
            first.insert(o.slot, (o.first_token, o.n_sinks));
        }
        // a backend returning outputs for the wrong slots — or completing a
        // span without a first token — is a contract violation; error the
        // whole wave so no client is left on a channel that closes without a
        // terminal event
        let covered = prefill_covers(
            &first,
            wave.iter().map(|(slot, p, matched)| {
                let total = 1 + p.req.prompt.len() + p.generated.len();
                (*slot, (matched + chunk).min(total), total)
            }),
        );
        if !covered {
            let msg = "backend prefill output does not cover the admitted wave";
            for (_, p, _) in &wave {
                p.reply.error(msg.to_string());
            }
            bail!(msg);
        }

        let mut skew = 0.0f64;
        let mut finished: Vec<usize> = Vec::new();
        for (slot, p, matched) in wave {
            let total = 1 + p.req.prompt.len() + p.generated.len();
            let end = (matched + chunk).min(total);
            let (first_token, n_sinks) = first[&slot];
            let fresh = p.queue_s.is_none();
            let queue_s = p.queue_s.unwrap_or_else(|| {
                wave_start.saturating_duration_since(p.submitted).as_secs_f64()
            });
            if fresh {
                self.stats.admitted += 1;
                self.stats.sum_queue_s += queue_s;
                let cls = &mut self.stats.per_class[p.req.priority.index()];
                cls.requests += 1;
                cls.sum_queue_s += queue_s;
                skew = skew.max(queue_s);
            } else {
                self.stats.resumed += 1;
            }
            self.stats.prefill_tokens += end - matched;
            self.slots[slot] = Some(Active {
                req: p.req,
                tokens: p.generated,
                next_token: 0,
                n_sinks: 0,
                reply: p.reply,
                submitted: p.submitted,
                queue_s,
                ttft: p.ttft_s,
                attempts: p.attempts,
                times_preempted: p.times_preempted,
                seq: p.seq,
                admitted_round: self.round,
                prefill_written: end,
                prefill_total: total,
                finish: None,
            });
            if end == total {
                let ft = first_token.expect("wave contract validated above");
                if self.complete_prefill(slot, ft, n_sinks) {
                    finished.push(slot);
                }
            }
        }
        self.stats.sum_dispatch_skew_s += skew;
        for slot in finished {
            self.finish(slot)?;
        }
        Ok(())
    }

    /// Advance every mid-prefill slot by one policy chunk (one backend call
    /// for all of them), emitting first tokens for the ones that complete.
    fn continue_prefill(&mut self) -> Result<()> {
        let chunk = self.policy.prefill_chunk().max(1);
        let rows: Vec<usize> = (0..self.slots.len())
            .filter(|&i| matches!(&self.slots[i], Some(a) if !a.decoding()))
            .collect();
        if rows.is_empty() {
            return Ok(());
        }
        let t0 = Instant::now();
        let mut jobs: Vec<PrefillJob> = Vec::with_capacity(rows.len());
        let mut spans: Vec<(usize, usize, usize)> = Vec::with_capacity(rows.len());
        for &i in &rows {
            let a = self.slots[i].as_ref().expect("filtered to occupied rows");
            let end = a.prefill_written.saturating_add(chunk).min(a.prefill_total);
            spans.push((i, end, a.prefill_total));
            jobs.push(PrefillJob {
                slot: i,
                req: &a.req,
                resumed: &a.tokens,
                start: a.prefill_written,
                end,
            });
        }
        // on a prefill error the slots stay in place: the server's recovery
        // path drains them (retrying token-less requests) after a rebuild
        let pre = self.backend.prefill(&mut self.kv, &jobs)?;
        drop(jobs);
        self.stats.prefill_calls += 1;
        self.stats.t_prefill_s += t0.elapsed().as_secs_f64();

        let mut first = BTreeMap::new();
        for o in pre {
            first.insert(o.slot, (o.first_token, o.n_sinks));
        }
        // contract violation: error every chunked slot before touching any,
        // so no client is left on a channel without a terminal event
        if !prefill_covers(&first, spans.iter().copied()) {
            let msg = "backend prefill output does not cover the chunked slots";
            for &(slot, _, _) in &spans {
                if let Some(a) = self.slots[slot].take() {
                    a.reply.error(msg.to_string());
                }
                let _ = self.kv.reset_slot(slot);
            }
            bail!(msg);
        }
        let mut finished: Vec<usize> = Vec::new();
        for (slot, end, total) in spans {
            let (first_token, n_sinks) = first[&slot];
            {
                let a = self.slots[slot].as_mut().expect("slot occupied");
                self.stats.prefill_tokens += end - a.prefill_written;
                a.prefill_written = end;
            }
            if end == total {
                let ft = first_token.expect("chunk contract validated above");
                if self.complete_prefill(slot, ft, n_sinks) {
                    finished.push(slot);
                }
            }
        }
        for slot in finished {
            self.finish(slot)?;
        }
        Ok(())
    }

    /// One engine step: advance chunked prefills, admit into free slots
    /// (policy order, possibly preempting), then run one decode round (one
    /// backend call per length-group), retiring slots as they complete.
    /// Returns whether any work remains.
    pub fn step(&mut self) -> Result<bool> {
        self.round += 1;
        self.continue_prefill()?;
        self.admit()?;
        let active = self.slots.iter().filter(|s| s.is_some()).count();
        if active > self.stats.peak_active_slots {
            self.stats.peak_active_slots = active;
        }

        // Collect rows that can no longer grow (cache full) and retire them.
        let full: Vec<usize> = (0..self.slots.len())
            .filter(|&i| {
                matches!(&self.slots[i], Some(a) if a.decoding())
                    && self.kv.row_len(i) >= self.kv.s_max
            })
            .collect();
        for i in full {
            if let Some(a) = self.slots[i].as_mut() {
                a.finish = Some(FinishReason::CacheFull);
            }
            self.finish(i)?;
        }

        // Group the decoding slots by their current cache length
        // (mid-prefill slots sit out the round).
        let mut groups: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        for i in 0..self.slots.len() {
            if matches!(&self.slots[i], Some(a) if a.decoding()) {
                groups.entry(self.kv.row_len(i)).or_default().push(i);
            }
        }
        if groups.is_empty() {
            return Ok(self.has_work());
        }
        self.stats.decode_rounds += 1;

        for (len, rows) in groups {
            let t0 = Instant::now();
            let group = DecodeGroup {
                len,
                tokens: rows
                    .iter()
                    .map(|&r| self.slots[r].as_ref().map(|a| a.next_token).unwrap_or(0))
                    .collect(),
                n_sinks: rows
                    .iter()
                    .map(|&r| self.slots[r].as_ref().map(|a| a.n_sinks).unwrap_or(0))
                    .collect(),
                seeds: rows
                    .iter()
                    .map(|&r| self.slots[r].as_ref().map(|a| a.req.seed).unwrap_or(0))
                    .collect(),
                rows,
            };
            let outs = self.backend.decode(&mut self.kv, &group)?;
            self.stats.decode_calls += 1;
            self.stats.t_decode_s += t0.elapsed().as_secs_f64();

            let mut finished: Vec<usize> = Vec::new();
            for o in outs {
                let Some(a) = self.slots[o.row].as_mut() else {
                    continue;
                };
                a.next_token = o.next_token;
                a.n_sinks = o.n_sinks;
                a.tokens.push(o.next_token);
                a.reply.token(o.next_token);
                self.stats.generated_tokens += 1;
                if a.req.stop_tokens.contains(&o.next_token) {
                    a.finish = Some(FinishReason::Stop);
                    finished.push(o.row);
                } else if a.tokens.len() >= a.req.max_new {
                    a.finish = Some(FinishReason::Length);
                    finished.push(o.row);
                }
            }
            for row in finished {
                self.finish(row)?;
            }
        }
        Ok(self.has_work())
    }

    /// Drive the engine until every submitted request has completed.
    pub fn run_to_idle(&mut self) -> Result<()> {
        while self.step()? {}
        Ok(())
    }

    /// Abort everything in flight: every busy slot and every pending request
    /// gets an error reply, and the slot table is cleared.  Used by the
    /// server at shutdown and when recovery is impossible.
    ///
    /// EVERY slot is reset, not just occupied ones: a failed admission wave
    /// can leave a slot with a page reservation (and partially written rows)
    /// but no `Active` entry, and those pages must go back to the pool or
    /// later admissions would see permanently shrunken capacity.
    pub fn fail_all(&mut self, msg: &str) {
        for i in 0..self.slots.len() {
            if let Some(a) = self.slots[i].take() {
                a.reply.error(msg.to_string());
            }
            let _ = self.kv.reset_slot(i);
        }
        self.deferred_ids.clear();
        while let Some(p) = self.pending.pop_front() {
            p.reply.error(msg.to_string());
        }
    }

    /// Drain the engine after a backend failure, for an engine rebuild:
    ///
    /// - ACTIVE slots that already streamed tokens get `msg` errors — their
    ///   mid-decode state died with the backend and the v2 contract is
    ///   conservative about half-delivered in-flight streams;
    /// - token-less active slots (mid-chunked-prefill) and EVERY queued
    ///   request — including preempted ones carrying generated tokens,
    ///   whose resume re-prefill does not depend on the dead cache — are
    ///   returned for [`ContinuousEngine::resubmit`] into the fresh engine
    ///   while their resubmission count is below `max_retries`;
    /// - the rest error out with the retry budget noted.
    ///
    /// Every slot is reset (reservations and partial prefills included).
    pub fn drain_for_recovery(&mut self, msg: &str, max_retries: usize) -> Vec<RetryReq> {
        let mut retry = Vec::new();
        for i in 0..self.slots.len() {
            if let Some(a) = self.slots[i].take() {
                if !a.tokens.is_empty() {
                    a.reply.error(msg.to_string());
                } else if a.attempts < max_retries {
                    retry.push(RetryReq {
                        req: a.req,
                        reply: a.reply,
                        submitted: a.submitted,
                        attempts: a.attempts + 1,
                        queue_s: Some(a.queue_s),
                        generated: Vec::new(),
                        ttft_s: None,
                    });
                } else {
                    a.reply.error(format!("{msg} (after {} retries)", a.attempts));
                }
            }
            let _ = self.kv.reset_slot(i);
        }
        while let Some(p) = self.pending.pop_front() {
            if p.attempts < max_retries {
                retry.push(RetryReq {
                    req: p.req,
                    reply: p.reply,
                    submitted: p.submitted,
                    attempts: p.attempts + 1,
                    queue_s: p.queue_s,
                    generated: p.generated,
                    ttft_s: p.ttft_s,
                });
            } else {
                p.reply.error(format!("{msg} (after {} retries)", p.attempts));
            }
        }
        self.deferred_ids.clear();
        retry
    }

    /// Translate engine counters into the server's [`Metrics`] shape.
    /// `requests` counts ADMITTED requests so it pairs with the TTFT and
    /// queue-wait sums, which are both recorded at first admission (completed
    /// would understate the denominator while slots are still decoding).
    pub fn metrics(&self) -> Metrics {
        let mut m = self.stats.to_metrics();
        m.active_slots = self.slots.iter().filter(|s| s.is_some()).count();
        m.kv_resident_bytes = self.kv.resident_kv_bytes();
        m.kv_used_bytes = self.kv.used_kv_bytes();
        if let Some(rs) = self.kv.radix_stats() {
            m.radix_lookups = rs.lookups;
            m.radix_hits = rs.hits;
            m.radix_hit_tokens = rs.hit_tokens;
            m.radix_cow_splits = rs.cow_splits;
            m.radix_evicted_pages = rs.evicted_pages;
            m.radix_shared_pages = rs.shared_pages;
            m.radix_shared_bytes = rs.shared_bytes;
        }
        m
    }

    /// Health/load snapshot for the cluster router.  `progress` is a
    /// monotone work counter: a router seeing it frozen across probes while
    /// requests are outstanding concludes the worker is wedged.
    pub fn probe(&self) -> WorkerProbe {
        let queued_tokens = self
            .pending
            .iter()
            .map(|p| {
                1 + p.req.prompt.len()
                    + p.generated.len()
                    + p.req.max_new.saturating_sub(p.generated.len())
            })
            .sum();
        WorkerProbe {
            state: ProbeState::Serving,
            progress: (self.stats.prefill_tokens
                + self.stats.generated_tokens
                + self.stats.decode_rounds) as u64,
            active_slots: self.slots.iter().filter(|s| s.is_some()).count(),
            queued_requests: self.pending.len(),
            queued_tokens,
            slots_total: self.slots.len(),
            kv_pages_total: self.kv.total_pages().unwrap_or(0),
            kv_pages_free: self.kv.free_pages().unwrap_or(0),
            metrics: self.metrics(),
        }
    }

    /// Give back every request the cluster router can safely re-dispatch
    /// elsewhere: all queued requests and every token-less in-flight slot.
    /// Their `Reply` handles are dropped WITHOUT a terminal event — this is
    /// a cluster-path API, and the router (which holds the client channels)
    /// re-dispatches the returned ids under fresh namespaced ids.  Slots
    /// that already streamed tokens keep running ("kept"); a drained worker
    /// finishes them and then idles.
    pub fn release_for_drain(&mut self) -> DrainReport {
        let mut released = Vec::new();
        for i in 0..self.slots.len() {
            let token_less = matches!(&self.slots[i], Some(a) if a.tokens.is_empty());
            if token_less {
                let a = self.slots[i].take().expect("matched occupied slot");
                released.push(a.req.id); // reply dropped with `a`: no terminal event
                let _ = self.kv.reset_slot(i);
            }
        }
        while let Some(p) = self.pending.pop_front() {
            released.push(p.req.id);
        }
        self.deferred_ids.clear();
        let kept = self.slots.iter().filter(|s| s.is_some()).count();
        DrainReport { released, kept }
    }

    /// Crash-style teardown for a killed worker: drop every reply without a
    /// terminal event (the router finishes or redistributes the streams from
    /// its own in-flight table), reset every slot, and report the final
    /// page-pool accounting so tests can prove nothing leaked.
    pub fn post_mortem(&mut self) -> WorkerPostMortem {
        let mut dropped_active = 0;
        for i in 0..self.slots.len() {
            if self.slots[i].take().is_some() {
                dropped_active += 1;
            }
            let _ = self.kv.reset_slot(i);
        }
        let dropped_queued = self.pending.len();
        self.pending.clear();
        self.deferred_ids.clear();
        // release the prefix cache's refs so the page accounting below proves
        // the whole pool drains (tree-held pages are not leaks, but a
        // post-mortem reports raw pool truth)
        let _ = self.kv.radix_flush();
        WorkerPostMortem {
            kv_pages_total: self.kv.total_pages().unwrap_or(0),
            kv_pages_free: self.kv.free_pages().unwrap_or(0),
            kv_prefix_pages: self.kv.prefix_page_ids().len(),
            dropped_active,
            dropped_queued,
        }
    }
}
