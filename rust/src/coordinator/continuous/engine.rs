//! Continuous-batching engine: a persistent decode loop over a slot table.
//!
//! Slot state machine (see rust/DESIGN.md; "prefilling" is transient inside
//! one admission wave and never observable — see [`SlotPhase`]):
//!
//!   Empty ──admit (prefill+install)──▶ Decoding ──max_new / cache full──▶ Done
//!     ▲                                                                    │
//!     └──────────────── reset_slot (zero + keep prefix) ◀──────────────────┘
//!
//! Between decode rounds the engine admits pending requests into free slots:
//! one prefill pass serves a whole admission wave (mixed prompt lengths are
//! fine — rows attend only within themselves), and the shared prefixed K/V
//! is already resident in every slot, so admission never recomputes it (the
//! paper's invariant is what makes mid-flight admission cheap).  Completed
//! slots retire immediately and their tokens stream to the client as they
//! are produced, so short requests are never held hostage by long ones.
//!
//! On a paged cache, admission is additionally a PAGE-availability check:
//! each admitted request reserves its worst-case page count (prompt + budget,
//! capped by row capacity) so mid-flight appends can never fail, a request
//! that doesn't fit the free pool WAITS at the head of the queue (FCFS — it
//! is not skipped), and retirement releases the slot's pages in O(pages) with
//! no memset.  Because long-tail sequences only hold the pages they use, the
//! engine can run many more slots than dense worst-case sizing would allow
//! over the same KV memory.

use std::collections::{BTreeMap, VecDeque};
use std::sync::mpsc::{channel, Receiver};
use std::time::Instant;

use anyhow::{bail, Result};

use crate::coordinator::kvcache::KvCache;
use crate::coordinator::request::{GenRequest, GenResponse, Metrics, Reply, StreamEvent};

use super::backend::{DecodeBackend, DecodeGroup, PrefillJob};

/// Observable lifecycle phase of a slot.  The engine is single-threaded, so
/// the transient phases can never be observed from outside: prefill happens
/// synchronously inside an admission wave, and a slot that reaches its
/// budget is retired (back to Empty) within the same `step()` call.
/// [`ContinuousEngine::phases`] therefore only ever reports Empty or
/// Decoding; Done names the terminal state of the machine in rust/DESIGN.md.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SlotPhase {
    Empty,
    Decoding,
    Done,
}

struct Active {
    id: u64,
    max_new: usize,
    tokens: Vec<i32>,
    next_token: i32,
    n_sinks: i32,
    reply: Reply,
    submitted: Instant,
    queue_s: f64,
    ttft_s: f64,
}

/// Counters the engine accumulates while serving.
#[derive(Debug, Clone, Default)]
pub struct EngineStats {
    pub admitted: usize,
    pub completed: usize,
    /// requests dropped at admission (prompt too long for the geometry, or a
    /// shape the page pool could never hold)
    pub rejected: usize,
    /// requests that waited at the queue head for free pages (each throttled
    /// request counts once, however many rounds it waited)
    pub deferred_admissions: usize,
    /// most slots simultaneously decoding (admission capacity actually used)
    pub peak_active_slots: usize,
    pub prefill_calls: usize,
    /// decode executions (one per length-group per round)
    pub decode_calls: usize,
    /// decode rounds (one per step with any active slot)
    pub decode_rounds: usize,
    /// requests admitted while at least one other slot was mid-decode
    pub mid_decode_admissions: usize,
    pub generated_tokens: usize,
    pub prefill_tokens: usize,
    pub sum_ttft_s: f64,
    pub sum_queue_s: f64,
    pub sum_total_s: f64,
    pub t_prefill_s: f64,
    pub t_decode_s: f64,
}

pub struct ContinuousEngine<B: DecodeBackend> {
    backend: B,
    kv: KvCache,
    slots: Vec<Option<Active>>,
    pending: VecDeque<(GenRequest, Reply, Instant)>,
    /// id of the request currently waiting at the queue head for pages, so
    /// `deferred_admissions` counts throttled requests, not polls
    last_deferred: Option<u64>,
    pub stats: EngineStats,
}

impl<B: DecodeBackend> ContinuousEngine<B> {
    pub fn new(backend: B) -> Result<Self> {
        let kv = backend.new_cache()?;
        if kv.batch != backend.batch_slots() {
            bail!("backend cache batch {} != slots {}", kv.batch, backend.batch_slots());
        }
        let slots = (0..backend.batch_slots()).map(|_| None).collect();
        Ok(Self {
            backend,
            kv,
            slots,
            pending: VecDeque::new(),
            last_deferred: None,
            stats: EngineStats::default(),
        })
    }

    /// Queue a request; its output goes to `reply`.  `submitted` anchors the
    /// queue-wait / TTFT clocks (pass the time the client handed it over).
    pub fn submit(&mut self, req: GenRequest, reply: Reply, submitted: Instant) {
        self.pending.push_back((req, reply, submitted));
    }

    /// Queue a request and stream its tokens over a fresh channel.
    pub fn submit_stream(&mut self, req: GenRequest) -> Receiver<StreamEvent> {
        let (tx, rx) = channel();
        self.submit(req, Reply::Stream(tx), Instant::now());
        rx
    }

    pub fn free_slots(&self) -> usize {
        self.slots.iter().filter(|s| s.is_none()).count()
    }

    /// The engine's KV cache (capacity reporting, benches).
    pub fn kv(&self) -> &KvCache {
        &self.kv
    }

    pub fn has_work(&self) -> bool {
        !self.pending.is_empty() || self.slots.iter().any(|s| s.is_some())
    }

    pub fn phases(&self) -> Vec<SlotPhase> {
        self.slots
            .iter()
            .map(|s| if s.is_some() { SlotPhase::Decoding } else { SlotPhase::Empty })
            .collect()
    }

    /// Retire slot `i`: deliver the response, zero the row, free the slot.
    fn finish(&mut self, i: usize) -> Result<()> {
        let Some(a) = self.slots[i].take() else {
            return Ok(());
        };
        let total_s = a.submitted.elapsed().as_secs_f64();
        self.stats.completed += 1;
        self.stats.sum_total_s += total_s;
        let resp = GenResponse {
            id: a.id,
            tokens: a.tokens,
            ttft_s: a.ttft_s,
            total_s,
            queue_s: a.queue_s,
        };
        a.reply.done(resp);
        self.kv.reset_slot(i)?;
        Ok(())
    }

    /// Admit pending requests into free slots (one prefill pass per wave).
    fn admit(&mut self) -> Result<()> {
        if self.pending.is_empty() {
            return Ok(());
        }
        let decoding_before = self.slots.iter().any(|s| s.is_some());
        let mut free: Vec<usize> =
            (0..self.slots.len()).filter(|&i| self.slots[i].is_none()).collect();
        if free.is_empty() {
            return Ok(());
        }
        free.reverse(); // pop() hands out the lowest slot first

        let wave_start = Instant::now();
        let mut wave: Vec<(usize, GenRequest, Reply, Instant)> = Vec::new();
        while let Some(&slot) = free.last() {
            let Some((req, reply, submitted)) = self.pending.pop_front() else {
                break;
            };
            let plen = req.prompt.len() + 1; // +BOS
            if plen > self.backend.max_prompt_tokens()
                || self.kv.n_prefix + plen > self.backend.cache_capacity()
            {
                self.stats.rejected += 1;
                reply.error(format!(
                    "prompt of {} tokens exceeds serving geometry (max prompt {}, cache {})",
                    plen,
                    self.backend.max_prompt_tokens(),
                    self.backend.cache_capacity()
                ));
                continue; // slot stays free for the next candidate
            }
            if !self.kv.admission_feasible(plen, req.max_new) {
                self.stats.rejected += 1;
                reply.error(format!(
                    "request needs more KV pages than the pool holds \
                     (prompt {} + max_new {} exceeds pool capacity): \
                     lower max_new or grow the page pool",
                    plen, req.max_new
                ));
                continue; // waiting would wedge the queue forever
            }
            if !self.kv.can_admit(plen, req.max_new) {
                // not enough free pages yet: wait at the head of the queue
                // (FCFS — retiring slots will release pages), don't skip
                // ahead.  Counted once per throttled REQUEST, not once per
                // poll — admit() re-checks the head every decode round.
                if self.last_deferred != Some(req.id) {
                    self.stats.deferred_admissions += 1;
                    self.last_deferred = Some(req.id);
                }
                self.pending.push_front((req, reply, submitted));
                break;
            }
            if let Err(e) = self.kv.reserve(slot, plen, req.max_new) {
                // can_admit passed, so this is an engine invariant violation;
                // fail the wave the way a prefill error would
                let msg = format!("page reservation failed: {e:#}");
                reply.error(msg.clone());
                for (_, _, r, _) in &wave {
                    r.error(msg.clone());
                }
                return Err(e);
            }
            free.pop();
            wave.push((slot, req, reply, submitted));
        }
        if wave.is_empty() {
            return Ok(());
        }

        let jobs: Vec<PrefillJob> =
            wave.iter().map(|(slot, req, _, _)| PrefillJob { slot: *slot, req }).collect();
        let pre = match self.backend.prefill(&mut self.kv, &jobs) {
            Ok(p) => p,
            Err(e) => {
                for (_, _, reply, _) in &wave {
                    reply.error(format!("prefill failed: {e:#}"));
                }
                return Err(e);
            }
        };
        drop(jobs);
        let t_prefill = wave_start.elapsed().as_secs_f64();
        self.stats.prefill_calls += 1;
        self.stats.t_prefill_s += t_prefill;
        self.stats.admitted += wave.len();
        if decoding_before {
            self.stats.mid_decode_admissions += wave.len();
        }

        let mut first = BTreeMap::new();
        for o in pre {
            first.insert(o.slot, (o.first_token, o.n_sinks));
        }
        // a backend returning outputs for the wrong slots is a contract
        // violation; error the whole wave so no client is left on a channel
        // that closes without a terminal event
        if wave.iter().any(|(slot, _, _, _)| !first.contains_key(slot)) {
            let msg = "backend prefill returned no output for an admitted slot";
            for (_, _, reply, _) in &wave {
                reply.error(msg.to_string());
            }
            bail!(msg);
        }
        let mut finished: Vec<usize> = Vec::new();
        for (slot, req, reply, submitted) in wave {
            let queue_s = wave_start.saturating_duration_since(submitted).as_secs_f64();
            let ttft_s = submitted.elapsed().as_secs_f64();
            let (first_token, n_sinks) = first[&slot];
            self.stats.prefill_tokens += req.prompt.len() + 1;
            self.stats.sum_queue_s += queue_s;
            // TTFT is recorded for every admitted request (prefill completion
            // even when max_new == 0) so its sum pairs with stats.admitted
            self.stats.sum_ttft_s += ttft_s;
            let mut tokens = Vec::new();
            if req.max_new > 0 {
                tokens.push(first_token);
                self.stats.generated_tokens += 1;
                reply.token(first_token);
            }
            let done = tokens.len() >= req.max_new;
            self.slots[slot] = Some(Active {
                id: req.id,
                max_new: req.max_new,
                tokens,
                next_token: first_token,
                n_sinks,
                reply,
                submitted,
                queue_s,
                ttft_s,
            });
            if done {
                finished.push(slot);
            }
        }
        for slot in finished {
            self.finish(slot)?;
        }
        Ok(())
    }

    /// One engine step: admit into free slots, then run one decode round
    /// (one backend call per length-group), retiring slots as they complete.
    /// Returns whether any work remains.
    pub fn step(&mut self) -> Result<bool> {
        self.admit()?;
        let active = self.slots.iter().filter(|s| s.is_some()).count();
        if active > self.stats.peak_active_slots {
            self.stats.peak_active_slots = active;
        }

        // Collect rows that can no longer grow (cache full) and retire them.
        let full: Vec<usize> = (0..self.slots.len())
            .filter(|&i| self.slots[i].is_some() && self.kv.row_len(i) >= self.kv.s_max)
            .collect();
        for i in full {
            self.finish(i)?;
        }

        // Group the decoding slots by their current cache length.
        let mut groups: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        for i in 0..self.slots.len() {
            if self.slots[i].is_some() {
                groups.entry(self.kv.row_len(i)).or_default().push(i);
            }
        }
        if groups.is_empty() {
            return Ok(self.has_work());
        }
        self.stats.decode_rounds += 1;

        for (len, rows) in groups {
            let t0 = Instant::now();
            let group = DecodeGroup {
                len,
                tokens: rows
                    .iter()
                    .map(|&r| self.slots[r].as_ref().map(|a| a.next_token).unwrap_or(0))
                    .collect(),
                n_sinks: rows
                    .iter()
                    .map(|&r| self.slots[r].as_ref().map(|a| a.n_sinks).unwrap_or(0))
                    .collect(),
                rows,
            };
            let outs = self.backend.decode(&mut self.kv, &group)?;
            self.stats.decode_calls += 1;
            self.stats.t_decode_s += t0.elapsed().as_secs_f64();

            let mut finished: Vec<usize> = Vec::new();
            for o in outs {
                let Some(a) = self.slots[o.row].as_mut() else {
                    continue;
                };
                a.next_token = o.next_token;
                a.n_sinks = o.n_sinks;
                a.tokens.push(o.next_token);
                a.reply.token(o.next_token);
                self.stats.generated_tokens += 1;
                if a.tokens.len() >= a.max_new {
                    finished.push(o.row);
                }
            }
            for row in finished {
                self.finish(row)?;
            }
        }
        Ok(self.has_work())
    }

    /// Drive the engine until every submitted request has completed.
    pub fn run_to_idle(&mut self) -> Result<()> {
        while self.step()? {}
        Ok(())
    }

    /// Abort everything in flight: every busy slot and every pending request
    /// gets an error reply, and the slot table is cleared.  Used by the
    /// server when a backend execution fails mid-round.
    ///
    /// EVERY slot is reset, not just occupied ones: a failed admission wave
    /// can leave a slot with a page reservation (and partially written rows)
    /// but no `Active` entry, and those pages must go back to the pool or
    /// later admissions would see permanently shrunken capacity.
    pub fn fail_all(&mut self, msg: &str) {
        for i in 0..self.slots.len() {
            if let Some(a) = self.slots[i].take() {
                a.reply.error(msg.to_string());
            }
            let _ = self.kv.reset_slot(i);
        }
        self.last_deferred = None;
        while let Some((_, reply, _)) = self.pending.pop_front() {
            reply.error(msg.to_string());
        }
    }

    /// Translate engine counters into the server's [`Metrics`] shape.
    /// `requests` counts ADMITTED requests so it pairs with the TTFT and
    /// queue-wait sums, which are both recorded at admission time (completed
    /// would understate the denominator while slots are still decoding).
    pub fn metrics(&self) -> Metrics {
        Metrics {
            requests: self.stats.admitted,
            batches: self.stats.prefill_calls,
            generated_tokens: self.stats.generated_tokens,
            prefill_tokens: self.stats.prefill_tokens,
            sum_ttft_s: self.stats.sum_ttft_s,
            sum_queue_s: self.stats.sum_queue_s,
            sum_prefill_s: self.stats.t_prefill_s,
            sum_busy_s: self.stats.t_prefill_s + self.stats.t_decode_s,
            active_slots: self.slots.iter().filter(|s| s.is_some()).count(),
            kv_resident_bytes: self.kv.resident_kv_bytes(),
            kv_used_bytes: self.kv.used_kv_bytes(),
            deferred_admissions: self.stats.deferred_admissions,
        }
    }
}
