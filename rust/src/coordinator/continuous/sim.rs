//! Deterministic simulation backend (no artifacts, no PJRT).
//!
//! `SimBackend` implements [`DecodeBackend`] host-side: prefill writes a
//! value derived from (token, position) into every K/V entry of the slot,
//! and the next token is a hash over the row's *stored cache contents* in
//! [n_prefix, len).  Because generation reads back through the cache, any
//! scheduling bug — wrong slot, wrong position, stale data leaking into a
//! reused slot, a lost append — changes the emitted stream.  That makes
//! stream parity between the continuous engine and the run-to-completion
//! baseline a real cache-lifecycle correctness check, not a coincidence.
//!
//! The backend defaults to the PAGED cache layout and reads it directly
//! through `KvCache::k_at`/`v_at` (no dense materialization), so the parity
//! tests exercise the page tables themselves: a wrong page mapping, a leaked
//! or prematurely-freed page, or a stale mirror would corrupt the hash and
//! diverge the stream.  Use [`SimBackend::with_kv_layout`] to pin the dense
//! baseline or size a page pool explicitly.
//!
//! Optional per-call busy-wait costs model the fixed-geometry executable
//! economics (a prefill/decode call costs the same whatever rows are real),
//! which is what the continuous-vs-batch throughput bench measures.

use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::config::ModelConfig;
use crate::coordinator::failpoint::{names, FailAction, Failpoints};
use crate::coordinator::kvcache::{KvCache, KvLayout};
use crate::model::PrefixState;
use crate::tensor::Tensor;

use super::backend::{DecodeBackend, DecodeGroup, DecodeOut, PrefillJob, PrefillOut};

/// Burn wall time without sleeping (sub-millisecond precision).
fn spin(d: Duration) {
    if d.is_zero() {
        return;
    }
    let t = Instant::now();
    while t.elapsed() < d {
        std::hint::spin_loop();
    }
}

/// K/V storage value for `token` at cache position `pos` (small integers,
/// exactly representable in f32 so the hash round-trips).
fn kv_val(token: i32, pos: usize) -> f32 {
    ((token as i64 * 31 + pos as i64 * 7 + 3).rem_euclid(997)) as f32
}

pub struct SimBackend {
    pub cfg: ModelConfig,
    pub prefix: PrefixState,
    pub b_exec: usize,
    pub s_exec: usize,
    pub bos: i32,
    /// simulated wall cost of one prefill execution (whole batch)
    pub prefill_cost: Duration,
    /// simulated wall cost of one decode execution (whole batch)
    pub decode_cost: Duration,
    /// cache layout for [`DecodeBackend::new_cache`] (paged by default)
    pub kv_layout: KvLayout,
    /// fault-injection sites (`sim.prefill` / `sim.decode`): an armed
    /// [`FailAction::Error`] makes the call fail deterministically at an
    /// exact execution offset, exercising the engine-rebuild recovery paths
    pub failpoints: Failpoints,
}

impl SimBackend {
    pub fn new(b_exec: usize, s_exec: usize, n_prefix: usize, cache_max: usize) -> Self {
        let cfg = ModelConfig {
            name: "sim".into(),
            vocab_size: 271,
            d_model: 8,
            n_layers: 2,
            n_heads: 2,
            d_head: 4,
            d_ff: 16,
            o_model: n_prefix,
            inject_amp: 1.0,
            inject_delta: 0.1,
            max_prefix: n_prefix.max(1),
            train_seq: s_exec,
            eval_seq: s_exec,
            cache_max,
            sites: vec!["down_in".into()],
        };
        let pshape = [cfg.n_layers, cfg.n_heads, cfg.max_prefix, cfg.d_head];
        let prefix = PrefixState {
            tokens: vec![49; n_prefix],
            n_prefix: n_prefix as i32,
            n_ctx_sinks: n_prefix as i32,
            k: Tensor::full(&pshape, 41.5),
            v: Tensor::full(&pshape, 41.5),
        };
        Self {
            cfg,
            prefix,
            b_exec,
            s_exec,
            bos: 1,
            prefill_cost: Duration::ZERO,
            decode_cost: Duration::ZERO,
            kv_layout: KvLayout::Paged { page_size: 8, n_pages: 0 },
            failpoints: Failpoints::default(),
        }
    }

    pub fn with_costs(mut self, prefill: Duration, decode: Duration) -> Self {
        self.prefill_cost = prefill;
        self.decode_cost = decode;
        self
    }

    pub fn with_kv_layout(mut self, layout: KvLayout) -> Self {
        self.kv_layout = layout;
        self
    }

    /// Share a fault-injection handle with this backend (tests arm it to
    /// fail prefill or decode at exact call offsets).
    pub fn with_failpoints(mut self, failpoints: Failpoints) -> Self {
        self.failpoints = failpoints;
        self
    }

    /// FNV-style hash over row `row`'s stored K AND V values in
    /// [n_prefix, end), across every layer and head — so corruption anywhere
    /// in the row (wrong layer offset, missed V write, stale reset) changes
    /// the emitted stream, not just bugs on the (0, 0) plane.
    fn row_hash(&self, kv: &KvCache, row: usize, end: usize) -> u64 {
        let mut h: u64 = 0xcbf29ce484222325;
        for l in 0..kv.n_layers {
            for hd in 0..kv.n_heads {
                for s in kv.n_prefix..end {
                    // reads go through the layout's own mapping (page tables
                    // for the paged store), so a mapping bug diverges streams
                    let a = kv.k_at(l, row, hd, s)[0] as i64 as u64;
                    let b = kv.v_at(l, row, hd, s)[0] as i64 as u64;
                    h = h.wrapping_mul(0x100000001b3).wrapping_add(a.wrapping_add(1));
                    h = h.wrapping_mul(0x100000001b3).wrapping_add(b.wrapping_add(2));
                }
            }
        }
        h
    }

    /// Next token from a row hash, mixed with the request's sampling seed.
    /// Seed 0 (the default) is the identity — XOR with 0 — so unseeded
    /// streams are unchanged and all pre-seed parity fixtures stay valid;
    /// any other seed perturbs every emission deterministically.
    fn next_from(&self, h: u64, seed: u64) -> i32 {
        let h = h ^ seed.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        3 + (h % (self.cfg.vocab_size as u64 - 3)) as i32
    }

    fn is_sink(tok: i32) -> bool {
        tok % 29 == 0
    }

    /// Write one token's K/V into `slot` at its current length.
    fn write_token(&self, kv: &mut KvCache, slot: usize, token: i32) -> Result<()> {
        let pos = kv.row_len(slot);
        let val = kv_val(token, pos);
        let shape = [self.cfg.n_layers, self.cfg.n_heads, self.cfg.d_head];
        let t = Tensor::full(&shape, val);
        kv.append_token_row(slot, &t, &t)
    }
}

impl DecodeBackend for SimBackend {
    fn batch_slots(&self) -> usize {
        self.b_exec
    }

    fn max_prompt_tokens(&self) -> usize {
        self.s_exec
    }

    fn cache_capacity(&self) -> usize {
        self.cfg.cache_max
    }

    fn bos(&self) -> i32 {
        self.bos
    }

    fn new_cache(&self) -> Result<KvCache> {
        let mut kv = KvCache::with_layout(&self.cfg, self.b_exec, self.kv_layout);
        kv.install_prefix(&self.prefix)?;
        Ok(kv)
    }

    fn prefill(&self, kv: &mut KvCache, jobs: &[PrefillJob]) -> Result<Vec<PrefillOut>> {
        if jobs.len() > self.b_exec {
            bail!("prefill wave {} exceeds batch {}", jobs.len(), self.b_exec);
        }
        if let Some(FailAction::Error) = self.failpoints.fire(names::SIM_PREFILL) {
            bail!("injected fault: prefill failed (failpoint {})", names::SIM_PREFILL);
        }
        spin(self.prefill_cost);
        let mut outs = Vec::with_capacity(jobs.len());
        for j in jobs {
            // the row's full sequence is BOS + prompt + resumed tokens (the
            // latter re-prefilled after a preemption); this call writes the
            // job's [start, end) span of it
            let total = j.total_tokens();
            if total > self.s_exec {
                bail!("prompt length {total} exceeds seq {}", self.s_exec);
            }
            if j.start >= j.end || j.end > total {
                bail!("invalid prefill span [{}, {}) of {total} tokens", j.start, j.end);
            }
            if kv.row_len(j.slot) != kv.n_prefix + j.start {
                bail!(
                    "prefill span start {} into slot {} at len {} (chunks must be contiguous)",
                    j.start,
                    j.slot,
                    kv.row_len(j.slot)
                );
            }
            for pos in j.start..j.end {
                let tok = if pos == 0 {
                    self.bos
                } else if pos - 1 < j.req.prompt.len() {
                    j.req.prompt[pos - 1]
                } else {
                    j.resumed[pos - 1 - j.req.prompt.len()]
                };
                self.write_token(kv, j.slot, tok)?;
            }
            if j.end < total {
                outs.push(PrefillOut { slot: j.slot, first_token: None, n_sinks: 0 });
                continue;
            }
            // sinks accumulate over the whole sequence, like the incremental
            // decode path would have counted them
            let mut n_sinks = self.prefix.n_ctx_sinks;
            if Self::is_sink(self.bos) {
                n_sinks += 1;
            }
            for &tok in j.req.prompt.iter().chain(j.resumed.iter()) {
                if Self::is_sink(tok) {
                    n_sinks += 1;
                }
            }
            let h = self.row_hash(kv, j.slot, kv.row_len(j.slot));
            outs.push(PrefillOut {
                slot: j.slot,
                first_token: Some(self.next_from(h, j.req.seed)),
                n_sinks,
            });
        }
        Ok(outs)
    }

    fn decode(&self, kv: &mut KvCache, group: &DecodeGroup) -> Result<Vec<DecodeOut>> {
        if let Some(FailAction::Error) = self.failpoints.fire(names::SIM_DECODE) {
            bail!("injected fault: decode failed (failpoint {})", names::SIM_DECODE);
        }
        spin(self.decode_cost);
        let mut outs = Vec::with_capacity(group.rows.len());
        for (i, &row) in group.rows.iter().enumerate() {
            if kv.row_len(row) != group.len {
                bail!("decode group len {} but row {row} at {}", group.len, kv.row_len(row));
            }
            let tok = group.tokens[i];
            self.write_token(kv, row, tok)?;
            let h = self.row_hash(kv, row, kv.row_len(row));
            let mut n_sinks = group.n_sinks[i];
            if Self::is_sink(tok) {
                n_sinks += 1;
            }
            let seed = group.seeds.get(i).copied().unwrap_or(0);
            outs.push(DecodeOut { row, next_token: self.next_from(h, seed), n_sinks });
        }
        Ok(outs)
    }
}

#[cfg(test)]
mod tests {
    use super::super::backend::run_to_completion;
    use super::*;
    use crate::coordinator::request::{FinishReason, GenRequest};

    fn req(id: u64, prompt: Vec<i32>, max_new: usize) -> GenRequest {
        GenRequest::new(id, prompt, max_new)
    }

    #[test]
    fn deterministic_and_row_independent() {
        let be = SimBackend::new(3, 16, 2, 48);
        // same prompt in two rows of one batch → identical streams
        let reqs =
            vec![req(0, vec![5, 6, 7], 5), req(1, vec![5, 6, 7], 5), req(2, vec![9, 9], 5)];
        let r = run_to_completion(&be, &reqs).unwrap();
        assert_eq!(r[0].tokens, r[1].tokens);
        assert_eq!(r[0].tokens.len(), 5);
        // the same request alone → same stream (rows don't interact)
        let solo = run_to_completion(&be, &[req(7, vec![9, 9], 5)]).unwrap();
        assert_eq!(solo[0].tokens, r[2].tokens);
        // different prompts diverge
        assert_ne!(r[0].tokens, r[2].tokens);
    }

    #[test]
    fn paged_and_dense_layouts_agree() {
        // the stream hashes stored cache contents, so layout-independent
        // streams mean the page tables map exactly what the dense rows hold
        let reqs =
            vec![req(0, vec![5, 6, 7, 8, 9], 6), req(1, vec![4, 4], 3), req(2, vec![30], 5)];
        let paged = SimBackend::new(3, 16, 2, 48); // paged by default
        let dense = SimBackend::new(3, 16, 2, 48).with_kv_layout(KvLayout::Dense);
        let a = run_to_completion(&paged, &reqs).unwrap();
        let b = run_to_completion(&dense, &reqs).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.tokens, y.tokens, "layouts diverged for request {}", x.id);
        }
    }

    #[test]
    fn respects_max_new_and_cache_bounds() {
        let be = SimBackend::new(2, 16, 1, 8);
        // cache 8, prefix 1, prompt 3+BOS → 3 free positions: the stream
        // stops when the row is full even though max_new asks for more
        let r = run_to_completion(&be, &[req(0, vec![4, 5, 6], 50)]).unwrap();
        assert!(r[0].tokens.len() < 50 && !r[0].tokens.is_empty());
        assert_eq!(r[0].finish, FinishReason::CacheFull);
        let r0 = run_to_completion(&be, &[req(0, vec![4, 5, 6], 0)]).unwrap();
        assert!(r0[0].tokens.is_empty());
    }

    #[test]
    fn stop_tokens_end_streams_early() {
        let be = SimBackend::new(2, 16, 2, 48);
        // discover the free-running stream, then re-run stopping at one of
        // its tokens: the stopped stream must be the prefix up to and
        // including the first occurrence of the stop token
        let free = run_to_completion(&be, &[req(0, vec![5, 6, 7], 6)]).unwrap();
        assert_eq!(free[0].finish, FinishReason::Length);
        let stop_at = free[0].tokens[2];
        let first = free[0].tokens.iter().position(|&t| t == stop_at).unwrap();
        let mut r = req(0, vec![5, 6, 7], 6);
        r.stop_tokens = vec![stop_at];
        let stopped = run_to_completion(&be, &[r]).unwrap();
        assert_eq!(stopped[0].finish, FinishReason::Stop);
        assert_eq!(stopped[0].tokens, free[0].tokens[..=first].to_vec());
    }

    /// The sampling seed perturbs every emission deterministically, and the
    /// default seed 0 leaves the stream exactly as the unseeded hash produced
    /// it (the identity property the pre-seed parity fixtures rely on).
    #[test]
    fn seed_perturbs_streams_and_zero_is_identity() {
        let be = SimBackend::new(2, 16, 2, 48);
        let base = run_to_completion(&be, &[req(0, vec![5, 6, 7], 5)]).unwrap();
        let mut seeded = req(0, vec![5, 6, 7], 5);
        seeded.seed = 0xA11CE;
        let s1 = run_to_completion(&be, &[seeded.clone()]).unwrap();
        let s2 = run_to_completion(&be, &[seeded]).unwrap();
        assert_eq!(s1[0].tokens, s2[0].tokens, "seeded streams are deterministic");
        assert_ne!(s1[0].tokens, base[0].tokens, "a nonzero seed perturbs the stream");
        let zero = run_to_completion(&be, &[req(0, vec![5, 6, 7], 5)]).unwrap();
        assert_eq!(zero[0].tokens, base[0].tokens, "seed 0 is the identity");
    }

    /// An armed failpoint fails exactly one call at the chosen offset, then
    /// disarms — the determinism the crash-recovery tests schedule against.
    #[test]
    fn failpoints_fire_once_at_exact_offsets() {
        let fp = Failpoints::default();
        let be = SimBackend::new(2, 16, 2, 48).with_failpoints(fp.clone());
        let r = req(0, vec![5, 6, 7], 4);
        // skip 0 → the first decode call fails, later ones succeed
        fp.arm(names::SIM_DECODE, 0, FailAction::Error);
        assert!(run_to_completion(&be, &[r.clone()]).is_err());
        assert_eq!(fp.fired(names::SIM_DECODE), 1);
        let ok = run_to_completion(&be, &[r.clone()]).unwrap();
        assert_eq!(ok[0].tokens.len(), 4, "failpoint is one-shot");
        // prefill site is independent of the decode site
        fp.arm(names::SIM_PREFILL, 0, FailAction::Error);
        assert!(run_to_completion(&be, &[r]).is_err());
    }

    /// Chunked prefill through the backend: writing a prompt in bounded
    /// spans yields the same first token and row contents as one full pass.
    #[test]
    fn chunked_prefill_matches_full() {
        let be = SimBackend::new(2, 24, 2, 48);
        let r = req(0, vec![5, 9, 6, 7, 8, 4, 11, 3], 4);
        let total = r.prompt.len() + 1;

        let mut kv_full = be.new_cache().unwrap();
        let full =
            be.prefill(&mut kv_full, &[PrefillJob::full(0, &r)]).unwrap().remove(0);

        let mut kv_chunk = be.new_cache().unwrap();
        let mut written = 0usize;
        let mut last = None;
        while written < total {
            let end = (written + 3).min(total);
            let job = PrefillJob { slot: 0, req: &r, resumed: &[], start: written, end };
            let out = be.prefill(&mut kv_chunk, &[job]).unwrap().remove(0);
            if end < total {
                assert!(out.first_token.is_none(), "incomplete span must not emit");
            }
            last = Some(out);
            written = end;
        }
        let last = last.unwrap();
        assert_eq!(last.first_token, full.first_token);
        assert_eq!(last.n_sinks, full.n_sinks);
        assert_eq!(kv_chunk.row_len(0), kv_full.row_len(0));
    }
}
