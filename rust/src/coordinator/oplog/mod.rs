//! Durable request oplog: an append-only, CRC-framed journal of everything
//! the cluster router decides and observes, with torn-tail recovery and
//! bit-identical replay.
//!
//! Golem-style idea, serving-shaped: because PrefixQuant's prefixed K/V is
//! deterministic and artifact-derived, a request's entire state is its
//! parameters plus the tokens emitted so far — so journaling admissions,
//! dispatch/resume decisions, tokens, and terminal outcomes is enough to
//! (a) resume any in-flight stream on a fresh fleet after a crash
//! ([`crate::coordinator::Router::recover`]) and (b) re-execute a whole
//! captured trace bit-identically ([`replay::replay`], `pq replay`).
//!
//! Durability model: every [`Oplog::append`] issues one `write_all` of a
//! complete frame (no user-space buffering), so an OS-level crash can tear
//! at most the final frame; `fsync` is deliberately NOT issued per append —
//! the ≤5% journaling-overhead budget buys process-crash and
//! restart-durability, not power-loss durability.  [`Oplog::open_recover`]
//! scans the frame sequence, keeps every complete entry, truncates the torn
//! tail, and reports what was dropped.  A log whose append failed (disk
//! error, injected torn write) wedges: further appends error and the router
//! downgrades to journal-less serving rather than crashing.

pub mod entry;
pub mod frame;
pub mod replay;

use std::fs::{File, OpenOptions};
use std::io::{Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

pub use entry::{BackendDesc, OpEntry, Outcome, RequestRecord, TraceView, FORMAT_VERSION};
pub use replay::{replay, ReplayReport};

use crate::coordinator::failpoint::{names, FailAction, Failpoints};

/// Append handle over one journal file (see module docs).
#[derive(Debug)]
pub struct Oplog {
    file: File,
    path: PathBuf,
    /// set after a failed append: the file may end in a torn frame, so no
    /// further appends are allowed (recovery will truncate the tail)
    wedged: bool,
    failpoints: Failpoints,
}

/// What [`Oplog::open_recover`] salvaged.
#[derive(Debug)]
pub struct Recovered {
    /// every complete, checksum-valid, decodable entry, in file order
    pub entries: Vec<OpEntry>,
    /// torn-tail bytes truncated from the file
    pub dropped_bytes: u64,
}

impl Oplog {
    /// Create (truncating) a new journal at `path`, writing the magic and a
    /// header entry describing the backend.
    pub fn create(path: impl AsRef<Path>, backend: &BackendDesc) -> Result<Oplog> {
        Oplog::create_with_failpoints(path, backend, Failpoints::default())
    }

    /// [`Oplog::create`] with a shared fault-injection handle (tests arm
    /// `oplog.append` to leave torn frames at exact append offsets).
    pub fn create_with_failpoints(
        path: impl AsRef<Path>,
        backend: &BackendDesc,
        failpoints: Failpoints,
    ) -> Result<Oplog> {
        let path = path.as_ref();
        let mut file = OpenOptions::new()
            .create(true)
            .write(true)
            .truncate(true)
            .open(path)
            .with_context(|| format!("create oplog {}", path.display()))?;
        file.write_all(frame::MAGIC)?;
        let mut log = Oplog { file, path: path.to_path_buf(), wedged: false, failpoints };
        log.append(&OpEntry::Header { version: FORMAT_VERSION, backend: backend.clone() })?;
        Ok(log)
    }

    /// Open an existing journal: decode every complete entry, truncate any
    /// torn tail in place, and return the log positioned for appending.
    /// Never panics on damaged input; a file without the oplog magic is an
    /// error (that is not a torn tail — it was never a journal).
    pub fn open_recover(path: impl AsRef<Path>) -> Result<(Oplog, Recovered)> {
        let path = path.as_ref();
        let bytes =
            std::fs::read(path).with_context(|| format!("read oplog {}", path.display()))?;
        if bytes.len() < frame::MAGIC.len() || !bytes.starts_with(frame::MAGIC) {
            bail!("{}: not an oplog (bad or missing magic)", path.display());
        }
        let scan = frame::scan(&bytes[frame::MAGIC.len()..]);
        // a CRC-valid but undecodable frame is corruption too: surrender it
        // and everything after it, same as a torn tail
        let mut entries = Vec::with_capacity(scan.frames.len());
        let mut good_len = 0u64;
        for payload in &scan.frames {
            match OpEntry::decode(payload) {
                Ok(e) => {
                    entries.push(e);
                    good_len += (frame::FRAME_HEADER + payload.len()) as u64;
                }
                Err(_) => break,
            }
        }
        let keep = frame::MAGIC.len() as u64 + good_len;
        let dropped_bytes = bytes.len() as u64 - keep;
        let mut file = OpenOptions::new()
            .read(true)
            .write(true)
            .open(path)
            .with_context(|| format!("reopen oplog {}", path.display()))?;
        if dropped_bytes > 0 {
            file.set_len(keep).context("truncate torn oplog tail")?;
        }
        file.seek(SeekFrom::End(0))?;
        let log = Oplog {
            file,
            path: path.to_path_buf(),
            wedged: false,
            failpoints: Failpoints::default(),
        };
        Ok((log, Recovered { entries, dropped_bytes }))
    }

    /// Append one entry as a complete frame (single `write_all`).  After any
    /// failure the log is wedged: the file may end mid-frame, so appends stop
    /// and the caller should continue without journaling.
    pub fn append(&mut self, e: &OpEntry) -> Result<()> {
        if self.wedged {
            bail!("oplog {} is wedged after a failed append", self.path.display());
        }
        let buf = frame::encode_frame(&e.encode());
        match self.failpoints.fire(names::OPLOG_APPEND) {
            Some(FailAction::Torn(n)) => {
                // persist a deliberately torn frame, then fail the append
                let n = n.min(buf.len());
                let _ = self.file.write_all(&buf[..n]);
                self.wedged = true;
                bail!("injected fault: oplog append torn after {n} of {} bytes", buf.len());
            }
            Some(_) => {
                self.wedged = true;
                bail!("injected fault: oplog append failed");
            }
            None => {}
        }
        if let Err(err) = self.file.write_all(&buf) {
            self.wedged = true;
            return Err(err).with_context(|| format!("append to oplog {}", self.path.display()));
        }
        Ok(())
    }

    /// Whether appends have been stopped by a failed write.
    pub fn is_wedged(&self) -> bool {
        self.wedged
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

/// What [`compact`] did to a journal.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CompactReport {
    /// entries in the compacted file (including its fresh header)
    pub kept_entries: usize,
    /// entries of the original file that were dropped
    pub dropped_entries: usize,
    /// fully-finished request records whose entries were dropped
    pub dropped_requests: usize,
    pub bytes_before: u64,
    pub bytes_after: u64,
}

/// Rewrite the journal at `path` without the records of fully-finished
/// requests: recovery only replays unfinished streams, so their entries are
/// dead weight a long-running router accretes without bound.  Kept verbatim:
/// every entry of every unfinished request, every `WorkerLost` and
/// `WorkerRestarted` event, and
/// the full record of the finished request holding the overall max `seq`
/// (recovery restarts the router's sequence counter above it — dropping
/// that record would let a recovered router re-issue journaled ids).  The
/// rewrite goes to a sibling temp file that replaces the original via
/// rename, so a crash mid-compaction leaves the original journal intact.
/// Any torn tail is dropped with the finished records.
pub fn compact(path: impl AsRef<Path>) -> Result<CompactReport> {
    let path = path.as_ref();
    let rec = read_log(path)?;
    let bytes_before = std::fs::metadata(path)
        .with_context(|| format!("stat oplog {}", path.display()))?
        .len();
    let view = TraceView::from_entries(&rec.entries);
    let Some(backend) = view.backend.clone() else {
        bail!("{}: cannot compact a journal without a header entry", path.display());
    };
    let mut keep: std::collections::HashSet<u64> = view.unfinished().map(|r| r.seq).collect();
    if let Some(max) = view.max_seq() {
        keep.insert(max);
    }
    let dropped_requests = view.records.iter().filter(|r| !keep.contains(&r.seq)).count();

    let tmp = path.with_extension("compact-tmp");
    let mut out = Oplog::create(&tmp, &backend)
        .with_context(|| format!("create compaction temp {}", tmp.display()))?;
    let mut kept_entries = 1usize; // the fresh header
    for e in &rec.entries {
        let carry = match e {
            // the temp file already opens with an equivalent header
            OpEntry::Header { .. } => false,
            OpEntry::WorkerLost { .. } | OpEntry::WorkerRestarted { .. } => true,
            OpEntry::Admitted { seq, .. }
            | OpEntry::Dispatched { seq, .. }
            | OpEntry::Token { seq, .. }
            | OpEntry::Finished { seq, .. }
            | OpEntry::Resumed { seq, .. } => keep.contains(seq),
        };
        if carry {
            out.append(e)?;
            kept_entries += 1;
        }
    }
    drop(out);
    std::fs::rename(&tmp, path)
        .with_context(|| format!("replace {} with compacted journal", path.display()))?;
    let bytes_after = std::fs::metadata(path)
        .with_context(|| format!("stat compacted oplog {}", path.display()))?
        .len();
    Ok(CompactReport {
        kept_entries,
        // the fresh header stands in for the original one, so the header
        // counts as carried, not dropped
        dropped_entries: rec.entries.len().saturating_sub(kept_entries),
        dropped_requests,
        bytes_before,
        bytes_after,
    })
}

/// Read-only load of a journal (no truncation, no append handle): the
/// decodable entry prefix plus the byte count of any torn tail.
pub fn read_log(path: impl AsRef<Path>) -> Result<Recovered> {
    let path = path.as_ref();
    let bytes = std::fs::read(path).with_context(|| format!("read oplog {}", path.display()))?;
    if bytes.len() < frame::MAGIC.len() || !bytes.starts_with(frame::MAGIC) {
        bail!("{}: not an oplog (bad or missing magic)", path.display());
    }
    let scan = frame::scan(&bytes[frame::MAGIC.len()..]);
    let mut entries = Vec::with_capacity(scan.frames.len());
    let mut good_len = 0u64;
    for payload in &scan.frames {
        match OpEntry::decode(payload) {
            Ok(e) => {
                entries.push(e);
                good_len += (frame::FRAME_HEADER + payload.len()) as u64;
            }
            Err(_) => break,
        }
    }
    let keep = frame::MAGIC.len() as u64 + good_len;
    Ok(Recovered { entries, dropped_bytes: bytes.len() as u64 - keep })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("pq-oplog-{name}-{}", std::process::id()));
        p
    }

    fn sim_desc() -> BackendDesc {
        BackendDesc::Sim { b_exec: 2, s_exec: 16, n_prefix: 1, cache_max: 64 }
    }

    #[test]
    fn create_append_recover_round_trips() {
        let path = tmp("roundtrip");
        let mut log = Oplog::create(&path, &sim_desc()).unwrap();
        let req = crate::coordinator::GenRequest::new(0, vec![5, 6], 3);
        log.append(&OpEntry::Admitted { seq: 0, req }).unwrap();
        log.append(&OpEntry::Dispatched { seq: 0, worker: 1 }).unwrap();
        log.append(&OpEntry::Token { seq: 0, token: 7 }).unwrap();
        drop(log);

        let (_log, rec) = Oplog::open_recover(&path).unwrap();
        assert_eq!(rec.dropped_bytes, 0);
        assert_eq!(rec.entries.len(), 4, "header + 3 appends");
        assert!(matches!(rec.entries[0], OpEntry::Header { .. }));
        let view = TraceView::from_entries(&rec.entries);
        assert_eq!(view.backend, Some(sim_desc()));
        assert_eq!(view.records.len(), 1);
        assert_eq!(view.records[0].tokens, vec![7]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn recovered_log_accepts_further_appends() {
        let path = tmp("reappend");
        let mut log = Oplog::create(&path, &sim_desc()).unwrap();
        log.append(&OpEntry::Token { seq: 0, token: 1 }).unwrap();
        drop(log);
        let (mut log, _) = Oplog::open_recover(&path).unwrap();
        log.append(&OpEntry::Token { seq: 0, token: 2 }).unwrap();
        drop(log);
        let rec = read_log(&path).unwrap();
        let toks: Vec<i32> = rec
            .entries
            .iter()
            .filter_map(|e| match e {
                OpEntry::Token { token, .. } => Some(*token),
                _ => None,
            })
            .collect();
        assert_eq!(toks, vec![1, 2], "appends after recovery extend the same stream");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn torn_append_failpoint_wedges_and_recovery_drops_the_tail() {
        let path = tmp("torn");
        let fp = Failpoints::default();
        let mut log = Oplog::create_with_failpoints(&path, &sim_desc(), fp.clone()).unwrap();
        log.append(&OpEntry::Token { seq: 0, token: 1 }).unwrap();
        fp.arm(names::OPLOG_APPEND, 0, FailAction::Torn(5));
        assert!(log.append(&OpEntry::Token { seq: 0, token: 2 }).is_err());
        assert!(log.is_wedged());
        assert!(log.append(&OpEntry::Token { seq: 0, token: 3 }).is_err(), "wedged stays wedged");
        drop(log);

        let (_log, rec) = Oplog::open_recover(&path).unwrap();
        assert_eq!(rec.dropped_bytes, 5, "the torn frame's bytes are surrendered");
        assert_eq!(rec.entries.len(), 2, "header + the one complete token");
        // the file itself was truncated back to the good prefix
        let again = read_log(&path).unwrap();
        assert_eq!(again.dropped_bytes, 0);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn compact_drops_finished_records_and_preserves_the_rest() {
        use crate::coordinator::cluster::DrainCause;
        use crate::coordinator::request::FinishReason;
        use crate::coordinator::GenRequest;

        let path = tmp("compact");
        let mut log = Oplog::create(&path, &sim_desc()).unwrap();
        // seq 0 finished (droppable); seq 1 unfinished (kept verbatim);
        // seq 2 finished but holds the overall max seq (kept)
        log.append(&OpEntry::Admitted { seq: 0, req: GenRequest::new(0, vec![1], 2) }).unwrap();
        log.append(&OpEntry::Dispatched { seq: 0, worker: 0 }).unwrap();
        log.append(&OpEntry::Token { seq: 0, token: 9 }).unwrap();
        log.append(&OpEntry::Finished {
            seq: 0,
            outcome: Outcome::Finish(FinishReason::Length),
            n_tokens: 1,
        })
        .unwrap();
        log.append(&OpEntry::Admitted { seq: 1, req: GenRequest::new(1, vec![2], 2) }).unwrap();
        log.append(&OpEntry::Dispatched { seq: 1, worker: 1 }).unwrap();
        log.append(&OpEntry::Token { seq: 1, token: 4 }).unwrap();
        log.append(&OpEntry::WorkerLost { worker: 0, cause: DrainCause::Killed }).unwrap();
        log.append(&OpEntry::Admitted { seq: 2, req: GenRequest::new(2, vec![3], 1) }).unwrap();
        log.append(&OpEntry::Finished {
            seq: 2,
            outcome: Outcome::Finish(FinishReason::Length),
            n_tokens: 0,
        })
        .unwrap();
        drop(log);

        let report = compact(&path).unwrap();
        assert_eq!(report.dropped_requests, 1, "only seq 0 drops (seq 2 holds max seq)");
        assert_eq!(report.dropped_entries, 4, "seq 0's four entries");
        assert!(report.bytes_after < report.bytes_before);

        let after = read_log(&path).unwrap();
        assert_eq!(after.dropped_bytes, 0);
        let view = TraceView::from_entries(&after.entries);
        assert_eq!(view.backend, Some(sim_desc()));
        assert_eq!(view.records.len(), 2);
        assert_eq!(view.max_seq(), Some(2), "recovery's seq restart point survives");
        let unfinished: Vec<u64> = view.unfinished().map(|r| r.seq).collect();
        assert_eq!(unfinished, vec![1]);
        assert_eq!(view.records[0].tokens, vec![4], "seq 1 kept verbatim");
        assert_eq!(view.worker_events, 1, "WorkerLost survives compaction");

        // compacting an already-compacted journal changes nothing
        let again = compact(&path).unwrap();
        assert_eq!(again.dropped_requests, 0);
        assert_eq!(again.bytes_after, report.bytes_after);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn non_oplog_files_are_rejected_not_recovered() {
        let path = tmp("notalog");
        std::fs::write(&path, b"definitely not a journal").unwrap();
        assert!(Oplog::open_recover(&path).is_err());
        assert!(read_log(&path).is_err());
        std::fs::write(&path, b"PQ").unwrap();
        assert!(Oplog::open_recover(&path).is_err(), "short magic");
        std::fs::remove_file(&path).ok();
    }
}
