//! Byte-level journal framing: CRC-checked length-prefixed frames behind an
//! 8-byte file magic.
//!
//! Layout after the magic: a sequence of `[len: u32 LE][crc32(payload): u32
//! LE][payload]` frames.  Appends are a single `write_all` of one whole
//! frame, so the only corruption a crash can introduce is at the TAIL: a
//! short frame header, a short payload, or a payload whose checksum does not
//! match.  [`scan`] walks frames until the first such defect and reports
//! everything from there as the torn tail — recovery keeps the complete
//! prefix and truncates the rest.  (A flipped bit in the middle of an
//! otherwise-complete file also lands here: the scan conservatively stops at
//! the damaged frame, surrendering the suffix rather than resynchronizing on
//! ambiguous bytes.)

use std::sync::OnceLock;

/// File magic identifying an oplog (and its framing version).
pub const MAGIC: &[u8; 8] = b"PQOPLOG1";

/// Sanity bound on one frame's payload: a torn length field must not make
/// the scanner treat gigabytes of garbage as "incomplete frame, keep
/// waiting" — anything over this is corruption.
pub const MAX_PAYLOAD: usize = 1 << 24;

/// Frame header bytes (length + checksum).
pub const FRAME_HEADER: usize = 8;

fn crc_table() -> &'static [u32; 256] {
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    })
}

/// CRC-32 (IEEE 802.3 / zlib polynomial, reflected).
pub fn crc32(data: &[u8]) -> u32 {
    let t = crc_table();
    let mut c = !0u32;
    for &b in data {
        c = t[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

/// Encode one payload as a complete frame.
pub fn encode_frame(payload: &[u8]) -> Vec<u8> {
    assert!(payload.len() <= MAX_PAYLOAD, "oplog frame exceeds the size bound");
    let mut out = Vec::with_capacity(FRAME_HEADER + payload.len());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&crc32(payload).to_le_bytes());
    out.extend_from_slice(payload);
    out
}

/// Result of scanning the frame region (the bytes after [`MAGIC`]).
#[derive(Debug)]
pub struct Scan {
    /// payloads of every complete, checksum-valid frame, in file order
    pub frames: Vec<Vec<u8>>,
    /// bytes (past the magic) covered by those frames
    pub good_len: u64,
    /// trailing bytes surrendered as the torn tail
    pub dropped_bytes: u64,
}

/// Walk frames until the first short, oversized, or checksum-failing one;
/// everything from there on is the torn tail.  Never panics, whatever the
/// input bytes.
pub fn scan(body: &[u8]) -> Scan {
    let mut frames = Vec::new();
    let mut off = 0usize;
    while body.len() - off >= FRAME_HEADER {
        let len = u32::from_le_bytes(body[off..off + 4].try_into().unwrap()) as usize;
        let crc = u32::from_le_bytes(body[off + 4..off + 8].try_into().unwrap());
        if len > MAX_PAYLOAD || body.len() - off - FRAME_HEADER < len {
            break;
        }
        let payload = &body[off + FRAME_HEADER..off + FRAME_HEADER + len];
        if crc32(payload) != crc {
            break;
        }
        frames.push(payload.to_vec());
        off += FRAME_HEADER + len;
    }
    Scan { frames, good_len: off as u64, dropped_bytes: (body.len() - off) as u64 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_matches_the_reference_vector() {
        // the canonical IEEE CRC-32 check value
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn frames_round_trip_in_order() {
        let payloads: Vec<Vec<u8>> = vec![vec![], vec![1], vec![2; 300], b"hello".to_vec()];
        let mut body = Vec::new();
        for p in &payloads {
            body.extend_from_slice(&encode_frame(p));
        }
        let s = scan(&body);
        assert_eq!(s.frames, payloads);
        assert_eq!(s.good_len, body.len() as u64);
        assert_eq!(s.dropped_bytes, 0);
    }

    #[test]
    fn truncation_anywhere_keeps_the_complete_prefix() {
        let payloads: Vec<Vec<u8>> = (0..4u8).map(|i| vec![i; 5 + i as usize]).collect();
        let mut body = Vec::new();
        let mut ends = Vec::new();
        for p in &payloads {
            body.extend_from_slice(&encode_frame(p));
            ends.push(body.len());
        }
        for cut in 0..=body.len() {
            let s = scan(&body[..cut]);
            let complete = ends.iter().filter(|&&e| e <= cut).count();
            assert_eq!(s.frames.len(), complete, "cut at {cut}");
            assert_eq!(s.frames, payloads[..complete].to_vec());
            assert_eq!(s.good_len, if complete == 0 { 0 } else { ends[complete - 1] as u64 });
            assert_eq!(s.dropped_bytes as usize, cut - s.good_len as usize);
        }
    }

    #[test]
    fn a_flipped_bit_surrenders_from_the_damaged_frame() {
        let payloads: Vec<Vec<u8>> = (0..3u8).map(|i| vec![i ^ 0x5A; 9]).collect();
        let mut body = Vec::new();
        let mut ends = Vec::new();
        for p in &payloads {
            body.extend_from_slice(&encode_frame(p));
            ends.push(body.len());
        }
        for byte in 0..body.len() {
            let mut dam = body.clone();
            dam[byte] ^= 0x10;
            let s = scan(&dam);
            // frames strictly before the damaged one survive intact and in
            // order; CRC-32 catches every single-bit payload flip, so the
            // damaged frame itself is never silently accepted
            let damaged_frame = ends.iter().position(|&e| byte < e).unwrap();
            assert!(s.frames.len() >= damaged_frame, "flip at {byte}: lost an undamaged frame");
            for (i, f) in s.frames.iter().enumerate().take(damaged_frame) {
                assert_eq!(f, &payloads[i], "flip at {byte}: frame {i} corrupted silently");
            }
            for f in s.frames.iter().skip(damaged_frame) {
                assert!(
                    payloads.contains(f),
                    "flip at {byte}: scan accepted a corrupted payload"
                );
            }
        }
    }

    #[test]
    fn oversized_length_field_is_corruption_not_a_wait() {
        let mut body = encode_frame(b"ok");
        body.extend_from_slice(&(u32::MAX).to_le_bytes());
        body.extend_from_slice(&[0; 40]);
        let s = scan(&body);
        assert_eq!(s.frames.len(), 1);
        assert_eq!(s.dropped_bytes, 44);
    }
}
