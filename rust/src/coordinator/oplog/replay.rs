//! Deterministic re-execution of a captured trace (`pq replay`).
//!
//! The sim backend's next token is a pure function of the request's own
//! sequence (batching-independent), and the model backend decodes greedily —
//! so any journaled stream that finished for a DETERMINISTIC reason (length
//! budget, stop token, cache full) must reproduce token for token on a fresh
//! fleet, whatever the scheduling interleave.  Streams cut short by external
//! events (cancellation, a lost worker, an error, or a crash that left them
//! unfinished) are checked by prefix instead: the journaled tokens and the
//! replayed tokens must agree on their common prefix.

use std::time::Instant;

use anyhow::Result;

use crate::coordinator::cluster::Router;

use super::entry::{Outcome, TraceView};

/// What [`replay`] observed, stream by stream.
#[derive(Debug, Clone, Default)]
pub struct ReplayReport {
    /// journaled requests re-executed
    pub total: usize,
    /// deterministic finishes that reproduced their tokens exactly
    pub exact: usize,
    /// non-deterministic records whose prefix relation held
    pub prefix_ok: usize,
    /// sequence numbers whose replay contradicted the journal
    pub mismatched: Vec<u64>,
    /// tokens produced by the replay run
    pub replayed_tokens: usize,
    /// wall time of the replay run
    pub wall_s: f64,
}

impl ReplayReport {
    /// A replay is bit-identical when no stream contradicted the journal.
    pub fn ok(&self) -> bool {
        self.mismatched.is_empty()
    }
}

/// Whether `a` and `b` agree on their common prefix (either may be the
/// longer stream).
fn prefix_agrees(a: &[i32], b: &[i32]) -> bool {
    let n = a.len().min(b.len());
    a[..n] == b[..n]
}

/// Re-execute every journaled request of `view` against `router` and compare
/// the streams (see the module docs for the exact/prefix split).  Requests
/// are submitted in `seq` order and pipelined; the router's scheduling is
/// free to interleave them differently from the original run — determinism
/// comes from the backend, not the schedule.
pub fn replay(view: &TraceView, router: &Router) -> Result<ReplayReport> {
    let t0 = Instant::now();
    let mut report = ReplayReport { total: view.records.len(), ..ReplayReport::default() };
    let mut handles = Vec::with_capacity(view.records.len());
    for rec in &view.records {
        handles.push(router.submit(rec.req.clone())?);
    }
    for (rec, h) in view.records.iter().zip(handles) {
        let got = h.collect();
        let deterministic = rec.finish.is_some_and(|o| o.deterministic());
        match got {
            Ok(resp) => {
                report.replayed_tokens += resp.tokens.len();
                if deterministic {
                    if resp.tokens == rec.tokens {
                        report.exact += 1;
                    } else {
                        report.mismatched.push(rec.seq);
                    }
                } else if prefix_agrees(&resp.tokens, &rec.tokens) {
                    report.prefix_ok += 1;
                } else {
                    report.mismatched.push(rec.seq);
                }
            }
            Err(_) => {
                // an error is consistent only with a journaled error outcome
                if rec.finish == Some(Outcome::Error) {
                    report.prefix_ok += 1;
                } else {
                    report.mismatched.push(rec.seq);
                }
            }
        }
    }
    report.wall_s = t0.elapsed().as_secs_f64();
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefix_relation_is_symmetric_and_positional() {
        assert!(prefix_agrees(&[1, 2, 3], &[1, 2]));
        assert!(prefix_agrees(&[1, 2], &[1, 2, 3]));
        assert!(prefix_agrees(&[], &[9]));
        assert!(!prefix_agrees(&[1, 2, 3], &[1, 9]));
    }
}
