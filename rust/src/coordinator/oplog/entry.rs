//! Typed oplog entries and the trace view reconstructed from them.
//!
//! Every entry encodes to a self-contained little-endian payload (one frame
//! in the journal).  The set covers the full request lifecycle the router
//! observes: admission (the complete `GenRequest`, seed included), dispatch
//! and resume decisions, every emitted token, terminal outcomes, and worker
//! lifecycle events — enough to (a) resume any in-flight stream from its
//! last journaled token and (b) re-execute the whole trace bit-identically.
//!
//! [`TraceView::from_entries`] folds a recovered entry sequence into
//! per-request records; [`TraceView::unfinished`] is the recovery worklist.

use std::collections::BTreeMap;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::coordinator::cluster::DrainCause;
use crate::coordinator::request::{FinishReason, GenRequest, Priority};

/// Entry-payload format version, journaled in the header entry.
pub const FORMAT_VERSION: u32 = 1;

/// Which backend family produced a trace — enough for `pq replay` to boot an
/// equivalent fleet.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum BackendDesc {
    /// deterministic sim fleet (tests, benches)
    Sim { b_exec: u32, s_exec: u32, n_prefix: u32, cache_max: u32 },
    /// artifact-booted fleet; `path` is the artifacts directory
    Artifact { path: String },
}

/// Terminal outcome journaled for a request: a [`FinishReason`] or an error.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Outcome {
    Finish(FinishReason),
    Error,
}

impl Outcome {
    fn code(self) -> u8 {
        match self {
            Outcome::Finish(FinishReason::Length) => 0,
            Outcome::Finish(FinishReason::Stop) => 1,
            Outcome::Finish(FinishReason::CacheFull) => 2,
            Outcome::Finish(FinishReason::Cancelled) => 3,
            Outcome::Finish(FinishReason::WorkerLost) => 4,
            Outcome::Error => 5,
            Outcome::Finish(FinishReason::Shed) => 6,
            Outcome::Finish(FinishReason::Quarantined) => 7,
        }
    }

    fn from_code(c: u8) -> Result<Outcome> {
        Ok(match c {
            0 => Outcome::Finish(FinishReason::Length),
            1 => Outcome::Finish(FinishReason::Stop),
            2 => Outcome::Finish(FinishReason::CacheFull),
            3 => Outcome::Finish(FinishReason::Cancelled),
            4 => Outcome::Finish(FinishReason::WorkerLost),
            5 => Outcome::Error,
            6 => Outcome::Finish(FinishReason::Shed),
            7 => Outcome::Finish(FinishReason::Quarantined),
            _ => bail!("unknown outcome code {c}"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            Outcome::Finish(f) => f.name(),
            Outcome::Error => "error",
        }
    }

    /// Whether a replay of this outcome must reproduce the journaled tokens
    /// EXACTLY (deterministic completions) rather than by prefix (streams
    /// cut short by external events — cancellation, a lost worker).
    pub fn deterministic(self) -> bool {
        matches!(
            self,
            Outcome::Finish(FinishReason::Length)
                | Outcome::Finish(FinishReason::Stop)
                | Outcome::Finish(FinishReason::CacheFull)
        )
    }
}

fn cause_code(c: DrainCause) -> u8 {
    match c {
        DrainCause::Dead => 0,
        DrainCause::Wedged => 1,
        DrainCause::Failing => 2,
        DrainCause::Killed => 3,
    }
}

fn cause_from_code(c: u8) -> Result<DrainCause> {
    Ok(match c {
        0 => DrainCause::Dead,
        1 => DrainCause::Wedged,
        2 => DrainCause::Failing,
        3 => DrainCause::Killed,
        _ => bail!("unknown drain-cause code {c}"),
    })
}

/// One journaled operation.  `seq` is the router's cluster-wide sequence
/// number — stable across re-dispatches, unlike the worker-namespaced id.
#[derive(Debug, Clone, PartialEq)]
pub enum OpEntry {
    /// first entry of every log: format version + backend description
    Header { version: u32, backend: BackendDesc },
    /// a request entered the router (the full request, seed included)
    Admitted { seq: u64, req: GenRequest },
    /// the request was dispatched to `worker` with no prior tokens
    Dispatched { seq: u64, worker: u64 },
    /// one generated token was forwarded to the client
    Token { seq: u64, token: i32 },
    /// the stream reached a terminal event with `n_tokens` delivered
    Finished { seq: u64, outcome: Outcome, n_tokens: u32 },
    /// a worker left the rotation (`cause` is the drain cause)
    WorkerLost { worker: u64, cause: DrainCause },
    /// a token-producing stream was re-dispatched to `worker`, resuming
    /// after `from_tokens` already-delivered tokens
    Resumed { seq: u64, worker: u64, from_tokens: u32 },
    /// the supervisor rebooted a replacement into worker slot `worker`;
    /// `restarts` is the slot's cumulative restart count after the reboot
    WorkerRestarted { worker: u64, restarts: u32 },
}

const TAG_HEADER: u8 = 0;
const TAG_ADMITTED: u8 = 1;
const TAG_DISPATCHED: u8 = 2;
const TAG_TOKEN: u8 = 3;
const TAG_FINISHED: u8 = 4;
const TAG_WORKER_LOST: u8 = 5;
const TAG_RESUMED: u8 = 6;
const TAG_WORKER_RESTARTED: u8 = 7;

/// `deadline: None` sentinel (a real deadline of u64::MAX ms is not a thing).
const NO_DEADLINE: u64 = u64::MAX;

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_tokens(out: &mut Vec<u8>, toks: &[i32]) {
    put_u32(out, toks.len() as u32);
    for &t in toks {
        out.extend_from_slice(&t.to_le_bytes());
    }
}

struct Cursor<'a> {
    buf: &'a [u8],
    off: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Cursor<'a> {
        Cursor { buf, off: 0 }
    }

    fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.buf.len() - self.off < n {
            bail!("entry truncated: wanted {n} bytes at offset {}", self.off);
        }
        let s = &self.buf[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.bytes(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }

    fn i32(&mut self) -> Result<i32> {
        Ok(i32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    fn tokens(&mut self) -> Result<Vec<i32>> {
        let n = self.u32()? as usize;
        if self.buf.len() - self.off < n * 4 {
            bail!("entry truncated: token list of {n} exceeds payload");
        }
        (0..n).map(|_| self.i32()).collect()
    }

    fn finish(self) -> Result<()> {
        if self.off != self.buf.len() {
            bail!("entry has {} trailing bytes", self.buf.len() - self.off);
        }
        Ok(())
    }
}

impl OpEntry {
    /// Serialize to one frame payload.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(32);
        match self {
            OpEntry::Header { version, backend } => {
                out.push(TAG_HEADER);
                put_u32(&mut out, *version);
                match backend {
                    BackendDesc::Sim { b_exec, s_exec, n_prefix, cache_max } => {
                        out.push(0);
                        put_u32(&mut out, *b_exec);
                        put_u32(&mut out, *s_exec);
                        put_u32(&mut out, *n_prefix);
                        put_u32(&mut out, *cache_max);
                    }
                    BackendDesc::Artifact { path } => {
                        out.push(1);
                        put_u32(&mut out, path.len() as u32);
                        out.extend_from_slice(path.as_bytes());
                    }
                }
            }
            OpEntry::Admitted { seq, req } => {
                out.push(TAG_ADMITTED);
                put_u64(&mut out, *seq);
                put_u64(&mut out, req.seed);
                out.push(req.priority.index() as u8);
                put_u64(
                    &mut out,
                    req.deadline
                        .map_or(NO_DEADLINE, |d| d.as_millis().min(u64::MAX as u128) as u64),
                );
                put_u32(&mut out, req.max_new as u32);
                put_tokens(&mut out, &req.prompt);
                put_tokens(&mut out, &req.stop_tokens);
            }
            OpEntry::Dispatched { seq, worker } => {
                out.push(TAG_DISPATCHED);
                put_u64(&mut out, *seq);
                put_u64(&mut out, *worker);
            }
            OpEntry::Token { seq, token } => {
                out.push(TAG_TOKEN);
                put_u64(&mut out, *seq);
                out.extend_from_slice(&token.to_le_bytes());
            }
            OpEntry::Finished { seq, outcome, n_tokens } => {
                out.push(TAG_FINISHED);
                put_u64(&mut out, *seq);
                out.push(outcome.code());
                put_u32(&mut out, *n_tokens);
            }
            OpEntry::WorkerLost { worker, cause } => {
                out.push(TAG_WORKER_LOST);
                put_u64(&mut out, *worker);
                out.push(cause_code(*cause));
            }
            OpEntry::Resumed { seq, worker, from_tokens } => {
                out.push(TAG_RESUMED);
                put_u64(&mut out, *seq);
                put_u64(&mut out, *worker);
                put_u32(&mut out, *from_tokens);
            }
            OpEntry::WorkerRestarted { worker, restarts } => {
                out.push(TAG_WORKER_RESTARTED);
                put_u64(&mut out, *worker);
                put_u32(&mut out, *restarts);
            }
        }
        out
    }

    /// Decode one frame payload.  Any defect is an error, never a panic —
    /// recovery treats an undecodable frame as the start of the torn tail.
    pub fn decode(payload: &[u8]) -> Result<OpEntry> {
        let mut c = Cursor::new(payload);
        let tag = c.u8().context("empty entry")?;
        let entry = match tag {
            TAG_HEADER => {
                let version = c.u32()?;
                let backend = match c.u8()? {
                    0 => BackendDesc::Sim {
                        b_exec: c.u32()?,
                        s_exec: c.u32()?,
                        n_prefix: c.u32()?,
                        cache_max: c.u32()?,
                    },
                    1 => {
                        let n = c.u32()? as usize;
                        let path = String::from_utf8(c.bytes(n)?.to_vec())
                            .context("artifact path is not UTF-8")?;
                        BackendDesc::Artifact { path }
                    }
                    k => bail!("unknown backend kind {k}"),
                };
                OpEntry::Header { version, backend }
            }
            TAG_ADMITTED => {
                let seq = c.u64()?;
                let seed = c.u64()?;
                let pi = c.u8()? as usize;
                let priority = *Priority::all()
                    .get(pi)
                    .with_context(|| format!("unknown priority index {pi}"))?;
                let deadline_ms = c.u64()?;
                let max_new = c.u32()? as usize;
                let prompt = c.tokens()?;
                let stop_tokens = c.tokens()?;
                let mut b = GenRequest::builder(seq)
                    .prompt(prompt)
                    .max_new(max_new)
                    .priority(priority)
                    .stop_tokens(stop_tokens)
                    .seed(seed);
                if deadline_ms != NO_DEADLINE {
                    b = b.deadline(Duration::from_millis(deadline_ms));
                }
                OpEntry::Admitted { seq, req: b.build() }
            }
            TAG_DISPATCHED => OpEntry::Dispatched { seq: c.u64()?, worker: c.u64()? },
            TAG_TOKEN => OpEntry::Token { seq: c.u64()?, token: c.i32()? },
            TAG_FINISHED => OpEntry::Finished {
                seq: c.u64()?,
                outcome: Outcome::from_code(c.u8()?)?,
                n_tokens: c.u32()?,
            },
            TAG_WORKER_LOST => {
                OpEntry::WorkerLost { worker: c.u64()?, cause: cause_from_code(c.u8()?)? }
            }
            TAG_RESUMED => {
                OpEntry::Resumed { seq: c.u64()?, worker: c.u64()?, from_tokens: c.u32()? }
            }
            TAG_WORKER_RESTARTED => {
                OpEntry::WorkerRestarted { worker: c.u64()?, restarts: c.u32()? }
            }
            _ => bail!("unknown entry tag {tag}"),
        };
        c.finish()?;
        Ok(entry)
    }
}

/// Per-request state folded out of a trace.
#[derive(Debug, Clone)]
pub struct RequestRecord {
    /// the router's cluster-wide sequence number (also `req.id`)
    pub seq: u64,
    pub req: GenRequest,
    /// every token journaled for this request, in emission order
    pub tokens: Vec<i32>,
    /// terminal outcome, `None` while the stream was still in flight
    pub finish: Option<Outcome>,
    /// dispatch + resume decisions journaled
    pub dispatches: usize,
}

/// A recovered trace: header (when journaled) plus seq-ordered request
/// records.
#[derive(Debug, Clone, Default)]
pub struct TraceView {
    pub version: u32,
    pub backend: Option<BackendDesc>,
    /// request records in `seq` order
    pub records: Vec<RequestRecord>,
    /// worker-loss events journaled (drains, kills, crashes)
    pub worker_events: usize,
    /// supervisor restart events journaled (replacement worker reboots)
    pub worker_restarts: usize,
}

impl TraceView {
    /// Fold an entry sequence into per-request records.  Entries referencing
    /// an unknown `seq` (their admission fell into a torn tail) are dropped —
    /// recovery can only act on requests whose full parameters survived.
    pub fn from_entries(entries: &[OpEntry]) -> TraceView {
        let mut view = TraceView::default();
        let mut records: BTreeMap<u64, RequestRecord> = BTreeMap::new();
        for e in entries {
            match e {
                OpEntry::Header { version, backend } => {
                    view.version = *version;
                    view.backend = Some(backend.clone());
                }
                OpEntry::Admitted { seq, req } => {
                    records.entry(*seq).or_insert_with(|| RequestRecord {
                        seq: *seq,
                        req: req.clone(),
                        tokens: Vec::new(),
                        finish: None,
                        dispatches: 0,
                    });
                }
                OpEntry::Dispatched { seq, .. } | OpEntry::Resumed { seq, .. } => {
                    if let Some(r) = records.get_mut(seq) {
                        r.dispatches += 1;
                    }
                }
                OpEntry::Token { seq, token } => {
                    if let Some(r) = records.get_mut(seq) {
                        r.tokens.push(*token);
                    }
                }
                OpEntry::Finished { seq, outcome, .. } => {
                    if let Some(r) = records.get_mut(seq) {
                        r.finish = Some(*outcome);
                    }
                }
                OpEntry::WorkerLost { .. } => view.worker_events += 1,
                OpEntry::WorkerRestarted { .. } => view.worker_restarts += 1,
            }
        }
        view.records = records.into_values().collect();
        view
    }

    /// Requests with no journaled terminal event — the recovery worklist.
    pub fn unfinished(&self) -> impl Iterator<Item = &RequestRecord> {
        self.records.iter().filter(|r| r.finish.is_none())
    }

    /// Largest sequence number in the trace (`None` for an empty trace);
    /// recovery restarts the router's counter above it.
    pub fn max_seq(&self) -> Option<u64> {
        self.records.last().map(|r| r.seq)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_entries() -> Vec<OpEntry> {
        let req = GenRequest::builder(3)
            .prompt(vec![10, 20, 30])
            .max_new(6)
            .priority(Priority::Interactive)
            .deadline(Duration::from_millis(250))
            .stop_tokens(vec![99])
            .seed(0xFEED)
            .build();
        vec![
            OpEntry::Header {
                version: FORMAT_VERSION,
                backend: BackendDesc::Sim { b_exec: 4, s_exec: 48, n_prefix: 1, cache_max: 128 },
            },
            OpEntry::Admitted { seq: 3, req },
            OpEntry::Admitted { seq: 4, req: GenRequest::new(4, vec![7], 2) },
            OpEntry::Dispatched { seq: 3, worker: 1 },
            OpEntry::Dispatched { seq: 4, worker: 0 },
            OpEntry::Token { seq: 3, token: 41 },
            OpEntry::Token { seq: 4, token: -2 },
            OpEntry::Token { seq: 3, token: 17 },
            OpEntry::WorkerLost { worker: 1, cause: DrainCause::Killed },
            OpEntry::WorkerRestarted { worker: 1, restarts: 1 },
            OpEntry::Resumed { seq: 3, worker: 0, from_tokens: 2 },
            OpEntry::Finished {
                seq: 4,
                outcome: Outcome::Finish(FinishReason::Length),
                n_tokens: 2,
            },
            OpEntry::Finished {
                seq: 5,
                outcome: Outcome::Finish(FinishReason::Shed),
                n_tokens: 0,
            },
            OpEntry::Finished {
                seq: 6,
                outcome: Outcome::Finish(FinishReason::Quarantined),
                n_tokens: 1,
            },
        ]
    }

    #[test]
    fn entries_round_trip_byte_exact() {
        for e in sample_entries() {
            let bytes = e.encode();
            let back = OpEntry::decode(&bytes).unwrap();
            assert_eq!(back, e);
            // field-level spot check on the rich one
            if let OpEntry::Admitted { req, .. } = &back {
                if req.seed != 0 {
                    assert_eq!(req.seed, 0xFEED);
                    assert_eq!(req.deadline, Some(Duration::from_millis(250)));
                    assert_eq!(req.priority, Priority::Interactive);
                }
            }
        }
        // artifact-backed header too
        let h = OpEntry::Header {
            version: FORMAT_VERSION,
            backend: BackendDesc::Artifact { path: "artifacts/llama".into() },
        };
        assert_eq!(OpEntry::decode(&h.encode()).unwrap(), h);
    }

    #[test]
    fn decode_rejects_truncation_and_junk_without_panicking() {
        for e in sample_entries() {
            let bytes = e.encode();
            for cut in 0..bytes.len() {
                assert!(OpEntry::decode(&bytes[..cut]).is_err(), "accepted a truncated entry");
            }
            let mut extended = bytes.clone();
            extended.push(0);
            assert!(OpEntry::decode(&extended).is_err(), "accepted trailing bytes");
        }
        assert!(OpEntry::decode(&[]).is_err());
        assert!(OpEntry::decode(&[200]).is_err(), "unknown tag");
    }

    #[test]
    fn trace_view_folds_lifecycle_and_orders_by_seq() {
        let view = TraceView::from_entries(&sample_entries());
        assert!(matches!(view.backend, Some(BackendDesc::Sim { b_exec: 4, .. })));
        assert_eq!(view.records.len(), 2);
        assert_eq!(view.records[0].seq, 3);
        assert_eq!(view.records[0].tokens, vec![41, 17]);
        assert_eq!(view.records[0].dispatches, 2, "dispatch + resume");
        assert!(view.records[0].finish.is_none());
        assert_eq!(view.records[1].tokens, vec![-2]);
        assert_eq!(view.records[1].finish, Some(Outcome::Finish(FinishReason::Length)));
        assert_eq!(view.worker_events, 1);
        assert_eq!(view.worker_restarts, 1);
        let unfinished: Vec<u64> = view.unfinished().map(|r| r.seq).collect();
        assert_eq!(unfinished, vec![3], "only the in-flight stream needs recovery");
        assert_eq!(view.max_seq(), Some(4));
    }

    #[test]
    fn shed_and_quarantined_are_nondeterministic_outcomes() {
        // both are router-side settlements of external events (overload,
        // crash loops): a replay completes them fully, so the replay check
        // must use the prefix relation, not exact token equality
        for f in [FinishReason::Shed, FinishReason::Quarantined] {
            assert!(!Outcome::Finish(f).deterministic());
        }
    }

    #[test]
    fn events_for_unadmitted_requests_are_dropped() {
        // admission lost to a torn tail: trailing events must not fabricate
        // a recoverable record
        let view = TraceView::from_entries(&[
            OpEntry::Token { seq: 9, token: 1 },
            OpEntry::Finished { seq: 9, outcome: Outcome::Error, n_tokens: 1 },
        ]);
        assert!(view.records.is_empty());
    }
}
