//! Request/response/streaming types for the serving coordinator.

use std::sync::mpsc::Sender;

/// A generation request (prompt already tokenized, no BOS — the scheduler
/// prepends it so every sequence starts with the initial-position token).
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new: usize,
}

#[derive(Debug, Clone)]
pub struct GenResponse {
    pub id: u64,
    /// generated continuation tokens (prompt excluded)
    pub tokens: Vec<i32>,
    /// time to first token in seconds (queue wait + prefill for served paths;
    /// prefill only when produced by a bare `run_batch` call)
    pub ttft_s: f64,
    /// total latency for this request (same clock origin as `ttft_s`)
    pub total_s: f64,
    /// time spent waiting before prefill started (submit → admission)
    pub queue_s: f64,
}

/// Incremental output of a streaming generation request.
#[derive(Debug, Clone)]
pub enum StreamEvent {
    /// One generated token, delivered as soon as it is produced.
    Token(i32),
    /// Terminal event: the full response (tokens repeated for convenience).
    Done(GenResponse),
    /// Terminal event: the request failed.
    Error(String),
}

/// Where a request's output goes: a single aggregate response, or a stream of
/// per-token events.  Send failures are ignored (client hung up).
pub enum Reply {
    Aggregate(Sender<Result<GenResponse, String>>),
    Stream(Sender<StreamEvent>),
}

impl Reply {
    pub fn token(&self, t: i32) {
        if let Reply::Stream(tx) = self {
            let _ = tx.send(StreamEvent::Token(t));
        }
    }

    pub fn done(&self, resp: GenResponse) {
        match self {
            Reply::Aggregate(tx) => {
                let _ = tx.send(Ok(resp));
            }
            Reply::Stream(tx) => {
                let _ = tx.send(StreamEvent::Done(resp));
            }
        }
    }

    pub fn error(&self, msg: String) {
        match self {
            Reply::Aggregate(tx) => {
                let _ = tx.send(Err(msg));
            }
            Reply::Stream(tx) => {
                let _ = tx.send(StreamEvent::Error(msg));
            }
        }
    }
}

/// Aggregate serving metrics (reported by the server / serve_batch example).
///
/// TTFT and queue-wait sums are PER REQUEST (every response is recorded);
/// `sum_prefill_s`/`sum_busy_s` are per dispatch, so decode throughput can be
/// computed as generated tokens over busy-minus-prefill wall time.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    pub requests: usize,
    /// dispatches: run-to-completion batches, or admission waves (continuous)
    pub batches: usize,
    pub generated_tokens: usize,
    pub prefill_tokens: usize,
    /// per-request time-to-first-token (queue wait + prefill), summed
    pub sum_ttft_s: f64,
    /// per-request queue wait (submit → prefill start), summed
    pub sum_queue_s: f64,
    /// wall time spent inside prefill executions
    pub sum_prefill_s: f64,
    /// wall time the engine was busy (prefill + decode)
    pub sum_busy_s: f64,
    /// slots decoding at report time (continuous engine; 0 for batch)
    pub active_slots: usize,
    /// bytes resident for KV storage (page pool or dense block + shim view)
    pub kv_resident_bytes: usize,
    /// bytes of KV holding live sequence state (mapped pages / live rows)
    pub kv_used_bytes: usize,
    /// admissions that waited at the queue head for free KV pages
    pub deferred_admissions: usize,
}

impl Metrics {
    /// Mean per-request time-to-first-token (includes queue wait).
    pub fn mean_ttft(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.sum_ttft_s / self.requests as f64
        }
    }

    /// Mean per-request queue wait (submit → prefill start).
    pub fn mean_queue_wait(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.sum_queue_s / self.requests as f64
        }
    }

    /// Aggregate decode throughput over the time the engine spent decoding.
    pub fn decode_tps(&self) -> f64 {
        let decode_time = self.sum_busy_s - self.sum_prefill_s;
        if decode_time <= 0.0 {
            0.0
        } else {
            self.generated_tokens as f64 / decode_time
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metrics_are_per_request() {
        let mut m = Metrics::default();
        // one batch of 4 requests: ttft must average over requests, not batches
        m.batches = 1;
        m.requests = 4;
        for _ in 0..4 {
            m.sum_ttft_s += 0.010;
            m.sum_queue_s += 0.002;
        }
        m.sum_prefill_s = 0.010;
        m.sum_busy_s = 0.110;
        m.generated_tokens = 50;
        assert!((m.mean_ttft() - 0.010).abs() < 1e-12);
        assert!((m.mean_queue_wait() - 0.002).abs() < 1e-12);
        assert!((m.decode_tps() - 500.0).abs() < 1e-6);
    }

    #[test]
    fn reply_routes_events() {
        let (tx, rx) = std::sync::mpsc::channel();
        let r = Reply::Stream(tx);
        r.token(7);
        let resp = GenResponse { id: 1, tokens: vec![7], ttft_s: 0.1, total_s: 0.2, queue_s: 0.0 };
        r.done(resp);
        assert!(matches!(rx.recv().unwrap(), StreamEvent::Token(7)));
        assert!(matches!(rx.recv().unwrap(), StreamEvent::Done(_)));

        let (tx, rx) = std::sync::mpsc::channel();
        let r = Reply::Aggregate(tx);
        r.token(7); // aggregate replies ignore per-token events
        r.error("boom".into());
        assert_eq!(rx.recv().unwrap().unwrap_err(), "boom");
    }
}
