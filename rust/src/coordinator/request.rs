//! Request/response/streaming types for the serving coordinator (API v2).
//!
//! A request carries a scheduling class ([`Priority`]), an optional deadline
//! hint, and stop tokens; responses carry a [`FinishReason`] so clients can
//! tell a budget-exhausted stream from a stop-token hit, a cache-full
//! truncation, or a cancellation.  Construct requests through
//! [`GenRequest::new`] (defaults) or [`GenRequest::builder`].

use std::sync::mpsc::Sender;
use std::time::Duration;

/// Scheduling class of a request.  Declaration order is priority order
/// (derived `Ord`: `BestEffort < Batch < Interactive`), which is what the
/// scheduling policies compare.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub enum Priority {
    /// throughput filler: runs when nothing better is waiting
    BestEffort = 0,
    /// default class: offline/bulk work with no latency target
    #[default]
    Batch = 1,
    /// latency-sensitive: admitted first, may preempt lower classes
    Interactive = 2,
}

impl Priority {
    /// Number of classes (sizes the per-class metric arrays).
    pub const COUNT: usize = 3;

    /// Index into per-class arrays (0 = BestEffort .. 2 = Interactive).
    pub fn index(self) -> usize {
        self as usize
    }

    pub fn name(self) -> &'static str {
        match self {
            Priority::BestEffort => "best-effort",
            Priority::Batch => "batch",
            Priority::Interactive => "interactive",
        }
    }

    /// All classes, lowest priority first (array order).
    pub fn all() -> [Priority; Priority::COUNT] {
        [Priority::BestEffort, Priority::Batch, Priority::Interactive]
    }
}

/// Why a generation stream ended.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// `max_new` tokens generated (the normal completion)
    Length,
    /// a stop token was emitted (the stop token is included in the stream)
    Stop,
    /// the cache row filled before the budget was reached
    CacheFull,
    /// cancelled via a request handle; tokens generated so far are returned
    Cancelled,
    /// the worker serving this stream died or wedged after producing tokens;
    /// the tokens generated so far are returned (token-less requests are
    /// silently redistributed to a surviving worker instead)
    WorkerLost,
    /// rejected by the admission controller before dispatch: the estimated
    /// queue delay made the deadline infeasible, a backlog limit tripped, or
    /// a brownout tier dropped the class — no tokens were generated
    Shed,
    /// implicated in two or more worker deaths while in flight — presumed
    /// poisonous and permanently removed from dispatch instead of being
    /// redistributed into (and potentially killing) another worker
    Quarantined,
}

impl FinishReason {
    pub fn name(self) -> &'static str {
        match self {
            FinishReason::Length => "length",
            FinishReason::Stop => "stop",
            FinishReason::CacheFull => "cache-full",
            FinishReason::Cancelled => "cancelled",
            FinishReason::WorkerLost => "worker-lost",
            FinishReason::Shed => "shed",
            FinishReason::Quarantined => "quarantined",
        }
    }
}

/// A generation request (prompt already tokenized, no BOS — the scheduler
/// prepends it so every sequence starts with the initial-position token).
///
/// Construct with [`GenRequest::new`] for the defaults (Batch priority, no
/// deadline, no stop tokens) or [`GenRequest::builder`] for the full surface.
#[derive(Debug, Clone, PartialEq)]
pub struct GenRequest {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new: usize,
    /// scheduling class (admission order, preemption rights)
    pub priority: Priority,
    /// latency budget from submission, used by policies as an ordering hint
    /// (a tighter deadline sorts earlier within a class); requests are NOT
    /// killed on expiry, but a terminal delivered after the budget elapses
    /// counts in [`Metrics::deadline_misses`]
    pub deadline: Option<Duration>,
    /// generation ends early when one of these tokens is emitted (the stop
    /// token itself is delivered, `FinishReason::Stop`)
    pub stop_tokens: Vec<i32>,
    /// sampling seed, journaled by the oplog and threaded to the backend so a
    /// replayed trace stays bit-identical once sampling lands (greedy decode
    /// ignores it; the sim backend mixes it into its token hash, with 0 — the
    /// default — leaving the hash untouched)
    pub seed: u64,
}

impl GenRequest {
    /// A request with default scheduling (Batch class, no deadline, no stop
    /// tokens) — the v1 constructor shape.
    pub fn new(id: u64, prompt: Vec<i32>, max_new: usize) -> GenRequest {
        GenRequest {
            id,
            prompt,
            max_new,
            priority: Priority::default(),
            deadline: None,
            stop_tokens: Vec::new(),
            seed: 0,
        }
    }

    pub fn builder(id: u64) -> GenRequestBuilder {
        GenRequestBuilder { req: GenRequest::new(id, Vec::new(), 0) }
    }
}

/// Builder for [`GenRequest`] (see [`GenRequest::builder`]).
#[derive(Debug, Clone)]
pub struct GenRequestBuilder {
    req: GenRequest,
}

impl GenRequestBuilder {
    pub fn prompt(mut self, prompt: Vec<i32>) -> Self {
        self.req.prompt = prompt;
        self
    }

    pub fn max_new(mut self, max_new: usize) -> Self {
        self.req.max_new = max_new;
        self
    }

    pub fn priority(mut self, priority: Priority) -> Self {
        self.req.priority = priority;
        self
    }

    pub fn deadline(mut self, deadline: Duration) -> Self {
        self.req.deadline = Some(deadline);
        self
    }

    pub fn stop_tokens(mut self, stop_tokens: Vec<i32>) -> Self {
        self.req.stop_tokens = stop_tokens;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.req.seed = seed;
        self
    }

    pub fn build(self) -> GenRequest {
        self.req
    }
}

#[derive(Debug, Clone)]
pub struct GenResponse {
    pub id: u64,
    /// generated continuation tokens (prompt excluded)
    pub tokens: Vec<i32>,
    /// time to first token in seconds (queue wait + prefill for served paths;
    /// prefill only when produced by a bare `run_batch` call)
    pub ttft_s: f64,
    /// total latency for this request (same clock origin as `ttft_s`)
    pub total_s: f64,
    /// time spent waiting before prefill started (submit → admission)
    pub queue_s: f64,
    /// why the stream ended
    pub finish: FinishReason,
}

/// Incremental output of a streaming generation request.
#[derive(Debug, Clone)]
pub enum StreamEvent {
    /// One generated token, delivered as soon as it is produced.
    Token(i32),
    /// Terminal event: the full response (tokens repeated for convenience).
    Done(GenResponse),
    /// Terminal event: the request failed.
    Error(String),
}

/// A stream event tagged with the (namespaced) request id that produced it.
///
/// The cluster router funnels every worker's streams onto ONE channel; the
/// tag is what lets it demultiplex events back to per-request client streams
/// and maintain its in-flight table (which requests have produced tokens —
/// the redistribution criterion when a worker is lost).
#[derive(Debug, Clone)]
pub struct RoutedEvent {
    /// namespaced request id (see [`request_id`])
    pub id: u64,
    pub ev: StreamEvent,
}

/// Where a request's output goes: a single aggregate response, a stream of
/// per-token events, or a router funnel carrying id-tagged events.  Send
/// failures are ignored (client hung up).
pub enum Reply {
    Aggregate(Sender<Result<GenResponse, String>>),
    Stream(Sender<StreamEvent>),
    /// Cluster path: events are tagged with the namespaced request id and
    /// multiplexed onto the router's single event channel.
    Routed(u64, Sender<RoutedEvent>),
}

impl Reply {
    pub fn token(&self, t: i32) {
        match self {
            Reply::Stream(tx) => {
                let _ = tx.send(StreamEvent::Token(t));
            }
            Reply::Routed(id, tx) => {
                let _ = tx.send(RoutedEvent { id: *id, ev: StreamEvent::Token(t) });
            }
            Reply::Aggregate(_) => {}
        }
    }

    pub fn done(&self, resp: GenResponse) {
        match self {
            Reply::Aggregate(tx) => {
                let _ = tx.send(Ok(resp));
            }
            Reply::Stream(tx) => {
                let _ = tx.send(StreamEvent::Done(resp));
            }
            Reply::Routed(id, tx) => {
                let _ = tx.send(RoutedEvent { id: *id, ev: StreamEvent::Done(resp) });
            }
        }
    }

    pub fn error(&self, msg: String) {
        match self {
            Reply::Aggregate(tx) => {
                let _ = tx.send(Err(msg));
            }
            Reply::Stream(tx) => {
                let _ = tx.send(StreamEvent::Error(msg));
            }
            Reply::Routed(id, tx) => {
                let _ = tx.send(RoutedEvent { id: *id, ev: StreamEvent::Error(msg) });
            }
        }
    }
}

/// Cluster-safe request-id namespacing.
///
/// A fleet of workers booted from one artifact must never emit colliding
/// request ids in merged output, so the router stamps every dispatched
/// request with `(worker + 1)` in the high [`request_id::WORKER_BITS`] bits
/// and a cluster-wide sequence number in the low [`request_id::SEQ_BITS`]
/// bits.  The `+ 1` keeps the whole low-48-bit plane (all ids produced by
/// direct, router-less `Server` use) recognizably un-namespaced:
/// [`request_id::worker_of`] returns `None` for those.
pub mod request_id {
    /// Low bits carrying the cluster-wide submission sequence number.
    pub const SEQ_BITS: u32 = 48;
    /// High bits carrying `worker + 1` (0 = not namespaced).
    pub const WORKER_BITS: u32 = 64 - SEQ_BITS;
    /// Mask selecting the sequence-number bits.
    pub const SEQ_MASK: u64 = (1u64 << SEQ_BITS) - 1;

    /// Id for cluster sequence number `seq` dispatched to `worker`.
    pub fn namespaced(worker: usize, seq: u64) -> u64 {
        ((worker as u64 + 1) << SEQ_BITS) | (seq & SEQ_MASK)
    }

    /// Worker a namespaced id was dispatched to (`None` when the id was not
    /// produced by the cluster path).
    pub fn worker_of(id: u64) -> Option<usize> {
        let w = id >> SEQ_BITS;
        if w == 0 {
            None
        } else {
            Some((w - 1) as usize)
        }
    }

    /// Cluster-wide sequence number of a namespaced id.
    pub fn seq_of(id: u64) -> u64 {
        id & SEQ_MASK
    }
}

/// Whether a probed worker is still serving or has entered its terminal
/// drain-failing loop (model factory exhausted its reload budget).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ProbeState {
    /// engine loop running; the load gauges below are live
    Serving,
    /// terminal: every new request is answered with an error — the router
    /// should drain and stop dispatching here
    Failing,
}

/// Snapshot of one worker's health and load, answered synchronously by the
/// worker loop (so a timely answer IS the liveness signal).
#[derive(Debug, Clone)]
pub struct WorkerProbe {
    pub state: ProbeState,
    /// monotone work counter (prefill tokens + generated tokens + decode
    /// rounds); frozen across probes while requests are outstanding means the
    /// worker is wedged
    pub progress: u64,
    /// slots currently decoding
    pub active_slots: usize,
    /// requests queued behind the active slots
    pub queued_requests: usize,
    /// token footprint of the queue (BOS + prompt + budget per request) —
    /// the load signal for least-loaded dispatch
    pub queued_tokens: usize,
    pub slots_total: usize,
    /// page-pool gauges (0 when the worker runs a dense layout)
    pub kv_pages_total: usize,
    pub kv_pages_free: usize,
    /// full metrics snapshot: kept by the router as the worker's last known
    /// counters so a fleet report can still account for a dead worker
    pub metrics: Metrics,
}

/// What a worker released when asked to drain: the namespaced ids of every
/// queued or token-less in-flight request it gave back for redistribution
/// (their `Reply` handles are dropped WITHOUT a terminal event — the router
/// re-dispatches them under fresh ids), and how many token-producing streams
/// it kept.
#[derive(Debug, Clone)]
pub struct DrainReport {
    pub released: Vec<u64>,
    pub kept: usize,
}

/// Final page-pool accounting from a killed worker, used by drain tests to
/// prove the pool leaked nothing: every non-prefix page must be free once the
/// engine has reset its slots.
#[derive(Debug, Clone)]
pub struct WorkerPostMortem {
    pub kv_pages_total: usize,
    pub kv_pages_free: usize,
    /// pages pinned by the shared prompt prefix (never freed while the cache
    /// lives)
    pub kv_prefix_pages: usize,
    /// in-flight requests dropped without a terminal event
    pub dropped_active: usize,
    /// queued requests dropped without a terminal event
    pub dropped_queued: usize,
}

/// Fixed-bucket log2 latency histogram (microsecond-grained, mergeable).
///
/// Bucket `b` counts samples in `[2^b, 2^{b+1})` microseconds (sub-µs
/// samples land in bucket 0; anything ≥ ~36 minutes clamps into the last
/// bucket).  Recording, merging, and percentile extraction are all integer
/// operations, so histograms aggregated across workers — or across runs —
/// are deterministic: [`LatencyHistogram::merge`] is a commutative monoid
/// exactly like the counters around it, and a percentile is always a bucket
/// upper bound, never an interpolated float.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencyHistogram {
    counts: [u64; Self::BUCKETS],
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram { counts: [0; Self::BUCKETS] }
    }
}

impl LatencyHistogram {
    pub const BUCKETS: usize = 32;

    /// Bucket index for a latency in seconds: `floor(log2(µs))`, clamped.
    fn bucket(seconds: f64) -> usize {
        let us = seconds.max(0.0) * 1e6;
        if us < 1.0 {
            return 0;
        }
        // us >= 1.0 and finite casts to a nonzero u64 (saturating on inf)
        let us = us as u64;
        ((63 - us.leading_zeros()) as usize).min(Self::BUCKETS - 1)
    }

    /// Representative value reported for bucket `b`: its upper bound, in
    /// seconds (a percentile therefore never under-reports a latency).
    fn bucket_upper_s(b: usize) -> f64 {
        (1u64 << (b + 1).min(63)) as f64 * 1e-6
    }

    pub fn record(&mut self, seconds: f64) {
        self.counts[Self::bucket(seconds)] += 1;
    }

    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (d, c) in self.counts.iter_mut().zip(&other.counts) {
            *d += *c;
        }
    }

    /// Total samples recorded.
    pub fn count(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// The `p`-quantile (`p` in [0, 1]) as a bucket upper bound in seconds.
    /// Deterministic: the smallest bucket whose cumulative count reaches
    /// `ceil(p * total)`.  Returns 0 for an empty histogram.
    pub fn percentile(&self, p: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = ((p.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (b, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= target {
                return Self::bucket_upper_s(b);
            }
        }
        Self::bucket_upper_s(Self::BUCKETS - 1)
    }

    pub fn p50(&self) -> f64 {
        self.percentile(0.50)
    }

    pub fn p95(&self) -> f64 {
        self.percentile(0.95)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(0.99)
    }
}

/// Per-priority-class serving counters (one entry per [`Priority`]).
#[derive(Debug, Clone, Copy, Default)]
pub struct ClassMetrics {
    /// requests admitted (first admission; preemption resumes not recounted)
    pub requests: usize,
    pub completed: usize,
    /// per-request time-to-first-token, summed (recorded at first admission)
    pub sum_ttft_s: f64,
    /// per-request queue wait, summed (recorded at first admission)
    pub sum_queue_s: f64,
    /// times a request of this class was preempted mid-decode
    pub preemptions: usize,
    pub cancelled: usize,
    /// time-to-first-token distribution (recorded alongside `sum_ttft_s`)
    pub ttft_hist: LatencyHistogram,
    /// time-per-output-token distribution — `(total − ttft) / (tokens − 1)`,
    /// recorded at completion for responses with ≥ 2 tokens
    pub tpot_hist: LatencyHistogram,
}

impl ClassMetrics {
    pub fn mean_ttft(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.sum_ttft_s / self.requests as f64
        }
    }

    pub fn mean_queue_wait(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.sum_queue_s / self.requests as f64
        }
    }
}

/// Aggregate serving metrics (reported by the server / serve_batch example).
///
/// TTFT and queue-wait sums are PER REQUEST (every response is recorded);
/// `sum_prefill_s`/`sum_decode_s`/`sum_busy_s` are per dispatch, so decode
/// throughput is generated tokens over directly-measured decode wall time.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    pub requests: usize,
    /// dispatches: run-to-completion batches, or admission waves (continuous)
    pub batches: usize,
    pub generated_tokens: usize,
    pub prefill_tokens: usize,
    /// per-request time-to-first-token (queue wait + prefill), summed
    pub sum_ttft_s: f64,
    /// per-request queue wait (submit → prefill start), summed
    pub sum_queue_s: f64,
    /// wall time spent inside prefill executions
    pub sum_prefill_s: f64,
    /// wall time spent inside decode executions (measured directly, so
    /// [`Metrics::decode_tps`] never divides by a raced busy−prefill residue)
    pub sum_decode_s: f64,
    /// wall time the engine was busy (prefill + decode)
    pub sum_busy_s: f64,
    /// per-dispatch queue→dispatch skew (longest enqueue-to-dispatch wait in
    /// each dispatched batch, summed) — the part of `sum_ttft_s` that is
    /// queueing rather than engine work
    pub sum_dispatch_skew_s: f64,
    /// slots decoding at report time (continuous engine; 0 for batch)
    pub active_slots: usize,
    /// bytes resident for KV storage (page pool or dense block + shim view)
    pub kv_resident_bytes: usize,
    /// bytes of KV holding live sequence state (mapped pages / live rows)
    pub kv_used_bytes: usize,
    /// admissions that waited at the queue head for free KV pages
    pub deferred_admissions: usize,
    /// Decoding slots preempted for a higher class (pages released, request
    /// requeued with its generated tokens preserved)
    pub preemptions: usize,
    /// requests cancelled via their handle (in-queue or mid-decode)
    pub cancelled: usize,
    /// token-less in-flight requests resubmitted after an engine rebuild
    pub retries: usize,
    /// model-level reloads: the worker re-invoked its model factory (e.g.
    /// re-read the QuantArtifact) after an engine rebuild on the same model
    /// failed — the pipeline never re-runs on this path
    pub model_reloads: usize,
    /// radix prefix-cache lookups at admission (0 when the cache is off)
    pub radix_lookups: usize,
    /// admissions that matched at least one cached page
    pub radix_hits: usize,
    /// cache positions served from the radix cache instead of prefill
    pub radix_hit_tokens: usize,
    /// copy-on-write page splits (partial-page divergence at admission)
    pub radix_cow_splits: usize,
    /// cache-only pages evicted from the radix tree under page pressure
    pub radix_evicted_pages: usize,
    /// pages currently held resident by the radix tree (gauge)
    pub radix_shared_pages: usize,
    /// bytes of K+V those shared pages pin resident (gauge)
    pub radix_shared_bytes: usize,
    /// terminals (other than cancellations) delivered after the request's
    /// [`GenRequest::deadline`] budget had already elapsed
    pub deadline_misses: usize,
    /// per-priority-class breakdown (index = `Priority::index()`)
    pub by_class: [ClassMetrics; Priority::COUNT],
}

impl Metrics {
    /// Accumulate another worker's counters into this one (multi-server
    /// aggregation).  Lives next to the struct so a new field cannot be
    /// silently dropped from aggregates — extend this when extending
    /// `Metrics`.
    pub fn merge(&mut self, m: &Metrics) {
        self.requests += m.requests;
        self.batches += m.batches;
        self.generated_tokens += m.generated_tokens;
        self.prefill_tokens += m.prefill_tokens;
        self.sum_ttft_s += m.sum_ttft_s;
        self.sum_queue_s += m.sum_queue_s;
        self.sum_prefill_s += m.sum_prefill_s;
        self.sum_decode_s += m.sum_decode_s;
        self.sum_busy_s += m.sum_busy_s;
        self.sum_dispatch_skew_s += m.sum_dispatch_skew_s;
        self.active_slots += m.active_slots;
        self.kv_resident_bytes += m.kv_resident_bytes;
        self.kv_used_bytes += m.kv_used_bytes;
        self.deferred_admissions += m.deferred_admissions;
        self.preemptions += m.preemptions;
        self.cancelled += m.cancelled;
        self.retries += m.retries;
        self.model_reloads += m.model_reloads;
        self.radix_lookups += m.radix_lookups;
        self.radix_hits += m.radix_hits;
        self.radix_hit_tokens += m.radix_hit_tokens;
        self.radix_cow_splits += m.radix_cow_splits;
        self.radix_evicted_pages += m.radix_evicted_pages;
        self.radix_shared_pages += m.radix_shared_pages;
        self.radix_shared_bytes += m.radix_shared_bytes;
        self.deadline_misses += m.deadline_misses;
        for (d, c) in self.by_class.iter_mut().zip(&m.by_class) {
            d.requests += c.requests;
            d.completed += c.completed;
            d.sum_ttft_s += c.sum_ttft_s;
            d.sum_queue_s += c.sum_queue_s;
            d.preemptions += c.preemptions;
            d.cancelled += c.cancelled;
            d.ttft_hist.merge(&c.ttft_hist);
            d.tpot_hist.merge(&c.tpot_hist);
        }
    }

    /// TTFT distribution aggregated over all classes.
    pub fn ttft_hist(&self) -> LatencyHistogram {
        let mut h = LatencyHistogram::default();
        for c in &self.by_class {
            h.merge(&c.ttft_hist);
        }
        h
    }

    /// TPOT distribution aggregated over all classes.
    pub fn tpot_hist(&self) -> LatencyHistogram {
        let mut h = LatencyHistogram::default();
        for c in &self.by_class {
            h.merge(&c.tpot_hist);
        }
        h
    }

    /// Mean per-request time-to-first-token (includes queue wait).
    pub fn mean_ttft(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.sum_ttft_s / self.requests as f64
        }
    }

    /// Mean per-request queue wait (submit → prefill start).
    pub fn mean_queue_wait(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.sum_queue_s / self.requests as f64
        }
    }

    /// Per-class counters for `p`.
    pub fn class(&self, p: Priority) -> &ClassMetrics {
        &self.by_class[p.index()]
    }

    /// Aggregate decode throughput over the time the engine spent decoding.
    ///
    /// Uses the directly-accumulated `sum_decode_s`; falls back to
    /// `sum_busy_s - sum_prefill_s` (clamped at zero) for metrics produced
    /// before the decode clock existed, so a stats probe racing a long batch
    /// window can never observe a negative decode time.
    pub fn decode_tps(&self) -> f64 {
        let decode_time = if self.sum_decode_s > 0.0 {
            self.sum_decode_s
        } else {
            (self.sum_busy_s - self.sum_prefill_s).max(0.0)
        };
        if decode_time <= 0.0 {
            0.0
        } else {
            self.generated_tokens as f64 / decode_time
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metrics_are_per_request() {
        let mut m = Metrics::default();
        // one batch of 4 requests: ttft must average over requests, not batches
        m.batches = 1;
        m.requests = 4;
        for _ in 0..4 {
            m.sum_ttft_s += 0.010;
            m.sum_queue_s += 0.002;
        }
        m.sum_prefill_s = 0.010;
        m.sum_decode_s = 0.100;
        m.sum_busy_s = 0.110;
        m.generated_tokens = 50;
        assert!((m.mean_ttft() - 0.010).abs() < 1e-12);
        assert!((m.mean_queue_wait() - 0.002).abs() < 1e-12);
        assert!((m.decode_tps() - 500.0).abs() < 1e-6);
    }

    /// Regression: a stats probe racing a long batch window used to observe
    /// `sum_busy_s < sum_prefill_s` (busy recorded per dispatch, prefill
    /// already charged) and report a NEGATIVE decode throughput.  The direct
    /// decode clock makes the fallback unreachable in served paths, and the
    /// fallback itself clamps at zero.
    #[test]
    fn decode_tps_never_negative() {
        let mut m = Metrics::default();
        m.generated_tokens = 10;
        m.sum_prefill_s = 0.5;
        m.sum_busy_s = 0.2; // raced probe: busy lags prefill
        assert_eq!(m.decode_tps(), 0.0, "clamped fallback, not negative");
        m.sum_decode_s = 0.1; // direct clock wins over the residue
        assert!((m.decode_tps() - 100.0).abs() < 1e-9);
        assert!(m.decode_tps() >= 0.0);
    }

    #[test]
    fn merge_aggregates_counters_and_classes() {
        let mut a = Metrics::default();
        a.requests = 1;
        a.model_reloads = 1;
        a.sum_ttft_s = 0.5;
        a.by_class[Priority::Interactive.index()].completed = 1;
        let mut b = Metrics::default();
        b.requests = 2;
        b.generated_tokens = 7;
        b.sum_ttft_s = 0.25;
        b.by_class[Priority::Interactive.index()].completed = 4;
        a.merge(&b);
        assert_eq!(a.requests, 3);
        assert_eq!(a.generated_tokens, 7);
        assert_eq!(a.model_reloads, 1);
        assert!((a.sum_ttft_s - 0.75).abs() < 1e-12);
        assert_eq!(a.by_class[Priority::Interactive.index()].completed, 5);
    }

    #[test]
    fn histogram_buckets_merge_and_percentiles() {
        let mut h = LatencyHistogram::default();
        assert_eq!(h.count(), 0);
        assert_eq!(h.p50(), 0.0, "empty histogram reports 0");
        // 1µs → bucket 0 (upper bound 2µs); 3µs → bucket 1 (upper 4µs);
        // 1ms = 1000µs → bucket 9 [512, 1024) (upper 1024µs)
        h.record(1e-6);
        h.record(3e-6);
        h.record(1e-3);
        assert_eq!(h.count(), 3);
        assert!((h.p50() - 4e-6).abs() < 1e-12);
        assert!((h.p99() - 1024e-6).abs() < 1e-9);
        // percentiles never under-report: every sample ≤ its bucket upper
        assert!(h.percentile(1.0) >= 1e-3);
        // negative / zero / huge samples clamp instead of panicking
        h.record(-1.0);
        h.record(0.0);
        h.record(1e9);
        assert_eq!(h.count(), 6);
        // merge is plain counter addition (commutative)
        let mut a = LatencyHistogram::default();
        a.record(5e-6);
        let mut ab = a;
        ab.merge(&h);
        let mut ba = h;
        ba.merge(&a);
        assert_eq!(ab, ba);
        assert_eq!(ab.count(), 7);
    }

    #[test]
    fn merge_carries_deadline_misses_and_class_histograms() {
        let mut a = Metrics { deadline_misses: 2, ..Metrics::default() };
        a.by_class[Priority::Interactive.index()].ttft_hist.record(0.010);
        let mut b = Metrics { deadline_misses: 3, ..Metrics::default() };
        b.by_class[Priority::Interactive.index()].ttft_hist.record(0.020);
        b.by_class[Priority::Batch.index()].tpot_hist.record(0.001);
        a.merge(&b);
        assert_eq!(a.deadline_misses, 5);
        assert_eq!(a.by_class[Priority::Interactive.index()].ttft_hist.count(), 2);
        assert_eq!(a.ttft_hist().count(), 2, "aggregate spans all classes");
        assert_eq!(a.tpot_hist().count(), 1);
    }

    #[test]
    fn priority_orders_and_indexes() {
        assert!(Priority::Interactive > Priority::Batch);
        assert!(Priority::Batch > Priority::BestEffort);
        assert_eq!(Priority::default(), Priority::Batch);
        for (i, p) in Priority::all().iter().enumerate() {
            assert_eq!(p.index(), i);
        }
    }

    #[test]
    fn builder_sets_all_fields() {
        let r = GenRequest::builder(7)
            .prompt(vec![1, 2, 3])
            .max_new(5)
            .priority(Priority::Interactive)
            .deadline(Duration::from_millis(50))
            .stop_tokens(vec![9])
            .seed(0xDEAD_BEEF)
            .build();
        assert_eq!(r.id, 7);
        assert_eq!(r.prompt, vec![1, 2, 3]);
        assert_eq!(r.max_new, 5);
        assert_eq!(r.priority, Priority::Interactive);
        assert_eq!(r.deadline, Some(Duration::from_millis(50)));
        assert_eq!(r.stop_tokens, vec![9]);
        assert_eq!(r.seed, 0xDEAD_BEEF);
        // `new` keeps the v1 defaults
        let d = GenRequest::new(1, vec![4], 2);
        assert_eq!(d.priority, Priority::Batch);
        assert!(d.deadline.is_none() && d.stop_tokens.is_empty());
        assert_eq!(d.seed, 0, "default seed is the identity for the sim hash");
    }

    #[test]
    fn reply_routes_events() {
        let (tx, rx) = std::sync::mpsc::channel();
        let r = Reply::Stream(tx);
        r.token(7);
        let resp = GenResponse {
            id: 1,
            tokens: vec![7],
            ttft_s: 0.1,
            total_s: 0.2,
            queue_s: 0.0,
            finish: FinishReason::Length,
        };
        r.done(resp);
        assert!(matches!(rx.recv().unwrap(), StreamEvent::Token(7)));
        assert!(matches!(rx.recv().unwrap(), StreamEvent::Done(_)));

        let (tx, rx) = std::sync::mpsc::channel();
        let r = Reply::Aggregate(tx);
        r.token(7); // aggregate replies ignore per-token events
        r.error("boom".into());
        assert_eq!(rx.recv().unwrap().unwrap_err(), "boom");
    }

    #[test]
    fn routed_reply_tags_every_event_with_its_id() {
        let (tx, rx) = std::sync::mpsc::channel();
        let id = request_id::namespaced(3, 41);
        let r = Reply::Routed(id, tx);
        r.token(7);
        r.error("boom".into());
        let ev = rx.recv().unwrap();
        assert_eq!(ev.id, id);
        assert!(matches!(ev.ev, StreamEvent::Token(7)));
        let ev = rx.recv().unwrap();
        assert_eq!(ev.id, id);
        assert!(matches!(ev.ev, StreamEvent::Error(_)));
    }

    #[test]
    fn request_id_namespacing_round_trips() {
        let id = request_id::namespaced(5, 1234);
        assert_eq!(request_id::worker_of(id), Some(5));
        assert_eq!(request_id::seq_of(id), 1234);
        // worker 0 is distinguishable from "not namespaced"
        let id0 = request_id::namespaced(0, 7);
        assert_eq!(request_id::worker_of(id0), Some(0));
        assert_eq!(request_id::seq_of(id0), 7);
        // plain low-plane ids (direct Server use) are not namespaced
        assert_eq!(request_id::worker_of(7), None);
        assert_eq!(request_id::worker_of(request_id::SEQ_MASK), None);
    }

    #[test]
    fn request_ids_never_collide_across_workers() {
        // same sequence number on different workers → different ids; same
        // worker, different sequence numbers → different ids
        let mut seen = std::collections::HashSet::new();
        for w in 0..4usize {
            for seq in 0..64u64 {
                assert!(seen.insert(request_id::namespaced(w, seq)));
            }
        }
    }

    /// Namespacing rewrites only the id: a request re-stamped for dispatch
    /// keeps its journaled sampling seed, so a worker crash + re-dispatch (or
    /// an oplog replay) decodes with the same seed the client submitted.
    #[test]
    fn namespacing_preserves_the_sampling_seed() {
        let req = GenRequest::builder(0).prompt(vec![1]).max_new(4).seed(41).build();
        for w in 0..4usize {
            let mut wreq = req.clone();
            wreq.id = request_id::namespaced(w, 9);
            assert_eq!(request_id::worker_of(wreq.id), Some(w));
            assert_eq!(request_id::seq_of(wreq.id), 9);
            assert_eq!(wreq.seed, 41, "dispatch stamping must not touch the seed");
        }
    }
}
