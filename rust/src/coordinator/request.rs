//! Request/response types for the serving coordinator.

/// A generation request (prompt already tokenized, no BOS — the scheduler
/// prepends it so every sequence starts with the initial-position token).
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new: usize,
}

#[derive(Debug, Clone)]
pub struct GenResponse {
    pub id: u64,
    /// generated continuation tokens (prompt excluded)
    pub tokens: Vec<i32>,
    /// time to first token (prefill) in seconds, shared across the batch
    pub ttft_s: f64,
    /// total latency for this request's batch
    pub total_s: f64,
}

/// Aggregate serving metrics (reported by the server / serve_batch example).
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    pub requests: usize,
    pub batches: usize,
    pub generated_tokens: usize,
    pub prefill_tokens: usize,
    pub sum_ttft_s: f64,
    pub sum_batch_s: f64,
}

impl Metrics {
    pub fn mean_ttft(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.sum_ttft_s / self.batches as f64
        }
    }

    pub fn decode_tps(&self) -> f64 {
        let decode_time = self.sum_batch_s - self.sum_ttft_s;
        if decode_time <= 0.0 {
            0.0
        } else {
            self.generated_tokens as f64 / decode_time
        }
    }
}
