//! KV-cache manager with shared prefixed entries (the paper's mechanism).
//!
//! The prefixed tokens' K/V are computed ONCE at model-quantization time and
//! occupy positions [0, n_prefix) of every sequence's cache — they are never
//! recomputed, never evicted, and identical across sequences (the "prefixed
//! outliers in the KV cache" of the title).  Prompt/decoded tokens occupy
//! positions [n_prefix, row_len(b)).
//!
//! Two storage layouts implement that contract behind one API
//! ([`KvLayout`]):
//!
//! - **Dense** (the original slot table): one `[L, B, H, Smax, dh]` block per
//!   K and V, every row reserving worst-case capacity.  The prefix is
//!   physically copied into every row and a retired row is zeroed (except the
//!   prefix) before reuse.  Kept as the baseline for parity tests and the
//!   paging benches.
//! - **Paged**: a fixed [`PagePool`] of `[L, H, page_size, dh]` pages plus a
//!   per-slot page table.  The prefixed K/V is written into refcounted
//!   *prefix pages* exactly once and MAPPED (not copied) into every slot —
//!   the sharing the paper's invariant makes correct, since every sequence's
//!   prefix entries are identical.  A slot's own positions take pages on
//!   demand, retirement drops its page refs with NO memset (freed pages are
//!   reused as-is; writers always write a position before any reader can see
//!   it), and admission becomes a page-availability check, so long-tail
//!   sequences stop pinning worst-case capacity.
//!
//! The decode/prefill executables still expect dense `[L, B, H, Smax, dh]`
//! inputs, so the paged layout offers [`KvCache::gather_dense`]: an
//! incrementally-mirrored dense view materialized per decode group at the
//! `ModelBackend` boundary, with only the newly written position scattered
//! back ([`KvCache::append_rows`]).  The simulation backend reads the paged
//! layout directly through [`KvCache::k_at`] so parity tests exercise the
//! page tables themselves.

use std::collections::HashSet;

use anyhow::{bail, Result};

use crate::config::ModelConfig;
use crate::coordinator::radix::{RadixStats, RadixTree};
use crate::model::PrefixState;
use crate::tensor::Tensor;

fn div_ceil(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

/// Flat offset of position (l, b, h, s) in a dense [L, B, H, Smax, dh] block.
fn dense_offset(
    batch: usize,
    n_heads: usize,
    s_max: usize,
    d_head: usize,
    l: usize,
    b: usize,
    h: usize,
    s: usize,
) -> usize {
    (((l * batch + b) * n_heads + h) * s_max + s) * d_head
}

/// Which storage layout a [`KvCache`] uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvLayout {
    /// one dense [L, B, H, Smax, dh] block per K/V (worst-case per row)
    Dense,
    /// fixed page pool + per-slot page tables; `n_pages == 0` auto-sizes the
    /// pool to dense-equivalent worst case `(batch + 1) * ceil(Smax / page)`
    Paged { page_size: usize, n_pages: usize },
}

/// Fixed pool of refcounted KV pages.  One page holds `page_size` consecutive
/// cache positions across EVERY layer and head (`[L, H, page_size, dh]` for K
/// and for V), so mapping a page into a slot maps those positions everywhere
/// at once — which is what lets the prefixed K/V be shared as whole pages.
///
/// Freed pages are pushed on a LIFO free list and handed out again WITHOUT
/// zeroing: every writer fills a position before any reader can observe it
/// (row lengths only advance past written positions), so a page can carry a
/// retired sequence's stale bytes harmlessly.
pub struct PagePool {
    pub n_pages: usize,
    pub page_size: usize,
    n_layers: usize,
    n_heads: usize,
    d_head: usize,
    k: Vec<f32>, // [n_pages, L, H, page_size, dh]
    v: Vec<f32>,
    refcount: Vec<u32>,
    free: Vec<u32>,
}

impl PagePool {
    pub fn new(
        n_pages: usize,
        page_size: usize,
        n_layers: usize,
        n_heads: usize,
        d_head: usize,
    ) -> Self {
        let elems = n_pages * n_layers * n_heads * page_size * d_head;
        Self {
            n_pages,
            page_size,
            n_layers,
            n_heads,
            d_head,
            k: vec![0.0; elems],
            v: vec![0.0; elems],
            refcount: vec![0; n_pages],
            free: (0..n_pages as u32).rev().collect(),
        }
    }

    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    pub fn used_pages(&self) -> usize {
        self.n_pages - self.free.len()
    }

    /// Refcount of `page` (0 = on the free list).
    pub fn refcount(&self, page: u32) -> u32 {
        self.refcount[page as usize]
    }

    /// Take a page off the free list with refcount 1.
    pub fn alloc(&mut self) -> Result<u32> {
        let Some(p) = self.free.pop() else {
            bail!("page pool exhausted ({} pages)", self.n_pages);
        };
        self.refcount[p as usize] = 1;
        Ok(p)
    }

    /// Add a reference to a live page (e.g. a slot mapping a prefix page).
    pub fn incref(&mut self, page: u32) -> Result<()> {
        if page as usize >= self.n_pages {
            bail!("incref of page {page} out of range ({})", self.n_pages);
        }
        if self.refcount[page as usize] == 0 {
            bail!("incref of free page {page}");
        }
        self.refcount[page as usize] += 1;
        Ok(())
    }

    /// Drop a reference; returns true when the page went back on the free
    /// list.  Dropping a reference on a free page is an error (double free).
    pub fn decref(&mut self, page: u32) -> Result<bool> {
        if page as usize >= self.n_pages {
            bail!("decref of page {page} out of range ({})", self.n_pages);
        }
        if self.refcount[page as usize] == 0 {
            bail!("double free of page {page}");
        }
        self.refcount[page as usize] -= 1;
        if self.refcount[page as usize] == 0 {
            self.free.push(page);
            return Ok(true);
        }
        Ok(false)
    }

    /// Flat offset of (page, l, h, position-in-page) — start of a dh span.
    fn slab_offset(&self, page: u32, l: usize, h: usize, po: usize) -> usize {
        (((page as usize * self.n_layers + l) * self.n_heads + h) * self.page_size + po)
            * self.d_head
    }

    /// K + V bytes of one page.
    pub fn page_bytes(&self) -> usize {
        2 * 4 * self.n_layers * self.n_heads * self.page_size * self.d_head
    }
}

/// Incrementally-mirrored dense view of a paged cache (the gather half of the
/// `ModelBackend` shim).  `mirrored[row]` positions of `row` are already
/// materialized for generation `gen[row]`; a gather copies only the delta.
struct DenseView {
    k: Tensor,
    v: Tensor,
    mirrored: Vec<usize>,
    gen: Vec<u64>,
}

/// Paged store: pool + page tables.
struct Paged {
    pool: PagePool,
    /// pages holding positions [0, n_prefix), shared by every slot; the cache
    /// holds one base reference and every slot holds one mapping reference,
    /// so a live prefix page's refcount is always `batch + 1` — it can never
    /// be freed by slot churn
    prefix_pages: Vec<u32>,
    /// per-slot own pages for positions [n_prefix, ...), in order
    own: Vec<Vec<u32>>,
    /// per-slot worst-case own-page reservation made at admission (0 when the
    /// slot was filled without a reservation, e.g. run-to-completion)
    reserved: Vec<usize>,
    /// bumped on retirement so dense mirrors of the old occupant invalidate
    generation: Vec<u64>,
    view: Option<DenseView>,
    /// generalized radix prefix cache over own-region pages (None = only the
    /// quantization prefix is shared, the pre-radix behaviour)
    radix: Option<RadixTree>,
}

impl Paged {
    /// Pages promised to admitted slots but not yet allocated.  The admission
    /// invariant `free_pages >= uncommitted()` guarantees an admitted slot's
    /// appends can never fail.
    fn uncommitted(&self) -> usize {
        self.own
            .iter()
            .zip(&self.reserved)
            .map(|(o, &r)| r.saturating_sub(o.len()))
            .sum()
    }

    /// Page holding own-region index `idx` of `slot`, allocating it if this
    /// is the next unallocated index.  Allocations beyond the slot's
    /// reservation must leave every other slot's outstanding reservation
    /// honorable.
    fn ensure_own_page(&mut self, slot: usize, idx: usize) -> Result<u32> {
        if idx < self.own[slot].len() {
            return Ok(self.own[slot][idx]);
        }
        if idx > self.own[slot].len() {
            bail!("non-contiguous page allocation for slot {slot}");
        }
        if self.own[slot].len() >= self.reserved[slot]
            && self.pool.free_pages() <= self.uncommitted()
        {
            bail!(
                "page pool exhausted ({} pages, {} free, {} promised)",
                self.pool.n_pages,
                self.pool.free_pages(),
                self.uncommitted()
            );
        }
        let page = self.pool.alloc()?;
        self.own[slot].push(page);
        Ok(page)
    }

    /// Copy-on-write guard for a write into `slot`'s own page `idx`: when
    /// the page is shared (the radix tree or another slot also references
    /// it), swap in a private copy first.  The radix flow never hands a slot
    /// a shared page it would write — matched pages are completely written
    /// and appends land past them, the divergent partial page is a fresh
    /// copy — so this never fires in normal operation; it exists to make
    /// "divergence cannot mutate a shared page" structural rather than
    /// circumstantial.  The allocation may exceed the slot's reservation,
    /// which is acceptable for a defensive path that normal flow never takes.
    fn cow_own_page(&mut self, slot: usize, idx: usize) -> Result<u32> {
        let page = self.own[slot][idx];
        if self.pool.refcount(page) <= 1 {
            return Ok(page);
        }
        let fresh = self.pool.alloc()?;
        let elems =
            self.pool.n_layers * self.pool.n_heads * self.pool.page_size * self.pool.d_head;
        let src = self.pool.slab_offset(page, 0, 0, 0);
        let dst = self.pool.slab_offset(fresh, 0, 0, 0);
        self.pool.k.copy_within(src..src + elems, dst);
        self.pool.v.copy_within(src..src + elems, dst);
        self.own[slot][idx] = fresh;
        let freed = self.pool.decref(page)?;
        debug_assert!(!freed, "a shared page cannot free on one decref");
        if let Some(t) = &mut self.radix {
            t.counters.cow_splits += 1;
        }
        Ok(fresh)
    }

    /// (page, in-page offset) of logical position `pos` of `slot`.
    fn locate(&self, n_prefix: usize, slot: usize, pos: usize) -> Result<(u32, usize)> {
        let ps = self.pool.page_size;
        if pos < n_prefix {
            return Ok((self.prefix_pages[pos / ps], pos % ps));
        }
        let rel = pos - n_prefix;
        match self.own[slot].get(rel / ps) {
            Some(&page) => Ok((page, rel % ps)),
            None => bail!("position {pos} unmapped in slot {slot}"),
        }
    }
}

/// Copy positions [start, end) of `row` from pages into a dense view, one
/// memcpy per (layer, head, page-contiguous span).
#[allow(clippy::too_many_arguments)]
fn copy_pages_to_dense(
    pool: &PagePool,
    prefix_pages: &[u32],
    own: &[u32],
    n_prefix: usize,
    row: usize,
    start: usize,
    end: usize,
    dk: &mut Tensor,
    dv: &mut Tensor,
    batch: usize,
    s_max: usize,
) -> Result<()> {
    let (ps, dh) = (pool.page_size, pool.d_head);
    for l in 0..pool.n_layers {
        for h in 0..pool.n_heads {
            let mut pos = start;
            while pos < end {
                // chunk bounded by the page holding `pos` and by the
                // prefix/own region boundary
                let (page, po, limit) = if pos < n_prefix {
                    (prefix_pages[pos / ps], pos % ps, n_prefix.min(end))
                } else {
                    let rel = pos - n_prefix;
                    let Some(&page) = own.get(rel / ps) else {
                        bail!("position {pos} unmapped in gather of row {row}");
                    };
                    (page, rel % ps, end)
                };
                let take = (ps - po).min(limit - pos);
                let src = pool.slab_offset(page, l, h, po);
                let dst = dense_offset(batch, pool.n_heads, s_max, dh, l, row, h, pos);
                dk.data[dst..dst + take * dh].copy_from_slice(&pool.k[src..src + take * dh]);
                dv.data[dst..dst + take * dh].copy_from_slice(&pool.v[src..src + take * dh]);
                pos += take;
            }
        }
    }
    Ok(())
}

enum Store {
    Dense { k: Tensor, v: Tensor },
    Paged(Paged),
}

pub struct KvCache {
    pub n_layers: usize,
    pub batch: usize,
    pub n_heads: usize,
    pub s_max: usize,
    pub d_head: usize,
    /// valid entries per row (incl. prefix slots)
    lens: Vec<usize>,
    pub n_prefix: usize,
    store: Store,
}

impl KvCache {
    /// Dense-layout cache (the baseline; engines default to paged).
    pub fn new(cfg: &ModelConfig, batch: usize) -> Self {
        Self::with_layout(cfg, batch, KvLayout::Dense)
    }

    pub fn with_layout(cfg: &ModelConfig, batch: usize, layout: KvLayout) -> Self {
        let store = match layout {
            KvLayout::Dense => {
                let shape = [cfg.n_layers, batch, cfg.n_heads, cfg.cache_max, cfg.d_head];
                Store::Dense { k: Tensor::zeros(&shape), v: Tensor::zeros(&shape) }
            }
            KvLayout::Paged { page_size, n_pages } => {
                let ps = page_size.max(1);
                let np = if n_pages == 0 {
                    (batch + 1) * div_ceil(cfg.cache_max, ps)
                } else {
                    n_pages
                };
                Store::Paged(Paged {
                    pool: PagePool::new(np, ps, cfg.n_layers, cfg.n_heads, cfg.d_head),
                    prefix_pages: Vec::new(),
                    own: vec![Vec::new(); batch],
                    reserved: vec![0; batch],
                    generation: vec![0; batch],
                    view: None,
                    radix: None,
                })
            }
        };
        Self {
            n_layers: cfg.n_layers,
            batch,
            n_heads: cfg.n_heads,
            s_max: cfg.cache_max,
            d_head: cfg.d_head,
            lens: vec![0; batch],
            n_prefix: 0,
            store,
        }
    }

    pub fn is_paged(&self) -> bool {
        matches!(self.store, Store::Paged(_))
    }

    /// dh-long K span at position (l, b, h, s).  Works on both layouts; the
    /// simulation backend and tests read the paged layout directly through
    /// this (no dense materialization).  Panics on an unmapped position, like
    /// out-of-range dense indexing would.
    pub fn k_at(&self, l: usize, b: usize, h: usize, s: usize) -> &[f32] {
        let dh = self.d_head;
        match &self.store {
            Store::Dense { k, .. } => {
                let o = dense_offset(self.batch, self.n_heads, self.s_max, dh, l, b, h, s);
                &k.data[o..o + dh]
            }
            Store::Paged(p) => {
                let (page, po) =
                    p.locate(self.n_prefix, b, s).expect("read of unmapped cache position");
                let o = p.pool.slab_offset(page, l, h, po);
                &p.pool.k[o..o + dh]
            }
        }
    }

    /// dh-long V span at position (l, b, h, s) (see [`KvCache::k_at`]).
    pub fn v_at(&self, l: usize, b: usize, h: usize, s: usize) -> &[f32] {
        let dh = self.d_head;
        match &self.store {
            Store::Dense { v, .. } => {
                let o = dense_offset(self.batch, self.n_heads, self.s_max, dh, l, b, h, s);
                &v.data[o..o + dh]
            }
            Store::Paged(p) => {
                let (page, po) =
                    p.locate(self.n_prefix, b, s).expect("read of unmapped cache position");
                let o = p.pool.slab_offset(page, l, h, po);
                &p.pool.v[o..o + dh]
            }
        }
    }

    /// Valid entries (incl. prefix) in row `b`.
    pub fn row_len(&self, b: usize) -> usize {
        self.lens[b]
    }

    pub fn lens(&self) -> &[usize] {
        &self.lens
    }

    /// Largest valid length across rows.
    pub fn max_len(&self) -> usize {
        self.lens.iter().copied().max().unwrap_or(0)
    }

    /// The shared length if every row agrees (run-to-completion invariant).
    pub fn uniform_len(&self) -> Option<usize> {
        let l0 = self.lens.first().copied()?;
        self.lens.iter().all(|&l| l == l0).then_some(l0)
    }

    /// Free positions in row `b`.
    pub fn remaining_row(&self, b: usize) -> usize {
        self.s_max - self.lens[b]
    }

    /// Free positions in the fullest row (conservative batch-wide headroom).
    pub fn remaining(&self) -> usize {
        self.s_max - self.max_len()
    }

    /// Worst-case own pages a request of `plen` prompt tokens and `max_new`
    /// budget can consume (0 for the dense layout).
    fn worst_own_pages(&self, plen: usize, max_new: usize) -> usize {
        match &self.store {
            Store::Dense { .. } => 0,
            Store::Paged(p) => {
                let end = (self.n_prefix + plen + max_new).min(self.s_max);
                div_ceil(end.saturating_sub(self.n_prefix), p.pool.page_size)
            }
        }
    }

    /// Can a request of this shape be admitted NOW without endangering any
    /// already-admitted slot's reservation?  Dense rows always can (slot
    /// availability is the engine's concern); paged admission is a
    /// page-availability check.
    pub fn can_admit(&self, plen: usize, max_new: usize) -> bool {
        match &self.store {
            Store::Dense { .. } => true,
            Store::Paged(p) => {
                p.pool.free_pages() >= p.uncommitted() + self.worst_own_pages(plen, max_new)
            }
        }
    }

    /// Could a request of this shape EVER be admitted (even into an idle
    /// cache)?  False means waiting for pages is pointless — reject it.
    pub fn admission_feasible(&self, plen: usize, max_new: usize) -> bool {
        match &self.store {
            Store::Dense { .. } => true,
            Store::Paged(p) => {
                p.prefix_pages.len() + self.worst_own_pages(plen, max_new) <= p.pool.n_pages
            }
        }
    }

    /// Would [`KvCache::can_admit`] hold if `slot` were retired first?  Lets
    /// the engine check that preempting a Decoding slot actually unblocks a
    /// page-starved candidate BEFORE destroying the victim's progress (an
    /// eviction that cannot cover the shortfall is pure lost work).
    pub fn can_admit_after_evicting(&self, slot: usize, plen: usize, max_new: usize) -> bool {
        match &self.store {
            Store::Dense { .. } => true,
            Store::Paged(p) => {
                if slot >= self.batch {
                    return false;
                }
                // retiring the slot returns its mapped own pages to the free
                // list and drops its outstanding (unfilled) reservation from
                // the promised total
                let own = p.own[slot].len();
                let outstanding = p.reserved[slot].saturating_sub(own);
                p.pool.free_pages() + own
                    >= p.uncommitted().saturating_sub(outstanding)
                        + self.worst_own_pages(plen, max_new)
            }
        }
    }

    /// Reserve worst-case pages for an admitted request in `slot` so its
    /// prefill/appends can never fail mid-flight.  No-op on the dense layout.
    pub fn reserve(&mut self, slot: usize, plen: usize, max_new: usize) -> Result<()> {
        if slot >= self.batch {
            bail!("reserve slot {slot} out of range");
        }
        let worst = self.worst_own_pages(plen, max_new);
        let clean = self.lens[slot] == self.n_prefix;
        match &mut self.store {
            Store::Dense { .. } => Ok(()),
            Store::Paged(p) => {
                if !clean || !p.own[slot].is_empty() {
                    bail!("reserve on a dirty slot {slot}");
                }
                if p.pool.free_pages() < p.uncommitted() + worst {
                    bail!(
                        "cannot reserve {worst} pages for slot {slot} ({} free, {} promised)",
                        p.pool.free_pages(),
                        p.uncommitted()
                    );
                }
                p.reserved[slot] = worst;
                Ok(())
            }
        }
    }

    /// Install the shared prefix into positions [0, n_prefix) of every row.
    /// Dense: physically copied per row.  Paged: written once into refcounted
    /// prefix pages mapped into every slot (one cache ref + one ref per slot).
    pub fn install_prefix(&mut self, p: &PrefixState) -> Result<()> {
        let n = p.n_prefix as usize;
        if n > self.s_max {
            bail!("prefix {} exceeds cache capacity {}", n, self.s_max);
        }
        let pcap = p.k.shape[2]; // padded prefix capacity P
        let dh = self.d_head;
        match &mut self.store {
            Store::Dense { k, v } => {
                for l in 0..self.n_layers {
                    for b in 0..self.batch {
                        for h in 0..self.n_heads {
                            // positions are contiguous in s on both sides:
                            // one memcpy per (layer, row, head) span
                            let src = (l * self.n_heads + h) * pcap * dh;
                            let dst =
                                dense_offset(self.batch, self.n_heads, self.s_max, dh, l, b, h, 0);
                            let span = n * dh;
                            k.data[dst..dst + span].copy_from_slice(&p.k.data[src..src + span]);
                            v.data[dst..dst + span].copy_from_slice(&p.v.data[src..src + span]);
                        }
                    }
                }
            }
            Store::Paged(pg) => {
                if pg.own.iter().any(|o| !o.is_empty()) {
                    bail!("install_prefix on a cache with live slots");
                }
                // release any previous prefix mapping: the cache's base ref
                // plus one mapping ref per slot
                for page in std::mem::take(&mut pg.prefix_pages) {
                    for _ in 0..self.batch + 1 {
                        pg.pool.decref(page)?;
                    }
                }
                let ps = pg.pool.page_size;
                for i in 0..div_ceil(n, ps) {
                    let page = pg.pool.alloc()?; // cache base ref
                    for _ in 0..self.batch {
                        pg.pool.incref(page)?; // one mapping ref per slot
                    }
                    let s0 = i * ps;
                    let cnt = (n - s0).min(ps);
                    for l in 0..self.n_layers {
                        for h in 0..self.n_heads {
                            let src = ((l * self.n_heads + h) * pcap + s0) * dh;
                            let dst = pg.pool.slab_offset(page, l, h, 0);
                            let span = cnt * dh;
                            pg.pool.k[dst..dst + span]
                                .copy_from_slice(&p.k.data[src..src + span]);
                            pg.pool.v[dst..dst + span]
                                .copy_from_slice(&p.v.data[src..src + span]);
                        }
                    }
                    pg.prefix_pages.push(page);
                }
                // dense mirrors of the previous prefix are stale
                for g in pg.generation.iter_mut() {
                    *g += 1;
                }
            }
        }
        self.n_prefix = n;
        self.lens.fill(n);
        Ok(())
    }

    /// Copy row `src_row` of a prefill executable's K/V output ([L, Bsrc, H,
    /// Ssrc, dh], storage domain) into slot `slot` for the first `prompt_len`
    /// positions, starting right after the prefix.  Sets
    /// row_len(slot) = n_prefix + prompt_len.
    pub fn write_prefill_row(
        &mut self,
        slot: usize,
        k: &Tensor,
        v: &Tensor,
        src_row: usize,
        prompt_len: usize,
    ) -> Result<()> {
        self.write_prefill_span(slot, k, v, src_row, 0, prompt_len)
    }

    /// Chunked-prefill write: copy token positions [start, end) of source row
    /// `src_row` (token domain: 0 = first prompt position) into slot `slot`
    /// at cache positions [n_prefix + start, n_prefix + end).  Chunks must be
    /// contiguous — the row's length must sit exactly at `n_prefix + start`
    /// (for `start == 0` this is the clean-slot discipline) — and the write
    /// advances row_len(slot) to `n_prefix + end`, so a partially-prefilled
    /// row can never be decoded past what was written.
    pub fn write_prefill_span(
        &mut self,
        slot: usize,
        k: &Tensor,
        v: &Tensor,
        src_row: usize,
        start: usize,
        end: usize,
    ) -> Result<()> {
        if k.shape.len() != 5 || v.shape != k.shape {
            bail!("prefill kv shape mismatch: {:?} vs {:?}", k.shape, v.shape);
        }
        let (l, b, h, s, dh) = (k.shape[0], k.shape[1], k.shape[2], k.shape[3], k.shape[4]);
        if l != self.n_layers || h != self.n_heads || dh != self.d_head {
            bail!("prefill kv shape mismatch: {:?}", k.shape);
        }
        if slot >= self.batch || src_row >= b {
            bail!("prefill row out of range: slot {slot}/{}, src {src_row}/{b}", self.batch);
        }
        if start > end {
            bail!("prefill span [{start}, {end}) is inverted");
        }
        if end > s {
            bail!("prefill span end {end} exceeds prefill output seq {s}");
        }
        if self.n_prefix + end > self.s_max {
            bail!("prompt too long: {} + {} > {}", self.n_prefix, end, self.s_max);
        }
        // contiguity discipline: chunk N+1 lands exactly where chunk N ended.
        // For start == 0 this is the clean-slot rule dense rows rely on to
        // bound the retirement memset and paged slots rely on so page tables
        // only ever grow from empty.
        if self.lens[slot] != self.n_prefix + start {
            bail!(
                "prefill span start {start} into slot {slot} at len {} (prefix {}): \
                 chunks must be contiguous (reset_slot first for a fresh row)",
                self.lens[slot],
                self.n_prefix
            );
        }
        let span_len = end - start;
        match &mut self.store {
            Store::Dense { k: kc, v: vc } => {
                for li in 0..l {
                    for hi in 0..h {
                        // positions are contiguous in s on both sides: one
                        // memcpy per (layer, head) span
                        let src = (((li * b + src_row) * h + hi) * s + start) * dh;
                        let dst = dense_offset(
                            self.batch,
                            self.n_heads,
                            self.s_max,
                            dh,
                            li,
                            slot,
                            hi,
                            self.n_prefix + start,
                        );
                        let span = span_len * dh;
                        kc.data[dst..dst + span].copy_from_slice(&k.data[src..src + span]);
                        vc.data[dst..dst + span].copy_from_slice(&v.data[src..src + span]);
                    }
                }
            }
            Store::Paged(pg) => {
                let ps = pg.pool.page_size;
                for idx in 0..div_ceil(end, ps) {
                    pg.ensure_own_page(slot, idx)?;
                    if (idx + 1) * ps > start {
                        // the page overlaps the written span [start, end):
                        // it must be private before any byte changes
                        pg.cow_own_page(slot, idx)?;
                    }
                }
                for li in 0..l {
                    for hi in 0..h {
                        let src_base = ((li * b + src_row) * h + hi) * s * dh;
                        let mut rel = start;
                        while rel < end {
                            let (idx, po) = (rel / ps, rel % ps);
                            let take = (ps - po).min(end - rel);
                            let page = pg.own[slot][idx];
                            let dst = pg.pool.slab_offset(page, li, hi, po);
                            let src = src_base + rel * dh;
                            let span = take * dh;
                            pg.pool.k[dst..dst + span]
                                .copy_from_slice(&k.data[src..src + span]);
                            pg.pool.v[dst..dst + span]
                                .copy_from_slice(&v.data[src..src + span]);
                            rel += take;
                        }
                    }
                }
            }
        }
        self.lens[slot] = self.n_prefix + end;
        Ok(())
    }

    /// Uniform-batch prefill (run-to-completion path): write the first
    /// `prompt_len` positions of every row from a [L, B, H, S, dh] output.
    pub fn write_prefill(&mut self, k: &Tensor, v: &Tensor, prompt_len: usize) -> Result<()> {
        if k.shape.len() != 5 || k.shape[1] != self.batch {
            bail!("prefill kv shape mismatch: {:?}", k.shape);
        }
        for row in 0..self.batch {
            // write_prefill_row rejects prompt_len > S / cache overflow
            self.write_prefill_row(row, k, v, row, prompt_len)?;
        }
        Ok(())
    }

    /// Adopt the decode executable's updated caches wholesale and bump every
    /// row (valid only when all rows advanced together on the DENSE layout —
    /// the paged store scatters per row via [`KvCache::append_rows`]).
    pub fn adopt(&mut self, k: Tensor, v: Tensor) -> Result<()> {
        let Some(len) = self.uniform_len() else {
            bail!("adopt requires uniform row lengths, got {:?}", self.lens);
        };
        if len + 1 > self.s_max {
            bail!("cache overflow at len {len}");
        }
        let Store::Dense { k: kc, v: vc } = &mut self.store else {
            bail!("adopt requires the dense layout");
        };
        if k.shape != kc.shape || v.shape != vc.shape {
            bail!("decode kv shape mismatch");
        }
        *kc = k;
        *vc = v;
        self.lens.fill(len + 1);
        Ok(())
    }

    /// Scatter the newly-written position `len` of `rows` from a decode
    /// executable's full-shape [L, B, H, Smax, dh] K/V output and bump those
    /// rows only.  Rows not listed keep their previous contents (the decode
    /// graph scribbles at position `len` of every row; only the listed rows
    /// own that position).  This is the scatter half of the paged shim.
    pub fn append_rows(&mut self, k: &Tensor, v: &Tensor, rows: &[usize], len: usize) -> Result<()> {
        let want = vec![self.n_layers, self.batch, self.n_heads, self.s_max, self.d_head];
        if k.shape != want || v.shape != want {
            bail!("decode kv shape mismatch: {:?}", k.shape);
        }
        if len + 1 > self.s_max {
            bail!("cache overflow at len {len}");
        }
        let dh = self.d_head;
        for &row in rows {
            if row >= self.batch {
                bail!("append row {row} out of range");
            }
            if self.lens[row] != len {
                bail!("append_rows: row {row} has len {}, group len {len}", self.lens[row]);
            }
            if len < self.n_prefix {
                bail!("append_rows into the prefix region (len {len})");
            }
            match &mut self.store {
                Store::Dense { k: kc, v: vc } => {
                    for l in 0..self.n_layers {
                        for h in 0..self.n_heads {
                            let off = dense_offset(
                                self.batch,
                                self.n_heads,
                                self.s_max,
                                dh,
                                l,
                                row,
                                h,
                                len,
                            );
                            kc.data[off..off + dh].copy_from_slice(&k.data[off..off + dh]);
                            vc.data[off..off + dh].copy_from_slice(&v.data[off..off + dh]);
                        }
                    }
                }
                Store::Paged(pg) => {
                    let ps = pg.pool.page_size;
                    let rel = len - self.n_prefix;
                    pg.ensure_own_page(row, rel / ps)?;
                    let page = pg.cow_own_page(row, rel / ps)?;
                    let po = rel % ps;
                    for l in 0..self.n_layers {
                        for h in 0..self.n_heads {
                            let src = dense_offset(
                                self.batch,
                                self.n_heads,
                                self.s_max,
                                dh,
                                l,
                                row,
                                h,
                                len,
                            );
                            let dst = pg.pool.slab_offset(page, l, h, po);
                            pg.pool.k[dst..dst + dh].copy_from_slice(&k.data[src..src + dh]);
                            pg.pool.v[dst..dst + dh].copy_from_slice(&v.data[src..src + dh]);
                        }
                    }
                }
            }
            self.lens[row] = len + 1;
        }
        Ok(())
    }

    /// Append one token's K/V ([L, H, dh] values) to row `slot` at its
    /// current length (host-computed backends, e.g. the simulation backend).
    pub fn append_token_row(&mut self, slot: usize, k: &Tensor, v: &Tensor) -> Result<()> {
        let want = [self.n_layers, self.n_heads, self.d_head];
        if k.shape != want || v.shape != want {
            bail!("append_token_row wants {:?}, got {:?}", want, k.shape);
        }
        if slot >= self.batch {
            bail!("append slot {slot} out of range");
        }
        let len = self.lens[slot];
        if len + 1 > self.s_max {
            bail!("cache overflow at len {len}");
        }
        let dh = self.d_head;
        match &mut self.store {
            Store::Dense { k: kc, v: vc } => {
                for l in 0..self.n_layers {
                    for h in 0..self.n_heads {
                        let src = (l * self.n_heads + h) * dh;
                        let dst =
                            dense_offset(self.batch, self.n_heads, self.s_max, dh, l, slot, h, len);
                        kc.data[dst..dst + dh].copy_from_slice(&k.data[src..src + dh]);
                        vc.data[dst..dst + dh].copy_from_slice(&v.data[src..src + dh]);
                    }
                }
            }
            Store::Paged(pg) => {
                let ps = pg.pool.page_size;
                let rel = len - self.n_prefix;
                pg.ensure_own_page(slot, rel / ps)?;
                let page = pg.cow_own_page(slot, rel / ps)?;
                let po = rel % ps;
                for l in 0..self.n_layers {
                    for h in 0..self.n_heads {
                        let src = (l * self.n_heads + h) * dh;
                        let dst = pg.pool.slab_offset(page, l, h, po);
                        pg.pool.k[dst..dst + dh].copy_from_slice(&k.data[src..src + dh]);
                        pg.pool.v[dst..dst + dh].copy_from_slice(&v.data[src..src + dh]);
                    }
                }
            }
        }
        self.lens[slot] = len + 1;
        Ok(())
    }

    /// Retire a slot so the next occupant starts clean with the shared prefix
    /// intact.
    ///
    /// Dense: zero the row's occupied non-prefix positions (cost scales with
    /// what the sequence used).  Paged: drop the slot's own-page references —
    /// prefix pages keep the cache's base ref plus every OTHER slot's mapping
    /// ref, freed pages go back to the pool unzeroed, and no KV byte is
    /// touched: retirement is O(pages held), independent of tokens stored.
    pub fn reset_slot(&mut self, slot: usize) -> Result<()> {
        if slot >= self.batch {
            bail!("reset slot {slot} out of range");
        }
        match &mut self.store {
            Store::Dense { k, v } => {
                let used = self.lens[slot].min(self.s_max);
                if self.n_prefix < used {
                    let span = (used - self.n_prefix) * self.d_head;
                    for l in 0..self.n_layers {
                        for h in 0..self.n_heads {
                            let start = dense_offset(
                                self.batch,
                                self.n_heads,
                                self.s_max,
                                self.d_head,
                                l,
                                slot,
                                h,
                                self.n_prefix,
                            );
                            k.data[start..start + span].fill(0.0);
                            v.data[start..start + span].fill(0.0);
                        }
                    }
                }
            }
            Store::Paged(pg) => {
                while let Some(page) = pg.own[slot].pop() {
                    pg.pool.decref(page)?;
                }
                pg.reserved[slot] = 0;
                pg.generation[slot] += 1;
            }
        }
        self.lens[slot] = self.n_prefix;
        Ok(())
    }

    /// Dense view of the cache for the fixed-geometry executables (the gather
    /// half of the `ModelBackend` shim).  Dense layout: the storage itself.
    /// Paged: an incrementally-mirrored [L, B, H, Smax, dh] scratch — only
    /// positions written since the last gather of each requested row are
    /// copied, so steady-state decode gathers O(1) positions per row.
    pub fn gather_dense(&mut self, rows: &[usize]) -> Result<(&Tensor, &Tensor)> {
        let (batch, s_max) = (self.batch, self.s_max);
        let shape = [self.n_layers, batch, self.n_heads, s_max, self.d_head];
        let n_prefix = self.n_prefix;
        let lens = &self.lens;
        match &mut self.store {
            Store::Dense { k, v } => Ok((&*k, &*v)),
            Store::Paged(pg) => {
                if pg.view.is_none() {
                    pg.view = Some(DenseView {
                        k: Tensor::zeros(&shape),
                        v: Tensor::zeros(&shape),
                        mirrored: vec![0; batch],
                        // generation counters start at 0: force a full first copy
                        gen: vec![u64::MAX; batch],
                    });
                }
                let Paged { pool, prefix_pages, own, generation, view, .. } = pg;
                let view = view.as_mut().expect("view allocated above");
                for &row in rows {
                    if row >= batch {
                        bail!("gather row {row} out of range");
                    }
                    let len = lens[row];
                    let start = if view.gen[row] == generation[row] {
                        view.mirrored[row].min(len)
                    } else {
                        0
                    };
                    copy_pages_to_dense(
                        pool,
                        prefix_pages,
                        &own[row],
                        n_prefix,
                        row,
                        start,
                        len,
                        &mut view.k,
                        &mut view.v,
                        batch,
                        s_max,
                    )?;
                    view.mirrored[row] = len;
                    view.gen[row] = generation[row];
                }
                Ok((&view.k, &view.v))
            }
        }
    }

    // ---- radix prefix cache ------------------------------------------------

    /// Turn on the generalized radix prefix cache (tree over own-region page
    /// runs, see `coordinator/radix/`).  Requires the paged layout — the tree
    /// shares physical pages, which dense rows cannot do.
    pub fn enable_radix(&mut self) -> Result<()> {
        match &mut self.store {
            Store::Dense { .. } => bail!("radix prefix cache requires the paged KV layout"),
            Store::Paged(p) => {
                if p.radix.is_none() {
                    p.radix = Some(RadixTree::new(p.pool.page_size));
                }
                Ok(())
            }
        }
    }

    pub fn radix_enabled(&self) -> bool {
        matches!(&self.store, Store::Paged(p) if p.radix.is_some())
    }

    /// Prefix-cache counters plus current shared-page gauges (None when the
    /// cache is dense or the radix tree is off).
    pub fn radix_stats(&self) -> Option<RadixStats> {
        match &self.store {
            Store::Dense { .. } => None,
            Store::Paged(p) => {
                let bytes = p.pool.page_bytes();
                p.radix.as_ref().map(|t| t.stats(bytes))
            }
        }
    }

    /// Drop every cached run and release the tree's page references (worker
    /// teardown, so post-mortem page accounting balances).  Returns the
    /// number of pages released.
    pub fn radix_flush(&mut self) -> Result<usize> {
        match &mut self.store {
            Store::Dense { .. } => Ok(0),
            Store::Paged(p) => {
                let Some(tree) = &mut p.radix else {
                    return Ok(0);
                };
                let pages = tree.flush();
                let n = pages.len();
                for pg in pages {
                    p.pool.decref(pg)?;
                }
                Ok(n)
            }
        }
    }

    /// Match-aware admission check: like [`KvCache::can_admit`], but credits
    /// the full pages the radix tree would serve for this row's token
    /// sequence AND the pages sustained LRU eviction of cache-only runs could
    /// free.  `tokens` is the row's own-region sequence (BOS + prompt +
    /// resumed, so `tokens.len() == plen`).  Falls back to the plain
    /// worst-case check when the tree is off.
    pub fn radix_can_admit(&self, plen: usize, max_new: usize, tokens: &[i32]) -> bool {
        match &self.store {
            Store::Dense { .. } => true,
            Store::Paged(p) => {
                let worst = self.worst_own_pages(plen, max_new);
                let Some(tree) = &p.radix else {
                    return p.pool.free_pages() >= p.uncommitted() + worst;
                };
                // cap the match one token short so every admission still
                // prefills at least one position (the first-token contract)
                let matched = tree.peek(tokens, plen.saturating_sub(1));
                let exclude: HashSet<u32> = matched.iter().copied().collect();
                let evictable = tree.evictable_pages(&exclude, |pg| p.pool.refcount(pg) == 1);
                p.pool.free_pages() + evictable
                    >= p.uncommitted() + worst.saturating_sub(matched.len())
            }
        }
    }

    /// Match-aware [`KvCache::can_admit_after_evicting`]: would preempting
    /// `slot` (plus LRU-evicting cache-only runs) actually cover the
    /// candidate's reservation?  Unlike the worst-case variant, only the
    /// victim's PRIVATE pages count as freed — a page the victim shares with
    /// the tree or another slot survives its retirement (though retirement
    /// does make victim+tree pages evictable, which the eviction term sees).
    pub fn radix_can_admit_after_evicting(
        &self,
        slot: usize,
        plen: usize,
        max_new: usize,
        tokens: &[i32],
    ) -> bool {
        match &self.store {
            Store::Dense { .. } => true,
            Store::Paged(p) => {
                if slot >= self.batch {
                    return false;
                }
                if p.radix.is_none() {
                    return self.can_admit_after_evicting(slot, plen, max_new);
                }
                let tree = p.radix.as_ref().expect("checked above");
                let worst = self.worst_own_pages(plen, max_new);
                let victim: HashSet<u32> = p.own[slot].iter().copied().collect();
                let own_freed =
                    p.own[slot].iter().filter(|&&pg| p.pool.refcount(pg) == 1).count();
                let outstanding = p.reserved[slot].saturating_sub(p.own[slot].len());
                let matched = tree.peek(tokens, plen.saturating_sub(1));
                let exclude: HashSet<u32> = matched.iter().copied().collect();
                let evictable = tree.evictable_pages(&exclude, |pg| {
                    // effective refcount once the victim's mapping is gone
                    let held = u32::from(victim.contains(&pg));
                    p.pool.refcount(pg).saturating_sub(held) == 1
                });
                p.pool.free_pages() + own_freed + evictable
                    >= p.uncommitted().saturating_sub(outstanding)
                        + worst.saturating_sub(matched.len())
            }
        }
    }

    /// Atomic radix admission of `slot`: walk the prefix cache with the
    /// row's own-region token sequence (`tokens` = BOS + prompt + resumed,
    /// `tokens.len() == plen`), map every matched full page into the slot's
    /// page table, copy-on-write the first divergent partial page, LRU-evict
    /// cache-only runs when the worst-case reservation needs the room, and
    /// reserve the remainder.  Returns the number of cache positions served
    /// from shared pages — the engine starts prefill there — or `Ok(None)`
    /// when pages are short even after eviction (the safe fallback: the
    /// caller defers or preempts exactly as for a failed
    /// [`KvCache::can_admit`]).  With the tree off this degenerates to
    /// [`KvCache::reserve`] semantics, reporting `Some(0)` or `None`.
    pub fn admit_radix(
        &mut self,
        slot: usize,
        plen: usize,
        max_new: usize,
        tokens: &[i32],
    ) -> Result<Option<usize>> {
        if slot >= self.batch {
            bail!("radix admission slot {slot} out of range");
        }
        let worst = self.worst_own_pages(plen, max_new);
        let clean = self.lens[slot] == self.n_prefix;
        let n_prefix = self.n_prefix;
        match &mut self.store {
            Store::Dense { .. } => Ok(Some(0)),
            Store::Paged(p) => {
                if !clean || !p.own[slot].is_empty() {
                    bail!("radix admission on a dirty slot {slot}");
                }
                let Paged { pool, radix, own, reserved, .. } = p;
                let promised = |own: &[Vec<u32>], reserved: &[usize]| -> usize {
                    own.iter()
                        .zip(reserved.iter())
                        .map(|(o, &r)| r.saturating_sub(o.len()))
                        .sum()
                };
                let Some(tree) = radix.as_mut() else {
                    if pool.free_pages() < promised(own, reserved) + worst {
                        return Ok(None);
                    }
                    reserved[slot] = worst;
                    return Ok(Some(0));
                };
                tree.counters.lookups += 1;
                let ps = pool.page_size;
                // cap one token short: every admission must prefill ≥ 1
                // position to carry the first-token contract
                let m = tree.lookup(tokens, plen.saturating_sub(1));
                let k_full = m.pages.len();
                let needed = worst.saturating_sub(k_full);
                let uncommitted = promised(own, reserved);
                let deficit = (uncommitted + needed).saturating_sub(pool.free_pages());
                if deficit > 0 {
                    // only evict when eviction can actually cover the gap —
                    // shrinking the cache for an admission that then defers
                    // anyway would be pure lost hits
                    let exclude: HashSet<u32> = m.pages.iter().copied().collect();
                    if tree.evictable_pages(&exclude, |pg| pool.refcount(pg) == 1) < deficit {
                        return Ok(None);
                    }
                    let evicted =
                        tree.evict_lru(deficit, &exclude, |pg| pool.refcount(pg) == 1);
                    for pg in evicted {
                        pool.decref(pg)?;
                    }
                    if (uncommitted + needed).saturating_sub(pool.free_pages()) > 0 {
                        return Ok(None); // eviction fell short: safe fallback
                    }
                }
                // transaction point: nothing below can fail for page shortage
                for &pg in &m.pages {
                    pool.incref(pg)?;
                    own[slot].push(pg);
                }
                reserved[slot] = worst;
                let mut matched_tok = k_full * ps;
                if let Some((src_page, cp)) = m.partial {
                    // divergent partial page: private copy of the shared
                    // tokens (cp ≥ 1, < page_size), inside the reservation —
                    // k_full < worst whenever a partial exists, and the
                    // eviction above guaranteed free ≥ uncommitted + needed
                    let fresh = pool.alloc()?;
                    for l in 0..pool.n_layers {
                        for h in 0..pool.n_heads {
                            let src = pool.slab_offset(src_page, l, h, 0);
                            let dst = pool.slab_offset(fresh, l, h, 0);
                            let span = cp * pool.d_head;
                            pool.k.copy_within(src..src + span, dst);
                            pool.v.copy_within(src..src + span, dst);
                        }
                    }
                    own[slot].push(fresh);
                    tree.counters.cow_splits += 1;
                    matched_tok += cp;
                }
                if matched_tok > 0 {
                    tree.counters.hits += 1;
                    tree.counters.hit_tokens += matched_tok;
                }
                self.lens[slot] = n_prefix + matched_tok;
                Ok(Some(matched_tok))
            }
        }
    }

    /// Offer a retiring slot's sequence to the prefix cache: every own page
    /// whose `page_size` positions were completely written becomes a tree
    /// node unless that chunk is already cached (first writer wins — the
    /// root-path invariant makes contents identical).  The tree takes one
    /// pool reference per adopted page, so they survive the caller's
    /// [`KvCache::reset_slot`].  `tokens` is the row's own-region sequence
    /// (BOS + prompt + generated).  Returns the pages adopted.
    pub fn radix_insert(&mut self, slot: usize, tokens: &[i32]) -> Result<usize> {
        if slot >= self.batch {
            bail!("radix insert slot {slot} out of range");
        }
        let written = self.lens[slot].saturating_sub(self.n_prefix);
        match &mut self.store {
            Store::Dense { .. } => Ok(0),
            Store::Paged(p) => {
                let Paged { pool, radix, own, .. } = p;
                let Some(tree) = radix.as_mut() else {
                    return Ok(0);
                };
                let ps = pool.page_size;
                let n_full = tokens.len().min(written) / ps;
                if n_full == 0 {
                    return Ok(0);
                }
                let adopted = tree.insert(&tokens[..n_full * ps], &own[slot][..n_full]);
                let n = adopted.len();
                for pg in adopted {
                    pool.incref(pg)?;
                }
                Ok(n)
            }
        }
    }

    // ---- capacity reporting ------------------------------------------------

    /// Bytes resident for KV storage (dense block, or page pool plus the
    /// dense shim scratch when one has been materialized).
    pub fn resident_kv_bytes(&self) -> usize {
        match &self.store {
            Store::Dense { k, .. } => 2 * 4 * k.data.len(),
            Store::Paged(p) => {
                let mut bytes = p.pool.n_pages * p.pool.page_bytes();
                if let Some(view) = &p.view {
                    bytes += 2 * 4 * view.k.data.len();
                }
                bytes
            }
        }
    }

    /// Bytes of KV actually holding live sequence state (dense: live
    /// positions; paged: mapped pages).
    pub fn used_kv_bytes(&self) -> usize {
        match &self.store {
            Store::Dense { .. } => {
                let pos_bytes = 2 * 4 * self.n_layers * self.n_heads * self.d_head;
                self.lens.iter().sum::<usize>() * pos_bytes
            }
            Store::Paged(p) => p.pool.used_pages() * p.pool.page_bytes(),
        }
    }

    pub fn page_size(&self) -> Option<usize> {
        match &self.store {
            Store::Dense { .. } => None,
            Store::Paged(p) => Some(p.pool.page_size),
        }
    }

    pub fn total_pages(&self) -> Option<usize> {
        match &self.store {
            Store::Dense { .. } => None,
            Store::Paged(p) => Some(p.pool.n_pages),
        }
    }

    pub fn free_pages(&self) -> Option<usize> {
        match &self.store {
            Store::Dense { .. } => None,
            Store::Paged(p) => Some(p.pool.free_pages()),
        }
    }

    /// Page ids of the shared prefix (paged layout; empty for dense).
    pub fn prefix_page_ids(&self) -> &[u32] {
        match &self.store {
            Store::Dense { .. } => &[],
            Store::Paged(p) => &p.prefix_pages,
        }
    }

    /// Refcount of `page` (paged layout only).
    pub fn page_refcount(&self, page: u32) -> Option<u32> {
        match &self.store {
            Store::Dense { .. } => None,
            Store::Paged(p) => Some(p.pool.refcount(page)),
        }
    }

    /// Page ids mapped into `slot`'s own (non-prefix) region.
    pub fn own_page_ids(&self, slot: usize) -> &[u32] {
        match &self.store {
            Store::Dense { .. } => &[],
            Store::Paged(p) => &p.own[slot],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            vocab_size: 272,
            d_model: 8,
            n_layers: 2,
            n_heads: 2,
            d_head: 4,
            d_ff: 16,
            o_model: 3,
            inject_amp: 1.0,
            inject_delta: 0.1,
            max_prefix: 4,
            train_seq: 8,
            eval_seq: 8,
            cache_max: 16,
            sites: vec!["down_in".into()],
        }
    }

    fn prefix(cfg: &ModelConfig, n: usize) -> PrefixState {
        let shape = [cfg.n_layers, cfg.n_heads, cfg.max_prefix, cfg.d_head];
        let mut k = Tensor::zeros(&shape);
        for (i, v) in k.data.iter_mut().enumerate() {
            *v = i as f32;
        }
        PrefixState {
            tokens: vec![49; n],
            n_prefix: n as i32,
            n_ctx_sinks: n as i32,
            v: k.clone(),
            k,
        }
    }

    fn paged(page_size: usize) -> KvLayout {
        KvLayout::Paged { page_size, n_pages: 0 }
    }

    fn layouts() -> [KvLayout; 2] {
        [KvLayout::Dense, paged(4)]
    }

    #[test]
    fn prefix_shared_across_rows() {
        let c = cfg();
        for layout in layouts() {
            let mut kv = KvCache::with_layout(&c, 3, layout);
            kv.install_prefix(&prefix(&c, 2)).unwrap();
            assert_eq!(kv.lens(), &[2, 2, 2]);
            // row 0 and row 2 hold identical prefix entries
            for l in 0..c.n_layers {
                for h in 0..c.n_heads {
                    for s in 0..2 {
                        assert_eq!(kv.k_at(l, 0, h, s), kv.k_at(l, 2, h, s));
                    }
                }
            }
        }
    }

    #[test]
    fn paged_prefix_is_mapped_not_copied() {
        let c = cfg();
        let mut kv = KvCache::with_layout(&c, 3, paged(4));
        kv.install_prefix(&prefix(&c, 2)).unwrap();
        // one physical page serves all three slots: refcount = slots + cache
        assert_eq!(kv.prefix_page_ids().len(), 1);
        let pg = kv.prefix_page_ids()[0];
        assert_eq!(kv.page_refcount(pg), Some(4));
        // retiring a slot must not release the shared prefix
        kv.reset_slot(1).unwrap();
        assert_eq!(kv.page_refcount(pg), Some(4));
        assert_eq!(kv.k_at(0, 1, 0, 0), kv.k_at(0, 0, 0, 0));
    }

    #[test]
    fn prefill_goes_after_prefix() {
        let c = cfg();
        for layout in layouts() {
            let mut kv = KvCache::with_layout(&c, 2, layout);
            kv.install_prefix(&prefix(&c, 2)).unwrap();
            let shape = [c.n_layers, 2, c.n_heads, 5, c.d_head];
            let k = Tensor::full(&shape, 7.0);
            kv.write_prefill(&k, &k, 5).unwrap();
            assert_eq!(kv.uniform_len(), Some(7));
            assert_eq!(kv.k_at(0, 0, 0, 2)[0], 7.0); // first prompt slot after prefix
            assert_ne!(kv.k_at(0, 0, 0, 1)[0], 7.0); // prefix untouched
        }
    }

    /// Chunked prefill: contiguous spans land at the right cache positions
    /// on both layouts, non-contiguous spans are rejected, and the row is
    /// byte-identical to a single full-row write.
    #[test]
    fn prefill_span_chunks_are_contiguous() {
        let c = cfg();
        for layout in layouts() {
            let mut kv = KvCache::with_layout(&c, 2, layout);
            kv.install_prefix(&prefix(&c, 2)).unwrap();
            let shape = [c.n_layers, 1, c.n_heads, 7, c.d_head];
            let mut src = Tensor::zeros(&shape);
            for (i, v) in src.data.iter_mut().enumerate() {
                *v = i as f32;
            }
            // full-row reference in slot 0
            kv.write_prefill_row(0, &src, &src, 0, 7).unwrap();
            // three chunks into slot 1
            kv.write_prefill_span(1, &src, &src, 0, 0, 3).unwrap();
            assert_eq!(kv.row_len(1), 2 + 3);
            // a gap or a replay is rejected (chunks must be contiguous)
            assert!(kv.write_prefill_span(1, &src, &src, 0, 4, 7).is_err());
            assert!(kv.write_prefill_span(1, &src, &src, 0, 0, 3).is_err());
            kv.write_prefill_span(1, &src, &src, 0, 3, 5).unwrap();
            kv.write_prefill_span(1, &src, &src, 0, 5, 7).unwrap();
            assert_eq!(kv.row_len(1), kv.row_len(0));
            for l in 0..c.n_layers {
                for h in 0..c.n_heads {
                    for s in 0..kv.row_len(0) {
                        assert_eq!(
                            kv.k_at(l, 0, h, s),
                            kv.k_at(l, 1, h, s),
                            "chunked row diverged at (l={l}, h={h}, s={s})"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn overflow_rejected() {
        let c = cfg();
        for layout in layouts() {
            let mut kv = KvCache::with_layout(&c, 1, layout);
            kv.install_prefix(&prefix(&c, 2)).unwrap();
            let shape = [c.n_layers, 1, c.n_heads, 20, c.d_head];
            let k = Tensor::zeros(&shape);
            assert!(kv.write_prefill_row(0, &k, &k, 0, 20).is_err());
        }
    }

    #[test]
    fn per_slot_write_and_reset_dense() {
        let c = cfg();
        let mut kv = KvCache::new(&c, 3);
        kv.install_prefix(&prefix(&c, 2)).unwrap();
        // write a 4-token prompt into slot 1 only, from source row 0
        let shape = [c.n_layers, 1, c.n_heads, 4, c.d_head];
        let k = Tensor::full(&shape, 9.0);
        kv.write_prefill_row(1, &k, &k, 0, 4).unwrap();
        assert_eq!(kv.lens(), &[2, 6, 2]);
        // neighbours untouched
        assert_eq!(kv.k_at(0, 0, 0, 2)[0], 0.0);
        assert_eq!(kv.k_at(0, 2, 0, 2)[0], 0.0);
        assert_eq!(kv.k_at(0, 1, 0, 2)[0], 9.0);

        // append one decoded token
        let step = Tensor::full(&[c.n_layers, c.n_heads, c.d_head], 3.0);
        kv.append_token_row(1, &step, &step).unwrap();
        assert_eq!(kv.row_len(1), 7);
        assert_eq!(kv.k_at(0, 1, 0, 6)[0], 3.0);

        // retire: non-prefix region zeroed, prefix survives
        kv.reset_slot(1).unwrap();
        assert_eq!(kv.row_len(1), 2);
        for s in 2..kv.s_max {
            assert_eq!(kv.k_at(0, 1, 0, s), [0.0; 4]);
        }
        assert_eq!(kv.k_at(0, 1, 0, 1), kv.k_at(0, 0, 0, 1)); // prefix intact
    }

    #[test]
    fn paged_slot_lifecycle_reuses_pages_without_memset() {
        let c = cfg();
        let mut kv = KvCache::with_layout(&c, 2, paged(4));
        kv.install_prefix(&prefix(&c, 2)).unwrap();
        let free0 = kv.free_pages().unwrap();

        let shape = [c.n_layers, 1, c.n_heads, 6, c.d_head];
        let k = Tensor::full(&shape, 9.0);
        kv.write_prefill_row(1, &k, &k, 0, 6).unwrap();
        assert_eq!(kv.row_len(1), 8);
        // 6 own positions after a 2-token prefix at page_size 4 → 2 pages
        let pages: Vec<u32> = kv.own_page_ids(1).to_vec();
        assert_eq!(pages.len(), 2);
        assert_eq!(kv.free_pages().unwrap(), free0 - 2);
        assert_eq!(kv.k_at(0, 1, 0, 5)[0], 9.0);

        // O(1) retirement: pages return to the pool, nothing is zeroed
        kv.reset_slot(1).unwrap();
        assert_eq!(kv.row_len(1), 2);
        assert_eq!(kv.free_pages().unwrap(), free0);
        for &p in &pages {
            assert_eq!(kv.page_refcount(p), Some(0));
        }

        // the next occupant reuses the freed pages (LIFO) and its own writes
        // fully determine what it reads back
        let k2 = Tensor::full(&shape, 5.0);
        kv.write_prefill_row(1, &k2, &k2, 0, 6).unwrap();
        let reused: Vec<u32> = kv.own_page_ids(1).to_vec();
        assert!(reused.iter().all(|p| pages.contains(p)), "freed pages must be reused");
        for s in 2..8 {
            assert_eq!(kv.k_at(0, 1, 0, s), [5.0; 4]);
        }
    }

    #[test]
    fn append_rows_updates_only_group() {
        let c = cfg();
        for layout in layouts() {
            let mut kv = KvCache::with_layout(&c, 2, layout);
            kv.install_prefix(&prefix(&c, 2)).unwrap();
            let shape = [c.n_layers, 2, c.n_heads, 3, c.d_head];
            let k = Tensor::full(&shape, 1.0);
            kv.write_prefill(&k, &k, 3).unwrap(); // both rows at len 5
            let full = Tensor::full(&[c.n_layers, 2, c.n_heads, c.cache_max, c.d_head], 5.0);
            kv.append_rows(&full.clone(), &full, &[0], 5).unwrap();
            assert_eq!(kv.lens(), &[6, 5]);
            assert_eq!(kv.k_at(0, 0, 0, 5)[0], 5.0);
            assert_eq!(kv.k_at(0, 1, 0, 4)[0], 1.0); // row 1 untouched
            // group-length mismatch rejected
            assert!(kv.append_rows(&full.clone(), &full.clone(), &[0], 5).is_err());
        }
    }

    #[test]
    fn paged_admission_accounting() {
        let c = cfg(); // cache_max 16
        // pool of 7 pages at page_size 4; prefix takes 1
        let mut kv = KvCache::with_layout(&c, 4, KvLayout::Paged { page_size: 4, n_pages: 7 });
        kv.install_prefix(&prefix(&c, 2)).unwrap();
        assert_eq!(kv.free_pages(), Some(6));

        // plen 5 + max_new 3 → span 8 → 2 pages
        assert!(kv.can_admit(5, 3));
        kv.reserve(0, 5, 3).unwrap();
        kv.reserve(1, 5, 3).unwrap();
        kv.reserve(2, 5, 3).unwrap();
        // 6 pages promised: a fourth reservation must be refused
        assert!(!kv.can_admit(5, 3));
        assert!(kv.reserve(3, 5, 3).is_err());
        // every free page is promised, so even a one-page request must wait
        assert!(!kv.can_admit(1, 1));
        // feasibility is about the POOL, not the current free count: the
        // worst shape (span capped at s_max → 4 own pages + 1 prefix ≤ 7)
        // still fits this pool, but not a 4-page pool
        assert!(kv.admission_feasible(16, 16));
        let mut tiny = KvCache::with_layout(&c, 4, KvLayout::Paged { page_size: 4, n_pages: 4 });
        tiny.install_prefix(&prefix(&c, 2)).unwrap();
        assert!(!tiny.admission_feasible(16, 16)); // 1 prefix + 4 own > 4
        assert!(tiny.admission_feasible(5, 3));

        // writes inside the reservation always succeed
        let shape = [c.n_layers, 1, c.n_heads, 5, c.d_head];
        let k = Tensor::full(&shape, 2.0);
        kv.write_prefill_row(0, &k, &k, 0, 5).unwrap();
        let step = Tensor::full(&[c.n_layers, c.n_heads, c.d_head], 3.0);
        kv.append_token_row(0, &step, &step).unwrap();

        // retiring releases both pages and the reservation
        kv.reset_slot(0).unwrap();
        kv.reset_slot(1).unwrap();
        kv.reset_slot(2).unwrap();
        assert_eq!(kv.free_pages(), Some(6));
        assert!(kv.can_admit(5, 3));
    }

    /// Deterministic prefill source: value at flat index i is i (so every
    /// (l, h, position) span is unique and byte-comparisons are meaningful).
    fn ramp_src(c: &ModelConfig, n_tok: usize) -> Tensor {
        let mut t = Tensor::zeros(&[c.n_layers, 1, c.n_heads, n_tok, c.d_head]);
        for (i, v) in t.data.iter_mut().enumerate() {
            *v = i as f32;
        }
        t
    }

    #[test]
    fn radix_admission_maps_matched_pages() {
        let c = cfg(); // cache_max 16, page_size 4 below, prefix 2
        let mut kv =
            KvCache::with_layout(&c, 2, KvLayout::Paged { page_size: 4, n_pages: 12 });
        kv.install_prefix(&prefix(&c, 2)).unwrap();
        kv.enable_radix().unwrap();
        let toks: Vec<i32> = (0..9).map(|i| 100 + i).collect(); // BOS + 8 prompt
        let src = ramp_src(&c, 9);

        // first occupant: cold lookup, full prefill, insertion at retirement
        assert_eq!(kv.admit_radix(0, 9, 2, &toks).unwrap(), Some(0));
        kv.write_prefill_span(0, &src, &src, 0, 0, 9).unwrap();
        assert_eq!(kv.radix_insert(0, &toks).unwrap(), 2, "9 tokens = 2 full pages");
        let shared: Vec<u32> = kv.own_page_ids(0)[..2].to_vec();
        kv.reset_slot(0).unwrap();
        for &pg in &shared {
            assert_eq!(kv.page_refcount(pg), Some(1), "tree keeps the run alive");
        }

        // second occupant with the same sequence: 2 pages MAPPED, prefill
        // resumes at token 8 (the cap leaves ≥ 1 token to prefill)
        assert_eq!(kv.admit_radix(1, 9, 2, &toks).unwrap(), Some(8));
        assert_eq!(kv.row_len(1), 2 + 8);
        assert_eq!(&kv.own_page_ids(1)[..2], shared.as_slice(), "mapped, not copied");
        for &pg in &shared {
            assert_eq!(kv.page_refcount(pg), Some(2), "tree + slot 1");
        }
        kv.write_prefill_span(1, &src, &src, 0, 8, 9).unwrap();
        // the row reads back exactly as a cold full prefill would
        for l in 0..c.n_layers {
            for h in 0..c.n_heads {
                for s in 2..kv.row_len(1) {
                    let src_off = ((l * c.n_heads + h) * 9 + (s - 2)) * c.d_head;
                    assert_eq!(
                        kv.k_at(l, 1, h, s),
                        &src.data[src_off..src_off + c.d_head],
                        "shared-page read diverged at (l={l}, h={h}, s={s})"
                    );
                }
            }
        }
        let stats = kv.radix_stats().unwrap();
        assert_eq!((stats.lookups, stats.hits, stats.hit_tokens), (2, 1, 8));
        assert_eq!(stats.shared_pages, 2);
    }

    #[test]
    fn radix_partial_divergence_cows_without_touching_the_shared_page() {
        let c = cfg();
        let mut kv =
            KvCache::with_layout(&c, 2, KvLayout::Paged { page_size: 4, n_pages: 12 });
        kv.install_prefix(&prefix(&c, 2)).unwrap();
        kv.enable_radix().unwrap();
        let a: Vec<i32> = vec![1, 10, 11, 12, 13, 14, 15, 16];
        let src = ramp_src(&c, 8);
        kv.admit_radix(0, 8, 0, &a).unwrap();
        kv.write_prefill_span(0, &src, &src, 0, 0, 8).unwrap();
        // byte snapshot of a's row while slot 0 still maps it (the same
        // physical pages the tree adopts below)
        let snapshot: Vec<f32> = (0..c.n_layers)
            .flat_map(|l| {
                (0..c.n_heads)
                    .flat_map(move |h| (2..10).map(move |s| (l, h, s)))
                    .collect::<Vec<_>>()
            })
            .flat_map(|(l, h, s)| kv.k_at(l, 0, h, s).to_vec())
            .collect();
        kv.radix_insert(0, &a).unwrap();
        kv.reset_slot(0).unwrap();

        // b shares chunk 1 fully and 2 tokens of chunk 2, then diverges
        let b: Vec<i32> = vec![1, 10, 11, 12, 13, 14, 77, 78];
        let matched = kv.admit_radix(1, 8, 0, &b).unwrap().unwrap();
        assert_eq!(matched, 6, "4 full-page tokens + 2 CoW tokens");
        assert_eq!(kv.row_len(1), 2 + 6);
        assert_eq!(kv.radix_stats().unwrap().cow_splits, 1);
        // b's second page must be a private copy, not the tree's page
        let cow_page = kv.own_page_ids(1)[1];
        assert_eq!(kv.page_refcount(cow_page), Some(1), "CoW page is private");
        // write b's divergent tail over the CoW page
        let mut div = ramp_src(&c, 8);
        for v in div.data.iter_mut() {
            *v = -*v - 1.0; // unmistakably different bytes
        }
        kv.write_prefill_span(1, &div, &div, 0, 6, 8).unwrap();

        // re-admit a: chunk 1 maps, chunk 2 partial-matches 3 tokens ([13,
        // 14, 15]) copied from the TREE's page — if b's divergence had
        // mutated the shared page, these reads would show it
        let matched_a = kv.admit_radix(0, 8, 0, &a).unwrap().unwrap();
        assert_eq!(matched_a, 4 + 3, "limit is plen-1 = 7");
        let mut off = 0;
        for l in 0..c.n_layers {
            for h in 0..c.n_heads {
                for s in 2..10 {
                    let want = &snapshot[off..off + c.d_head];
                    if s < 2 + 7 {
                        assert_eq!(
                            kv.k_at(l, 0, h, s),
                            want,
                            "divergence mutated a shared page at (l={l}, h={h}, s={s})"
                        );
                    }
                    off += c.d_head;
                }
            }
        }
    }

    #[test]
    fn radix_eviction_frees_cache_only_runs_under_pressure() {
        let c = cfg();
        // 6 pages: 1 prefix + room for exactly one worst-case occupant (2
        // pages) plus one cached run (2 pages) plus one spare
        let mut kv =
            KvCache::with_layout(&c, 1, KvLayout::Paged { page_size: 4, n_pages: 6 });
        kv.install_prefix(&prefix(&c, 2)).unwrap();
        kv.enable_radix().unwrap();
        let a: Vec<i32> = vec![1, 20, 21, 22, 23, 24, 25, 26];
        let src = ramp_src(&c, 8);
        kv.admit_radix(0, 8, 0, &a).unwrap();
        kv.write_prefill_span(0, &src, &src, 0, 0, 8).unwrap();
        kv.radix_insert(0, &a).unwrap();
        kv.reset_slot(0).unwrap();
        assert_eq!(kv.free_pages(), Some(3), "tree holds 2 of 5 non-prefix pages");

        // an unrelated 14-token worst case needs 4 pages: 3 free + eviction
        let b: Vec<i32> = (0..12).map(|i| 200 + i).collect();
        assert!(kv.radix_can_admit(12, 2, &b), "eviction credit must count");
        let matched = kv.admit_radix(0, 12, 2, &b).unwrap();
        assert_eq!(matched, Some(0), "no shared prefix with the cached run");
        let stats = kv.radix_stats().unwrap();
        assert!(stats.evicted_pages >= 1, "pressure must evict the cold run");
        // zero leak: every page is either free, prefix, or slot-0 promised
        kv.reset_slot(0).unwrap();
        kv.radix_flush().unwrap();
        assert_eq!(kv.free_pages(), Some(5), "all non-prefix pages back");
    }

    #[test]
    fn gather_dense_mirrors_pages() {
        let c = cfg();
        let mut kv = KvCache::with_layout(&c, 2, paged(4));
        kv.install_prefix(&prefix(&c, 2)).unwrap();
        let shape = [c.n_layers, 1, c.n_heads, 3, c.d_head];
        let k = Tensor::full(&shape, 6.0);
        kv.write_prefill_row(0, &k, &k, 0, 3).unwrap();

        let want_prefix: Vec<f32> = kv.k_at(0, 0, 0, 1).to_vec();
        {
            let (dk, _dv) = kv.gather_dense(&[0]).unwrap();
            assert_eq!(dk.shape, vec![c.n_layers, 2, c.n_heads, c.cache_max, c.d_head]);
            let o = dense_offset(2, c.n_heads, c.cache_max, c.d_head, 0, 0, 0, 2);
            assert_eq!(dk.data[o], 6.0);
            let op = dense_offset(2, c.n_heads, c.cache_max, c.d_head, 0, 0, 0, 1);
            assert_eq!(&dk.data[op..op + c.d_head], want_prefix.as_slice());
        }

        // scatter one decode position back, then re-gather: the view picks up
        // exactly the new position
        let full = Tensor::full(&[c.n_layers, 2, c.n_heads, c.cache_max, c.d_head], 8.0);
        kv.append_rows(&full.clone(), &full, &[0], 5).unwrap();
        let (dk, _dv) = kv.gather_dense(&[0]).unwrap();
        let o5 = dense_offset(2, c.n_heads, c.cache_max, c.d_head, 0, 0, 0, 5);
        assert_eq!(dk.data[o5], 8.0);

        // slot reuse invalidates the mirror: a fresh occupant's gather must
        // not show the old sequence
        kv.reset_slot(0).unwrap();
        let k2 = Tensor::full(&shape, 1.5);
        kv.write_prefill_row(0, &k2, &k2, 0, 3).unwrap();
        let (dk, _dv) = kv.gather_dense(&[0]).unwrap();
        let o2 = dense_offset(2, c.n_heads, c.cache_max, c.d_head, 0, 0, 0, 2);
        assert_eq!(dk.data[o2], 1.5);
    }
}
