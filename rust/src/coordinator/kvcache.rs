//! KV-cache manager with shared prefixed entries (the paper's mechanism).
//!
//! The prefixed tokens' K/V are computed ONCE at model-quantization time and
//! installed into slots [0, n_prefix) of every sequence's cache — they are
//! never recomputed, never evicted, and identical across sequences (the
//! "prefixed outliers in the KV cache" of the title).  Prompt/decoded tokens
//! occupy positions [n_prefix, row_len(b)).
//!
//! Since the continuous-batching engine landed, the batch dimension is a SLOT
//! TABLE: every row carries its own valid length (`lens`), rows are written
//! and appended independently, and a retired row is zeroed (except the shared
//! prefix) before reuse so a stale sequence can never leak into its
//! successor.  The uniform-length helpers (`write_prefill`, `adopt`) remain
//! for the run-to-completion path where every row advances in lock-step.

use anyhow::{bail, Result};

use crate::config::ModelConfig;
use crate::model::PrefixState;
use crate::tensor::Tensor;

pub struct KvCache {
    pub n_layers: usize,
    pub batch: usize,
    pub n_heads: usize,
    pub s_max: usize,
    pub d_head: usize,
    /// [L, B, H, Smax, dh] storage-domain tensors fed to decode_step
    pub k: Tensor,
    pub v: Tensor,
    /// valid entries per row (incl. prefix slots)
    lens: Vec<usize>,
    pub n_prefix: usize,
}

impl KvCache {
    pub fn new(cfg: &ModelConfig, batch: usize) -> Self {
        let shape = [cfg.n_layers, batch, cfg.n_heads, cfg.cache_max, cfg.d_head];
        Self {
            n_layers: cfg.n_layers,
            batch,
            n_heads: cfg.n_heads,
            s_max: cfg.cache_max,
            d_head: cfg.d_head,
            k: Tensor::zeros(&shape),
            v: Tensor::zeros(&shape),
            lens: vec![0; batch],
            n_prefix: 0,
        }
    }

    /// Flat offset of position (l, b, h, s) — start of a d_head-long span.
    pub fn offset(&self, l: usize, b: usize, h: usize, s: usize) -> usize {
        (((l * self.batch + b) * self.n_heads + h) * self.s_max + s) * self.d_head
    }

    /// Valid entries (incl. prefix) in row `b`.
    pub fn row_len(&self, b: usize) -> usize {
        self.lens[b]
    }

    pub fn lens(&self) -> &[usize] {
        &self.lens
    }

    /// Largest valid length across rows.
    pub fn max_len(&self) -> usize {
        self.lens.iter().copied().max().unwrap_or(0)
    }

    /// The shared length if every row agrees (run-to-completion invariant).
    pub fn uniform_len(&self) -> Option<usize> {
        let l0 = self.lens.first().copied()?;
        self.lens.iter().all(|&l| l == l0).then_some(l0)
    }

    /// Free positions in row `b`.
    pub fn remaining_row(&self, b: usize) -> usize {
        self.s_max - self.lens[b]
    }

    /// Free positions in the fullest row (conservative batch-wide headroom).
    pub fn remaining(&self) -> usize {
        self.s_max - self.max_len()
    }

    /// Install the shared prefix into positions [0, n_prefix) of every row.
    pub fn install_prefix(&mut self, p: &PrefixState) -> Result<()> {
        let n = p.n_prefix as usize;
        if n == 0 {
            self.lens.fill(0);
            self.n_prefix = 0;
            return Ok(());
        }
        if n > self.s_max {
            bail!("prefix {} exceeds cache capacity {}", n, self.s_max);
        }
        let pcap = p.k.shape[2]; // padded prefix capacity P
        let dh = self.d_head;
        for l in 0..self.n_layers {
            for b in 0..self.batch {
                for h in 0..self.n_heads {
                    for s in 0..n {
                        let src = ((l * self.n_heads + h) * pcap + s) * dh;
                        let dst = self.offset(l, b, h, s);
                        self.k.data[dst..dst + dh].copy_from_slice(&p.k.data[src..src + dh]);
                        self.v.data[dst..dst + dh].copy_from_slice(&p.v.data[src..src + dh]);
                    }
                }
            }
        }
        self.n_prefix = n;
        self.lens.fill(n);
        Ok(())
    }

    /// Copy row `src_row` of a prefill executable's K/V output ([L, Bsrc, H,
    /// Ssrc, dh], storage domain) into slot `slot` for the first `prompt_len`
    /// positions, starting right after the prefix.  Sets
    /// row_len(slot) = n_prefix + prompt_len.
    pub fn write_prefill_row(
        &mut self,
        slot: usize,
        k: &Tensor,
        v: &Tensor,
        src_row: usize,
        prompt_len: usize,
    ) -> Result<()> {
        if k.shape.len() != 5 || v.shape != k.shape {
            bail!("prefill kv shape mismatch: {:?} vs {:?}", k.shape, v.shape);
        }
        let (l, b, h, s, dh) = (k.shape[0], k.shape[1], k.shape[2], k.shape[3], k.shape[4]);
        if l != self.n_layers || h != self.n_heads || dh != self.d_head {
            bail!("prefill kv shape mismatch: {:?}", k.shape);
        }
        if slot >= self.batch || src_row >= b {
            bail!("prefill row out of range: slot {slot}/{}, src {src_row}/{b}", self.batch);
        }
        if prompt_len > s {
            bail!("prompt_len {prompt_len} exceeds prefill output seq {s}");
        }
        if self.n_prefix + prompt_len > self.s_max {
            bail!("prompt too long: {} + {} > {}", self.n_prefix, prompt_len, self.s_max);
        }
        // clean-slot discipline keeps "positions ≥ row_len are zero" true,
        // which is what lets reset_slot bound its memset to the used region
        if self.lens[slot] != self.n_prefix {
            bail!(
                "prefill into dirty slot {slot} (len {}, prefix {}): reset_slot first",
                self.lens[slot],
                self.n_prefix
            );
        }
        for li in 0..l {
            for hi in 0..h {
                for si in 0..prompt_len {
                    let src = (((li * b + src_row) * h + hi) * s + si) * dh;
                    let dst = self.offset(li, slot, hi, self.n_prefix + si);
                    self.k.data[dst..dst + dh].copy_from_slice(&k.data[src..src + dh]);
                    self.v.data[dst..dst + dh].copy_from_slice(&v.data[src..src + dh]);
                }
            }
        }
        self.lens[slot] = self.n_prefix + prompt_len;
        Ok(())
    }

    /// Uniform-batch prefill (run-to-completion path): write the first
    /// `prompt_len` positions of every row from a [L, B, H, S, dh] output.
    pub fn write_prefill(&mut self, k: &Tensor, v: &Tensor, prompt_len: usize) -> Result<()> {
        if k.shape.len() != 5 || k.shape[1] != self.batch {
            bail!("prefill kv shape mismatch: {:?}", k.shape);
        }
        for row in 0..self.batch {
            // write_prefill_row rejects prompt_len > S / cache overflow
            self.write_prefill_row(row, k, v, row, prompt_len)?;
        }
        Ok(())
    }

    /// Adopt the decode executable's updated caches wholesale and bump every
    /// row (valid only when all rows advanced together, i.e. the decode step
    /// ran with the whole batch at one shared cache_len).
    pub fn adopt(&mut self, k: Tensor, v: Tensor) -> Result<()> {
        if k.shape != self.k.shape || v.shape != self.v.shape {
            bail!("decode kv shape mismatch");
        }
        let Some(len) = self.uniform_len() else {
            bail!("adopt requires uniform row lengths, got {:?}", self.lens);
        };
        if len + 1 > self.s_max {
            bail!("cache overflow at len {len}");
        }
        self.k = k;
        self.v = v;
        self.lens.fill(len + 1);
        Ok(())
    }

    /// Copy the newly-written position `len` of `rows` from a decode
    /// executable's full-shape K/V output and bump those rows only.  Rows not
    /// listed keep their previous contents (the decode graph scribbles at
    /// position `len` of every row; only the listed rows own that position).
    pub fn append_rows(&mut self, k: &Tensor, v: &Tensor, rows: &[usize], len: usize) -> Result<()> {
        if k.shape != self.k.shape || v.shape != self.v.shape {
            bail!("decode kv shape mismatch: {:?}", k.shape);
        }
        if len + 1 > self.s_max {
            bail!("cache overflow at len {len}");
        }
        let dh = self.d_head;
        for &row in rows {
            if row >= self.batch {
                bail!("append row {row} out of range");
            }
            if self.lens[row] != len {
                bail!("append_rows: row {row} has len {}, group len {len}", self.lens[row]);
            }
            for l in 0..self.n_layers {
                for h in 0..self.n_heads {
                    let off = self.offset(l, row, h, len);
                    self.k.data[off..off + dh].copy_from_slice(&k.data[off..off + dh]);
                    self.v.data[off..off + dh].copy_from_slice(&v.data[off..off + dh]);
                }
            }
            self.lens[row] = len + 1;
        }
        Ok(())
    }

    /// Append one token's K/V ([L, H, dh] values) to row `slot` at its
    /// current length (host-computed backends, e.g. the simulation backend).
    pub fn append_token_row(&mut self, slot: usize, k: &Tensor, v: &Tensor) -> Result<()> {
        let want = [self.n_layers, self.n_heads, self.d_head];
        if k.shape != want || v.shape != want {
            bail!("append_token_row wants {:?}, got {:?}", want, k.shape);
        }
        if slot >= self.batch {
            bail!("append slot {slot} out of range");
        }
        let len = self.lens[slot];
        if len + 1 > self.s_max {
            bail!("cache overflow at len {len}");
        }
        let dh = self.d_head;
        for l in 0..self.n_layers {
            for h in 0..self.n_heads {
                let src = (l * self.n_heads + h) * dh;
                let dst = self.offset(l, slot, h, len);
                self.k.data[dst..dst + dh].copy_from_slice(&k.data[src..src + dh]);
                self.v.data[dst..dst + dh].copy_from_slice(&v.data[src..src + dh]);
            }
        }
        self.lens[slot] = len + 1;
        Ok(())
    }

    /// Retire a slot: zero the row's occupied non-prefix positions and reset
    /// its length to the prefix, so the next occupant starts from a clean row
    /// and the shared prefix entries survive untouched.  Positions beyond the
    /// occupied region are zero by construction (fresh caches are zeroed and
    /// writes only ever advance `lens`), so only [n_prefix, row_len) needs
    /// the memset — retirement cost scales with what the sequence used, not
    /// with cache capacity.
    pub fn reset_slot(&mut self, slot: usize) -> Result<()> {
        if slot >= self.batch {
            bail!("reset slot {slot} out of range");
        }
        let used = self.lens[slot].min(self.s_max);
        if self.n_prefix < used {
            let span = (used - self.n_prefix) * self.d_head;
            for l in 0..self.n_layers {
                for h in 0..self.n_heads {
                    let start = self.offset(l, slot, h, self.n_prefix);
                    self.k.data[start..start + span].fill(0.0);
                    self.v.data[start..start + span].fill(0.0);
                }
            }
        }
        self.lens[slot] = self.n_prefix;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            vocab_size: 272,
            d_model: 8,
            n_layers: 2,
            n_heads: 2,
            d_head: 4,
            d_ff: 16,
            o_model: 3,
            inject_amp: 1.0,
            inject_delta: 0.1,
            max_prefix: 4,
            train_seq: 8,
            eval_seq: 8,
            cache_max: 16,
            sites: vec!["down_in".into()],
        }
    }

    fn prefix(cfg: &ModelConfig, n: usize) -> PrefixState {
        let shape = [cfg.n_layers, cfg.n_heads, cfg.max_prefix, cfg.d_head];
        let mut k = Tensor::zeros(&shape);
        for (i, v) in k.data.iter_mut().enumerate() {
            *v = i as f32;
        }
        PrefixState {
            tokens: vec![49; n],
            n_prefix: n as i32,
            n_ctx_sinks: n as i32,
            v: k.clone(),
            k,
        }
    }

    #[test]
    fn prefix_shared_across_rows() {
        let c = cfg();
        let mut kv = KvCache::new(&c, 3);
        kv.install_prefix(&prefix(&c, 2)).unwrap();
        assert_eq!(kv.lens(), &[2, 2, 2]);
        // row 0 and row 2 hold identical prefix entries
        for l in 0..c.n_layers {
            for h in 0..c.n_heads {
                for s in 0..2 {
                    let a = kv.offset(l, 0, h, s);
                    let b = kv.offset(l, 2, h, s);
                    assert_eq!(kv.k.data[a..a + 4], kv.k.data[b..b + 4]);
                }
            }
        }
    }

    #[test]
    fn prefill_goes_after_prefix() {
        let c = cfg();
        let mut kv = KvCache::new(&c, 2);
        kv.install_prefix(&prefix(&c, 2)).unwrap();
        let shape = [c.n_layers, 2, c.n_heads, 5, c.d_head];
        let k = Tensor::full(&shape, 7.0);
        kv.write_prefill(&k, &k, 5).unwrap();
        assert_eq!(kv.uniform_len(), Some(7));
        let o = kv.offset(0, 0, 0, 2);
        assert_eq!(kv.k.data[o], 7.0); // first prompt slot right after prefix
        let o1 = kv.offset(0, 0, 0, 1);
        assert_ne!(kv.k.data[o1], 7.0); // prefix untouched
    }

    #[test]
    fn overflow_rejected() {
        let c = cfg();
        let mut kv = KvCache::new(&c, 1);
        kv.install_prefix(&prefix(&c, 2)).unwrap();
        let shape = [c.n_layers, 1, c.n_heads, 20, c.d_head];
        let k = Tensor::zeros(&shape);
        assert!(kv.write_prefill_row(0, &k, &k, 0, 20).is_err());
    }

    #[test]
    fn per_slot_write_and_reset() {
        let c = cfg();
        let mut kv = KvCache::new(&c, 3);
        kv.install_prefix(&prefix(&c, 2)).unwrap();
        // write a 4-token prompt into slot 1 only, from source row 0
        let shape = [c.n_layers, 1, c.n_heads, 4, c.d_head];
        let k = Tensor::full(&shape, 9.0);
        kv.write_prefill_row(1, &k, &k, 0, 4).unwrap();
        assert_eq!(kv.lens(), &[2, 6, 2]);
        // neighbours untouched
        assert_eq!(kv.k.data[kv.offset(0, 0, 0, 2)], 0.0);
        assert_eq!(kv.k.data[kv.offset(0, 2, 0, 2)], 0.0);
        assert_eq!(kv.k.data[kv.offset(0, 1, 0, 2)], 9.0);

        // append one decoded token
        let step = Tensor::full(&[c.n_layers, c.n_heads, c.d_head], 3.0);
        kv.append_token_row(1, &step, &step).unwrap();
        assert_eq!(kv.row_len(1), 7);
        assert_eq!(kv.k.data[kv.offset(0, 1, 0, 6)], 3.0);

        // retire: non-prefix region zeroed, prefix survives
        kv.reset_slot(1).unwrap();
        assert_eq!(kv.row_len(1), 2);
        for s in 2..kv.s_max {
            let o = kv.offset(0, 1, 0, s);
            assert_eq!(kv.k.data[o..o + c.d_head], [0.0; 4]);
        }
        let p = kv.offset(0, 1, 0, 1);
        assert_eq!(kv.k.data[p], kv.k.data[kv.offset(0, 0, 0, 1)]); // prefix intact
    }

    #[test]
    fn append_rows_updates_only_group() {
        let c = cfg();
        let mut kv = KvCache::new(&c, 2);
        kv.install_prefix(&prefix(&c, 2)).unwrap();
        let shape = [c.n_layers, 2, c.n_heads, 3, c.d_head];
        let k = Tensor::full(&shape, 1.0);
        kv.write_prefill(&k, &k, 3).unwrap(); // both rows at len 5
        let full = Tensor::full(&[c.n_layers, 2, c.n_heads, c.cache_max, c.d_head], 5.0);
        kv.append_rows(&full.clone(), &full, &[0], 5).unwrap();
        assert_eq!(kv.lens(), &[6, 5]);
        assert_eq!(kv.k.data[kv.offset(0, 0, 0, 5)], 5.0);
        assert_eq!(kv.k.data[kv.offset(0, 1, 0, 5)], 0.0); // row 1 untouched
        // group-length mismatch rejected
        assert!(kv.append_rows(&full.clone(), &full.clone(), &[0], 5).is_err());
    }
}
