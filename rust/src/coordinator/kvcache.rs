//! KV-cache manager with shared prefixed entries (the paper's mechanism).
//!
//! The prefixed tokens' K/V are computed ONCE at model-quantization time and
//! installed into slots [0, n_prefix) of every sequence's cache — they are
//! never recomputed, never evicted, and identical across sequences (the
//! "prefixed outliers in the KV cache" of the title).  Prompt/decoded tokens
//! occupy slots [n_prefix, cache_len).

use anyhow::{bail, Result};

use crate::config::ModelConfig;
use crate::model::PrefixState;
use crate::tensor::Tensor;

pub struct KvCache {
    pub n_layers: usize,
    pub batch: usize,
    pub n_heads: usize,
    pub s_max: usize,
    pub d_head: usize,
    /// [L, B, H, Smax, dh] storage-domain tensors fed to decode_step
    pub k: Tensor,
    pub v: Tensor,
    /// valid entries (incl. prefix slots); uniform across the batch
    pub len: usize,
    pub n_prefix: usize,
}

impl KvCache {
    pub fn new(cfg: &ModelConfig, batch: usize) -> Self {
        let shape = [cfg.n_layers, batch, cfg.n_heads, cfg.cache_max, cfg.d_head];
        Self {
            n_layers: cfg.n_layers,
            batch,
            n_heads: cfg.n_heads,
            s_max: cfg.cache_max,
            d_head: cfg.d_head,
            k: Tensor::zeros(&shape),
            v: Tensor::zeros(&shape),
            len: 0,
            n_prefix: 0,
        }
    }

    fn off(&self, l: usize, b: usize, h: usize, s: usize) -> usize {
        (((l * self.batch + b) * self.n_heads + h) * self.s_max + s) * self.d_head
    }

    /// Install the shared prefix into slots [0, n_prefix) of every row.
    pub fn install_prefix(&mut self, p: &PrefixState) -> Result<()> {
        let n = p.n_prefix as usize;
        if n == 0 {
            self.len = 0;
            self.n_prefix = 0;
            return Ok(());
        }
        let pcap = p.k.shape[2]; // padded prefix capacity P
        let dh = self.d_head;
        for l in 0..self.n_layers {
            for b in 0..self.batch {
                for h in 0..self.n_heads {
                    for s in 0..n {
                        let src = ((l * self.n_heads + h) * pcap + s) * dh;
                        let dst = self.off(l, b, h, s);
                        self.k.data[dst..dst + dh].copy_from_slice(&p.k.data[src..src + dh]);
                        self.v.data[dst..dst + dh].copy_from_slice(&p.v.data[src..src + dh]);
                    }
                }
            }
        }
        self.n_prefix = n;
        self.len = n;
        Ok(())
    }

    /// Write prefill K/V ([L, B, H, S, dh], quantized storage domain from the
    /// prefill executable) for the first `prompt_len` positions of each row,
    /// starting at slot n_prefix.  Sets len = n_prefix + prompt_len.
    pub fn write_prefill(&mut self, k: &Tensor, v: &Tensor, prompt_len: usize) -> Result<()> {
        let (l, b, h, s, dh) =
            (k.shape[0], k.shape[1], k.shape[2], k.shape[3], k.shape[4]);
        if l != self.n_layers || b != self.batch || h != self.n_heads || dh != self.d_head {
            bail!("prefill kv shape mismatch: {:?}", k.shape);
        }
        if self.n_prefix + prompt_len > self.s_max {
            bail!("prompt too long: {} + {} > {}", self.n_prefix, prompt_len, self.s_max);
        }
        for li in 0..l {
            for bi in 0..b {
                for hi in 0..h {
                    for si in 0..prompt_len.min(s) {
                        let src = (((li * b + bi) * h + hi) * s + si) * dh;
                        let dst = self.off(li, bi, hi, self.n_prefix + si);
                        self.k.data[dst..dst + dh].copy_from_slice(&k.data[src..src + dh]);
                        self.v.data[dst..dst + dh].copy_from_slice(&v.data[src..src + dh]);
                    }
                }
            }
        }
        self.len = self.n_prefix + prompt_len;
        Ok(())
    }

    /// Adopt the decode executable's updated caches and bump len.
    pub fn adopt(&mut self, k: Tensor, v: Tensor) -> Result<()> {
        if k.shape != self.k.shape || v.shape != self.v.shape {
            bail!("decode kv shape mismatch");
        }
        if self.len + 1 > self.s_max {
            bail!("cache overflow at len {}", self.len);
        }
        self.k = k;
        self.v = v;
        self.len += 1;
        Ok(())
    }

    pub fn remaining(&self) -> usize {
        self.s_max - self.len
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            vocab_size: 272,
            d_model: 8,
            n_layers: 2,
            n_heads: 2,
            d_head: 4,
            d_ff: 16,
            o_model: 3,
            inject_amp: 1.0,
            inject_delta: 0.1,
            max_prefix: 4,
            train_seq: 8,
            eval_seq: 8,
            cache_max: 16,
            sites: vec!["down_in".into()],
        }
    }

    fn prefix(cfg: &ModelConfig, n: usize) -> PrefixState {
        let shape = [cfg.n_layers, cfg.n_heads, cfg.max_prefix, cfg.d_head];
        let mut k = Tensor::zeros(&shape);
        for (i, v) in k.data.iter_mut().enumerate() {
            *v = i as f32;
        }
        PrefixState {
            tokens: vec![49; n],
            n_prefix: n as i32,
            n_ctx_sinks: n as i32,
            v: k.clone(),
            k,
        }
    }

    #[test]
    fn prefix_shared_across_rows() {
        let c = cfg();
        let mut kv = KvCache::new(&c, 3);
        kv.install_prefix(&prefix(&c, 2)).unwrap();
        assert_eq!(kv.len, 2);
        // row 0 and row 2 hold identical prefix entries
        for l in 0..c.n_layers {
            for h in 0..c.n_heads {
                for s in 0..2 {
                    let a = kv.off(l, 0, h, s);
                    let b = kv.off(l, 2, h, s);
                    assert_eq!(kv.k.data[a..a + 4], kv.k.data[b..b + 4]);
                }
            }
        }
    }

    #[test]
    fn prefill_goes_after_prefix() {
        let c = cfg();
        let mut kv = KvCache::new(&c, 2);
        kv.install_prefix(&prefix(&c, 2)).unwrap();
        let shape = [c.n_layers, 2, c.n_heads, 5, c.d_head];
        let k = Tensor::full(&shape, 7.0);
        kv.write_prefill(&k, &k, 5).unwrap();
        assert_eq!(kv.len, 7);
        let o = kv.off(0, 0, 0, 2);
        assert_eq!(kv.k.data[o], 7.0); // first prompt slot right after prefix
        let o1 = kv.off(0, 0, 0, 1);
        assert_ne!(kv.k.data[o1], 7.0); // prefix untouched
    }

    #[test]
    fn overflow_rejected() {
        let c = cfg();
        let mut kv = KvCache::new(&c, 1);
        kv.install_prefix(&prefix(&c, 2)).unwrap();
        let shape = [c.n_layers, 1, c.n_heads, 20, c.d_head];
        let k = Tensor::zeros(&shape);
        assert!(kv.write_prefill(&k, &k, 20).is_err());
    }
}
