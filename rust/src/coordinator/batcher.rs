//! Dynamic batcher: groups pending requests into fixed-geometry batches.
//!
//! The decode executable has a fixed batch dimension and a single shared
//! cache_len, so a batch must have uniform prompt length — the batcher
//! buckets by length and releases the largest eligible bucket, oldest first
//! (vLLM-style FCFS within a shape bucket).

use std::collections::VecDeque;

use super::request::GenRequest;

pub struct Batcher {
    pending: VecDeque<GenRequest>,
    pub max_batch: usize,
}

impl Batcher {
    pub fn new(max_batch: usize) -> Self {
        Self { pending: VecDeque::new(), max_batch }
    }

    pub fn push(&mut self, req: GenRequest) {
        self.pending.push_back(req);
    }

    pub fn len(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Pop the next batch: all requests sharing the prompt length of the
    /// *oldest* pending request (FCFS head-of-line), up to max_batch.
    pub fn next_batch(&mut self) -> Vec<GenRequest> {
        let Some(head) = self.pending.front() else {
            return Vec::new();
        };
        let want = head.prompt.len();
        let mut batch = Vec::with_capacity(self.max_batch);
        let mut rest = VecDeque::with_capacity(self.pending.len());
        while let Some(r) = self.pending.pop_front() {
            if r.prompt.len() == want && batch.len() < self.max_batch {
                batch.push(r);
            } else {
                rest.push_back(r);
            }
        }
        self.pending = rest;
        batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, len: usize) -> GenRequest {
        GenRequest { id, prompt: vec![5; len], max_new: 4 }
    }

    #[test]
    fn batches_by_head_length_fcfs() {
        let mut b = Batcher::new(4);
        for (id, len) in [(1, 8), (2, 16), (3, 8), (4, 8), (5, 16)] {
            b.push(req(id, len));
        }
        let first = b.next_batch();
        assert_eq!(first.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 3, 4]);
        let second = b.next_batch();
        assert_eq!(second.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2, 5]);
        assert!(b.is_empty());
    }

    #[test]
    fn respects_max_batch() {
        let mut b = Batcher::new(2);
        for id in 0..5 {
            b.push(req(id, 8));
        }
        assert_eq!(b.next_batch().len(), 2);
        assert_eq!(b.next_batch().len(), 2);
        assert_eq!(b.next_batch().len(), 1);
    }

    #[test]
    fn empty_gives_empty() {
        let mut b = Batcher::new(4);
        assert!(b.next_batch().is_empty());
    }
}
