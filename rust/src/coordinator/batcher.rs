//! Dynamic batcher: groups pending requests into fixed-geometry batches.
//!
//! The run-to-completion decode path has a fixed batch dimension and a single
//! shared cache_len, so a batch must have uniform prompt length.  Pending
//! requests are indexed by prompt length (one FCFS queue per bucket), and
//! `next_batch` releases the fullest bucket — except that a bucket passed
//! over `max_skips` times is released first, so a rare-length request can
//! never starve behind a popular bucket.  Each entry carries its enqueue
//! time so the server can report per-request queue wait.

use std::collections::{BTreeMap, VecDeque};
use std::time::Instant;

use super::request::GenRequest;

/// A queued request plus its enqueue timestamp (for queue-wait metrics).
#[derive(Debug, Clone)]
pub struct Pending {
    pub req: GenRequest,
    pub enqueued: Instant,
    /// arrival order, monotonically increasing across all buckets
    pub seq: u64,
}

pub struct Batcher {
    /// prompt length → FCFS queue (BTreeMap for deterministic iteration)
    buckets: BTreeMap<usize, VecDeque<Pending>>,
    /// prompt length → times this non-empty bucket was passed over
    skips: BTreeMap<usize, u32>,
    count: usize,
    next_seq: u64,
    pub max_batch: usize,
    /// a bucket skipped this many times is dispatched before fuller buckets
    pub max_skips: u32,
}

impl Batcher {
    pub fn new(max_batch: usize) -> Self {
        Self {
            buckets: BTreeMap::new(),
            skips: BTreeMap::new(),
            count: 0,
            next_seq: 0,
            max_batch,
            max_skips: 4,
        }
    }

    pub fn push(&mut self, req: GenRequest) {
        self.push_at(req, Instant::now());
    }

    /// Push with an explicit enqueue time (tests, replayed traces).
    pub fn push_at(&mut self, req: GenRequest, enqueued: Instant) {
        let len = req.prompt.len();
        let seq = self.next_seq;
        self.next_seq += 1;
        self.buckets.entry(len).or_default().push_back(Pending { req, enqueued, seq });
        self.skips.entry(len).or_insert(0);
        self.count += 1;
    }

    /// Remove a queued request by id (serving-API cancellation).  Returns
    /// the pending entry when it was still waiting; `None` when the request
    /// was already dispatched (run-to-completion batches are not interrupted)
    /// or never queued.
    pub fn cancel(&mut self, id: u64) -> Option<Pending> {
        let mut hit: Option<(usize, usize)> = None; // (bucket len, index)
        for (&len, q) in &self.buckets {
            if let Some(idx) = q.iter().position(|p| p.req.id == id) {
                hit = Some((len, idx));
                break;
            }
        }
        let (len, idx) = hit?;
        let q = self.buckets.get_mut(&len)?;
        let p = q.remove(idx)?;
        if q.is_empty() {
            self.buckets.remove(&len);
            self.skips.remove(&len);
        }
        self.count -= 1;
        Some(p)
    }

    pub fn len(&self) -> usize {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Choose the bucket to dispatch: any bucket skipped `max_skips` times
    /// wins (oldest head first among those); otherwise the fullest bucket
    /// (oldest head breaks ties).
    fn pick_bucket(&self) -> Option<usize> {
        let mut starving: Option<(u64, usize)> = None; // (head seq, len)
        let mut fullest: Option<(usize, u64, usize)> = None; // (size, head seq, len)
        for (&len, q) in &self.buckets {
            let Some(front) = q.front() else {
                continue; // unreachable: buckets are pruned when emptied
            };
            let head_seq = front.seq;
            let skips = self.skips.get(&len).copied().unwrap_or(0);
            if skips >= self.max_skips {
                match starving {
                    Some((s, _)) if s <= head_seq => {}
                    _ => starving = Some((head_seq, len)),
                }
            }
            let better = match fullest {
                None => true,
                Some((sz, hs, _)) => q.len() > sz || (q.len() == sz && head_seq < hs),
            };
            if better {
                fullest = Some((q.len(), head_seq, len));
            }
        }
        starving.map(|(_, len)| len).or(fullest.map(|(_, _, len)| len))
    }

    /// Pop the next uniform-length batch (up to max_batch, FCFS within the
    /// bucket), and age every bucket that was passed over.
    pub fn next_batch(&mut self) -> Vec<Pending> {
        let Some(want) = self.pick_bucket() else {
            return Vec::new();
        };
        let mut batch = Vec::with_capacity(self.max_batch);
        if let Some(q) = self.buckets.get_mut(&want) {
            while batch.len() < self.max_batch {
                match q.pop_front() {
                    Some(p) => batch.push(p),
                    None => break,
                }
            }
            if q.is_empty() {
                self.buckets.remove(&want);
                self.skips.remove(&want);
            } else {
                self.skips.insert(want, 0);
            }
        }
        self.count -= batch.len();
        for (&len, s) in self.skips.iter_mut() {
            if len != want {
                *s += 1;
            }
        }
        batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, len: usize) -> GenRequest {
        GenRequest::new(id, vec![5; len], 4)
    }

    fn ids(batch: &[Pending]) -> Vec<u64> {
        batch.iter().map(|p| p.req.id).collect()
    }

    #[test]
    fn fullest_bucket_first_fcfs_within() {
        let mut b = Batcher::new(4);
        for (id, len) in [(1, 8), (2, 16), (3, 8), (4, 8), (5, 16)] {
            b.push(req(id, len));
        }
        assert_eq!(ids(&b.next_batch()), vec![1, 3, 4]); // bucket 8 is fullest
        assert_eq!(ids(&b.next_batch()), vec![2, 5]);
        assert!(b.is_empty());
    }

    #[test]
    fn respects_max_batch() {
        let mut b = Batcher::new(2);
        for id in 0..5 {
            b.push(req(id, 8));
        }
        assert_eq!(b.next_batch().len(), 2);
        assert_eq!(b.next_batch().len(), 2);
        assert_eq!(b.next_batch().len(), 1);
    }

    #[test]
    fn empty_gives_empty() {
        let mut b = Batcher::new(4);
        assert!(b.next_batch().is_empty());
    }

    #[test]
    fn rare_length_cannot_starve() {
        let mut b = Batcher::new(2);
        b.push(req(99, 16)); // lone rare-length request
        let mut next_id = 0;
        for _ in 0..2 {
            b.push(req(next_id, 8));
            next_id += 1;
            b.push(req(next_id, 8));
            next_id += 1;
        }
        let mut dispatches_before_rare = 0;
        loop {
            // keep the popular bucket replenished, like a hot serving queue
            b.push(req(next_id, 8));
            next_id += 1;
            b.push(req(next_id, 8));
            next_id += 1;
            let batch = b.next_batch();
            if batch.iter().any(|p| p.req.id == 99) {
                break;
            }
            dispatches_before_rare += 1;
            assert!(
                dispatches_before_rare <= b.max_skips as usize + 1,
                "rare-length request starved for {dispatches_before_rare} dispatches"
            );
        }
    }

    #[test]
    fn cancel_removes_only_the_target() {
        let mut b = Batcher::new(4);
        for (id, len) in [(1, 8), (2, 8), (3, 16)] {
            b.push(req(id, len));
        }
        assert_eq!(b.cancel(99), None, "unknown id is a no-op");
        let p = b.cancel(2).expect("queued request is cancellable");
        assert_eq!(p.req.id, 2);
        assert_eq!(b.len(), 2);
        // the lone bucket-16 entry cancels cleanly and prunes its bucket
        assert_eq!(b.cancel(3).unwrap().req.id, 3);
        assert_eq!(ids(&b.next_batch()), vec![1]);
        assert!(b.is_empty());
        assert_eq!(b.cancel(1), None, "dispatched requests are gone");
    }

    #[test]
    fn queue_wait_recorded() {
        let mut b = Batcher::new(4);
        let t0 = Instant::now();
        b.push_at(req(1, 8), t0);
        let batch = b.next_batch();
        assert_eq!(batch.len(), 1);
        assert!(batch[0].enqueued.elapsed().as_secs_f64() >= 0.0);
        assert_eq!(batch[0].seq, 0);
    }
}
