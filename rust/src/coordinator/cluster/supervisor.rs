//! Fleet self-healing: supervised worker restarts, the redispatch retry
//! budget, and overload-protected admission.
//!
//! Everything here is a deterministic, thread-free state machine driven by
//! explicit `Instant`s, so the policies are unit-testable without booting a
//! fleet and the router's chaos behavior is reproducible run-to-run:
//!
//! - [`Supervisor`] — when the router declares a worker `Lost`, schedule a
//!   replacement boot after a seeded exponential backoff with deterministic
//!   jitter ([`SplitMix64`], so two fleets with the same seed compute the
//!   same schedule).  Restarts are budgeted per sliding window; a slot that
//!   exhausts the budget is permanently retired with its last loss cause.
//!   Every restart records its scheduled-vs-actual time, so a bench can
//!   assert zero backoff-schedule violations.
//! - [`RetryBudget`] — a global token bucket bounding redispatches during
//!   crash loops: every worker death redistributes its queued requests, and
//!   without a bound a crash loop turns each death into a redispatch storm
//!   that re-poisons the survivors.
//! - [`AdmissionController`] — sheds work at the router front before it
//!   costs anything: requests whose deadline is infeasible given the
//!   estimated queue delay, requests beyond the queue-depth/token-backlog
//!   limits, and (under sustained overload) brownout tiers that first shed
//!   `BestEffort` entirely and then cap `Batch` token budgets.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use crate::coordinator::request::{GenRequest, Priority};
use crate::util::rng::SplitMix64;

use super::health::DrainCause;

/// Supervisor policy knobs.  `Default`: 50ms base backoff doubling to a 2s
/// cap with 20% jitter, at most 3 restarts per 10s sliding window.
#[derive(Debug, Clone)]
pub struct SupervisorConfig {
    /// delay before the first restart attempt (doubles per attempt)
    pub backoff_base: Duration,
    /// backoff ceiling
    pub backoff_max: Duration,
    /// extra delay as a fraction of the backoff, drawn deterministically
    /// from the seeded rng (de-synchronizes simultaneous restarts)
    pub jitter_frac: f64,
    /// sliding window over which restarts are budgeted
    pub restart_window: Duration,
    /// restarts allowed per window; exceeding it retires the slot for good
    pub max_restarts: usize,
    /// jitter rng seed (same seed → same schedule)
    pub seed: u64,
}

impl Default for SupervisorConfig {
    fn default() -> SupervisorConfig {
        SupervisorConfig {
            backoff_base: Duration::from_millis(50),
            backoff_max: Duration::from_secs(2),
            jitter_frac: 0.2,
            restart_window: Duration::from_secs(10),
            max_restarts: 3,
            seed: 0x5EED,
        }
    }
}

impl SupervisorConfig {
    pub fn backoff_base(mut self, d: Duration) -> Self {
        self.backoff_base = d;
        self
    }

    pub fn backoff_max(mut self, d: Duration) -> Self {
        self.backoff_max = d;
        self
    }

    pub fn jitter_frac(mut self, f: f64) -> Self {
        self.jitter_frac = f.max(0.0);
        self
    }

    pub fn restart_window(mut self, d: Duration) -> Self {
        self.restart_window = d;
        self
    }

    pub fn max_restarts(mut self, n: usize) -> Self {
        self.max_restarts = n;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
}

/// What the supervisor decided about a lost worker.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RestartPlan {
    /// a replacement boot is scheduled for `due`
    Scheduled { due: Instant, attempt: usize },
    /// restart budget exhausted: the slot is permanently out of the fleet
    Retired { cause: DrainCause },
}

/// Acknowledgement of a completed restart.
#[derive(Debug, Clone, Copy)]
pub struct RestartDone {
    /// the slot's cumulative restart count (journaled)
    pub restarts: u32,
    /// the restart ran BEFORE its scheduled due time — a backoff-schedule
    /// violation (the bench holds this at zero)
    pub violated: bool,
}

#[derive(Debug, Clone)]
struct SlotSup {
    /// pending restart: (due, attempt number within the current window)
    scheduled: Option<(Instant, usize)>,
    /// completed-restart instants inside the sliding window (pruned lazily)
    window: VecDeque<Instant>,
    restarts: u32,
    retired: Option<DrainCause>,
}

impl SlotSup {
    fn new() -> SlotSup {
        SlotSup { scheduled: None, window: VecDeque::new(), restarts: 0, retired: None }
    }
}

/// Per-slot restart scheduler (see module docs).  All decisions are pure in
/// the `now` arguments, so tests drive it on a synthetic clock.
pub struct Supervisor {
    cfg: SupervisorConfig,
    rng: SplitMix64,
    slots: Vec<SlotSup>,
    violations: usize,
}

impl Supervisor {
    pub fn new(n_workers: usize, cfg: SupervisorConfig) -> Supervisor {
        let rng = SplitMix64::new(cfg.seed);
        let slots = (0..n_workers).map(|_| SlotSup::new()).collect();
        Supervisor { cfg, rng, slots, violations: 0 }
    }

    /// Exponential backoff with deterministic jitter for the given attempt
    /// (0-based).  Consumes one rng draw per call, so schedules differ
    /// between restarts but are identical across same-seed fleets.
    fn backoff(&mut self, attempt: usize) -> Duration {
        let base = self.cfg.backoff_base.as_secs_f64();
        let exp = base * (1u64 << attempt.min(32)) as f64;
        let capped = exp.min(self.cfg.backoff_max.as_secs_f64());
        let jitter = capped * self.cfg.jitter_frac * self.rng.unit_f64();
        Duration::from_secs_f64(capped + jitter)
    }

    fn prune(window: &mut VecDeque<Instant>, horizon: Duration, now: Instant) {
        while let Some(&front) = window.front() {
            if now.duration_since(front) > horizon {
                window.pop_front();
            } else {
                break;
            }
        }
    }

    /// A worker was declared lost: schedule a replacement or retire the
    /// slot when its window budget is spent.
    pub fn on_worker_lost(&mut self, w: usize, cause: DrainCause, now: Instant) -> RestartPlan {
        if let Some(cause) = self.slots[w].retired {
            return RestartPlan::Retired { cause };
        }
        Self::prune(&mut self.slots[w].window, self.cfg.restart_window, now);
        let attempt = self.slots[w].window.len();
        if attempt >= self.cfg.max_restarts {
            self.slots[w].retired = Some(cause);
            self.slots[w].scheduled = None;
            return RestartPlan::Retired { cause };
        }
        let due = now + self.backoff(attempt);
        self.slots[w].scheduled = Some((due, attempt));
        RestartPlan::Scheduled { due, attempt }
    }

    /// Workers whose scheduled restart is due.  The schedule entry stays
    /// until [`Supervisor::on_restarted`] or
    /// [`Supervisor::on_restart_failed`] resolves it.
    pub fn due(&self, now: Instant) -> Vec<usize> {
        self.slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.scheduled.is_some_and(|(due, _)| now >= due))
            .map(|(w, _)| w)
            .collect()
    }

    /// A replacement booted into slot `w`.  Records the restart against the
    /// window budget and checks the backoff schedule was honored.
    pub fn on_restarted(&mut self, w: usize, now: Instant) -> RestartDone {
        let slot = &mut self.slots[w];
        let violated = slot.scheduled.take().is_some_and(|(due, _)| now < due);
        if violated {
            self.violations += 1;
        }
        slot.window.push_back(now);
        slot.restarts += 1;
        RestartDone { restarts: slot.restarts, violated }
    }

    /// The replacement boot itself failed: re-schedule with the next
    /// backoff, or retire when the budget is gone.  The failed attempt
    /// charges the window budget — a factory that cannot produce workers
    /// must not retry forever.
    pub fn on_restart_failed(&mut self, w: usize, cause: DrainCause, now: Instant) -> RestartPlan {
        self.slots[w].scheduled = None;
        self.slots[w].window.push_back(now);
        self.on_worker_lost(w, cause, now)
    }

    pub fn is_retired(&self, w: usize) -> bool {
        self.slots[w].retired.is_some()
    }

    pub fn retired_cause(&self, w: usize) -> Option<DrainCause> {
        self.slots[w].retired
    }

    /// Cumulative restarts of slot `w`.
    pub fn restarts(&self, w: usize) -> u32 {
        self.slots[w].restarts
    }

    /// Restarts that ran ahead of their scheduled backoff (should be zero).
    pub fn schedule_violations(&self) -> usize {
        self.violations
    }
}

/// Global token bucket bounding redispatches during crash loops.  `capacity`
/// is the burst allowance; tokens refill continuously at `refill_per_s`.
#[derive(Debug, Clone)]
pub struct RetryBudget {
    capacity: f64,
    tokens: f64,
    refill_per_s: f64,
    last: Option<Instant>,
}

impl RetryBudget {
    pub fn new(capacity: usize, refill_per_s: f64) -> RetryBudget {
        RetryBudget {
            capacity: capacity as f64,
            tokens: capacity as f64,
            refill_per_s: refill_per_s.max(0.0),
            last: None,
        }
    }

    /// Take one retry token; `false` means the redispatch is denied.
    pub fn try_take(&mut self, now: Instant) -> bool {
        if let Some(last) = self.last {
            let dt = now.saturating_duration_since(last).as_secs_f64();
            self.tokens = (self.tokens + dt * self.refill_per_s).min(self.capacity);
        }
        self.last = Some(now);
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            true
        } else {
            false
        }
    }
}

/// Admission policy knobs.  Limits set to 0 are disabled.  `Default`:
/// no hard limits, deadline shedding on, brownout armed at 75% pressure
/// sustained for 8 consecutive submissions, Batch capped to 32 tokens in
/// the deep tier.
#[derive(Debug, Clone)]
pub struct AdmissionConfig {
    /// max in-flight requests fleet-wide (0 = unlimited)
    pub max_queue_depth: usize,
    /// max token-equivalent backlog fleet-wide (0 = unlimited)
    pub max_backlog_tokens: usize,
    /// shed requests whose deadline cannot survive the estimated queue delay
    pub shed_infeasible: bool,
    /// estimated service seconds per token-equivalent of backlog per worker
    pub est_token_cost_s: f64,
    /// pressure fraction (backlog or depth over its limit) that arms the
    /// brownout streak
    pub brownout_enter: f64,
    /// consecutive over-pressure submissions before tier 1 engages (tier 2
    /// engages at twice this streak)
    pub brownout_sustain: usize,
    /// `max_new_tokens` cap applied to Batch requests in brownout tier 2
    pub batch_cap_tokens: usize,
}

impl Default for AdmissionConfig {
    fn default() -> AdmissionConfig {
        AdmissionConfig {
            max_queue_depth: 0,
            max_backlog_tokens: 0,
            shed_infeasible: true,
            est_token_cost_s: 0.0005,
            brownout_enter: 0.75,
            brownout_sustain: 8,
            batch_cap_tokens: 32,
        }
    }
}

impl AdmissionConfig {
    pub fn max_queue_depth(mut self, n: usize) -> Self {
        self.max_queue_depth = n;
        self
    }

    pub fn max_backlog_tokens(mut self, n: usize) -> Self {
        self.max_backlog_tokens = n;
        self
    }

    pub fn shed_infeasible(mut self, on: bool) -> Self {
        self.shed_infeasible = on;
        self
    }

    pub fn est_token_cost_s(mut self, s: f64) -> Self {
        self.est_token_cost_s = s.max(0.0);
        self
    }

    pub fn brownout_enter(mut self, f: f64) -> Self {
        self.brownout_enter = f.max(0.0);
        self
    }

    pub fn brownout_sustain(mut self, n: usize) -> Self {
        self.brownout_sustain = n.max(1);
        self
    }

    pub fn batch_cap_tokens(mut self, n: usize) -> Self {
        self.batch_cap_tokens = n.max(1);
        self
    }
}

/// Admission verdict for one request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    Admit,
    /// admit with `max_new_tokens` capped (brownout tier 2, Batch class)
    AdmitCapped(usize),
    /// reject before dispatch (`FinishReason::Shed`); the str names why
    Shed(&'static str),
}

/// Early-shedding front (see module docs).  Stateful only in the brownout
/// streak, and deterministic in its inputs.
#[derive(Debug, Clone)]
pub struct AdmissionController {
    cfg: AdmissionConfig,
    /// consecutive over-pressure submissions
    streak: usize,
    // per-reason shed counters (introspection/tests)
    pub shed_limit: usize,
    pub shed_infeasible: usize,
    pub shed_brownout: usize,
}

impl AdmissionController {
    pub fn new(cfg: AdmissionConfig) -> AdmissionController {
        AdmissionController { cfg, streak: 0, shed_limit: 0, shed_infeasible: 0, shed_brownout: 0 }
    }

    /// Queue delay estimate: backlog split across the alive workers, each
    /// consuming `est_token_cost_s` per token-equivalent.
    pub fn est_queue_delay_s(&self, backlog_tokens: usize, alive_workers: usize) -> f64 {
        backlog_tokens as f64 * self.cfg.est_token_cost_s / alive_workers.max(1) as f64
    }

    /// Overload pressure: the worst fraction of any configured limit.
    fn pressure(&self, queue_depth: usize, backlog_tokens: usize) -> f64 {
        let mut p: f64 = 0.0;
        if self.cfg.max_queue_depth > 0 {
            p = p.max(queue_depth as f64 / self.cfg.max_queue_depth as f64);
        }
        if self.cfg.max_backlog_tokens > 0 {
            p = p.max(backlog_tokens as f64 / self.cfg.max_backlog_tokens as f64);
        }
        p
    }

    /// Brownout tier: 0 = normal, 1 = shed BestEffort, 2 = also cap Batch.
    pub fn brownout_level(&self) -> usize {
        if self.streak >= 2 * self.cfg.brownout_sustain {
            2
        } else if self.streak >= self.cfg.brownout_sustain {
            1
        } else {
            0
        }
    }

    /// Assess one submission against the current fleet load signals.
    pub fn assess(
        &mut self,
        req: &GenRequest,
        queue_depth: usize,
        backlog_tokens: usize,
        alive_workers: usize,
    ) -> Admission {
        if self.pressure(queue_depth, backlog_tokens) >= self.cfg.brownout_enter {
            self.streak += 1;
        } else {
            self.streak = 0;
        }
        if (self.cfg.max_queue_depth > 0 && queue_depth >= self.cfg.max_queue_depth)
            || (self.cfg.max_backlog_tokens > 0 && backlog_tokens >= self.cfg.max_backlog_tokens)
        {
            self.shed_limit += 1;
            return Admission::Shed("backlog-limit");
        }
        if self.cfg.shed_infeasible {
            if let Some(deadline) = req.deadline {
                if self.est_queue_delay_s(backlog_tokens, alive_workers) > deadline.as_secs_f64() {
                    self.shed_infeasible += 1;
                    return Admission::Shed("deadline-infeasible");
                }
            }
        }
        let level = self.brownout_level();
        if level >= 1 && req.priority == Priority::BestEffort {
            self.shed_brownout += 1;
            return Admission::Shed("brownout");
        }
        if level >= 2 && req.priority == Priority::Batch && req.max_new > self.cfg.batch_cap_tokens
        {
            return Admission::AdmitCapped(self.cfg.batch_cap_tokens);
        }
        Admission::Admit
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sup(max_restarts: usize) -> Supervisor {
        let cfg = SupervisorConfig::default()
            .backoff_base(Duration::from_millis(100))
            .backoff_max(Duration::from_millis(400))
            .jitter_frac(0.5)
            .restart_window(Duration::from_secs(10))
            .max_restarts(max_restarts)
            .seed(7);
        Supervisor::new(2, cfg)
    }

    #[test]
    fn backoff_doubles_caps_and_jitters_deterministically() {
        let mut a = sup(10);
        let mut b = sup(10);
        for attempt in 0..6 {
            let (da, db) = (a.backoff(attempt), b.backoff(attempt));
            assert_eq!(da, db, "same seed → same schedule");
            let base = Duration::from_millis(100 * (1 << attempt)).min(Duration::from_millis(400));
            assert!(da >= base, "jitter only adds delay: {da:?} < {base:?}");
            assert!(da <= base.mul_f64(1.5), "jitter bounded by jitter_frac");
        }
        // differently-seeded supervisors draw different jitter eventually
        let mut c = Supervisor::new(1, SupervisorConfig::default().seed(99).jitter_frac(0.5));
        let mut d = Supervisor::new(1, SupervisorConfig::default().seed(7).jitter_frac(0.5));
        assert!((0..8).any(|i| c.backoff(i) != d.backoff(i)));
    }

    #[test]
    fn lost_worker_is_scheduled_then_due_then_restarted() {
        let mut s = sup(3);
        let t0 = Instant::now();
        let RestartPlan::Scheduled { due, attempt } = s.on_worker_lost(0, DrainCause::Dead, t0)
        else {
            panic!("first loss must schedule");
        };
        assert_eq!(attempt, 0);
        assert!(due > t0);
        assert!(s.due(t0).is_empty(), "not due before the backoff elapses");
        assert_eq!(s.due(due), vec![0]);
        let done = s.on_restarted(0, due);
        assert_eq!(done.restarts, 1);
        assert!(!done.violated);
        assert!(s.due(due + Duration::from_secs(1)).is_empty(), "schedule resolved");
        assert_eq!(s.schedule_violations(), 0);
    }

    #[test]
    fn premature_restart_counts_as_a_schedule_violation() {
        let mut s = sup(3);
        let t0 = Instant::now();
        let RestartPlan::Scheduled { due, .. } = s.on_worker_lost(0, DrainCause::Dead, t0) else {
            panic!("must schedule");
        };
        let done = s.on_restarted(0, due - Duration::from_millis(1));
        assert!(done.violated);
        assert_eq!(s.schedule_violations(), 1);
    }

    #[test]
    fn window_budget_retires_the_slot_with_the_last_cause() {
        let mut s = sup(2);
        let mut now = Instant::now();
        for i in 0..2 {
            let plan = s.on_worker_lost(0, DrainCause::Dead, now);
            let RestartPlan::Scheduled { due, attempt } = plan else {
                panic!("restart {i} inside the budget");
            };
            assert_eq!(attempt, i, "attempt counts restarts in the window");
            s.on_restarted(0, due);
            now = due;
        }
        let plan = s.on_worker_lost(0, DrainCause::Wedged, now);
        assert_eq!(plan, RestartPlan::Retired { cause: DrainCause::Wedged });
        assert!(s.is_retired(0));
        assert_eq!(s.retired_cause(0), Some(DrainCause::Wedged));
        // retired is terminal, whatever the cause of later losses
        let again = s.on_worker_lost(0, DrainCause::Dead, now);
        assert_eq!(again, RestartPlan::Retired { cause: DrainCause::Wedged });
        // the other slot is unaffected
        let other = s.on_worker_lost(1, DrainCause::Dead, now);
        assert!(matches!(other, RestartPlan::Scheduled { .. }));
    }

    #[test]
    fn window_slides_so_old_restarts_stop_charging_the_budget() {
        let mut s = sup(1);
        let t0 = Instant::now();
        let RestartPlan::Scheduled { due, .. } = s.on_worker_lost(0, DrainCause::Dead, t0) else {
            panic!("must schedule");
        };
        s.on_restarted(0, due);
        // inside the window the budget is spent
        let soon = due + Duration::from_secs(1);
        assert!(matches!(
            s.on_worker_lost(0, DrainCause::Dead, soon),
            RestartPlan::Retired { .. }
        ));
        // a fresh slot past the window heals: rebuild and lose it much later
        let mut s = sup(1);
        let RestartPlan::Scheduled { due, .. } = s.on_worker_lost(0, DrainCause::Dead, t0) else {
            panic!("must schedule");
        };
        s.on_restarted(0, due);
        let later = due + Duration::from_secs(11);
        assert!(matches!(
            s.on_worker_lost(0, DrainCause::Dead, later),
            RestartPlan::Scheduled { attempt: 0, .. }
        ));
    }

    #[test]
    fn failed_factory_boot_charges_the_budget_and_reschedules() {
        let mut s = sup(2);
        let t0 = Instant::now();
        let RestartPlan::Scheduled { due, .. } = s.on_worker_lost(0, DrainCause::Dead, t0) else {
            panic!("must schedule");
        };
        let plan = s.on_restart_failed(0, DrainCause::Dead, due);
        assert!(matches!(plan, RestartPlan::Scheduled { attempt: 1, .. }), "retries with backoff");
        let RestartPlan::Scheduled { due: due2, .. } = plan else { unreachable!() };
        assert!(matches!(
            s.on_restart_failed(0, DrainCause::Dead, due2),
            RestartPlan::Retired { .. }
        ));
    }

    #[test]
    fn retry_budget_allows_the_burst_then_denies_until_refill() {
        let mut b = RetryBudget::new(2, 1.0);
        let t0 = Instant::now();
        assert!(b.try_take(t0));
        assert!(b.try_take(t0));
        assert!(!b.try_take(t0), "burst spent");
        assert!(!b.try_take(t0 + Duration::from_millis(500)), "half a token is not a token");
        assert!(b.try_take(t0 + Duration::from_millis(1600)));
        // refill never exceeds capacity
        let far = t0 + Duration::from_secs(3600);
        assert!(b.try_take(far));
        assert!(b.try_take(far));
        assert!(!b.try_take(far));
    }

    fn req(priority: Priority, max_new: usize, deadline_ms: Option<u64>) -> GenRequest {
        let mut b = GenRequest::builder(0).prompt(vec![1, 2]).max_new(max_new).priority(priority);
        if let Some(ms) = deadline_ms {
            b = b.deadline(Duration::from_millis(ms));
        }
        b.build()
    }

    #[test]
    fn hard_limits_shed_before_anything_else() {
        let cfg = AdmissionConfig::default().max_queue_depth(4).max_backlog_tokens(1000);
        let mut a = AdmissionController::new(cfg);
        let r = req(Priority::Interactive, 8, None);
        assert_eq!(a.assess(&r, 0, 0, 2), Admission::Admit);
        assert_eq!(a.assess(&r, 4, 0, 2), Admission::Shed("backlog-limit"));
        assert_eq!(a.assess(&r, 0, 1000, 2), Admission::Shed("backlog-limit"));
        assert_eq!(a.shed_limit, 2);
    }

    #[test]
    fn infeasible_deadlines_are_shed_early() {
        let cfg = AdmissionConfig::default().est_token_cost_s(0.001);
        let mut a = AdmissionController::new(cfg);
        // 1000 backlog tokens over 1 worker at 1ms each → ~1s queue delay
        let tight = req(Priority::Interactive, 8, Some(100));
        assert_eq!(a.assess(&tight, 0, 1000, 1), Admission::Shed("deadline-infeasible"));
        // the same backlog split across 20 workers is feasible
        assert_eq!(a.assess(&tight, 0, 1000, 20), Admission::Admit);
        // no deadline → nothing to be infeasible against
        let lazy = req(Priority::BestEffort, 8, None);
        assert_eq!(a.assess(&lazy, 0, 1000, 1), Admission::Admit);
        assert_eq!(a.shed_infeasible, 1);
    }

    #[test]
    fn brownout_tiers_shed_best_effort_then_cap_batch() {
        let cfg = AdmissionConfig::default()
            .max_backlog_tokens(1000)
            .brownout_enter(0.75)
            .brownout_sustain(2)
            .batch_cap_tokens(4)
            .shed_infeasible(false);
        let mut a = AdmissionController::new(cfg);
        let be = req(Priority::BestEffort, 8, None);
        let batch = req(Priority::Batch, 64, None);
        let inter = req(Priority::Interactive, 64, None);
        // below pressure: everything admits, streak stays zero
        assert_eq!(a.assess(&be, 0, 100, 2), Admission::Admit);
        assert_eq!(a.brownout_level(), 0);
        // sustained 80% pressure: tier 1 after 2, tier 2 after 4
        assert_eq!(a.assess(&be, 0, 800, 2), Admission::Admit, "streak 1: not sustained yet");
        assert_eq!(a.assess(&be, 0, 800, 2), Admission::Shed("brownout"), "tier 1");
        assert_eq!(a.assess(&batch, 0, 800, 2), Admission::Admit, "tier 1 leaves Batch alone");
        assert_eq!(a.assess(&batch, 0, 800, 2), Admission::AdmitCapped(4), "tier 2 caps Batch");
        assert_eq!(a.assess(&inter, 0, 800, 2), Admission::Admit, "Interactive never browns out");
        // pressure release resets the streak and the tiers
        assert_eq!(a.assess(&be, 0, 100, 2), Admission::Admit);
        assert_eq!(a.brownout_level(), 0);
        assert_eq!(a.shed_brownout, 1);
    }

    #[test]
    fn capped_batch_within_budget_is_not_touched() {
        let cfg = AdmissionConfig::default()
            .max_backlog_tokens(100)
            .brownout_enter(0.5)
            .brownout_sustain(1)
            .batch_cap_tokens(16)
            .shed_infeasible(false);
        let mut a = AdmissionController::new(cfg);
        let small = req(Priority::Batch, 8, None);
        assert_eq!(a.assess(&small, 0, 60, 2), Admission::Admit, "streak 1 → tier 1");
        assert_eq!(a.assess(&small, 0, 60, 2), Admission::Admit, "already under the cap");
    }
}
