//! The cluster router: a stateless-ish front-end owning a fleet of
//! [`Server`] workers, in the worker-executor/worker-service shape — the
//! router holds no model state, only the in-flight table and per-worker
//! load/health views.
//!
//! ## Event flow
//!
//! Clients submit through [`Router::submit`] and read a per-request
//! [`StreamEvent`] channel, exactly like talking to one server.  Internally
//! every dispatch uses `Reply::Routed`: ALL workers' token/terminal events
//! funnel onto ONE channel, id-tagged with the namespaced request id (high
//! bits = worker + 1, low bits = cluster sequence — see
//! [`request_id`](crate::coordinator::request::request_id)), and the router
//! core demultiplexes them back to the client channels.  That funnel is what
//! makes redistribution safe: the router always knows which requests have
//! produced tokens, and a re-dispatched request gets a FRESH namespaced id,
//! so a straggler event from the old worker can never corrupt the new
//! stream.
//!
//! ## Health and drain
//!
//! Alive workers are probed on `RouterConfig::health_interval` (fired
//! asynchronously — a wedged worker cannot stall the loop).  A probe that
//! errors or misses `probe_timeout` marks the worker Dead; probes that
//! answer while the engine's progress counter stays frozen across
//! `wedge_probes` probes with work outstanding mark it Wedged; a probe
//! answering `ProbeState::Failing` retires it cooperatively.  In every case
//! the worker's queued and token-less requests are re-dispatched to
//! survivors (bounded by `max_redispatch`), and its token-producing streams
//! are finished with `FinishReason::WorkerLost` carrying the tokens
//! delivered so far.  [`Router::drain_worker`] is the cooperative version:
//! the worker reports exactly which ids it released (authoritative — the
//! router only re-dispatches those), keeps its token-producing streams
//! running, and leaves the dispatch rotation.
//!
//! ## Durable oplog and stream resume
//!
//! With [`RouterConfig::oplog`] set, the core journals every admission,
//! dispatch/resume decision, forwarded token, terminal outcome, and worker
//! loss to an append-only [`Oplog`] — journaling runs on the router thread,
//! off the workers' decode paths.  Two capabilities fall out:
//!
//! - **resume instead of `WorkerLost`**: with `resume_streams` on (implied
//!   by `oplog`), a token-producing stream whose worker dies is re-dispatched
//!   to a survivor carrying its delivered tokens; the engine re-prefills
//!   `prompt + tokens` and the stream continues from its last token.
//! - **crash recovery**: [`Router::recover`] rebuilds a router from the
//!   journal after a full-process crash ([`Router::simulate_crash`] in
//!   tests), resuming every journaled in-flight stream on a fresh fleet.
//!
//! A failed journal append (disk error, injected torn write) downgrades the
//! router to journal-less serving — it never takes the fleet down.

use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::coordinator::oplog::{OpEntry, Oplog, Outcome, TraceView};
use crate::coordinator::request::{
    request_id, DrainReport, FinishReason, GenRequest, GenResponse, Metrics, ProbeState,
    RoutedEvent, StreamEvent, WorkerPostMortem, WorkerProbe,
};
use crate::coordinator::server::Server;

use super::dispatch::{DispatchPolicy, RoundRobin, WorkerLoad};
use super::fleet::{FleetMetrics, FleetReport, WorkerFleetMetrics};
use super::health::{DrainCause, HealthTracker, WorkerState};
use super::supervisor::{
    Admission, AdmissionConfig, AdmissionController, RestartPlan, RetryBudget, Supervisor,
    SupervisorConfig,
};

/// Boots a replacement [`Server`] for a worker slot (from the same shared
/// artifact/backend the original came from).
pub type WorkerFactory = Box<dyn FnMut(usize) -> Result<Server> + Send>;

/// A request implicated in this many worker deaths is quarantined
/// (finished with `FinishReason::Quarantined`) instead of redispatched.
const QUARANTINE_DEATHS: usize = 2;

/// Router configuration.  `Default`: round-robin dispatch, 50ms health
/// interval, 1s probe deadline, 4 stale probes to a wedge verdict, 3
/// redistributions per request.
pub struct RouterConfig {
    pub policy: Box<dyn DispatchPolicy>,
    /// how often each Alive worker is probed
    pub health_interval: Duration,
    /// probe answer deadline; a miss marks the worker Dead
    pub probe_timeout: Duration,
    /// consecutive progress-frozen probes (with work outstanding) before a
    /// worker is declared Wedged
    pub wedge_probes: usize,
    /// re-dispatches allowed per request before it errors out
    pub max_redispatch: usize,
    /// journal admissions/dispatches/tokens/outcomes to this oplog
    pub oplog: Option<Oplog>,
    /// resume token-producing streams on a survivor when their worker dies
    /// (instead of finishing them with `FinishReason::WorkerLost`); off by
    /// default, implied on by [`RouterConfig::oplog`]
    pub resume_streams: bool,
    /// supervised restarts: lost workers are rebooted via `worker_factory`
    /// on the supervisor's backoff schedule (requires `worker_factory`)
    pub supervisor: Option<SupervisorConfig>,
    /// boots replacement workers for the supervisor
    pub worker_factory: Option<WorkerFactory>,
    /// overload-protected admission at the router front
    pub admission: Option<AdmissionConfig>,
    /// global redispatch token bucket (crash-loop storm bound)
    pub retry_budget: Option<RetryBudget>,
}

impl Default for RouterConfig {
    fn default() -> RouterConfig {
        RouterConfig {
            policy: Box::new(RoundRobin::new()),
            health_interval: Duration::from_millis(50),
            probe_timeout: Duration::from_secs(1),
            wedge_probes: 4,
            max_redispatch: 3,
            oplog: None,
            resume_streams: false,
            supervisor: None,
            worker_factory: None,
            admission: None,
            retry_budget: None,
        }
    }
}

impl RouterConfig {
    pub fn policy(mut self, policy: Box<dyn DispatchPolicy>) -> Self {
        self.policy = policy;
        self
    }

    pub fn health_interval(mut self, interval: Duration) -> Self {
        self.health_interval = interval;
        self
    }

    pub fn probe_timeout(mut self, timeout: Duration) -> Self {
        self.probe_timeout = timeout;
        self
    }

    pub fn wedge_probes(mut self, probes: usize) -> Self {
        self.wedge_probes = probes.max(1);
        self
    }

    pub fn max_redispatch(mut self, n: usize) -> Self {
        self.max_redispatch = n;
        self
    }

    /// Journal to `log`; also turns `resume_streams` on (a journaled fleet
    /// can always reconstruct a stream, so losing it would be a waste).
    pub fn oplog(mut self, log: Oplog) -> Self {
        self.oplog = Some(log);
        self.resume_streams = true;
        self
    }

    pub fn resume_streams(mut self, on: bool) -> Self {
        self.resume_streams = on;
        self
    }

    /// Supervise the fleet: lost workers are rebooted by `factory` on
    /// `cfg`'s backoff schedule, budgeted per sliding window.
    pub fn supervise(mut self, cfg: SupervisorConfig, factory: WorkerFactory) -> Self {
        self.supervisor = Some(cfg);
        self.worker_factory = Some(factory);
        self
    }

    /// Shed overload at the router front (see [`AdmissionConfig`]).
    pub fn admission(mut self, cfg: AdmissionConfig) -> Self {
        self.admission = Some(cfg);
        self
    }

    /// Bound crash-loop redispatch storms with a token bucket: `capacity`
    /// burst, refilling at `refill_per_s` tokens per second.
    pub fn retry_budget(mut self, capacity: usize, refill_per_s: f64) -> Self {
        self.retry_budget = Some(RetryBudget::new(capacity, refill_per_s));
        self
    }
}

/// Control messages from the client side to the router core.
enum Ctl {
    Submit(GenRequest, u64, Instant, Sender<StreamEvent>),
    /// recovery path: a journaled stream resuming with its delivered tokens
    SubmitResumed(GenRequest, u64, Vec<i32>, Instant, Sender<StreamEvent>),
    Cancel(u64),
    Report(Sender<FleetReport>),
    Locate(u64, Sender<Option<usize>>),
    Drain(usize, Sender<Result<DrainReport, String>>),
    Kill(usize, Sender<Result<WorkerPostMortem, String>>),
    Shutdown,
    /// simulated process crash: the core exits immediately, settling nothing
    Die,
}

/// Client-side handle for one routed request.  Events carry NAMESPACED ids:
/// `request_id::seq_of(resp.id)` equals [`RouterHandle::id`], and
/// `request_id::worker_of(resp.id)` names the worker that served (or lost)
/// the stream.
pub struct RouterHandle {
    seq: u64,
    rx: Receiver<StreamEvent>,
    ctl: Sender<Ctl>,
}

impl RouterHandle {
    /// Cluster-wide sequence number of this request (the low bits of every
    /// response id it will ever produce).
    pub fn id(&self) -> u64 {
        self.seq
    }

    /// Ask the router to cancel this request wherever it currently is.
    pub fn cancel(&self) -> Result<()> {
        self.ctl.send(Ctl::Cancel(self.seq)).map_err(|_| anyhow!("router is down"))
    }

    pub fn receiver(&self) -> &Receiver<StreamEvent> {
        &self.rx
    }

    pub fn recv(&self) -> Result<StreamEvent> {
        self.rx.recv().map_err(|_| anyhow!("router dropped request"))
    }

    pub fn into_receiver(self) -> Receiver<StreamEvent> {
        self.rx
    }

    /// Drain the stream to its terminal event.
    pub fn collect(self) -> Result<GenResponse> {
        loop {
            match self.rx.recv() {
                Ok(StreamEvent::Token(_)) => {}
                Ok(StreamEvent::Done(resp)) => return Ok(resp),
                Ok(StreamEvent::Error(e)) => bail!(e),
                Err(_) => bail!("router dropped stream"),
            }
        }
    }
}

/// Prefix-affinity router over a fleet of workers (see the module docs).
pub struct Router {
    ctl: Sender<Ctl>,
    seq: AtomicU64,
    handle: Option<JoinHandle<()>>,
}

impl Router {
    /// Front the fleet with a router thread.  The workers should all be
    /// booted from the same artifact (the router assumes any worker can
    /// serve any request).
    pub fn new(workers: Vec<Server>, cfg: RouterConfig) -> Result<Router> {
        if workers.is_empty() {
            bail!("router needs at least one worker");
        }
        let RouterConfig {
            policy,
            health_interval,
            probe_timeout,
            wedge_probes,
            max_redispatch,
            oplog,
            resume_streams,
            supervisor,
            worker_factory,
            admission,
            retry_budget,
        } = cfg;
        if supervisor.is_some() && worker_factory.is_none() {
            bail!("supervised restarts need a worker factory (RouterConfig::supervise)");
        }
        let (ctl_tx, ctl_rx) = channel::<Ctl>();
        let (ev_tx, ev_rx) = channel::<RoutedEvent>();
        let now = Instant::now();
        let n_workers = workers.len();
        let slots = workers
            .into_iter()
            .map(|server| WorkerSlot {
                server: Some(server),
                state: WorkerState::Alive,
                health: HealthTracker::new(wedge_probes),
                active_slots: 0,
                queued_requests: 0,
                queued_tokens: 0,
                slots_total: 0,
                dispatched_since_probe: 0,
                outstanding: 0,
                probe_pending: None,
                last_probe_at: now,
                last_metrics: Metrics::default(),
                dispatched: 0,
                affinity_hits: 0,
                prefix_hit_tokens: 0,
                redistributions_absorbed: 0,
                completed: 0,
                restarts: 0,
                last_cause: None,
            })
            .collect();
        let core = Core {
            workers: slots,
            policy,
            health_interval,
            probe_timeout,
            wedge_probes,
            max_redispatch,
            ctl_rx,
            ev_rx,
            ev_tx,
            routes: HashMap::new(),
            by_seq: HashMap::new(),
            fleet: FleetMetrics::default(),
            oplog,
            resume_streams,
            supervisor: supervisor.map(|cfg| Supervisor::new(n_workers, cfg)),
            factory: worker_factory,
            admission: admission.map(AdmissionController::new),
            retry_budget,
            implicated: HashMap::new(),
            lost_metrics: Metrics::default(),
        };
        let handle = std::thread::Builder::new().name("pq-router".into()).spawn(move || {
            core.run();
        })?;
        Ok(Router { ctl: ctl_tx, seq: AtomicU64::new(0), handle: Some(handle) })
    }

    /// Rebuild a router from a journal after a crash: open (and
    /// torn-tail-truncate) the oplog at `path`, restart the sequence counter
    /// above the largest journaled value, and resume every journaled stream
    /// with no terminal outcome on the fresh `workers`.
    ///
    /// Returns one [`RouterHandle`] per resumed stream, in `seq` order.
    /// Each handle's channel is pre-fed the stream's already-journaled
    /// tokens, so draining it yields the COMPLETE stream — the journaled
    /// prefix followed by the freshly decoded continuation.  `cfg.oplog` is
    /// replaced by the recovered log (appends continue in the same file) and
    /// `resume_streams` is forced on.
    pub fn recover(
        workers: Vec<Server>,
        mut cfg: RouterConfig,
        path: impl AsRef<Path>,
    ) -> Result<(Router, Vec<RouterHandle>)> {
        let (log, recovered) = Oplog::open_recover(path)?;
        let view = TraceView::from_entries(&recovered.entries);
        cfg.oplog = Some(log);
        cfg.resume_streams = true;
        let router = Router::new(workers, cfg)?;
        router.seq.store(view.max_seq().map_or(0, |s| s + 1), Ordering::Relaxed);
        let mut handles = Vec::new();
        for rec in view.unfinished() {
            let (tx, rx) = channel();
            for &t in &rec.tokens {
                let _ = tx.send(StreamEvent::Token(t));
            }
            router
                .ctl
                .send(Ctl::SubmitResumed(
                    rec.req.clone(),
                    rec.seq,
                    rec.tokens.clone(),
                    Instant::now(),
                    tx,
                ))
                .map_err(|_| anyhow!("router died during recovery"))?;
            handles.push(RouterHandle { seq: rec.seq, rx, ctl: router.ctl.clone() });
        }
        Ok((router, handles))
    }

    /// Crash the router as a process would: the core thread exits
    /// immediately — no terminal events, no worker drains, no journal
    /// settlement.  What the oplog holds at this instant is exactly what
    /// [`Router::recover`] gets to work with.  (The worker `Server` handles
    /// owned by the core are dropped, which ends their threads; a real crash
    /// would kill those too.)
    pub fn simulate_crash(mut self) {
        let _ = self.ctl.send(Ctl::Die);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }

    /// Submit a request; the router picks the worker.  The request's own
    /// `id` field is replaced by a namespaced id on dispatch — correlate
    /// through the handle's sequence number instead.
    pub fn submit(&self, req: GenRequest) -> Result<RouterHandle> {
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = channel();
        self.ctl
            .send(Ctl::Submit(req, seq, Instant::now(), tx))
            .map_err(|_| anyhow!("router is down"))?;
        Ok(RouterHandle { seq, rx, ctl: self.ctl.clone() })
    }

    /// Fleet-wide report: router counters, per-worker breakdown, and every
    /// worker's engine metrics merged (lost workers contribute their last
    /// probe snapshot).
    pub fn report(&self) -> Result<FleetReport> {
        let (tx, rx) = channel();
        self.ctl.send(Ctl::Report(tx)).map_err(|_| anyhow!("router is down"))?;
        rx.recv().map_err(|_| anyhow!("router dropped report request"))
    }

    /// Which worker a request (by handle sequence number) is currently on.
    pub fn locate(&self, seq: u64) -> Result<Option<usize>> {
        let (tx, rx) = channel();
        self.ctl.send(Ctl::Locate(seq, tx)).map_err(|_| anyhow!("router is down"))?;
        rx.recv().map_err(|_| anyhow!("router dropped locate request"))
    }

    /// Cooperatively drain a worker: it leaves the dispatch rotation, its
    /// queued/token-less requests are re-dispatched to survivors (the
    /// worker's released-id report is authoritative), and its
    /// token-producing streams keep running to completion.
    pub fn drain_worker(&self, worker: usize) -> Result<DrainReport> {
        let (tx, rx) = channel();
        self.ctl.send(Ctl::Drain(worker, tx)).map_err(|_| anyhow!("router is down"))?;
        rx.recv().map_err(|_| anyhow!("router dropped drain request"))?.map_err(|e| anyhow!(e))
    }

    /// Kill a worker as if it crashed mid-flight: its replies are dropped
    /// without terminal events, then the router redistributes its token-less
    /// requests and finishes its token-producing streams with
    /// `FinishReason::WorkerLost`.  Returns the worker's final page-pool
    /// accounting.
    pub fn kill_worker(&self, worker: usize) -> Result<WorkerPostMortem> {
        let (tx, rx) = channel();
        self.ctl.send(Ctl::Kill(worker, tx)).map_err(|_| anyhow!("router is down"))?;
        rx.recv().map_err(|_| anyhow!("router dropped kill request"))?.map_err(|e| anyhow!(e))
    }

    pub fn shutdown(mut self) {
        let _ = self.ctl.send(Ctl::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        let _ = self.ctl.send(Ctl::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// One in-flight request in the router's table.
struct Route {
    seq: u64,
    client: Sender<StreamEvent>,
    /// the original request (cloned per dispatch with a fresh namespaced id)
    req: GenRequest,
    submitted: Instant,
    worker: usize,
    /// tokens forwarded so far — the redistribution criterion, and the
    /// payload of a synthesized `WorkerLost` response
    tokens: Vec<i32>,
    redispatches: usize,
    first_token_s: Option<f64>,
}

/// Router-side view of one worker.
struct WorkerSlot {
    /// taken on loss (abandoned or joined); None = no longer contactable
    server: Option<Server>,
    state: WorkerState,
    health: HealthTracker,
    // last-probe gauges
    active_slots: usize,
    queued_requests: usize,
    queued_tokens: usize,
    slots_total: usize,
    /// dispatches since the last answered probe (load-staleness correction)
    dispatched_since_probe: usize,
    /// dispatched and not yet terminal (router-side, always current)
    outstanding: usize,
    probe_pending: Option<(Receiver<WorkerProbe>, Instant)>,
    last_probe_at: Instant,
    /// last engine metrics seen (probe or report refresh) — what a lost
    /// worker contributes to the merged fleet view
    last_metrics: Metrics,
    // fleet counters
    dispatched: usize,
    affinity_hits: usize,
    prefix_hit_tokens: usize,
    redistributions_absorbed: usize,
    completed: usize,
    /// supervised replacement boots into this slot
    restarts: usize,
    /// why the slot last left the rotation (survives restarts)
    last_cause: Option<DrainCause>,
}

impl WorkerSlot {
    fn alive(&self) -> bool {
        self.state == WorkerState::Alive && self.server.is_some()
    }
}

/// The router core, owned by the `pq-router` thread.
struct Core {
    workers: Vec<WorkerSlot>,
    policy: Box<dyn DispatchPolicy>,
    health_interval: Duration,
    probe_timeout: Duration,
    max_redispatch: usize,
    ctl_rx: Receiver<Ctl>,
    ev_rx: Receiver<RoutedEvent>,
    /// kept so `ev_rx` never disconnects while workers churn; cloned into
    /// every dispatch
    ev_tx: Sender<RoutedEvent>,
    /// in-flight table keyed by namespaced id
    routes: HashMap<u64, Route>,
    /// handle sequence number → current namespaced id
    by_seq: HashMap<u64, u64>,
    fleet: FleetMetrics,
    /// durable journal; dropped (with a stderr notice) after a failed append
    oplog: Option<Oplog>,
    /// resume token-producing streams off lost workers instead of finishing
    /// them with `WorkerLost`
    resume_streams: bool,
    /// wedge threshold, kept so restarted workers get a fresh tracker
    wedge_probes: usize,
    /// restart scheduler (None = unsupervised fleet)
    supervisor: Option<Supervisor>,
    /// boots replacement workers for the supervisor
    factory: Option<WorkerFactory>,
    /// overload front (None = admit everything)
    admission: Option<AdmissionController>,
    /// global redispatch token bucket (None = unbounded retries)
    retry_budget: Option<RetryBudget>,
    /// seq → worker deaths this request was in flight for (poison tracking)
    implicated: HashMap<u64, usize>,
    /// merged engine metrics of every lost worker incarnation, so restarted
    /// slots don't erase the work their dead predecessors served
    lost_metrics: Metrics,
}

impl Core {
    fn run(mut self) {
        loop {
            loop {
                match self.ctl_rx.try_recv() {
                    Ok(Ctl::Shutdown) | Err(TryRecvError::Disconnected) => {
                        self.shutdown_all();
                        return;
                    }
                    // simulated process crash: exit with NOTHING settled —
                    // no terminal events, no journal entries, no drains
                    Ok(Ctl::Die) => return,
                    Ok(m) => self.on_ctl(m),
                    Err(TryRecvError::Empty) => break,
                }
            }
            while let Ok(ev) = self.ev_rx.try_recv() {
                self.on_event(ev);
            }
            self.poll_probes();
            self.start_due_probes();
            self.tick_supervisor();
            // Park on the event funnel: token events are the high-rate
            // stream; control messages wait at most one quantum.
            match self.ev_rx.recv_timeout(self.quantum()) {
                Ok(ev) => self.on_event(ev),
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => unreachable!("core holds an ev_tx clone"),
            }
        }
    }

    fn quantum(&self) -> Duration {
        let busy =
            !self.routes.is_empty() || self.workers.iter().any(|w| w.probe_pending.is_some());
        if busy {
            Duration::from_micros(500)
        } else {
            Duration::from_millis(2)
        }
    }

    fn on_ctl(&mut self, m: Ctl) {
        match m {
            Ctl::Submit(mut req, seq, submitted, client) => {
                self.fleet.submitted += 1;
                match self.assess_admission(&req) {
                    Admission::Admit => {}
                    // the cap is applied BEFORE the admission journal entry:
                    // replay re-executes the journaled request verbatim, and
                    // a deterministic finish must reproduce exactly
                    Admission::AdmitCapped(cap) => req.max_new = req.max_new.min(cap),
                    Admission::Shed(_) => {
                        self.journal(&OpEntry::Admitted { seq, req: req.clone() });
                        self.finish_shed(seq, submitted, &client);
                        return;
                    }
                }
                self.journal(&OpEntry::Admitted { seq, req: req.clone() });
                self.dispatch(Route {
                    seq,
                    client,
                    req,
                    submitted,
                    worker: 0,
                    tokens: Vec::new(),
                    redispatches: 0,
                    first_token_s: None,
                });
            }
            Ctl::SubmitResumed(req, seq, tokens, submitted, client) => {
                // recovery path: the request's admission is already in the
                // journal — only the resume decision gets a fresh entry
                // (inside dispatch), and the ledger counts it as submitted
                // to THIS router incarnation
                self.fleet.submitted += 1;
                self.dispatch(Route {
                    seq,
                    client,
                    req,
                    submitted,
                    worker: 0,
                    tokens,
                    redispatches: 0,
                    first_token_s: None,
                });
            }
            Ctl::Cancel(seq) => {
                let Some(&wid) = self.by_seq.get(&seq) else {
                    return; // already terminal: cancel raced the finish
                };
                let Some(route) = self.routes.get(&wid) else {
                    // by_seq says in-flight but the route is gone — an
                    // internal inconsistency; settle by dropping the stale
                    // index entry instead of panicking mid-demux
                    eprintln!("pq-router: dropping stale by_seq entry for seq {seq}");
                    self.by_seq.remove(&seq);
                    return;
                };
                if let Some(server) = self.workers[route.worker].server.as_ref() {
                    // terminal Done(Cancelled) comes back via the funnel
                    let _ = server.cancel(wid);
                }
            }
            Ctl::Report(tx) => {
                let report = self.report();
                let _ = tx.send(report);
            }
            Ctl::Locate(seq, tx) => {
                let w = self
                    .by_seq
                    .get(&seq)
                    .and_then(|wid| self.routes.get(wid))
                    .map(|route| route.worker);
                let _ = tx.send(w);
            }
            Ctl::Drain(w, tx) => {
                let r = self.drain_worker(w);
                let _ = tx.send(r);
            }
            Ctl::Kill(w, tx) => {
                let r = self.kill_worker(w);
                let _ = tx.send(r);
            }
            Ctl::Shutdown | Ctl::Die => unreachable!("handled in run()"),
        }
    }

    /// Append one entry to the journal, when journaling is on.  A failed
    /// append wedges the log (the file may end mid-frame), so the router
    /// downgrades to journal-less serving — reported once on stderr, and
    /// visible as a missing oplog suffix at the next recovery.
    fn journal(&mut self, e: &OpEntry) {
        if let Some(log) = self.oplog.as_mut() {
            if let Err(err) = log.append(e) {
                eprintln!(
                    "pq-router: journaling disabled after a failed append to {}: {err:#}",
                    log.path().display()
                );
                self.oplog = None;
            }
        }
    }

    fn alive_loads(&self) -> Vec<WorkerLoad> {
        self.workers
            .iter()
            .enumerate()
            .filter(|(_, ws)| ws.alive())
            .map(|(worker, ws)| WorkerLoad {
                worker,
                active_slots: ws.active_slots,
                queued_requests: ws.queued_requests,
                queued_tokens: ws.queued_tokens,
                dispatched_since_probe: ws.dispatched_since_probe,
                outstanding: ws.outstanding,
                slots_total: ws.slots_total,
                radix_shared_pages: ws.last_metrics.radix_shared_pages,
                radix_hit_tokens: ws.last_metrics.radix_hit_tokens,
            })
            .collect()
    }

    /// Dispatch (or re-dispatch) a route to a policy-picked alive worker.
    /// A worker whose channel is already gone is declared lost on the spot
    /// and the pick retried against the survivors.
    fn dispatch(&mut self, mut route: Route) {
        loop {
            let loads = self.alive_loads();
            if loads.is_empty() {
                self.fleet.errors += 1;
                self.journal(&OpEntry::Finished {
                    seq: route.seq,
                    outcome: Outcome::Error,
                    n_tokens: route.tokens.len() as u32,
                });
                let _ = route
                    .client
                    .send(StreamEvent::Error("no alive workers in the fleet".into()));
                return;
            }
            let pick = self.policy.pick(&route.req, &loads);
            let w = pick.worker;
            let wid = request_id::namespaced(w, route.seq);
            let mut wreq = route.req.clone();
            wreq.id = wid;
            let ev_tx = self.ev_tx.clone();
            // a route carrying tokens is a stream resume: the worker
            // re-prefills prompt + tokens and emits only NEW tokens
            let sent = match self.workers[w].server.as_ref() {
                Some(server) if route.tokens.is_empty() => {
                    server.submit_routed(wreq, ev_tx, route.submitted).is_ok()
                }
                Some(server) => server
                    .submit_routed_resumed(wreq, route.tokens.clone(), ev_tx, route.submitted)
                    .is_ok(),
                None => false,
            };
            if !sent {
                self.declare_lost(w, DrainCause::Dead);
                continue;
            }
            let ws = &mut self.workers[w];
            ws.dispatched += 1;
            ws.dispatched_since_probe += 1;
            ws.outstanding += 1;
            self.fleet.dispatched += 1;
            self.fleet.dispatched_prefill_tokens +=
                1 + route.req.prompt.len() + route.tokens.len();
            if pick.affinity_hit {
                ws.affinity_hits += 1;
                ws.prefix_hit_tokens += pick.hit_tokens;
                self.fleet.affinity_hits += 1;
                self.fleet.prefix_hit_tokens += pick.hit_tokens;
            }
            if route.redispatches > 0 {
                ws.redistributions_absorbed += 1;
                self.fleet.redistributed += 1;
            }
            if route.tokens.is_empty() {
                self.journal(&OpEntry::Dispatched { seq: route.seq, worker: w as u64 });
            } else {
                self.fleet.stream_resumes += 1;
                self.journal(&OpEntry::Resumed {
                    seq: route.seq,
                    worker: w as u64,
                    from_tokens: route.tokens.len() as u32,
                });
            }
            route.worker = w;
            self.by_seq.insert(route.seq, wid);
            self.routes.insert(wid, route);
            return;
        }
    }

    /// Demultiplex one funnel event back to its client stream.  Every arm
    /// re-looks its route up and settles quietly on a miss: stale ids
    /// (redistributed or torn-down routes) are EXPECTED here, and the demux
    /// thread must never panic on one — it would take the whole fleet's
    /// event flow down with it.
    fn on_event(&mut self, ev: RoutedEvent) {
        match ev.ev {
            StreamEvent::Token(t) => {
                let Some(route) = self.routes.get_mut(&ev.id) else {
                    return; // stale: the route moved on, drop silently
                };
                if route.tokens.is_empty() {
                    route.first_token_s = Some(route.submitted.elapsed().as_secs_f64());
                }
                route.tokens.push(t);
                let _ = route.client.send(StreamEvent::Token(t));
                let seq = route.seq;
                self.journal(&OpEntry::Token { seq, token: t });
            }
            StreamEvent::Done(resp) => {
                let Some(route) = self.routes.remove(&ev.id) else {
                    return;
                };
                self.by_seq.remove(&route.seq);
                self.implicated.remove(&route.seq);
                let ws = &mut self.workers[route.worker];
                ws.outstanding = ws.outstanding.saturating_sub(1);
                ws.completed += 1;
                if resp.finish == FinishReason::Cancelled {
                    self.fleet.cancelled += 1;
                } else {
                    self.fleet.completed += 1;
                }
                self.journal(&OpEntry::Finished {
                    seq: route.seq,
                    outcome: Outcome::Finish(resp.finish),
                    n_tokens: resp.tokens.len() as u32,
                });
                let _ = route.client.send(StreamEvent::Done(resp));
            }
            StreamEvent::Error(e) => {
                let Some(route) = self.routes.remove(&ev.id) else {
                    return;
                };
                self.by_seq.remove(&route.seq);
                let ws = &mut self.workers[route.worker];
                ws.outstanding = ws.outstanding.saturating_sub(1);
                let retryable = route.tokens.is_empty() || self.resume_streams;
                if retryable && route.redispatches < self.max_redispatch {
                    // token-less failure: give another worker a try — at most
                    // `max_redispatch` redispatches over the route's lifetime
                    // (check-then-increment, the one idiom every retry path
                    // uses), so a deterministic rejection cannot ping-pong
                    // forever.  With resume on, token-producing streams retry
                    // too — the dispatch carries their tokens and resumes.
                    let mut route = route;
                    route.redispatches += 1;
                    self.dispatch(route);
                } else {
                    self.fleet.errors += 1;
                    self.implicated.remove(&route.seq);
                    self.journal(&OpEntry::Finished {
                        seq: route.seq,
                        outcome: Outcome::Error,
                        n_tokens: route.tokens.len() as u32,
                    });
                    let _ = route.client.send(StreamEvent::Error(e));
                }
            }
        }
    }

    /// Fire probes for Alive workers whose interval elapsed.
    fn start_due_probes(&mut self) {
        for w in 0..self.workers.len() {
            let due = {
                let ws = &self.workers[w];
                ws.alive()
                    && ws.probe_pending.is_none()
                    && ws.last_probe_at.elapsed() >= self.health_interval
            };
            if !due {
                continue;
            }
            // alive() checked server presence, but settle (never panic) if
            // the handle vanished between the check and the probe
            let started = match self.workers[w].server.as_ref() {
                Some(server) => server.probe_start(),
                None => {
                    self.declare_lost(w, DrainCause::Dead);
                    continue;
                }
            };
            match started {
                Ok(rx) => self.workers[w].probe_pending = Some((rx, Instant::now())),
                Err(_) => self.declare_lost(w, DrainCause::Dead),
            }
        }
    }

    /// Poll outstanding probe answers; apply dead/wedged/failing verdicts.
    fn poll_probes(&mut self) {
        for w in 0..self.workers.len() {
            let Some((rx, sent_at)) = self.workers[w].probe_pending.as_ref() else {
                continue;
            };
            match rx.try_recv() {
                Ok(probe) => {
                    let ws = &mut self.workers[w];
                    ws.probe_pending = None;
                    ws.last_probe_at = Instant::now();
                    ws.active_slots = probe.active_slots;
                    ws.queued_requests = probe.queued_requests;
                    ws.queued_tokens = probe.queued_tokens;
                    ws.slots_total = probe.slots_total;
                    ws.dispatched_since_probe = 0;
                    ws.last_metrics = probe.metrics.clone();
                    if probe.state == ProbeState::Failing {
                        self.declare_lost(w, DrainCause::Failing);
                        continue;
                    }
                    let outstanding = ws.outstanding;
                    if ws.health.on_probe(probe.progress, outstanding) {
                        self.declare_lost(w, DrainCause::Wedged);
                    }
                }
                Err(TryRecvError::Empty) => {
                    if sent_at.elapsed() > self.probe_timeout {
                        self.declare_lost(w, DrainCause::Dead);
                    }
                }
                Err(TryRecvError::Disconnected) => {
                    self.declare_lost(w, DrainCause::Dead);
                }
            }
        }
    }

    /// Terminal demotion: take the worker out of the fleet and settle every
    /// route it held — token-less requests are re-dispatched to survivors,
    /// token-producing streams are finished with `FinishReason::WorkerLost`
    /// (their response id names the lost worker).
    fn declare_lost(&mut self, w: usize, cause: DrainCause) {
        if matches!(self.workers[w].state, WorkerState::Lost(_)) {
            return;
        }
        // flush the funnel first: token events already sent by the dying
        // worker decide which routes count as token-producing
        while let Ok(ev) = self.ev_rx.try_recv() {
            self.on_event(ev);
        }
        self.workers[w].state = WorkerState::Lost(cause);
        self.workers[w].last_cause = Some(cause);
        self.workers[w].probe_pending = None;
        // fold the dead incarnation's last metrics snapshot into the lost
        // accumulator now: a supervised restart will zero the slot's gauges,
        // and the merged fleet view must keep the work this one served
        let snapshot = self.workers[w].last_metrics.clone();
        self.lost_metrics.merge(&snapshot);
        self.journal(&OpEntry::WorkerLost { worker: w as u64, cause });
        match cause {
            DrainCause::Dead => self.fleet.workers_dead += 1,
            DrainCause::Wedged => self.fleet.workers_wedged += 1,
            DrainCause::Failing => self.fleet.workers_drained += 1,
            DrainCause::Killed => self.fleet.workers_killed += 1,
        }
        self.policy.forget_worker(w);
        if let Some(server) = self.workers[w].server.take() {
            match cause {
                // a killed worker's thread has already exited: joining is
                // instant and reaps it
                DrainCause::Killed => server.shutdown(),
                // dead/wedged threads may never exit: do NOT join
                _ => server.abandon(),
            }
        }
        let wids: Vec<u64> =
            self.routes.iter().filter(|(_, r)| r.worker == w).map(|(&id, _)| id).collect();
        for wid in wids {
            let Some(route) = self.routes.remove(&wid) else {
                // a dispatch above may have re-homed this id already;
                // nothing left to settle
                continue;
            };
            self.by_seq.remove(&route.seq);
            // poison tracking: this request was in flight on a dying worker.
            // Implicated in QUARANTINE_DEATHS deaths → presumed poisonous,
            // finished instead of redispatched into another victim.
            let deaths = {
                let c = self.implicated.entry(route.seq).or_insert(0);
                *c += 1;
                *c
            };
            if deaths >= QUARANTINE_DEATHS {
                self.finish_quarantined(wid, route);
                continue;
            }
            if route.tokens.is_empty() || self.resume_streams {
                // token-less requests are re-dispatched fresh; with resume
                // on, token-PRODUCING streams are re-dispatched too, carrying
                // their delivered tokens — the survivor re-prefills
                // prompt + tokens and the stream continues seamlessly.  At
                // most `max_redispatch` redispatches per route
                // (check-then-increment, same idiom as every retry path),
                // gated by the global retry budget during crash loops.
                let mut route = route;
                if route.redispatches < self.max_redispatch && self.retry_allowed() {
                    route.redispatches += 1;
                    self.dispatch(route);
                } else if route.tokens.is_empty() {
                    self.fleet.errors += 1;
                    self.implicated.remove(&route.seq);
                    self.journal(&OpEntry::Finished {
                        seq: route.seq,
                        outcome: Outcome::Error,
                        n_tokens: 0,
                    });
                    let _ = route.client.send(StreamEvent::Error(format!(
                        "worker {w} {} and the redistribution budget is exhausted",
                        cause.name()
                    )));
                } else {
                    self.finish_worker_lost(wid, route);
                }
            } else {
                self.finish_worker_lost(wid, route);
            }
        }
        self.workers[w].outstanding = 0;
        self.notify_supervisor_lost(w, cause);
    }

    /// Consult the global retry token bucket (always allowed when none is
    /// configured).  A denial is counted — the caller settles the request.
    fn retry_allowed(&mut self) -> bool {
        match self.retry_budget.as_mut() {
            None => true,
            Some(bucket) => {
                let ok = bucket.try_take(Instant::now());
                if !ok {
                    self.fleet.retries_denied += 1;
                }
                ok
            }
        }
    }

    /// Terminal settlement of a token-producing stream whose worker died and
    /// that cannot (or may not) be resumed: the client gets a `Done` with
    /// `FinishReason::WorkerLost` carrying the tokens delivered so far.
    fn finish_worker_lost(&mut self, wid: u64, route: Route) {
        self.fleet.worker_lost += 1;
        self.implicated.remove(&route.seq);
        self.journal(&OpEntry::Finished {
            seq: route.seq,
            outcome: Outcome::Finish(FinishReason::WorkerLost),
            n_tokens: route.tokens.len() as u32,
        });
        let resp = GenResponse {
            id: wid,
            tokens: route.tokens.clone(),
            ttft_s: route.first_token_s.unwrap_or(0.0),
            total_s: route.submitted.elapsed().as_secs_f64(),
            queue_s: 0.0,
            finish: FinishReason::WorkerLost,
        };
        let _ = route.client.send(StreamEvent::Done(resp));
    }

    /// Terminal settlement of a request implicated in `QUARANTINE_DEATHS`
    /// worker deaths: presumed poisonous, it is finished with
    /// `FinishReason::Quarantined` (tokens delivered so far attached)
    /// instead of being redispatched into another worker.
    fn finish_quarantined(&mut self, wid: u64, route: Route) {
        self.fleet.quarantined += 1;
        self.implicated.remove(&route.seq);
        self.journal(&OpEntry::Finished {
            seq: route.seq,
            outcome: Outcome::Finish(FinishReason::Quarantined),
            n_tokens: route.tokens.len() as u32,
        });
        let resp = GenResponse {
            id: wid,
            tokens: route.tokens.clone(),
            ttft_s: route.first_token_s.unwrap_or(0.0),
            total_s: route.submitted.elapsed().as_secs_f64(),
            queue_s: 0.0,
            finish: FinishReason::Quarantined,
        };
        let _ = route.client.send(StreamEvent::Done(resp));
    }

    /// Terminal settlement of a request the admission controller rejected
    /// before dispatch: no worker involved, no tokens, a plain (seq) id.
    fn finish_shed(&mut self, seq: u64, submitted: Instant, client: &Sender<StreamEvent>) {
        self.fleet.shed += 1;
        self.journal(&OpEntry::Finished {
            seq,
            outcome: Outcome::Finish(FinishReason::Shed),
            n_tokens: 0,
        });
        let resp = GenResponse {
            id: seq,
            tokens: Vec::new(),
            ttft_s: 0.0,
            total_s: submitted.elapsed().as_secs_f64(),
            queue_s: 0.0,
            finish: FinishReason::Shed,
        };
        let _ = client.send(StreamEvent::Done(resp));
    }

    /// Run one submission through the admission controller (admit-everything
    /// when none is configured).
    fn assess_admission(&mut self, req: &GenRequest) -> Admission {
        if self.admission.is_none() {
            return Admission::Admit;
        }
        // same token-equivalent load estimate the dispatch policies use
        let loads = self.alive_loads();
        let backlog: usize = loads.iter().map(|l| l.score()).sum();
        let admission = self.admission.as_mut().expect("checked above");
        admission.assess(req, self.routes.len(), backlog, loads.len())
    }

    /// Let the supervisor react to a lost worker: schedule a replacement on
    /// the backoff schedule, or retire the slot when its budget is spent.
    fn notify_supervisor_lost(&mut self, w: usize, cause: DrainCause) {
        let Some(sup) = self.supervisor.as_mut() else {
            return;
        };
        match sup.on_worker_lost(w, cause, Instant::now()) {
            RestartPlan::Scheduled { .. } => {}
            RestartPlan::Retired { cause } => {
                self.fleet.workers_retired += 1;
                eprintln!(
                    "pq-router: worker {w} retired permanently after exhausting its restart \
                     budget (last cause: {})",
                    cause.name()
                );
            }
        }
    }

    /// Boot due replacement workers (supervised fleets only).
    fn tick_supervisor(&mut self) {
        if self.supervisor.is_none() {
            return;
        }
        let due = self.supervisor.as_ref().expect("checked above").due(Instant::now());
        for w in due {
            let built = match self.factory.as_mut() {
                Some(f) => f(w),
                None => unreachable!("Router::new requires a factory with a supervisor"),
            };
            match built {
                Ok(server) => self.reenlist(w, server),
                Err(e) => {
                    eprintln!("pq-router: replacement boot for worker {w} failed: {e:#}");
                    let sup = self.supervisor.as_mut().expect("checked above");
                    if let RestartPlan::Retired { cause } =
                        sup.on_restart_failed(w, DrainCause::Dead, Instant::now())
                    {
                        self.fleet.workers_retired += 1;
                        eprintln!(
                            "pq-router: worker {w} retired permanently after exhausting its \
                             restart budget (last cause: {})",
                            cause.name()
                        );
                    }
                }
            }
        }
    }

    /// Re-enlist a freshly booted replacement into worker slot `w`: reset
    /// the slot's health/load state (the process behind it is new), keep the
    /// cumulative dispatch counters, drop the dispatch policy's stale
    /// per-worker state, and journal the restart so recovery and replay see
    /// the same fleet history.
    fn reenlist(&mut self, w: usize, server: Server) {
        let now = Instant::now();
        let ws = &mut self.workers[w];
        ws.server = Some(server);
        ws.state = WorkerState::Alive;
        ws.health = HealthTracker::new(self.wedge_probes);
        ws.active_slots = 0;
        ws.queued_requests = 0;
        ws.queued_tokens = 0;
        ws.slots_total = 0;
        ws.dispatched_since_probe = 0;
        ws.outstanding = 0;
        ws.probe_pending = None;
        ws.last_probe_at = now;
        // the dead incarnation's snapshot lives in lost_metrics already
        ws.last_metrics = Metrics::default();
        ws.restarts += 1;
        self.policy.worker_restarted(w);
        let done = self
            .supervisor
            .as_mut()
            .expect("reenlist only runs on supervised fleets")
            .on_restarted(w, now);
        if done.violated {
            self.fleet.restart_schedule_violations += 1;
        }
        self.fleet.workers_restarted += 1;
        self.journal(&OpEntry::WorkerRestarted { worker: w as u64, restarts: done.restarts });
    }

    /// Cooperative drain (see [`Router::drain_worker`]).
    fn drain_worker(&mut self, w: usize) -> Result<DrainReport, String> {
        if w >= self.workers.len() {
            return Err(format!("no worker {w} in a fleet of {}", self.workers.len()));
        }
        if self.workers[w].state != WorkerState::Alive {
            return Err(format!("worker {w} is {}", self.workers[w].state.name()));
        }
        let Some(server) = self.workers[w].server.as_ref() else {
            return Err(format!("worker {w} has no server handle"));
        };
        let report = match server.drain(self.probe_timeout) {
            Ok(r) => r,
            Err(e) => {
                // a worker that cannot answer a drain is dead
                self.declare_lost(w, DrainCause::Dead);
                return Err(format!("drain failed, worker {w} declared dead: {e:#}"));
            }
        };
        self.workers[w].state = WorkerState::Draining;
        self.fleet.workers_drained += 1;
        self.policy.forget_worker(w);
        // the worker's released list is authoritative: only those ids are
        // re-dispatched, so a token event racing the drain can never spawn a
        // duplicate stream
        for &wid in &report.released {
            let Some(mut route) = self.routes.remove(&wid) else {
                continue;
            };
            self.by_seq.remove(&route.seq);
            let ws = &mut self.workers[w];
            ws.outstanding = ws.outstanding.saturating_sub(1);
            // at most `max_redispatch` redispatches per route — the same
            // check-then-increment idiom as the loss and error-retry paths
            if route.redispatches < self.max_redispatch {
                route.redispatches += 1;
                self.dispatch(route);
            } else {
                self.fleet.errors += 1;
                self.journal(&OpEntry::Finished {
                    seq: route.seq,
                    outcome: Outcome::Error,
                    n_tokens: route.tokens.len() as u32,
                });
                let _ = route.client.send(StreamEvent::Error(format!(
                    "worker {w} drained and the redistribution budget is exhausted"
                )));
            }
        }
        Ok(report)
    }

    /// Forced kill (see [`Router::kill_worker`]).
    fn kill_worker(&mut self, w: usize) -> Result<WorkerPostMortem, String> {
        if w >= self.workers.len() {
            return Err(format!("no worker {w} in a fleet of {}", self.workers.len()));
        }
        if matches!(self.workers[w].state, WorkerState::Lost(_)) {
            return Err(format!("worker {w} is already lost"));
        }
        let Some(server) = self.workers[w].server.as_ref() else {
            return Err(format!("worker {w} has no server handle"));
        };
        match server.kill(self.probe_timeout) {
            Ok(pm) => {
                self.declare_lost(w, DrainCause::Killed);
                Ok(pm)
            }
            Err(e) => {
                self.declare_lost(w, DrainCause::Dead);
                Err(format!("kill failed, worker {w} declared dead: {e:#}"))
            }
        }
    }

    fn report(&mut self) -> FleetReport {
        // lost incarnations were folded into lost_metrics at declare_lost;
        // merging a Lost slot's snapshot again would double-count it
        let mut merged = self.lost_metrics.clone();
        let mut workers = Vec::with_capacity(self.workers.len());
        for w in 0..self.workers.len() {
            if let Some(server) = self.workers[w].server.as_ref() {
                if let Ok(m) = server.metrics_timeout(self.probe_timeout) {
                    self.workers[w].last_metrics = m;
                }
            }
            let retired = self.supervisor.as_ref().is_some_and(|s| s.is_retired(w));
            let ws = &self.workers[w];
            if !matches!(ws.state, WorkerState::Lost(_)) {
                merged.merge(&ws.last_metrics);
            }
            let saturation = if ws.slots_total > 0 {
                ws.active_slots as f64 / ws.slots_total as f64
            } else {
                0.0
            };
            workers.push(WorkerFleetMetrics {
                worker: w,
                state: ws.state,
                dispatched: ws.dispatched,
                affinity_hits: ws.affinity_hits,
                prefix_hit_tokens: ws.prefix_hit_tokens,
                redistributions_absorbed: ws.redistributions_absorbed,
                completed: ws.completed,
                outstanding: ws.outstanding,
                saturation,
                last_progress: ws.health.last_progress(),
                radix_shared_pages: ws.last_metrics.radix_shared_pages,
                radix_hit_tokens: ws.last_metrics.radix_hit_tokens,
                ttft_p50_s: ws.last_metrics.ttft_hist().p50(),
                ttft_p99_s: ws.last_metrics.ttft_hist().p99(),
                deadline_misses: ws.last_metrics.deadline_misses,
                cause: ws.last_cause,
                restarts: ws.restarts,
                retired,
            });
        }
        FleetReport { fleet: self.fleet.clone(), workers, merged }
    }

    /// Router shutdown: error every remaining stream, then shut the fleet
    /// down (workers with in-flight work error it again internally; the
    /// client channels are gone by then, which is fine).
    fn shutdown_all(&mut self) {
        // orderly shutdown settles the journal too: a cleanly stopped log
        // has no unfinished records, so a later recover() resumes nothing
        let routes: Vec<Route> = self.routes.drain().map(|(_, r)| r).collect();
        for route in routes {
            self.journal(&OpEntry::Finished {
                seq: route.seq,
                outcome: Outcome::Error,
                n_tokens: route.tokens.len() as u32,
            });
            let _ = route.client.send(StreamEvent::Error("router shut down".into()));
        }
        self.by_seq.clear();
        for ws in self.workers.iter_mut() {
            if let Some(server) = ws.server.take() {
                match ws.state {
                    // never join a worker that might be wedged
                    WorkerState::Lost(_) => server.abandon(),
                    _ => server.shutdown(),
                }
            }
        }
    }
}
