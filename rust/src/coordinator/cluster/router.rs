//! The cluster router: a stateless-ish front-end owning a fleet of
//! [`Server`] workers, in the worker-executor/worker-service shape — the
//! router holds no model state, only the in-flight table and per-worker
//! load/health views.
//!
//! ## Event flow
//!
//! Clients submit through [`Router::submit`] and read a per-request
//! [`StreamEvent`] channel, exactly like talking to one server.  Internally
//! every dispatch uses `Reply::Routed`: ALL workers' token/terminal events
//! funnel onto ONE channel, id-tagged with the namespaced request id (high
//! bits = worker + 1, low bits = cluster sequence — see
//! [`request_id`](crate::coordinator::request::request_id)), and the router
//! core demultiplexes them back to the client channels.  That funnel is what
//! makes redistribution safe: the router always knows which requests have
//! produced tokens, and a re-dispatched request gets a FRESH namespaced id,
//! so a straggler event from the old worker can never corrupt the new
//! stream.
//!
//! ## Health and drain
//!
//! Alive workers are probed on `RouterConfig::health_interval` (fired
//! asynchronously — a wedged worker cannot stall the loop).  A probe that
//! errors or misses `probe_timeout` marks the worker Dead; probes that
//! answer while the engine's progress counter stays frozen across
//! `wedge_probes` probes with work outstanding mark it Wedged; a probe
//! answering `ProbeState::Failing` retires it cooperatively.  In every case
//! the worker's queued and token-less requests are re-dispatched to
//! survivors (bounded by `max_redispatch`), and its token-producing streams
//! are finished with `FinishReason::WorkerLost` carrying the tokens
//! delivered so far.  [`Router::drain_worker`] is the cooperative version:
//! the worker reports exactly which ids it released (authoritative — the
//! router only re-dispatches those), keeps its token-producing streams
//! running, and leaves the dispatch rotation.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::coordinator::request::{
    request_id, DrainReport, FinishReason, GenRequest, GenResponse, Metrics, ProbeState,
    RoutedEvent, StreamEvent, WorkerPostMortem, WorkerProbe,
};
use crate::coordinator::server::Server;

use super::dispatch::{DispatchPolicy, RoundRobin, WorkerLoad};
use super::fleet::{FleetMetrics, FleetReport, WorkerFleetMetrics};
use super::health::{DrainCause, HealthTracker, WorkerState};

/// Router configuration.  `Default`: round-robin dispatch, 50ms health
/// interval, 1s probe deadline, 4 stale probes to a wedge verdict, 3
/// redistributions per request.
pub struct RouterConfig {
    pub policy: Box<dyn DispatchPolicy>,
    /// how often each Alive worker is probed
    pub health_interval: Duration,
    /// probe answer deadline; a miss marks the worker Dead
    pub probe_timeout: Duration,
    /// consecutive progress-frozen probes (with work outstanding) before a
    /// worker is declared Wedged
    pub wedge_probes: usize,
    /// re-dispatches allowed per request before it errors out
    pub max_redispatch: usize,
}

impl Default for RouterConfig {
    fn default() -> RouterConfig {
        RouterConfig {
            policy: Box::new(RoundRobin::new()),
            health_interval: Duration::from_millis(50),
            probe_timeout: Duration::from_secs(1),
            wedge_probes: 4,
            max_redispatch: 3,
        }
    }
}

impl RouterConfig {
    pub fn policy(mut self, policy: Box<dyn DispatchPolicy>) -> Self {
        self.policy = policy;
        self
    }

    pub fn health_interval(mut self, interval: Duration) -> Self {
        self.health_interval = interval;
        self
    }

    pub fn probe_timeout(mut self, timeout: Duration) -> Self {
        self.probe_timeout = timeout;
        self
    }

    pub fn wedge_probes(mut self, probes: usize) -> Self {
        self.wedge_probes = probes.max(1);
        self
    }

    pub fn max_redispatch(mut self, n: usize) -> Self {
        self.max_redispatch = n;
        self
    }
}

/// Control messages from the client side to the router core.
enum Ctl {
    Submit(GenRequest, u64, Instant, Sender<StreamEvent>),
    Cancel(u64),
    Report(Sender<FleetReport>),
    Locate(u64, Sender<Option<usize>>),
    Drain(usize, Sender<Result<DrainReport, String>>),
    Kill(usize, Sender<Result<WorkerPostMortem, String>>),
    Shutdown,
}

/// Client-side handle for one routed request.  Events carry NAMESPACED ids:
/// `request_id::seq_of(resp.id)` equals [`RouterHandle::id`], and
/// `request_id::worker_of(resp.id)` names the worker that served (or lost)
/// the stream.
pub struct RouterHandle {
    seq: u64,
    rx: Receiver<StreamEvent>,
    ctl: Sender<Ctl>,
}

impl RouterHandle {
    /// Cluster-wide sequence number of this request (the low bits of every
    /// response id it will ever produce).
    pub fn id(&self) -> u64 {
        self.seq
    }

    /// Ask the router to cancel this request wherever it currently is.
    pub fn cancel(&self) -> Result<()> {
        self.ctl.send(Ctl::Cancel(self.seq)).map_err(|_| anyhow!("router is down"))
    }

    pub fn receiver(&self) -> &Receiver<StreamEvent> {
        &self.rx
    }

    pub fn recv(&self) -> Result<StreamEvent> {
        self.rx.recv().map_err(|_| anyhow!("router dropped request"))
    }

    pub fn into_receiver(self) -> Receiver<StreamEvent> {
        self.rx
    }

    /// Drain the stream to its terminal event.
    pub fn collect(self) -> Result<GenResponse> {
        loop {
            match self.rx.recv() {
                Ok(StreamEvent::Token(_)) => {}
                Ok(StreamEvent::Done(resp)) => return Ok(resp),
                Ok(StreamEvent::Error(e)) => bail!(e),
                Err(_) => bail!("router dropped stream"),
            }
        }
    }
}

/// Prefix-affinity router over a fleet of workers (see the module docs).
pub struct Router {
    ctl: Sender<Ctl>,
    seq: AtomicU64,
    handle: Option<JoinHandle<()>>,
}

impl Router {
    /// Front the fleet with a router thread.  The workers should all be
    /// booted from the same artifact (the router assumes any worker can
    /// serve any request).
    pub fn new(workers: Vec<Server>, cfg: RouterConfig) -> Result<Router> {
        if workers.is_empty() {
            bail!("router needs at least one worker");
        }
        let RouterConfig { policy, health_interval, probe_timeout, wedge_probes, max_redispatch } =
            cfg;
        let (ctl_tx, ctl_rx) = channel::<Ctl>();
        let (ev_tx, ev_rx) = channel::<RoutedEvent>();
        let now = Instant::now();
        let slots = workers
            .into_iter()
            .map(|server| WorkerSlot {
                server: Some(server),
                state: WorkerState::Alive,
                health: HealthTracker::new(wedge_probes),
                active_slots: 0,
                queued_requests: 0,
                queued_tokens: 0,
                slots_total: 0,
                dispatched_since_probe: 0,
                outstanding: 0,
                probe_pending: None,
                last_probe_at: now,
                last_metrics: Metrics::default(),
                dispatched: 0,
                affinity_hits: 0,
                prefix_hit_tokens: 0,
                redistributions_absorbed: 0,
                completed: 0,
            })
            .collect();
        let core = Core {
            workers: slots,
            policy,
            health_interval,
            probe_timeout,
            max_redispatch,
            ctl_rx,
            ev_rx,
            ev_tx,
            routes: HashMap::new(),
            by_seq: HashMap::new(),
            fleet: FleetMetrics::default(),
        };
        let handle = std::thread::Builder::new().name("pq-router".into()).spawn(move || {
            core.run();
        })?;
        Ok(Router { ctl: ctl_tx, seq: AtomicU64::new(0), handle: Some(handle) })
    }

    /// Submit a request; the router picks the worker.  The request's own
    /// `id` field is replaced by a namespaced id on dispatch — correlate
    /// through the handle's sequence number instead.
    pub fn submit(&self, req: GenRequest) -> Result<RouterHandle> {
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = channel();
        self.ctl
            .send(Ctl::Submit(req, seq, Instant::now(), tx))
            .map_err(|_| anyhow!("router is down"))?;
        Ok(RouterHandle { seq, rx, ctl: self.ctl.clone() })
    }

    /// Fleet-wide report: router counters, per-worker breakdown, and every
    /// worker's engine metrics merged (lost workers contribute their last
    /// probe snapshot).
    pub fn report(&self) -> Result<FleetReport> {
        let (tx, rx) = channel();
        self.ctl.send(Ctl::Report(tx)).map_err(|_| anyhow!("router is down"))?;
        rx.recv().map_err(|_| anyhow!("router dropped report request"))
    }

    /// Which worker a request (by handle sequence number) is currently on.
    pub fn locate(&self, seq: u64) -> Result<Option<usize>> {
        let (tx, rx) = channel();
        self.ctl.send(Ctl::Locate(seq, tx)).map_err(|_| anyhow!("router is down"))?;
        rx.recv().map_err(|_| anyhow!("router dropped locate request"))
    }

    /// Cooperatively drain a worker: it leaves the dispatch rotation, its
    /// queued/token-less requests are re-dispatched to survivors (the
    /// worker's released-id report is authoritative), and its
    /// token-producing streams keep running to completion.
    pub fn drain_worker(&self, worker: usize) -> Result<DrainReport> {
        let (tx, rx) = channel();
        self.ctl.send(Ctl::Drain(worker, tx)).map_err(|_| anyhow!("router is down"))?;
        rx.recv().map_err(|_| anyhow!("router dropped drain request"))?.map_err(|e| anyhow!(e))
    }

    /// Kill a worker as if it crashed mid-flight: its replies are dropped
    /// without terminal events, then the router redistributes its token-less
    /// requests and finishes its token-producing streams with
    /// `FinishReason::WorkerLost`.  Returns the worker's final page-pool
    /// accounting.
    pub fn kill_worker(&self, worker: usize) -> Result<WorkerPostMortem> {
        let (tx, rx) = channel();
        self.ctl.send(Ctl::Kill(worker, tx)).map_err(|_| anyhow!("router is down"))?;
        rx.recv().map_err(|_| anyhow!("router dropped kill request"))?.map_err(|e| anyhow!(e))
    }

    pub fn shutdown(mut self) {
        let _ = self.ctl.send(Ctl::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        let _ = self.ctl.send(Ctl::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// One in-flight request in the router's table.
struct Route {
    seq: u64,
    client: Sender<StreamEvent>,
    /// the original request (cloned per dispatch with a fresh namespaced id)
    req: GenRequest,
    submitted: Instant,
    worker: usize,
    /// tokens forwarded so far — the redistribution criterion, and the
    /// payload of a synthesized `WorkerLost` response
    tokens: Vec<i32>,
    redispatches: usize,
    first_token_s: Option<f64>,
}

/// Router-side view of one worker.
struct WorkerSlot {
    /// taken on loss (abandoned or joined); None = no longer contactable
    server: Option<Server>,
    state: WorkerState,
    health: HealthTracker,
    // last-probe gauges
    active_slots: usize,
    queued_requests: usize,
    queued_tokens: usize,
    slots_total: usize,
    /// dispatches since the last answered probe (load-staleness correction)
    dispatched_since_probe: usize,
    /// dispatched and not yet terminal (router-side, always current)
    outstanding: usize,
    probe_pending: Option<(Receiver<WorkerProbe>, Instant)>,
    last_probe_at: Instant,
    /// last engine metrics seen (probe or report refresh) — what a lost
    /// worker contributes to the merged fleet view
    last_metrics: Metrics,
    // fleet counters
    dispatched: usize,
    affinity_hits: usize,
    prefix_hit_tokens: usize,
    redistributions_absorbed: usize,
    completed: usize,
}

impl WorkerSlot {
    fn alive(&self) -> bool {
        self.state == WorkerState::Alive && self.server.is_some()
    }
}

/// The router core, owned by the `pq-router` thread.
struct Core {
    workers: Vec<WorkerSlot>,
    policy: Box<dyn DispatchPolicy>,
    health_interval: Duration,
    probe_timeout: Duration,
    max_redispatch: usize,
    ctl_rx: Receiver<Ctl>,
    ev_rx: Receiver<RoutedEvent>,
    /// kept so `ev_rx` never disconnects while workers churn; cloned into
    /// every dispatch
    ev_tx: Sender<RoutedEvent>,
    /// in-flight table keyed by namespaced id
    routes: HashMap<u64, Route>,
    /// handle sequence number → current namespaced id
    by_seq: HashMap<u64, u64>,
    fleet: FleetMetrics,
}

impl Core {
    fn run(mut self) {
        loop {
            loop {
                match self.ctl_rx.try_recv() {
                    Ok(Ctl::Shutdown) | Err(TryRecvError::Disconnected) => {
                        self.shutdown_all();
                        return;
                    }
                    Ok(m) => self.on_ctl(m),
                    Err(TryRecvError::Empty) => break,
                }
            }
            while let Ok(ev) = self.ev_rx.try_recv() {
                self.on_event(ev);
            }
            self.poll_probes();
            self.start_due_probes();
            // Park on the event funnel: token events are the high-rate
            // stream; control messages wait at most one quantum.
            match self.ev_rx.recv_timeout(self.quantum()) {
                Ok(ev) => self.on_event(ev),
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => unreachable!("core holds an ev_tx clone"),
            }
        }
    }

    fn quantum(&self) -> Duration {
        let busy =
            !self.routes.is_empty() || self.workers.iter().any(|w| w.probe_pending.is_some());
        if busy {
            Duration::from_micros(500)
        } else {
            Duration::from_millis(2)
        }
    }

    fn on_ctl(&mut self, m: Ctl) {
        match m {
            Ctl::Submit(req, seq, submitted, client) => {
                self.fleet.submitted += 1;
                self.dispatch(Route {
                    seq,
                    client,
                    req,
                    submitted,
                    worker: 0,
                    tokens: Vec::new(),
                    redispatches: 0,
                    first_token_s: None,
                });
            }
            Ctl::Cancel(seq) => {
                if let Some(&wid) = self.by_seq.get(&seq) {
                    let w = self.routes[&wid].worker;
                    if let Some(server) = self.workers[w].server.as_ref() {
                        // terminal Done(Cancelled) comes back via the funnel
                        let _ = server.cancel(wid);
                    }
                }
            }
            Ctl::Report(tx) => {
                let report = self.report();
                let _ = tx.send(report);
            }
            Ctl::Locate(seq, tx) => {
                let w = self.by_seq.get(&seq).map(|wid| self.routes[wid].worker);
                let _ = tx.send(w);
            }
            Ctl::Drain(w, tx) => {
                let r = self.drain_worker(w);
                let _ = tx.send(r);
            }
            Ctl::Kill(w, tx) => {
                let r = self.kill_worker(w);
                let _ = tx.send(r);
            }
            Ctl::Shutdown => unreachable!("handled in run()"),
        }
    }

    fn alive_loads(&self) -> Vec<WorkerLoad> {
        self.workers
            .iter()
            .enumerate()
            .filter(|(_, ws)| ws.alive())
            .map(|(worker, ws)| WorkerLoad {
                worker,
                active_slots: ws.active_slots,
                queued_requests: ws.queued_requests,
                queued_tokens: ws.queued_tokens,
                dispatched_since_probe: ws.dispatched_since_probe,
                outstanding: ws.outstanding,
                slots_total: ws.slots_total,
            })
            .collect()
    }

    /// Dispatch (or re-dispatch) a route to a policy-picked alive worker.
    /// A worker whose channel is already gone is declared lost on the spot
    /// and the pick retried against the survivors.
    fn dispatch(&mut self, mut route: Route) {
        loop {
            let loads = self.alive_loads();
            if loads.is_empty() {
                self.fleet.errors += 1;
                let _ = route
                    .client
                    .send(StreamEvent::Error("no alive workers in the fleet".into()));
                return;
            }
            let pick = self.policy.pick(&route.req, &loads);
            let w = pick.worker;
            let wid = request_id::namespaced(w, route.seq);
            let mut wreq = route.req.clone();
            wreq.id = wid;
            let ev_tx = self.ev_tx.clone();
            let sent = match self.workers[w].server.as_ref() {
                Some(server) => server.submit_routed(wreq, ev_tx, route.submitted).is_ok(),
                None => false,
            };
            if !sent {
                self.declare_lost(w, DrainCause::Dead);
                continue;
            }
            let ws = &mut self.workers[w];
            ws.dispatched += 1;
            ws.dispatched_since_probe += 1;
            ws.outstanding += 1;
            self.fleet.dispatched += 1;
            self.fleet.dispatched_prefill_tokens += 1 + route.req.prompt.len();
            if pick.affinity_hit {
                ws.affinity_hits += 1;
                ws.prefix_hit_tokens += pick.hit_tokens;
                self.fleet.affinity_hits += 1;
                self.fleet.prefix_hit_tokens += pick.hit_tokens;
            }
            if route.redispatches > 0 {
                ws.redistributions_absorbed += 1;
                self.fleet.redistributed += 1;
            }
            route.worker = w;
            self.by_seq.insert(route.seq, wid);
            self.routes.insert(wid, route);
            return;
        }
    }

    /// Demultiplex one funnel event back to its client stream.
    fn on_event(&mut self, ev: RoutedEvent) {
        // stale ids (redistributed or torn-down routes) drop silently
        if !self.routes.contains_key(&ev.id) {
            return;
        }
        match ev.ev {
            StreamEvent::Token(t) => {
                let route = self.routes.get_mut(&ev.id).expect("checked above");
                if route.tokens.is_empty() {
                    route.first_token_s = Some(route.submitted.elapsed().as_secs_f64());
                }
                route.tokens.push(t);
                let _ = route.client.send(StreamEvent::Token(t));
            }
            StreamEvent::Done(resp) => {
                let route = self.routes.remove(&ev.id).expect("checked above");
                self.by_seq.remove(&route.seq);
                let ws = &mut self.workers[route.worker];
                ws.outstanding = ws.outstanding.saturating_sub(1);
                ws.completed += 1;
                if resp.finish == FinishReason::Cancelled {
                    self.fleet.cancelled += 1;
                } else {
                    self.fleet.completed += 1;
                }
                let _ = route.client.send(StreamEvent::Done(resp));
            }
            StreamEvent::Error(e) => {
                let route = self.routes.remove(&ev.id).expect("checked above");
                self.by_seq.remove(&route.seq);
                let ws = &mut self.workers[route.worker];
                ws.outstanding = ws.outstanding.saturating_sub(1);
                if route.tokens.is_empty() && route.redispatches < self.max_redispatch {
                    // token-less failure: give another worker a try (bounded,
                    // so a deterministic rejection cannot ping-pong forever)
                    let mut route = route;
                    route.redispatches += 1;
                    self.dispatch(route);
                } else {
                    self.fleet.errors += 1;
                    let _ = route.client.send(StreamEvent::Error(e));
                }
            }
        }
    }

    /// Fire probes for Alive workers whose interval elapsed.
    fn start_due_probes(&mut self) {
        for w in 0..self.workers.len() {
            let due = {
                let ws = &self.workers[w];
                ws.alive()
                    && ws.probe_pending.is_none()
                    && ws.last_probe_at.elapsed() >= self.health_interval
            };
            if !due {
                continue;
            }
            let started = self.workers[w]
                .server
                .as_ref()
                .expect("alive() checked server presence")
                .probe_start();
            match started {
                Ok(rx) => self.workers[w].probe_pending = Some((rx, Instant::now())),
                Err(_) => self.declare_lost(w, DrainCause::Dead),
            }
        }
    }

    /// Poll outstanding probe answers; apply dead/wedged/failing verdicts.
    fn poll_probes(&mut self) {
        for w in 0..self.workers.len() {
            let Some((rx, sent_at)) = self.workers[w].probe_pending.as_ref() else {
                continue;
            };
            match rx.try_recv() {
                Ok(probe) => {
                    let ws = &mut self.workers[w];
                    ws.probe_pending = None;
                    ws.last_probe_at = Instant::now();
                    ws.active_slots = probe.active_slots;
                    ws.queued_requests = probe.queued_requests;
                    ws.queued_tokens = probe.queued_tokens;
                    ws.slots_total = probe.slots_total;
                    ws.dispatched_since_probe = 0;
                    ws.last_metrics = probe.metrics.clone();
                    if probe.state == ProbeState::Failing {
                        self.declare_lost(w, DrainCause::Failing);
                        continue;
                    }
                    let outstanding = ws.outstanding;
                    if ws.health.on_probe(probe.progress, outstanding) {
                        self.declare_lost(w, DrainCause::Wedged);
                    }
                }
                Err(TryRecvError::Empty) => {
                    if sent_at.elapsed() > self.probe_timeout {
                        self.declare_lost(w, DrainCause::Dead);
                    }
                }
                Err(TryRecvError::Disconnected) => {
                    self.declare_lost(w, DrainCause::Dead);
                }
            }
        }
    }

    /// Terminal demotion: take the worker out of the fleet and settle every
    /// route it held — token-less requests are re-dispatched to survivors,
    /// token-producing streams are finished with `FinishReason::WorkerLost`
    /// (their response id names the lost worker).
    fn declare_lost(&mut self, w: usize, cause: DrainCause) {
        if matches!(self.workers[w].state, WorkerState::Lost(_)) {
            return;
        }
        // flush the funnel first: token events already sent by the dying
        // worker decide which routes count as token-producing
        while let Ok(ev) = self.ev_rx.try_recv() {
            self.on_event(ev);
        }
        self.workers[w].state = WorkerState::Lost(cause);
        self.workers[w].probe_pending = None;
        match cause {
            DrainCause::Dead => self.fleet.workers_dead += 1,
            DrainCause::Wedged => self.fleet.workers_wedged += 1,
            DrainCause::Failing => self.fleet.workers_drained += 1,
            DrainCause::Killed => self.fleet.workers_killed += 1,
        }
        self.policy.forget_worker(w);
        if let Some(server) = self.workers[w].server.take() {
            match cause {
                // a killed worker's thread has already exited: joining is
                // instant and reaps it
                DrainCause::Killed => server.shutdown(),
                // dead/wedged threads may never exit: do NOT join
                _ => server.abandon(),
            }
        }
        let wids: Vec<u64> =
            self.routes.iter().filter(|(_, r)| r.worker == w).map(|(&id, _)| id).collect();
        for wid in wids {
            let route = self.routes.remove(&wid).expect("collected above");
            self.by_seq.remove(&route.seq);
            if route.tokens.is_empty() {
                let mut route = route;
                route.redispatches += 1;
                if route.redispatches <= self.max_redispatch {
                    self.dispatch(route);
                } else {
                    self.fleet.errors += 1;
                    let _ = route.client.send(StreamEvent::Error(format!(
                        "worker {w} {} and the redistribution budget is exhausted",
                        cause.name()
                    )));
                }
            } else {
                self.fleet.worker_lost += 1;
                let resp = GenResponse {
                    id: wid,
                    tokens: route.tokens.clone(),
                    ttft_s: route.first_token_s.unwrap_or(0.0),
                    total_s: route.submitted.elapsed().as_secs_f64(),
                    queue_s: 0.0,
                    finish: FinishReason::WorkerLost,
                };
                let _ = route.client.send(StreamEvent::Done(resp));
            }
        }
        self.workers[w].outstanding = 0;
    }

    /// Cooperative drain (see [`Router::drain_worker`]).
    fn drain_worker(&mut self, w: usize) -> Result<DrainReport, String> {
        if w >= self.workers.len() {
            return Err(format!("no worker {w} in a fleet of {}", self.workers.len()));
        }
        if self.workers[w].state != WorkerState::Alive {
            return Err(format!("worker {w} is {}", self.workers[w].state.name()));
        }
        let Some(server) = self.workers[w].server.as_ref() else {
            return Err(format!("worker {w} has no server handle"));
        };
        let report = match server.drain(self.probe_timeout) {
            Ok(r) => r,
            Err(e) => {
                // a worker that cannot answer a drain is dead
                self.declare_lost(w, DrainCause::Dead);
                return Err(format!("drain failed, worker {w} declared dead: {e:#}"));
            }
        };
        self.workers[w].state = WorkerState::Draining;
        self.fleet.workers_drained += 1;
        self.policy.forget_worker(w);
        // the worker's released list is authoritative: only those ids are
        // re-dispatched, so a token event racing the drain can never spawn a
        // duplicate stream
        for &wid in &report.released {
            let Some(mut route) = self.routes.remove(&wid) else {
                continue;
            };
            self.by_seq.remove(&route.seq);
            let ws = &mut self.workers[w];
            ws.outstanding = ws.outstanding.saturating_sub(1);
            route.redispatches += 1;
            if route.redispatches <= self.max_redispatch {
                self.dispatch(route);
            } else {
                self.fleet.errors += 1;
                let _ = route.client.send(StreamEvent::Error(format!(
                    "worker {w} drained and the redistribution budget is exhausted"
                )));
            }
        }
        Ok(report)
    }

    /// Forced kill (see [`Router::kill_worker`]).
    fn kill_worker(&mut self, w: usize) -> Result<WorkerPostMortem, String> {
        if w >= self.workers.len() {
            return Err(format!("no worker {w} in a fleet of {}", self.workers.len()));
        }
        if matches!(self.workers[w].state, WorkerState::Lost(_)) {
            return Err(format!("worker {w} is already lost"));
        }
        let Some(server) = self.workers[w].server.as_ref() else {
            return Err(format!("worker {w} has no server handle"));
        };
        match server.kill(self.probe_timeout) {
            Ok(pm) => {
                self.declare_lost(w, DrainCause::Killed);
                Ok(pm)
            }
            Err(e) => {
                self.declare_lost(w, DrainCause::Dead);
                Err(format!("kill failed, worker {w} declared dead: {e:#}"))
            }
        }
    }

    fn report(&mut self) -> FleetReport {
        let mut merged = Metrics::default();
        let mut workers = Vec::with_capacity(self.workers.len());
        for w in 0..self.workers.len() {
            if let Some(server) = self.workers[w].server.as_ref() {
                if let Ok(m) = server.metrics_timeout(self.probe_timeout) {
                    self.workers[w].last_metrics = m;
                }
            }
            let ws = &self.workers[w];
            merged.merge(&ws.last_metrics);
            let saturation = if ws.slots_total > 0 {
                ws.active_slots as f64 / ws.slots_total as f64
            } else {
                0.0
            };
            workers.push(WorkerFleetMetrics {
                worker: w,
                state: ws.state,
                dispatched: ws.dispatched,
                affinity_hits: ws.affinity_hits,
                prefix_hit_tokens: ws.prefix_hit_tokens,
                redistributions_absorbed: ws.redistributions_absorbed,
                completed: ws.completed,
                outstanding: ws.outstanding,
                saturation,
                last_progress: ws.health.last_progress(),
            });
        }
        FleetReport { fleet: self.fleet.clone(), workers, merged }
    }

    /// Router shutdown: error every remaining stream, then shut the fleet
    /// down (workers with in-flight work error it again internally; the
    /// client channels are gone by then, which is fine).
    fn shutdown_all(&mut self) {
        for (_, route) in self.routes.drain() {
            let _ = route.client.send(StreamEvent::Error("router shut down".into()));
        }
        self.by_seq.clear();
        for ws in self.workers.iter_mut() {
            if let Some(server) = ws.server.take() {
                match ws.state {
                    // never join a worker that might be wedged
                    WorkerState::Lost(_) => server.abandon(),
                    _ => server.shutdown(),
                }
            }
        }
    }
}
