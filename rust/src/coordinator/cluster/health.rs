//! Worker health: the per-worker lifecycle state machine and the
//! progress-based wedge detector.
//!
//! The router probes each Alive worker on a fixed interval.  Three signals
//! demote a worker:
//!
//! - **Dead**: the probe channel errored or the answer missed its deadline —
//!   the worker thread is gone or blocked solid.
//! - **Wedged**: probes keep answering but the engine's monotone progress
//!   counter is frozen across `wedge_probes` consecutive probes while
//!   requests are outstanding.  This generalizes the server-internal
//!   `ReloadGovernor` no-progress test to the fleet level: the governor
//!   bounds reload loops inside one worker, the wedge detector catches a
//!   worker whose loop stopped consuming work at all.
//! - **Failing**: the probe answered with `ProbeState::Failing` — the worker
//!   exhausted its model-reload budget and is terminally erroring requests.
//!
//! All three end in `Lost`, which triggers redistribution of the worker's
//! queued/token-less requests (see the router).  `Draining` is the
//! cooperative middle state: excluded from dispatch, token-producing streams
//! still running.

/// Why a worker left the dispatch rotation for good.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DrainCause {
    /// liveness probe failed (channel error or deadline miss)
    Dead,
    /// probes answered but progress stayed frozen with work outstanding
    Wedged,
    /// the worker reported `ProbeState::Failing` (reload budget exhausted)
    Failing,
    /// explicitly killed (crash simulation / forced retirement)
    Killed,
}

impl DrainCause {
    pub fn name(self) -> &'static str {
        match self {
            DrainCause::Dead => "dead",
            DrainCause::Wedged => "wedged",
            DrainCause::Failing => "failing",
            DrainCause::Killed => "killed",
        }
    }
}

/// Lifecycle state of one worker in the fleet.
///
///   Alive ──drain──▶ Draining         (kept streams finish, then idle)
///   Alive | Draining ──dead / wedged / failing / killed──▶ Lost(cause)
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkerState {
    /// in the dispatch rotation, probed on the health interval
    Alive,
    /// out of the rotation; token-producing streams still completing
    Draining,
    /// terminal: server handle released, requests redistributed
    Lost(DrainCause),
}

impl WorkerState {
    pub fn name(self) -> &'static str {
        match self {
            WorkerState::Alive => "alive",
            WorkerState::Draining => "draining",
            WorkerState::Lost(_) => "lost",
        }
    }
}

/// Progress-based wedge detector, deterministic and thread-free so the
/// policy is testable without booting workers.
#[derive(Debug, Clone)]
pub struct HealthTracker {
    /// probes answered with frozen progress while work was outstanding
    stale_probes: usize,
    last_progress: u64,
    /// consecutive stale probes tolerated before the wedged verdict
    wedge_probes: usize,
}

impl HealthTracker {
    pub fn new(wedge_probes: usize) -> HealthTracker {
        HealthTracker { stale_probes: 0, last_progress: 0, wedge_probes: wedge_probes.max(1) }
    }

    /// Record one answered probe; returns true when the worker should be
    /// declared wedged.  An idle worker (nothing outstanding) legitimately
    /// makes no progress, so staleness only accumulates under load.
    pub fn on_probe(&mut self, progress: u64, outstanding: usize) -> bool {
        if progress > self.last_progress {
            self.last_progress = progress;
            self.stale_probes = 0;
            return false;
        }
        if outstanding == 0 {
            self.stale_probes = 0;
            return false;
        }
        self.stale_probes += 1;
        self.stale_probes >= self.wedge_probes
    }

    /// Last progress counter seen (fleet reporting).
    pub fn last_progress(&self) -> u64 {
        self.last_progress
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wedge_needs_consecutive_stale_probes_under_load() {
        let mut h = HealthTracker::new(3);
        assert!(!h.on_probe(10, 4));
        assert!(!h.on_probe(10, 4), "stale probe 1");
        assert!(!h.on_probe(10, 4), "stale probe 2");
        assert!(h.on_probe(10, 4), "stale probe 3 → wedged");
    }

    #[test]
    fn progress_resets_the_stale_count() {
        let mut h = HealthTracker::new(2);
        assert!(!h.on_probe(5, 1));
        assert!(!h.on_probe(5, 1), "one stale probe");
        assert!(!h.on_probe(6, 1), "progress clears staleness");
        assert_eq!(h.last_progress(), 6);
        assert!(!h.on_probe(6, 1));
        assert!(h.on_probe(6, 1));
    }

    #[test]
    fn idle_workers_are_never_wedged() {
        let mut h = HealthTracker::new(1);
        for _ in 0..10 {
            assert!(!h.on_probe(0, 0), "no outstanding work → no wedge verdict");
        }
    }
}
