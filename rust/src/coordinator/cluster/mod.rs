//! Cluster serving layer: a [`Router`] front-end over a fleet of
//! [`Server`](crate::coordinator::server::Server) workers booted from one
//! shared quantization artifact.
//!
//! The module splits into five pieces:
//!
//! - [`dispatch`] — the [`DispatchPolicy`] trait and its three
//!   implementations: [`RoundRobin`], [`LeastLoaded`] (active slots + queued
//!   tokens from the last probe, corrected by dispatches since), and
//!   [`PrefixAffinity`] (FNV hash of the longest tracked prompt-prefix block
//!   → worker, overflowing to least-loaded when the sticky worker lags too
//!   far behind).  Prefix affinity is the cluster-level completion of the
//!   paper's prefixed-token design: the prefixed K/V pages every worker
//!   shares are free, but per-conversation shared prefixes are only hot on
//!   the worker that served them last — routing by prefix keeps them hot.
//! - [`health`] — the worker lifecycle state machine
//!   (Alive → Draining → Lost) and the progress-based [`HealthTracker`]
//!   wedge detector.
//! - [`router`] — the [`Router`] itself: id-namespaced dispatch, the
//!   single-funnel event demultiplexer, health probing, drain/kill
//!   redistribution, and fleet reporting.
//! - [`fleet`] — [`FleetMetrics`] (the exactly-once request ledger and
//!   prefix-hit accounting) and the per-worker/merged [`FleetReport`].
//! - [`supervisor`] — fleet self-healing: the [`Supervisor`] restart
//!   scheduler (seeded exponential backoff, sliding-window budgets,
//!   permanent retirement), the [`RetryBudget`] redispatch token bucket,
//!   and the [`AdmissionController`] overload front (deadline-infeasibility
//!   shedding, backlog limits, brownout tiers).

pub mod dispatch;
pub mod fleet;
pub mod health;
pub mod router;
pub mod supervisor;

pub use dispatch::{DispatchPolicy, LeastLoaded, Pick, PrefixAffinity, RoundRobin, WorkerLoad};
pub use fleet::{FleetMetrics, FleetReport, WorkerFleetMetrics};
pub use health::{DrainCause, HealthTracker, WorkerState};
pub use router::{Router, RouterConfig, RouterHandle};
pub use supervisor::{
    Admission, AdmissionConfig, AdmissionController, RestartPlan, RetryBudget, Supervisor,
    SupervisorConfig,
};
