//! Fleet-wide metrics: router counters, per-worker dispatch/affinity
//! breakdowns, and the merged engine [`Metrics`] view.
//!
//! The router-level counters form an exactly-once ledger: every submitted
//! request ends in exactly one of `completed`, `cancelled`, `worker_lost`,
//! `shed`, `quarantined`, or `errors`, whatever workers died along the way —
//! the drain test holds the fleet to `submitted == terminal()` at the end of
//! a run.

use crate::coordinator::request::Metrics;

use super::health::{DrainCause, WorkerState};

/// Router-level counters (cluster scope; per-engine counters live in the
/// merged [`Metrics`]).
#[derive(Debug, Clone, Default)]
pub struct FleetMetrics {
    /// requests accepted by the router
    pub submitted: usize,
    /// dispatches to workers (> `submitted` when requests are redistributed)
    pub dispatched: usize,
    /// terminal: streams finished normally on some worker
    pub completed: usize,
    /// terminal: streams finished via cancellation
    pub cancelled: usize,
    /// terminal: token-producing streams finished with
    /// `FinishReason::WorkerLost` when their worker died
    pub worker_lost: usize,
    /// terminal: error events forwarded to clients
    pub errors: usize,
    /// re-dispatches of queued/token-less requests off dead, wedged, or
    /// draining workers (also counts error-retry re-dispatches)
    pub redistributed: usize,
    /// token-producing streams resumed on a surviving worker after their
    /// worker died (`RouterConfig::resume_streams`); without resume these
    /// would have been `worker_lost` terminals
    pub stream_resumes: usize,
    /// dispatches whose worker was chosen by a tracked prompt-prefix match
    pub affinity_hits: usize,
    /// prompt tokens (incl. BOS) covered by the matched prefix on affinity
    /// hits — the pages the target worker's radix cache can serve hot
    pub prefix_hit_tokens: usize,
    /// prompt tokens (incl. BOS) across all dispatches — the denominator of
    /// [`FleetMetrics::prefix_hit_rate`]
    pub dispatched_prefill_tokens: usize,
    pub workers_dead: usize,
    pub workers_wedged: usize,
    pub workers_drained: usize,
    pub workers_killed: usize,
    /// terminal: rejected by the admission controller before dispatch
    /// (`FinishReason::Shed`) — deadline infeasible, backlog limit, or
    /// brownout tier
    pub shed: usize,
    /// terminal: implicated in ≥2 worker deaths and removed from dispatch
    /// (`FinishReason::Quarantined`)
    pub quarantined: usize,
    /// replacement workers the supervisor booted into lost slots
    pub workers_restarted: usize,
    /// worker slots permanently retired after exhausting the restart budget
    pub workers_retired: usize,
    /// redispatches denied by the global retry token bucket (each denial
    /// settles its request, so the ledger still balances)
    pub retries_denied: usize,
    /// restarts that ran ahead of their scheduled backoff (invariant: 0)
    pub restart_schedule_violations: usize,
}

impl FleetMetrics {
    /// Requests that reached a terminal client event.
    pub fn terminal(&self) -> usize {
        self.completed
            + self.cancelled
            + self.worker_lost
            + self.errors
            + self.shed
            + self.quarantined
    }

    /// Requests still in flight (or lost to an accounting bug — the drain
    /// test asserts this hits zero).
    pub fn unresolved(&self) -> usize {
        self.submitted.saturating_sub(self.terminal())
    }

    /// Fraction of dispatched prompt tokens covered by tracked-prefix hits
    /// (the shared-prefix page-hit rate the bench compares across policies).
    pub fn prefix_hit_rate(&self) -> f64 {
        if self.dispatched_prefill_tokens == 0 {
            0.0
        } else {
            self.prefix_hit_tokens as f64 / self.dispatched_prefill_tokens as f64
        }
    }

    /// Prompt tokens a worker actually had to prefill cold (dispatched minus
    /// prefix-hit tokens).
    pub fn net_prefill_tokens(&self) -> usize {
        self.dispatched_prefill_tokens.saturating_sub(self.prefix_hit_tokens)
    }
}

/// Per-worker fleet-level counters (dispatch/affinity/redistribution view —
/// the engine-level counters are in the worker's own [`Metrics`]).
#[derive(Debug, Clone)]
pub struct WorkerFleetMetrics {
    pub worker: usize,
    pub state: WorkerState,
    /// requests dispatched to this worker (first dispatches + absorbed)
    pub dispatched: usize,
    /// dispatches that landed here via a tracked prompt-prefix match
    pub affinity_hits: usize,
    pub prefix_hit_tokens: usize,
    /// redistributed requests this worker absorbed from lost/drained peers
    pub redistributions_absorbed: usize,
    /// terminal events (completed/cancelled) observed from this worker
    pub completed: usize,
    /// dispatched and not yet terminal (router-side view)
    pub outstanding: usize,
    /// active slots over total slots at the last probe
    pub saturation: f64,
    /// engine progress counter at the last probe
    pub last_progress: u64,
    /// pages the worker's radix prefix cache held resident at the last probe
    pub radix_shared_pages: usize,
    /// cache positions this worker served from its radix cache instead of
    /// prefill (cumulative, as of the last probe)
    pub radix_hit_tokens: usize,
    /// engine-side TTFT p50, from the worker's merged per-class latency
    /// histograms at the last probe (log2-bucket upper bound, seconds)
    pub ttft_p50_s: f64,
    /// engine-side TTFT p99 at the last probe (bucket upper bound, seconds)
    pub ttft_p99_s: f64,
    /// terminals this worker delivered after their request's deadline budget
    pub deadline_misses: usize,
    /// why this slot last left the rotation (`None` = never lost); survives
    /// a supervised restart so the fleet table can show crash history
    pub cause: Option<DrainCause>,
    /// times the supervisor rebooted a replacement into this slot
    pub restarts: usize,
    /// the slot exhausted its restart budget and is permanently out
    pub retired: bool,
}

/// One fleet-wide report: router counters, per-worker breakdown, and every
/// worker's engine [`Metrics`] merged via [`Metrics::merge`].  Lost workers
/// contribute their last probe snapshot, so the merged view still accounts
/// for work they served before dying.
#[derive(Debug, Clone)]
pub struct FleetReport {
    pub fleet: FleetMetrics,
    pub workers: Vec<WorkerFleetMetrics>,
    pub merged: Metrics,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ledger_accounts_every_request_exactly_once() {
        let mut f = FleetMetrics {
            submitted: 13,
            completed: 6,
            cancelled: 1,
            worker_lost: 2,
            errors: 1,
            shed: 2,
            quarantined: 1,
            ..FleetMetrics::default()
        };
        assert_eq!(f.terminal(), 13, "shed/quarantined are terminals too");
        assert_eq!(f.unresolved(), 0);
        f.submitted = 15;
        assert_eq!(f.unresolved(), 2);
    }

    #[test]
    fn hit_rate_and_net_prefill() {
        assert_eq!(
            FleetMetrics::default().prefix_hit_rate(),
            0.0,
            "no dispatches → rate 0, not NaN"
        );
        let f = FleetMetrics {
            dispatched_prefill_tokens: 200,
            prefix_hit_tokens: 50,
            ..FleetMetrics::default()
        };
        assert!((f.prefix_hit_rate() - 0.25).abs() < 1e-12);
        assert_eq!(f.net_prefill_tokens(), 150);
    }
}
