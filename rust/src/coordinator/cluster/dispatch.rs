//! Dispatch policies: which worker gets the next request.
//!
//! The router calls [`DispatchPolicy::pick`] with the load view of every
//! ALIVE worker (draining and lost workers are already filtered out).  Three
//! built-ins:
//!
//! - [`RoundRobin`] — rotate through the alive set; the baseline.
//! - [`LeastLoaded`] — minimize [`WorkerLoad::score`] (active slots +
//!   queued-token backlog from the last probe, plus what the router
//!   dispatched since that probe, so a probe-staleness window cannot pile
//!   everything onto one worker).
//! - [`PrefixAffinity`] — hash the prompt at block boundaries and send the
//!   request to the worker whose tracked-prefix LRU holds the LONGEST
//!   matching prefix: that worker's paged KV most likely still has the
//!   shared prefix's refcounted pages resident (the PrefixQuant prefix
//!   itself is resident in every slot of every worker; this targets the
//!   PROMPT prefix above it).  Falls back to least-loaded on a miss, or
//!   when the matched worker is overloaded past `max_lag`.

use std::collections::{HashMap, VecDeque};

use crate::coordinator::request::GenRequest;

/// Router-side load view of one alive worker: probe gauges plus the
/// dispatches made since that probe refreshed them.
#[derive(Debug, Clone)]
pub struct WorkerLoad {
    pub worker: usize,
    /// slots decoding at the last probe
    pub active_slots: usize,
    /// requests queued at the last probe
    pub queued_requests: usize,
    /// token footprint of the queue at the last probe
    pub queued_tokens: usize,
    /// requests dispatched since the last probe (not yet in the gauges)
    pub dispatched_since_probe: usize,
    /// dispatched and not yet terminal (router-side, always current)
    pub outstanding: usize,
    pub slots_total: usize,
    /// pages the worker's radix prefix cache held resident at the last probe
    /// (0 when the worker serves without the radix cache)
    pub radix_shared_pages: usize,
    /// cumulative cache positions the worker served from its radix cache
    /// instead of prefill, as of the last probe
    pub radix_hit_tokens: usize,
}

/// Tokens a decoding slot or an unprobed dispatch is charged in the load
/// score (a slot's backlog is unknown, so it weighs like a medium request).
const SLOT_COST_TOKENS: usize = 64;

impl WorkerLoad {
    /// Scalar load score (lower = less loaded): probed token backlog plus a
    /// per-slot charge for decoding slots and the dispatches the probe has
    /// not seen yet.
    pub fn score(&self) -> usize {
        self.queued_tokens
            + (self.active_slots + self.queued_requests + self.dispatched_since_probe)
                * SLOT_COST_TOKENS
    }
}

/// A dispatch decision.
#[derive(Debug, Clone, Copy)]
pub struct Pick {
    pub worker: usize,
    /// chosen by a tracked prompt-prefix match (not by rotation/load)
    pub affinity_hit: bool,
    /// prompt tokens (incl. BOS) covered by the matched prefix
    pub hit_tokens: usize,
}

impl Pick {
    fn cold(worker: usize) -> Pick {
        Pick { worker, affinity_hit: false, hit_tokens: 0 }
    }
}

/// Which alive worker serves the next request.  `workers` is non-empty and
/// holds only alive workers; `pick` must return one of their ids.
pub trait DispatchPolicy: Send {
    fn name(&self) -> &'static str;

    fn pick(&mut self, req: &GenRequest, workers: &[WorkerLoad]) -> Pick;

    /// A worker left the fleet for good: drop any per-worker state (tracked
    /// prefixes must not keep routing at a dead worker).
    fn forget_worker(&mut self, _worker: usize) {}

    /// The supervisor rebooted a replacement into slot `worker`: the slot id
    /// is live again but the process behind it is fresh, so any per-worker
    /// cache state (tracked prefixes) must be dropped, not inherited.
    fn worker_restarted(&mut self, _worker: usize) {}
}

/// Rotate through the alive workers in id order.
#[derive(Debug, Default)]
pub struct RoundRobin {
    cursor: usize,
}

impl RoundRobin {
    pub fn new() -> RoundRobin {
        RoundRobin::default()
    }
}

impl DispatchPolicy for RoundRobin {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn pick(&mut self, _req: &GenRequest, workers: &[WorkerLoad]) -> Pick {
        let w = workers[self.cursor % workers.len()].worker;
        self.cursor = self.cursor.wrapping_add(1);
        Pick::cold(w)
    }
}

/// Minimize [`WorkerLoad::score`] (ties broken by lowest worker id).
#[derive(Debug, Default)]
pub struct LeastLoaded;

impl LeastLoaded {
    pub fn new() -> LeastLoaded {
        LeastLoaded
    }

    fn least(workers: &[WorkerLoad]) -> usize {
        workers
            .iter()
            .min_by_key(|l| (l.score(), l.worker))
            .expect("pick is called with a non-empty alive set")
            .worker
    }
}

impl DispatchPolicy for LeastLoaded {
    fn name(&self) -> &'static str {
        "least-loaded"
    }

    fn pick(&mut self, _req: &GenRequest, workers: &[WorkerLoad]) -> Pick {
        Pick::cold(LeastLoaded::least(workers))
    }
}

/// FNV-1a over the first `n` prompt tokens (block-boundary prefix hashes).
fn prefix_hashes(prompt: &[i32], block: usize) -> Vec<u64> {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut hashes = Vec::new();
    for (i, &t) in prompt.iter().enumerate() {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
        if (i + 1) % block == 0 {
            hashes.push(h);
        }
    }
    hashes
}

/// Bounded per-worker LRU of prefix-block hashes — a stand-in for the slice
/// of a radix/page cache a worker can realistically keep hot.  The bound is
/// what makes policies comparable: a policy that sprays one prefix group
/// over every worker thrashes each worker's small tracked set, exactly like
/// spraying requests thrashes real per-worker page pools.
#[derive(Debug, Default)]
struct LruSet {
    order: VecDeque<u64>,
}

impl LruSet {
    fn contains(&self, h: u64) -> bool {
        self.order.contains(&h)
    }

    fn touch(&mut self, h: u64, capacity: usize) {
        if let Some(pos) = self.order.iter().position(|&x| x == h) {
            self.order.remove(pos);
        }
        self.order.push_back(h);
        while self.order.len() > capacity {
            self.order.pop_front();
        }
    }
}

/// Send requests to the worker already tracking their longest prompt prefix;
/// fall back to least-loaded on a miss or when the matched worker is
/// overloaded.
#[derive(Debug)]
pub struct PrefixAffinity {
    /// tokens per hashed prefix block
    block: usize,
    /// tracked prefix blocks per worker (the LRU bound)
    capacity: usize,
    /// affinity is overridden when the matched worker's score exceeds the
    /// least-loaded score by more than this many tokens
    max_lag: usize,
    tracked: HashMap<usize, LruSet>,
}

impl Default for PrefixAffinity {
    fn default() -> PrefixAffinity {
        PrefixAffinity {
            block: 16,
            capacity: 256,
            max_lag: 8 * SLOT_COST_TOKENS,
            tracked: HashMap::new(),
        }
    }
}

impl PrefixAffinity {
    pub fn new() -> PrefixAffinity {
        PrefixAffinity::default()
    }

    /// Tokens per hashed prefix block (match granularity).
    pub fn with_block(mut self, block: usize) -> Self {
        self.block = block.max(1);
        self
    }

    /// Tracked prefix blocks per worker.
    pub fn with_capacity(mut self, capacity: usize) -> Self {
        self.capacity = capacity.max(1);
        self
    }

    /// Overload headroom (in score tokens) before affinity yields to
    /// least-loaded.
    pub fn with_max_lag(mut self, max_lag: usize) -> Self {
        self.max_lag = max_lag;
        self
    }
}

impl DispatchPolicy for PrefixAffinity {
    fn name(&self) -> &'static str {
        "prefix-affinity"
    }

    fn pick(&mut self, req: &GenRequest, workers: &[WorkerLoad]) -> Pick {
        // Reconcile the router-side tracker against the workers' REAL radix
        // prefix caches: when radix gauges are flowing at all, a worker
        // reporting zero shared pages resident holds none of the prefixes we
        // tracked for it (cache evicted or engine rebuilt) — routing on that
        // memory would chase pages that no longer exist, so drop it.  With
        // the radix cache off fleet-wide every gauge is zero and the tracker
        // behaves exactly as before.
        let gauges_live =
            workers.iter().any(|l| l.radix_shared_pages > 0 || l.radix_hit_tokens > 0);
        if gauges_live {
            for l in workers {
                if l.radix_shared_pages == 0 {
                    self.tracked.remove(&l.worker);
                }
            }
        }
        let hashes = prefix_hashes(&req.prompt, self.block);
        // longest tracked match across the alive workers' LRUs
        let mut hit: Option<(usize, usize)> = None; // (worker, matched blocks)
        for (k, h) in hashes.iter().enumerate().rev() {
            for l in workers {
                if self.tracked.get(&l.worker).is_some_and(|s| s.contains(*h)) {
                    hit = Some((l.worker, k + 1));
                    break;
                }
            }
            if hit.is_some() {
                break;
            }
        }
        let least = LeastLoaded::least(workers);
        let pick = match hit {
            Some((w, blocks)) => {
                let w_score =
                    workers.iter().find(|l| l.worker == w).map(|l| l.score()).unwrap_or(0);
                let least_score =
                    workers.iter().find(|l| l.worker == least).map(|l| l.score()).unwrap_or(0);
                if w_score > least_score + self.max_lag {
                    // overflow: the affinity target is too far behind
                    Pick::cold(least)
                } else {
                    // +1 for BOS: the hit covers the prefix pages incl. the
                    // shared first page
                    Pick { worker: w, affinity_hit: true, hit_tokens: blocks * self.block + 1 }
                }
            }
            None => Pick::cold(least),
        };
        // register this prompt's blocks where the request actually lands
        let set = self.tracked.entry(pick.worker).or_default();
        for h in hashes {
            set.touch(h, self.capacity);
        }
        pick
    }

    fn forget_worker(&mut self, worker: usize) {
        self.tracked.remove(&worker);
    }

    fn worker_restarted(&mut self, worker: usize) {
        // the slot is back but the replacement booted with an empty radix
        // cache — tracked prefixes describe the dead process, not this one
        self.tracked.remove(&worker);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idle(workers: &[usize]) -> Vec<WorkerLoad> {
        workers
            .iter()
            .map(|&worker| WorkerLoad {
                worker,
                active_slots: 0,
                queued_requests: 0,
                queued_tokens: 0,
                dispatched_since_probe: 0,
                outstanding: 0,
                slots_total: 4,
                radix_shared_pages: 0,
                radix_hit_tokens: 0,
            })
            .collect()
    }

    fn req(prompt: Vec<i32>) -> GenRequest {
        GenRequest::new(0, prompt, 8)
    }

    #[test]
    fn round_robin_cycles_the_alive_set() {
        let mut p = RoundRobin::new();
        let loads = idle(&[0, 2, 5]);
        let picks: Vec<usize> =
            (0..6).map(|_| p.pick(&req(vec![1, 2]), &loads).worker).collect();
        assert_eq!(picks, vec![0, 2, 5, 0, 2, 5]);
    }

    #[test]
    fn least_loaded_minimizes_score() {
        let mut loads = idle(&[0, 1, 2]);
        loads[0].queued_tokens = 500;
        loads[1].active_slots = 1; // 1 slot charge
        loads[2].active_slots = 3;
        let mut p = LeastLoaded::new();
        assert_eq!(p.pick(&req(vec![1]), &loads).worker, 1);
        // unprobed dispatches count against a worker too
        loads[1].dispatched_since_probe = 5;
        assert_eq!(p.pick(&req(vec![1]), &loads).worker, 2);
    }

    #[test]
    fn prefix_affinity_sticks_a_shared_prefix_to_one_worker() {
        let mut p = PrefixAffinity::new().with_block(4);
        let loads = idle(&[0, 1, 2]);
        let shared: Vec<i32> = (0..8).collect();
        let first = p.pick(&req(shared.clone()), &loads);
        assert!(!first.affinity_hit, "nothing tracked yet");
        for tail in 0..5 {
            let mut prompt = shared.clone();
            prompt.push(100 + tail);
            let pick = p.pick(&req(prompt), &loads);
            assert_eq!(pick.worker, first.worker, "same prefix → same worker");
            assert!(pick.affinity_hit);
            assert_eq!(pick.hit_tokens, 8 + 1, "both shared blocks + BOS");
        }
        // an unrelated prompt is NOT a hit
        let other = p.pick(&req(vec![900, 901, 902, 903, 904]), &loads);
        assert!(!other.affinity_hit);
    }

    #[test]
    fn prefix_affinity_overflows_to_least_loaded() {
        let mut p = PrefixAffinity::new().with_block(2).with_max_lag(10);
        let mut loads = idle(&[0, 1]);
        let shared = vec![7, 7, 7, 7];
        let first = p.pick(&req(shared.clone()), &loads).worker;
        // overload the affinity target far past max_lag
        loads.iter_mut().find(|l| l.worker == first).unwrap().queued_tokens = 10_000;
        let pick = p.pick(&req(shared), &loads);
        assert_ne!(pick.worker, first, "overloaded target must be bypassed");
        assert!(!pick.affinity_hit);
    }

    #[test]
    fn forget_worker_drops_its_tracked_prefixes() {
        let mut p = PrefixAffinity::new().with_block(2);
        let loads = idle(&[0, 1]);
        let shared = vec![3, 3, 3, 3];
        let first = p.pick(&req(shared.clone()), &loads).worker;
        p.forget_worker(first);
        let survivors = idle(&[1 - first]);
        let pick = p.pick(&req(shared), &survivors);
        assert!(!pick.affinity_hit, "tracked prefixes of a lost worker are gone");
        assert_eq!(pick.worker, 1 - first);
    }

    #[test]
    fn worker_restarted_drops_tracked_prefixes_but_keeps_the_slot_routable() {
        let mut p = PrefixAffinity::new().with_block(2);
        let loads = idle(&[0, 1]);
        let shared = vec![4, 4, 4, 4];
        let first = p.pick(&req(shared.clone()), &loads).worker;
        assert!(p.pick(&req(shared.clone()), &loads).affinity_hit, "tracker primed");
        p.worker_restarted(first);
        // same slot ids remain routable, but the replacement's cache is cold:
        // no stale affinity hit may route on the dead process's prefixes
        let pick = p.pick(&req(shared.clone()), &loads);
        assert!(!pick.affinity_hit, "restarted worker's tracked prefixes are gone");
        // the pick re-registers the prefix, so affinity rebuilds naturally
        assert!(p.pick(&req(shared), &loads).affinity_hit);
    }

    #[test]
    fn live_radix_gauges_invalidate_tracked_prefixes_of_a_cold_worker() {
        let mut p = PrefixAffinity::new().with_block(2);
        let mut loads = idle(&[0, 1]);
        let shared = vec![5, 5, 5, 5];
        let first = p.pick(&req(shared.clone()), &loads).worker;
        assert!(p.pick(&req(shared.clone()), &loads).affinity_hit, "tracker primed");
        // radix stats start flowing: the affinity target reports an EMPTY
        // cache while another worker holds pages — its tracked prefixes are
        // provably stale and must stop attracting traffic
        loads.iter_mut().find(|l| l.worker != first).unwrap().radix_shared_pages = 3;
        let pick = p.pick(&req(shared.clone()), &loads);
        assert!(!pick.affinity_hit, "cold worker's tracked prefixes are dropped");
        // that pick re-registered the prefix at its landing worker; once the
        // landing worker reports resident pages the affinity is live again
        for l in loads.iter_mut() {
            if l.worker == pick.worker {
                l.radix_shared_pages = 2;
            }
        }
        let again = p.pick(&req(shared), &loads);
        assert!(again.affinity_hit);
        assert_eq!(again.worker, pick.worker);
    }

    #[test]
    fn lru_capacity_evicts_oldest_blocks() {
        let mut p = PrefixAffinity::new().with_block(2).with_capacity(2);
        let loads = idle(&[0]);
        let a = vec![1, 1]; // 1 block
        let b = vec![2, 2];
        let c = vec![3, 3];
        p.pick(&req(a.clone()), &loads);
        p.pick(&req(b), &loads);
        p.pick(&req(c), &loads); // capacity 2: evicts a's block
        assert!(!p.pick(&req(a), &loads).affinity_hit, "evicted prefix no longer hits");
    }
}
