//! Pluggable scheduling policy for the continuous engine.
//!
//! The engine owns the *mechanism* — slot table, page reservations, chunked
//! prefill plumbing, preemption/resume bookkeeping — and delegates every
//! *decision* to a [`SchedulePolicy`]:
//!
//! - **admission order**: which pending request to try next
//!   ([`SchedulePolicy::next_candidate`]);
//! - **preemption**: which Decoding slot, if any, to evict when the chosen
//!   candidate cannot be admitted ([`SchedulePolicy::preempt_victim`]);
//! - **prefill chunking**: how many prompt tokens one engine step may
//!   prefill for a single request ([`SchedulePolicy::prefill_chunk`]), which
//!   bounds how long a long-prompt admission can stall decode rounds.
//!
//! Two implementations ship: [`Fcfs`] reproduces the pre-policy engine
//! exactly (head-of-queue order, never preempts, unbounded chunk) and is the
//! parity baseline; [`PriorityPreempt`] orders by [`Priority`] with
//! round-based aging (so sustained high-priority load cannot starve lower
//! classes), preempts lower-priority Decoding slots for Interactive
//! arrivals, and bounds prefill chunks.
//!
//! Aging and admission bookkeeping are measured in ENGINE ROUNDS, not wall
//! time, so policy decisions are deterministic and testable on the
//! simulation backend.

use super::request::Priority;

/// A pending request as a policy sees it.
#[derive(Debug, Clone)]
pub struct QueueView {
    pub id: u64,
    pub priority: Priority,
    /// engine rounds spent waiting in the pending queue
    pub waited_rounds: u64,
    /// seconds until the request's deadline hint elapses (negative = past
    /// due); `None` when the request has no deadline
    pub deadline_remaining_s: Option<f64>,
    /// arrival order, monotone across the engine's lifetime
    pub seq: u64,
    /// tokens the admission prefill must write (BOS + prompt + any tokens
    /// re-prefilled after a preemption)
    pub prompt_tokens: usize,
    /// generation budget still owed
    pub remaining_new: usize,
    /// true when this is a preempted request awaiting resume
    pub resumed: bool,
}

/// A busy slot as a policy sees it (preemption-victim candidate).
#[derive(Debug, Clone, Copy)]
pub struct SlotView {
    pub slot: usize,
    pub id: u64,
    pub priority: Priority,
    /// tokens generated so far (lost work ≈ resume re-prefill cost)
    pub generated: usize,
    /// generation budget still owed
    pub remaining_new: usize,
    /// engine round at which the slot was (re)admitted
    pub admitted_round: u64,
    /// finished (chunked) prefill and is decoding
    pub decoding: bool,
    /// times this request has already been preempted (thrash guard:
    /// [`PriorityPreempt`] never evicts a request twice, which bounds the
    /// work a sustained high-priority flood can steal from a victim)
    pub times_preempted: usize,
}

/// Scheduling decisions for the continuous engine.  Implementations must be
/// `Send` (the policy crosses into the server's worker thread).
pub trait SchedulePolicy: Send {
    fn name(&self) -> &'static str;

    /// A fresh instance with the same configuration (the server rebuilds the
    /// engine — and its policy — after a backend failure).
    fn fresh(&self) -> Box<dyn SchedulePolicy>;

    /// Index into `queue` of the next admission candidate, or `None` to stop
    /// admitting this round.  Called repeatedly within one admission round
    /// with already-admitted requests removed; returning a blocked candidate
    /// ends the round (the engine never skips past a blocked pick, so a
    /// policy's order is also its head-of-line discipline).
    fn next_candidate(&mut self, round: u64, queue: &[QueueView]) -> Option<usize>;

    /// Slot to preempt so `candidate` can be admitted, or `None` to leave
    /// the candidate waiting.  `busy` holds only slots the engine considers
    /// evictable (Decoding, resume-feasible).  Preempted slots release their
    /// pages and requeue with generated-so-far tokens preserved.
    fn preempt_victim(&mut self, candidate: &QueueView, busy: &[SlotView]) -> Option<usize> {
        let _ = (candidate, busy);
        None
    }

    /// Maximum prompt tokens one engine step may prefill for one request.
    /// `usize::MAX` disables chunking (whole prompt in the admission wave).
    fn prefill_chunk(&self) -> usize {
        usize::MAX
    }
}

/// Strict first-come-first-served: admission in arrival order, a blocked
/// head request blocks the queue (it is never skipped), no preemption, no
/// prefill chunking.  This is byte-for-byte the pre-policy engine behavior
/// and the parity baseline for the continuous test suite.
#[derive(Debug, Clone, Copy, Default)]
pub struct Fcfs;

impl SchedulePolicy for Fcfs {
    fn name(&self) -> &'static str {
        "fcfs"
    }

    fn fresh(&self) -> Box<dyn SchedulePolicy> {
        Box::new(Fcfs)
    }

    fn next_candidate(&mut self, _round: u64, queue: &[QueueView]) -> Option<usize> {
        if queue.is_empty() {
            None
        } else {
            Some(0)
        }
    }
}

/// Priority scheduling with round-based aging, preemption, and bounded
/// prefill chunks.
///
/// - **Order**: highest *effective* class first, where a request's class is
///   promoted one level per `age_rounds` rounds waited (capped at
///   Interactive) — sustained Interactive load therefore cannot starve Batch
///   beyond `2 * age_rounds` rounds.  Within a class: tighter FEASIBLE
///   deadline first (past-due deadlines sort with "no deadline" — spending
///   slots chasing an already-blown SLO would starve work that can still
///   make its budget), then arrival order.
/// - **Preemption**: when the chosen candidate cannot be admitted, the
///   lowest-RAW-priority Decoding slot below the candidate's raw class is
///   evicted (ties: fewest generated tokens — cheapest resume — then most
///   recently admitted).  Raw priority, not aged: aging grants queue
///   position, never eviction rights, so an aged Batch request cannot churn
///   other Batch slots.  A request is never evicted twice (`times_preempted`
///   guard), so a sustained Interactive flood cannot preempt a resumed
///   victim forever — combined with aging this BOUNDS Batch starvation.
/// - **Chunking**: at most `chunk` prompt tokens prefilled per step per
///   request, so one long prompt stalls concurrent decode rounds by at most
///   one chunk.
#[derive(Debug, Clone, Copy)]
pub struct PriorityPreempt {
    /// rounds waited per one-class promotion (anti-starvation aging)
    pub age_rounds: u64,
    /// max prompt tokens prefilled per engine step per request
    pub chunk: usize,
}

impl Default for PriorityPreempt {
    fn default() -> Self {
        PriorityPreempt { age_rounds: 32, chunk: 16 }
    }
}

impl PriorityPreempt {
    /// Aging level: one per `age_rounds` waited (uncapped — also the
    /// class-tie breaker, so an aged request cannot be starved by a stream
    /// of fresh same-effective-class arrivals carrying deadline hints).
    fn boost(&self, q: &QueueView) -> u64 {
        if self.age_rounds == 0 {
            0
        } else {
            q.waited_rounds / self.age_rounds
        }
    }

    /// Effective class index after aging (0..=2).
    fn effective(&self, q: &QueueView) -> usize {
        (q.priority.index() + self.boost(q) as usize).min(Priority::Interactive.index())
    }
}

impl SchedulePolicy for PriorityPreempt {
    fn name(&self) -> &'static str {
        "priority-preempt"
    }

    fn fresh(&self) -> Box<dyn SchedulePolicy> {
        Box::new(*self)
    }

    fn next_candidate(&mut self, _round: u64, queue: &[QueueView]) -> Option<usize> {
        let mut best: Option<usize> = None;
        for (i, q) in queue.iter().enumerate() {
            let better = match best {
                None => true,
                Some(b) => {
                    let bq = &queue[b];
                    let (eff_b, eff_q) = (self.effective(bq), self.effective(q));
                    if eff_q != eff_b {
                        eff_q > eff_b
                    } else if self.boost(q) != self.boost(bq) {
                        // longer-aged wins the class tie BEFORE deadlines, so
                        // a boosted request cannot be starved by a stream of
                        // fresh deadline-carrying arrivals (the aging bound
                        // holds whether or not clients set deadlines)
                        self.boost(q) > self.boost(bq)
                    } else {
                        // tighter FEASIBLE deadline first: a past-due request
                        // (remaining budget already negative) cannot make its
                        // SLO no matter what, so it must not outrank work that
                        // still can — past-due sorts with None (last), then
                        // FCFS breaks the remaining ties
                        let feasible = |d: Option<f64>| match d {
                            Some(r) if r >= 0.0 => r,
                            _ => f64::INFINITY,
                        };
                        let dq = feasible(q.deadline_remaining_s);
                        let db = feasible(bq.deadline_remaining_s);
                        if dq != db {
                            dq < db
                        } else {
                            q.seq < bq.seq
                        }
                    }
                }
            };
            if better {
                best = Some(i);
            }
        }
        best
    }

    fn preempt_victim(&mut self, candidate: &QueueView, busy: &[SlotView]) -> Option<usize> {
        let mut victim: Option<SlotView> = None;
        for s in busy {
            if !s.decoding || s.priority >= candidate.priority || s.times_preempted > 0 {
                continue;
            }
            let better = match &victim {
                None => true,
                Some(v) => {
                    (s.priority, s.generated, std::cmp::Reverse(s.admitted_round))
                        < (v.priority, v.generated, std::cmp::Reverse(v.admitted_round))
                }
            };
            if better {
                victim = Some(*s);
            }
        }
        victim.map(|v| v.slot)
    }

    fn prefill_chunk(&self) -> usize {
        self.chunk.max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn qv(id: u64, priority: Priority, waited: u64, seq: u64) -> QueueView {
        QueueView {
            id,
            priority,
            waited_rounds: waited,
            deadline_remaining_s: None,
            seq,
            prompt_tokens: 4,
            remaining_new: 4,
            resumed: false,
        }
    }

    fn sv(slot: usize, priority: Priority, generated: usize, round: u64) -> SlotView {
        SlotView {
            slot,
            id: 100 + slot as u64,
            priority,
            generated,
            remaining_new: 8,
            admitted_round: round,
            decoding: true,
            times_preempted: 0,
        }
    }

    #[test]
    fn fcfs_is_head_of_queue() {
        let mut p = Fcfs;
        assert_eq!(p.next_candidate(0, &[]), None);
        let q = [qv(1, Priority::BestEffort, 0, 0), qv(2, Priority::Interactive, 0, 1)];
        assert_eq!(p.next_candidate(0, &q), Some(0), "fcfs ignores priority");
        assert_eq!(p.preempt_victim(&q[1], &[sv(0, Priority::BestEffort, 0, 0)]), None);
        assert_eq!(p.prefill_chunk(), usize::MAX);
    }

    #[test]
    fn priority_orders_classes_then_fcfs() {
        let mut p = PriorityPreempt::default();
        let q = [
            qv(1, Priority::Batch, 0, 0),
            qv(2, Priority::Interactive, 0, 1),
            qv(3, Priority::Interactive, 0, 2),
            qv(4, Priority::BestEffort, 0, 3),
        ];
        // highest class first; FCFS within class
        assert_eq!(p.next_candidate(0, &q), Some(1));
    }

    #[test]
    fn aging_promotes_waiting_requests() {
        let mut p = PriorityPreempt { age_rounds: 10, chunk: 16 };
        // a Batch request that waited 10+ rounds ties Interactive and wins on
        // arrival order
        let q = [qv(1, Priority::Interactive, 0, 5), qv(2, Priority::Batch, 10, 1)];
        assert_eq!(p.next_candidate(0, &q), Some(1));
        // under the aging threshold, Interactive still wins
        let q = [qv(1, Priority::Interactive, 0, 5), qv(2, Priority::Batch, 9, 1)];
        assert_eq!(p.next_candidate(0, &q), Some(0));
    }

    #[test]
    fn aged_request_beats_fresh_deadline_carriers() {
        // the aging guarantee must hold even when the competing fresh
        // arrivals carry deadline hints: boost outranks deadline in the tie
        let mut p = PriorityPreempt { age_rounds: 10, chunk: 16 };
        let mut fresh = qv(1, Priority::Interactive, 0, 50);
        fresh.deadline_remaining_s = Some(0.010);
        let aged = qv(2, Priority::Batch, 10, 1); // boost 1, no deadline
        assert_eq!(p.next_candidate(0, &[fresh, aged]), Some(1));
    }

    #[test]
    fn deadline_breaks_ties_within_class() {
        let mut p = PriorityPreempt::default();
        let mut a = qv(1, Priority::Interactive, 0, 0);
        let mut b = qv(2, Priority::Interactive, 0, 1);
        a.deadline_remaining_s = None;
        b.deadline_remaining_s = Some(0.05);
        assert_eq!(p.next_candidate(0, &[a, b]), Some(1), "deadline beats arrival order");
    }

    #[test]
    fn past_due_deadlines_lose_to_feasible_ones() {
        let mut p = PriorityPreempt::default();
        // a past-due request (negative remaining budget) must not outrank a
        // feasible deadline carrier, however loose that deadline is
        let mut past_due = qv(1, Priority::Interactive, 0, 0);
        let mut feasible = qv(2, Priority::Interactive, 0, 1);
        past_due.deadline_remaining_s = Some(-0.5);
        feasible.deadline_remaining_s = Some(3.0);
        assert_eq!(p.next_candidate(0, &[past_due, feasible]), Some(1));
        // past-due sorts with the deadline-less: FCFS decides between them
        let mut no_deadline = qv(3, Priority::Interactive, 0, 2);
        no_deadline.deadline_remaining_s = None;
        assert_eq!(
            p.next_candidate(0, &[past_due, no_deadline]),
            Some(0),
            "past-due vs no-deadline falls back to arrival order"
        );
    }

    #[test]
    fn preemption_picks_lowest_class_cheapest_resume() {
        let mut p = PriorityPreempt::default();
        let cand = qv(9, Priority::Interactive, 0, 9);
        let busy = [
            sv(0, Priority::Batch, 2, 1),
            sv(1, Priority::BestEffort, 5, 2),
            sv(2, Priority::BestEffort, 1, 3),
        ];
        // lowest class first, then fewest generated tokens
        assert_eq!(p.preempt_victim(&cand, &busy), Some(2));
        // equals are not preempted: an Interactive slot never evicts another
        let peers = [sv(0, Priority::Interactive, 0, 1)];
        assert_eq!(p.preempt_victim(&cand, &peers), None);
        // a Batch candidate does not evict Batch slots (raw priority rule)
        let batch_cand = qv(8, Priority::Batch, 1000, 8);
        assert_eq!(p.preempt_victim(&batch_cand, &[sv(0, Priority::Batch, 0, 1)]), None);
    }

    #[test]
    fn non_decoding_slots_are_not_victims() {
        let mut p = PriorityPreempt::default();
        let cand = qv(9, Priority::Interactive, 0, 9);
        let mut s = sv(0, Priority::BestEffort, 0, 1);
        s.decoding = false;
        assert_eq!(p.preempt_victim(&cand, &[s]), None);
    }

    #[test]
    fn already_preempted_slots_are_not_victims_again() {
        let mut p = PriorityPreempt::default();
        let cand = qv(9, Priority::Interactive, 0, 9);
        let mut s = sv(0, Priority::Batch, 2, 1);
        s.times_preempted = 1;
        assert_eq!(p.preempt_victim(&cand, &[s]), None, "thrash guard");
        s.times_preempted = 0;
        assert_eq!(p.preempt_victim(&cand, &[s]), Some(0));
    }
}
